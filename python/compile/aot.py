"""AOT compiler: lower every L2 computation to HLO text + write a manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (behind the
rust `xla` 0.1.6 crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--tfm-preset small|e2e|100m]

Outputs:
    artifacts/<name>.hlo.txt   one per computation variant
    artifacts/manifest.json    name -> file, input/output shapes+dtypes,
                               flat-parameter layouts, hyper-parameter meta
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _flat(out):
    """Flatten nested loss outputs (loss, (correct, n)) -> (loss, correct, n)."""
    return tuple(jax.tree_util.tree_leaves(out))


def _io_meta(avals):
    avals = jax.tree_util.tree_leaves(avals)
    return [{"shape": [int(d) for d in a.shape], "dtype": str(a.dtype)} for a in avals]


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "models": {}}

    def add(self, name: str, fn, in_specs, meta=None):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        entry = {
            "file": fname,
            "inputs": _io_meta(in_specs),
            "outputs": _io_meta(out_avals),
        }
        if meta:
            entry["meta"] = meta
        self.manifest["artifacts"][name] = entry
        print(f"  {name}: {len(text)} chars, "
              f"{len(in_specs)} inputs -> {len(out_avals)} outputs")

    def add_model(self, name: str, specs, extra=None):
        layout, total = M.param_layout(specs)
        entry = {"params": layout, "param_count": total}
        if extra:
            entry.update(extra)
        self.manifest["models"][name] = entry
        print(f"  model {name}: {total} params, {len(layout)} tensors")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


# ---------------------------------------------------------------------------


def build_cocoa(b: Builder, s: int, f: int):
    """CoCoA/SCD artifacts over dense (s, f) chunk blocks."""
    b.add(
        f"scd_chunk_s{s}_f{f}",
        M.scd_chunk,
        (spec((s, f)), spec((s,)), spec((s,), I32), spec((s,)), spec((f,)),
         spec(()), spec(())),
        meta={"kind": "scd_chunk", "samples": s, "features": f},
    )
    b.add(
        f"linear_eval_s{s}_f{f}",
        M.linear_eval,
        (spec((s, f)), spec((s,)), spec((s,)), spec((f,))),
        meta={"kind": "linear_eval", "samples": s, "features": f},
    )


def build_mlp(b: Builder, grad_batch: int, eval_batch: int):
    dims = M.MLP_DIMS
    specs = M.mlp_specs(dims)
    _, total = M.param_layout(specs)
    b.add_model("mlp", specs, {"dims": list(dims)})
    b.add("mlp_init", lambda seed: M.mlp_init(seed[0]), (spec((1,), I32),),
          meta={"kind": "init", "model": "mlp"})
    b.add(
        f"mlp_grad_l{grad_batch}",
        functools.partial(M.mlp_grad, dims=dims),
        (spec((total,)), spec((grad_batch, dims[0])), spec((grad_batch,), I32)),
        meta={"kind": "grad", "model": "mlp", "batch": grad_batch},
    )
    b.add(
        f"mlp_eval_b{eval_batch}",
        lambda p, x, y: _flat(M.mlp_loss(p, x, y, dims)),
        (spec((total,)), spec((eval_batch, dims[0])), spec((eval_batch,), I32)),
        meta={"kind": "eval", "model": "mlp", "batch": eval_batch},
    )


def build_cnn(b: Builder, grad_batch: int, eval_batch: int):
    cfg = M.CnnConfig()
    specs = M.cnn_specs(cfg)
    _, total = M.param_layout(specs)
    b.add_model("cnn", specs, {"input_dim": cfg.input_dim, "n_classes": cfg.n_classes})
    b.add("cnn_init", lambda seed: M.cnn_init(seed[0], cfg), (spec((1,), I32),),
          meta={"kind": "init", "model": "cnn"})
    b.add(
        f"cnn_grad_l{grad_batch}",
        functools.partial(M.cnn_grad, cfg=cfg),
        (spec((total,)), spec((grad_batch, cfg.input_dim)), spec((grad_batch,), I32)),
        meta={"kind": "grad", "model": "cnn", "batch": grad_batch},
    )
    b.add(
        f"cnn_eval_b{eval_batch}",
        lambda p, x, y: _flat(M.cnn_loss(p, x, y, cfg)),
        (spec((total,)), spec((eval_batch, cfg.input_dim)), spec((eval_batch,), I32)),
        meta={"kind": "eval", "model": "cnn", "batch": eval_batch},
    )


TFM_PRESETS = {
    # vocab, d_model, n_layers, n_heads, d_ff, seq_len — "e2e" is the default
    # end-to-end validation size for this CPU-PJRT testbed; "100m" matches the
    # brief's ~100M-param ask and compiles, but is slow on CPU.
    "small": M.TfmConfig(vocab=1024, d_model=128, n_layers=2, n_heads=4, d_ff=512, seq_len=64),
    "e2e": M.TfmConfig(vocab=4096, d_model=256, n_layers=4, n_heads=8, d_ff=1024, seq_len=64),
    "100m": M.TfmConfig(vocab=32768, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq_len=128),
}


def build_tfm(b: Builder, preset: str, grad_batch: int):
    cfg = TFM_PRESETS[preset]
    specs = M.tfm_specs(cfg)
    _, total = M.param_layout(specs)
    b.add_model(f"tfm_{preset}", specs, {"config": dataclass_dict(cfg)})
    b.add(f"tfm_{preset}_init", lambda seed: M.tfm_init(seed[0], cfg),
          (spec((1,), I32),), meta={"kind": "init", "model": f"tfm_{preset}"})
    b.add(
        f"tfm_{preset}_grad_b{grad_batch}",
        functools.partial(M.tfm_grad, cfg=cfg),
        (spec((total,)), spec((grad_batch, cfg.seq_len), I32)),
        meta={"kind": "grad", "model": f"tfm_{preset}", "batch": grad_batch},
    )
    b.add(
        f"tfm_{preset}_eval_b{grad_batch}",
        lambda p, t: _flat(M.tfm_loss(p, t, cfg)),
        (spec((total,)), spec((grad_batch, cfg.seq_len), I32)),
        meta={"kind": "eval", "model": f"tfm_{preset}", "batch": grad_batch},
    )


def dataclass_dict(cfg):
    import dataclasses
    return dataclasses.asdict(cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tfm-preset", default="small",
                    choices=list(TFM_PRESETS) + ["none"])
    ap.add_argument("--nn-batch", type=int, default=8,
                    help="local batch L for lSGD grad artifacts (paper: L=8)")
    ap.add_argument("--eval-batch", type=int, default=256)
    ap.add_argument("--chunk-samples", type=int, default=256)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    b = Builder(args.out_dir)
    print("lowering CoCoA artifacts...")
    build_cocoa(b, args.chunk_samples, 28)   # higgs_like feature width
    print("lowering MLP artifacts...")
    build_mlp(b, args.nn_batch, args.eval_batch)
    print("lowering CNN artifacts...")
    build_cnn(b, args.nn_batch, args.eval_batch)
    if args.tfm_preset != "none":
        print(f"lowering transformer ({args.tfm_preset}) artifacts...")
        build_tfm(b, args.tfm_preset, grad_batch=8)
    b.finish()
    print(f"manifest written to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
