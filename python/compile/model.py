"""L2: Chicle's compute graphs in JAX, calling the L1 Pallas kernels.

This module defines every computation the rust solvers execute at runtime:

  * CoCoA/SCD local-solver pass over a dense chunk (`scd_chunk`) and the
    per-chunk duality-gap contributions (`linear_eval`) — paper §2.2/§5.1.
  * The paper's CNN (2 conv+maxpool layers, 3 FC layers — §5.1 "Synchronous
    local SGD") loss/grads for local-SGD, plus eval.
  * An MLP for the Fashion-MNIST-like workload.
  * A decoder-only transformer LM (the end-to-end validation workload).

All dense layers go through `kernels.fused_linear` so the Pallas kernels lower
into the same HLO modules that rust loads via PJRT. Parameters cross the
rust<->HLO boundary as one flat f32 vector; the layout (name/shape/offset) is
recorded in artifacts/manifest.json by aot.py so the rust optimizer can
address individual tensors.

Python runs ONCE at build time (`make artifacts`); nothing here is on the
training path.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import fused_linear, scd_block

# ---------------------------------------------------------------------------
# Flat parameter handling
# ---------------------------------------------------------------------------


def param_layout(specs: Sequence[tuple[str, tuple[int, ...]]]):
    """[(name, shape)] -> [{name, shape, offset, size}] + total size."""
    out, off = [], 0
    for name, shape in specs:
        size = 1
        for d in shape:
            size *= d
        out.append({"name": name, "shape": list(shape), "offset": off, "size": size})
        off += size
    return out, off


def unflatten(flat: jax.Array, specs):
    params = []
    off = 0
    for _, shape in specs:
        size = 1
        for d in shape:
            size *= d
        params.append(lax.dynamic_slice_in_dim(flat, off, size).reshape(shape))
        off += size
    return params


def flatten(params) -> jax.Array:
    return jnp.concatenate([p.reshape(-1) for p in params])


# ---------------------------------------------------------------------------
# CoCoA / SCD (GLM path)
# ---------------------------------------------------------------------------


def scd_chunk(x, y, order, alpha, v, lam_n, sigma):
    """One local-SCD pass over a dense chunk (see kernels.scd)."""
    return scd_block(x, y, order, alpha, v, lam_n, sigma)


def linear_eval(x, y, alpha, w):
    """Per-chunk duality-gap contributions for a hinge-loss SVM.

    Padding rows carry y == 0 and are masked out. Returns
    (sum_hinge, sum_alpha, correct, n_valid); the trainer combines chunks as
      gap = (sum_hinge - sum_alpha)/n + lambda * ||w||^2.
    """
    valid = (y != 0.0).astype(jnp.float32)
    margins = y * (x @ w)
    hinge = jnp.maximum(0.0, 1.0 - margins)
    correct = (margins > 0.0).astype(jnp.float32)
    return (
        jnp.sum(hinge * valid),
        jnp.sum(alpha * valid),
        jnp.sum(correct * valid),
        jnp.sum(valid),
    )


# ---------------------------------------------------------------------------
# Shared NN pieces
# ---------------------------------------------------------------------------


def _softmax_xent(logits, labels, n_classes):
    """Per-example CE with -1 = padding; returns (loss_sum, correct, n_valid)."""
    valid = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[:, None], axis=-1)[:, 0]
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == safe).astype(jnp.float32)
    return jnp.sum(nll * valid), jnp.sum(correct * valid), jnp.sum(valid)


# ---------------------------------------------------------------------------
# MLP (Fashion-MNIST-like workload)
# ---------------------------------------------------------------------------

MLP_DIMS = (784, 256, 128, 10)


def mlp_specs(dims=MLP_DIMS):
    specs = []
    for i in range(len(dims) - 1):
        specs.append((f"fc{i}.w", (dims[i], dims[i + 1])))
        specs.append((f"fc{i}.b", (dims[i + 1],)))
    return specs


def mlp_forward(flat, x, dims=MLP_DIMS):
    params = unflatten(flat, mlp_specs(dims))
    h = x
    n_layers = len(dims) - 1
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = "none" if i == n_layers - 1 else "relu"
        h = fused_linear(h, w, b, act)
    return h


def mlp_loss(flat, x, y, dims=MLP_DIMS):
    logits = mlp_forward(flat, x, dims)
    loss_sum, correct, n = _softmax_xent(logits, y, dims[-1])
    return loss_sum / jnp.maximum(n, 1.0), (correct, n)


def mlp_grad(flat, x, y, dims=MLP_DIMS):
    (loss, (correct, n)), g = jax.value_and_grad(mlp_loss, has_aux=True)(flat, x, y, dims)
    return g, loss, correct, n


def mlp_init(seed, dims=MLP_DIMS):
    key = jax.random.PRNGKey(seed)
    parts = []
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        parts.append(jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32) * scale)
        parts.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return flatten(parts)


# ---------------------------------------------------------------------------
# CNN (the paper's CIFAR-10 net: 2x [conv5x5 + maxpool + relu], 3x FC)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    # Channel/FC widths sized for the 2-core CPU testbed (the paper's CNN
    # is "two convolutional layers with maxpooling followed by 3 fully
    # connected layers"; it does not pin the widths).
    image: tuple[int, int, int] = (32, 32, 3)  # H, W, C (NHWC)
    conv_channels: tuple[int, int] = (8, 16)
    kernel: int = 5
    fc_dims: tuple[int, int] = (256, 128)
    n_classes: int = 10

    @property
    def flat_after_conv(self) -> int:
        h, w, _ = self.image
        return (h // 4) * (w // 4) * self.conv_channels[1]

    @property
    def input_dim(self) -> int:
        h, w, c = self.image
        return h * w * c


def cnn_specs(cfg: CnnConfig):
    k = cfg.kernel
    c0 = cfg.image[2]
    c1, c2 = cfg.conv_channels
    f0 = cfg.flat_after_conv
    f1, f2 = cfg.fc_dims
    return [
        ("conv1.w", (k, k, c0, c1)),
        ("conv1.b", (c1,)),
        ("conv2.w", (k, k, c1, c2)),
        ("conv2.b", (c2,)),
        ("fc1.w", (f0, f1)),
        ("fc1.b", (f1,)),
        ("fc2.w", (f1, f2)),
        ("fc2.b", (f2,)),
        ("fc3.w", (f2, cfg.n_classes)),
        ("fc3.b", (cfg.n_classes,)),
    ]


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _conv(x, w, b):
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b[None, None, None, :]


def cnn_forward(flat, x_flat, cfg: CnnConfig):
    p = unflatten(flat, cnn_specs(cfg))
    h_img, w_img, c = cfg.image
    x = x_flat.reshape((-1, h_img, w_img, c))
    x = jnp.maximum(_maxpool2(_conv(x, p[0], p[1])), 0.0)
    x = jnp.maximum(_maxpool2(_conv(x, p[2], p[3])), 0.0)
    x = x.reshape((x.shape[0], cfg.flat_after_conv))
    x = fused_linear(x, p[4], p[5], "relu")
    x = fused_linear(x, p[6], p[7], "relu")
    return fused_linear(x, p[8], p[9], "none")


def cnn_loss(flat, x, y, cfg: CnnConfig):
    logits = cnn_forward(flat, x, cfg)
    loss_sum, correct, n = _softmax_xent(logits, y, cfg.n_classes)
    return loss_sum / jnp.maximum(n, 1.0), (correct, n)


def cnn_grad(flat, x, y, cfg: CnnConfig):
    (loss, (correct, n)), g = jax.value_and_grad(cnn_loss, has_aux=True)(flat, x, y, cfg)
    return g, loss, correct, n


def cnn_init(seed, cfg: CnnConfig):
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in cnn_specs(cfg):
        key, k = jax.random.split(key)
        if name.endswith(".b"):
            parts.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            parts.append(jax.random.normal(k, shape, jnp.float32) * scale)
    return flatten(parts)


# ---------------------------------------------------------------------------
# Decoder-only transformer LM (end-to-end validation workload)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TfmConfig:
    vocab: int = 4096
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 1024
    seq_len: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def tfm_specs(cfg: TfmConfig):
    d, f = cfg.d_model, cfg.d_ff
    specs = [
        ("tok_emb", (cfg.vocab, d)),
        ("pos_emb", (cfg.seq_len, d)),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1.g", (d,)), (f"l{i}.ln1.b", (d,)),
            (f"l{i}.qkv.w", (d, 3 * d)), (f"l{i}.qkv.b", (3 * d,)),
            (f"l{i}.proj.w", (d, d)), (f"l{i}.proj.b", (d,)),
            (f"l{i}.ln2.g", (d,)), (f"l{i}.ln2.b", (d,)),
            (f"l{i}.ff1.w", (d, f)), (f"l{i}.ff1.b", (f,)),
            (f"l{i}.ff2.w", (f, d)), (f"l{i}.ff2.b", (d,)),
        ]
    specs += [("ln_f.g", (d,)), ("ln_f.b", (d,))]
    return specs


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + eps) * g + b


def _attention(x2d, bt_shape, qkv_w, qkv_b, proj_w, proj_b, cfg: TfmConfig):
    b, t = bt_shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = fused_linear(x2d, qkv_w, qkv_b, "none")  # (B*T, 3d) via Pallas
    qkv = qkv.reshape(b, t, 3, h, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (B, T, H, hd)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhij,bjhd->bihd", att, v).reshape(b * t, h * hd)
    return fused_linear(out, proj_w, proj_b, "none")


def tfm_forward(flat, tokens, cfg: TfmConfig):
    specs = tfm_specs(cfg)
    p = dict(zip([n for n, _ in specs], unflatten(flat, specs)))
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t]
    x2d = x.reshape(b * t, cfg.d_model)
    for i in range(cfg.n_layers):
        pre = _ln(x2d, p[f"l{i}.ln1.g"], p[f"l{i}.ln1.b"])
        x2d = x2d + _attention(
            pre, (b, t), p[f"l{i}.qkv.w"], p[f"l{i}.qkv.b"],
            p[f"l{i}.proj.w"], p[f"l{i}.proj.b"], cfg,
        )
        pre = _ln(x2d, p[f"l{i}.ln2.g"], p[f"l{i}.ln2.b"])
        h = fused_linear(pre, p[f"l{i}.ff1.w"], p[f"l{i}.ff1.b"], "gelu")
        x2d = x2d + fused_linear(h, p[f"l{i}.ff2.w"], p[f"l{i}.ff2.b"], "none")
    x2d = _ln(x2d, p["ln_f.g"], p["ln_f.b"])
    logits = jnp.dot(x2d, p["tok_emb"].T)  # tied embedding
    return logits.reshape(b, t, cfg.vocab)


def tfm_loss(flat, tokens, cfg: TfmConfig):
    logits = tfm_forward(flat, tokens, cfg)
    b, t, v = logits.shape
    pred = logits[:, :-1].reshape(b * (t - 1), v)
    tgt = tokens[:, 1:].reshape(b * (t - 1))
    loss_sum, correct, n = _softmax_xent(pred, tgt, v)
    return loss_sum / jnp.maximum(n, 1.0), (correct, n)


def tfm_grad(flat, tokens, cfg: TfmConfig):
    (loss, (correct, n)), g = jax.value_and_grad(tfm_loss, has_aux=True)(flat, tokens, cfg)
    return g, loss, correct, n


def tfm_init(seed, cfg: TfmConfig):
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in tfm_specs(cfg):
        key, k = jax.random.split(key)
        if name.endswith((".b", "ln1.g", "ln2.g", "ln_f.g")) or name.endswith(".g"):
            if name.endswith(".g"):
                parts.append(jnp.ones(shape, jnp.float32))
            else:
                parts.append(jnp.zeros(shape, jnp.float32))
        elif name in ("tok_emb", "pos_emb"):
            parts.append(jax.random.normal(k, shape, jnp.float32) * 0.02)
        else:
            scale = jnp.sqrt(1.0 / shape[0])
            parts.append(jax.random.normal(k, shape, jnp.float32) * scale)
    return flatten(parts)
