"""L1 Pallas kernels: tiled matmul and fused linear (matmul + bias + activation).

These are the dense compute hot-spots of Chicle's NN solvers (the FC layers of
the paper's CNN, the MLP, and the transformer FFN/attention projections). They
are written as Pallas kernels so the L2 jax models lower them into the same
HLO module that the rust runtime executes via PJRT.

TPU notes (see DESIGN.md §Hardware-Adaptation / §Perf): blocks default to
128x128 which matches the MXU systolic array; the K dimension is kept whole in
VMEM per block-row (all shapes used in this repo have K*bm*4B well under the
~16MiB VMEM budget — the manifest records the footprint per variant). On this
testbed kernels run with interpret=True because the CPU PJRT client cannot
execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Interpret mode is mandatory on CPU-PJRT (see module docstring).
INTERPRET = True

# Default tile sizes, chosen for the MXU (128x128 systolic array).
BLOCK_M = 128
BLOCK_N = 128


def _act(x, act: str):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {act!r}")


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (bm, K) x (K, bn) tile product, f32 accumulation on the MXU.
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = _act(acc + b_ref[...][None, :], act)


def _grid(m: int, n: int, bm: int, bn: int):
    return (pl.cdiv(m, bm), pl.cdiv(n, bn))


def matmul(x: jax.Array, w: jax.Array, *, bm: int = BLOCK_M, bn: int = BLOCK_N) -> jax.Array:
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N), f32 accumulate."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=_grid(m, n, bm, bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w)


def _fused_linear_fwd_pallas(x, w, b, act: str, bm: int, bn: int):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    kernel = functools.partial(_fused_linear_kernel, act=act)
    return pl.pallas_call(
        kernel,
        grid=_grid(m, n, bm, bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act: str = "relu"):
    """act(x @ w + b) with a Pallas forward and Pallas-matmul backward.

    Pallas kernels carry no autodiff rule, so the VJP is hand-written: both
    backward products (dy @ w.T and x.T @ dy) reuse the tiled matmul kernel.
    """
    return _fused_linear_fwd_pallas(x, w, b, act, BLOCK_M, BLOCK_N)


def _fused_linear_fwd(x, w, b, act: str):
    y = _fused_linear_fwd_pallas(x, w, b, act, BLOCK_M, BLOCK_N)
    if act == "gelu":
        # gelu' needs the pre-activation; keep it as residual.
        pre = matmul(x, w) + b[None, :]
        return y, (x, w, pre)
    return y, (x, w, y)


def _fused_linear_bwd(act: str, res, g):
    x, w, saved = res
    if act == "none":
        dy = g
    elif act == "relu":
        # saved == y; relu' masks where the output was clamped.
        dy = g * (saved > 0.0).astype(g.dtype)
    elif act == "gelu":
        dy = g * jax.grad(lambda t: jnp.sum(jax.nn.gelu(t)))(saved)
    else:  # pragma: no cover
        raise ValueError(act)
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
