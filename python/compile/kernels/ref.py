"""Pure-jnp/numpy oracles for the Pallas kernels (build-time correctness).

Every kernel in this package has a reference implementation here written with
plain jax.numpy (no pallas), used by pytest/hypothesis to validate numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ref_matmul(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def ref_fused_linear(x, w, b, act: str = "relu"):
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        return jax.nn.gelu(y)
    raise ValueError(act)


def ref_scd_block(x, y, order, alpha, v, lam_n, sigma):
    """Sequential numpy SDCA — the ground truth for kernels.scd.scd_block."""
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    alpha = np.array(alpha, np.float32, copy=True)
    v = np.array(v, np.float32, copy=True)
    dv = np.zeros_like(v)
    lam_n = np.float32(lam_n)
    sigma = np.float32(sigma)
    for i in np.asarray(order, np.int64):
        xi = x[i]
        sqi = np.float32(np.dot(xi, xi))
        if sqi <= 0.0:
            continue
        margin = y[i] * np.float32(np.dot(xi, v))
        step = (np.float32(1.0) - margin) / (sigma * sqi / lam_n)
        a_new = np.clip(alpha[i] + step, 0.0, 1.0).astype(np.float32)
        upd = (a_new - alpha[i]) * y[i] / lam_n * xi
        alpha[i] = a_new
        # CoCoA+ local view: own updates enter scaled by sigma'.
        v = v + sigma * upd
        dv = dv + upd
    return alpha, dv


def ref_duality_gap(x, y, alpha, w, lam):
    """gap = P(w) - D(alpha) for hinge-loss SVM; w must equal w(alpha)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    alpha = np.asarray(alpha, np.float64)
    w = np.asarray(w, np.float64)
    margins = y * (x @ w)
    hinge = np.maximum(0.0, 1.0 - margins)
    # P - D = 1/n sum(hinge_i - alpha_i) + lambda ||w||^2
    return float(np.mean(hinge - alpha) + lam * np.dot(w, w))
