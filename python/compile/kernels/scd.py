"""L1 Pallas kernel: a block of stochastic dual coordinate ascent (SDCA) steps.

This is the CoCoA local-solver hot spot (paper §2.2, §4.1): each uni-task runs
H sequential coordinate updates over the training samples in its local data
chunks, against the shared vector v = w, and emits the accumulated model delta
dv. Per-sample dual state alpha lives *with the chunk* (paper §4.4) and is
updated in place.

Math (hinge-loss SVM, CoCoA+ with aggregation parameter sigma' = K):
    primal  P(w) = lambda/2 ||w||^2 + 1/n sum_i max(0, 1 - y_i x_i.w)
    dual    D(a) = 1/n sum_i a_i - lambda/2 ||w(a)||^2,  a_i in [0, 1]
    with    w(a) = (1/(lambda n)) sum_i a_i y_i x_i
SDCA closed-form step on coordinate i (sq_i = ||x_i||^2), on the CoCoA+
local subproblem: the solver's local view is w_loc = w + sigma' * dv (its
own accumulated delta scaled by the aggregation parameter), and the step
is damped by sigma':
    delta = (1 - y_i x_i.w_loc) / (sigma * sq_i / (lambda n))
    a_i  <- clip(a_i + delta, 0, 1)
    dv   += (a_i_new - a_i_old) y_i x_i / (lambda n)
The *unscaled* dv is returned; the trainer sums dv over tasks (gamma = 1).

The whole (S, F) chunk block stays resident in VMEM; the sequential loop over
coordinates is a fori_loop *inside* the kernel (the dependence chain through v
is inherent to SCD — see Wright 2015). interpret=True for CPU-PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _scd_kernel(x_ref, y_ref, order_ref, alpha_ref, v_ref, scal_ref,
                alpha_out_ref, dv_ref):
    X = x_ref[...]            # (S, F) dense chunk block
    y = y_ref[...]            # (S,)  labels in {-1, +1}
    order = order_ref[...]    # (H,)  coordinate visit order (i32)
    lam_n = scal_ref[0]       # lambda * n  (global sample count)
    sigma = scal_ref[1]       # CoCoA aggregation parameter sigma' (= K)

    sq = jnp.sum(X * X, axis=1)  # per-sample squared norms, hoisted
    h = order.shape[0]

    def body(t, carry):
        alpha, v, dv = carry
        i = order[t]
        xi = jax.lax.dynamic_slice_in_dim(X, i, 1, axis=0)[0]      # (F,)
        yi = jax.lax.dynamic_slice_in_dim(y, i, 1, axis=0)[0]
        ai = jax.lax.dynamic_slice_in_dim(alpha, i, 1, axis=0)[0]
        sqi = jax.lax.dynamic_slice_in_dim(sq, i, 1, axis=0)[0]
        margin = yi * jnp.dot(xi, v)
        denom = sigma * sqi / lam_n
        # Guard zero-norm samples (padding rows use x = 0): no update.
        step = jnp.where(sqi > 0.0, (1.0 - margin) / jnp.where(sqi > 0.0, denom, 1.0), 0.0)
        a_new = jnp.clip(ai + step, 0.0, 1.0)
        d = (a_new - ai) * yi / lam_n
        upd = d * xi
        alpha = jax.lax.dynamic_update_slice_in_dim(alpha, a_new[None], i, axis=0)
        # CoCoA+ local view: own updates enter scaled by sigma'.
        return alpha, v + sigma * upd, dv + upd

    alpha0 = alpha_ref[...]
    v0 = v_ref[...]
    dv0 = jnp.zeros_like(v0)
    alpha1, _v1, dv1 = jax.lax.fori_loop(0, h, body, (alpha0, v0, dv0))
    alpha_out_ref[...] = alpha1
    dv_ref[...] = dv1


def scd_block(x, y, order, alpha, v, lam_n, sigma):
    """Run len(order) sequential SDCA steps over a dense chunk block.

    Args:
      x:      f32 (S, F) samples.
      y:      f32 (S,) labels in {-1, +1}.
      order:  i32 (H,) visit order (row indices into x; may repeat / be shorter
              or longer than S).
      alpha:  f32 (S,) dual state (chunk-resident, paper §4.4).
      v:      f32 (F,) shared vector (= w) snapshot for this iteration.
      lam_n:  f32 scalar, lambda * n_total.
      sigma:  f32 scalar, CoCoA sigma' (the paper sets it to K).

    Returns:
      (alpha_out (S,), dv (F,)) — updated dual state and accumulated model
      delta; the trainer merges dv across tasks weighted by |D_k|/|D| (eq. 2).
    """
    s, f = x.shape
    scal = jnp.stack([jnp.asarray(lam_n, jnp.float32),
                      jnp.asarray(sigma, jnp.float32)])
    return pl.pallas_call(
        _scd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((s,), jnp.float32),
            jax.ShapeDtypeStruct((f,), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, y, order, alpha, v, scal)
