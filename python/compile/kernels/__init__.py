"""L1 Pallas kernels for Chicle's compute hot-spots + pure-jnp oracles."""

from .fused_linear import fused_linear, matmul  # noqa: F401
from .scd import scd_block  # noqa: F401
