"""AOT pipeline checks: HLO-text lowering round-trip + manifest sanity.

Validates the compile path end-to-end *within python*: lowering a small
jitted function through the same `to_hlo_text` used by aot.py produces
parseable HLO text with the expected entry signature, and — when
`make artifacts` has run — the manifest agrees with the artifact files.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_small_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f32[2,2] parameters appear in the entry computation.
    assert text.count("f32[2,2]") >= 3


def test_scd_chunk_lowering_has_expected_signature():
    s, f = 8, 4
    lowered = jax.jit(M.scd_chunk).lower(
        aot.spec((s, f)), aot.spec((s,)), aot.spec((s,), jnp.int32),
        aot.spec((s,)), aot.spec((f,)), aot.spec(()), aot.spec(()),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # The sequential SCD loop lowers to a while op.
    assert "while" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_artifact_files():
    with open(os.path.join(ART_DIR, "manifest.json")) as fh:
        manifest = json.load(fh)
    assert manifest["artifacts"], "no artifacts in manifest"
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(ART_DIR, meta["file"])
        assert os.path.exists(path), f"{name}: missing {meta['file']}"
        head = open(path).read(200)
        assert "HloModule" in head, f"{name}: not HLO text"
        assert meta["inputs"], name
        assert meta["outputs"], name
    # Model layouts are internally consistent.
    for name, model in manifest["models"].items():
        sizes = sum(p["size"] for p in model["params"])
        assert sizes == model["param_count"], name
        offset = 0
        for p in model["params"]:
            assert p["offset"] == offset, f"{name}/{p['name']}"
            offset += p["size"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_grad_batches_match_cli_default():
    with open(os.path.join(ART_DIR, "manifest.json")) as fh:
        manifest = json.load(fh)
    grads = [a for a in manifest["artifacts"].values()
             if a.get("meta", {}).get("kind") == "grad"]
    assert grads
    for g in grads:
        assert g["meta"]["batch"] == 8  # paper's L
