"""L2 model tests: shapes, gradient sanity, training-progress smoke tests."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# flat param plumbing
# ---------------------------------------------------------------------------


def test_param_layout_offsets_contiguous():
    specs = [("a", (3, 4)), ("b", (5,)), ("c", (2, 2, 2))]
    layout, total = M.param_layout(specs)
    assert total == 12 + 5 + 8
    off = 0
    for entry in layout:
        assert entry["offset"] == off
        off += entry["size"]


def test_flatten_unflatten_roundtrip():
    specs = [("a", (3, 4)), ("b", (5,))]
    rng = np.random.default_rng(0)
    params = [jnp.asarray(rng.standard_normal(s).astype(np.float32))
              for _, s in specs]
    flat = M.flatten(params)
    back = M.unflatten(flat, specs)
    for p, q in zip(params, back):
        assert_allclose(np.asarray(p), np.asarray(q))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _mlp_data(rng, n, dims=M.MLP_DIMS):
    x = rng.standard_normal((n, dims[0])).astype(np.float32)
    y = rng.integers(0, dims[-1], size=n).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def test_mlp_shapes_and_finite():
    rng = np.random.default_rng(0)
    flat = M.mlp_init(0)
    _, total = M.param_layout(M.mlp_specs())
    assert flat.shape == (total,)
    x, y = _mlp_data(rng, 8)
    g, loss, correct, n = M.mlp_grad(flat, x, y)
    assert g.shape == flat.shape
    assert np.isfinite(float(loss))
    assert 0 <= float(correct) <= 8 and float(n) == 8.0


def test_mlp_loss_decreases_under_sgd():
    rng = np.random.default_rng(1)
    flat = M.mlp_init(1)
    x, y = _mlp_data(rng, 8)
    l0 = None
    for _ in range(30):
        g, loss, _, _ = M.mlp_grad(flat, x, y)
        if l0 is None:
            l0 = float(loss)
        flat = flat - 0.05 * g
    assert float(loss) < l0 * 0.5


def test_mlp_padding_labels_masked():
    rng = np.random.default_rng(2)
    flat = M.mlp_init(2)
    x, y = _mlp_data(rng, 8)
    y_pad = y.at[4:].set(-1)
    _, loss_pad, _, n = M.mlp_grad(flat, x, y_pad)
    assert float(n) == 4.0
    # masked loss must only depend on the first 4 rows
    x2 = x.at[4:].set(0.0)
    _, loss_pad2, _, _ = M.mlp_grad(flat, x2, y_pad)
    assert_allclose(float(loss_pad), float(loss_pad2), rtol=1e-6)


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------


def test_cnn_shapes_and_grad():
    cfg = M.CnnConfig()
    rng = np.random.default_rng(3)
    flat = M.cnn_init(3, cfg)
    x = jnp.asarray(rng.standard_normal((4, cfg.input_dim)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=4).astype(np.int32))
    logits = M.cnn_forward(flat, x, cfg)
    assert logits.shape == (4, 10)
    g, loss, correct, n = M.cnn_grad(flat, x, y, cfg)
    assert g.shape == flat.shape
    assert np.isfinite(float(loss)) and float(n) == 4.0
    assert float(jnp.sum(jnp.abs(g))) > 0.0


def test_cnn_learns_templates():
    # Two constant-template classes: should be separable within a few steps.
    cfg = M.CnnConfig()
    flat = M.cnn_init(4, cfg)
    x0 = np.full((4, cfg.input_dim), 0.5, np.float32)
    x1 = np.full((4, cfg.input_dim), -0.5, np.float32)
    x = jnp.asarray(np.concatenate([x0, x1]))
    y = jnp.asarray(np.array([0] * 4 + [1] * 4, np.int32))
    for _ in range(15):
        g, loss, correct, _ = M.cnn_grad(flat, x, y, cfg)
        flat = flat - 0.05 * g
    assert float(correct) == 8.0


# ---------------------------------------------------------------------------
# Transformer
# ---------------------------------------------------------------------------

TFM_TINY = M.TfmConfig(vocab=64, d_model=32, n_layers=2, n_heads=4,
                       d_ff=64, seq_len=16)


def test_tfm_shapes():
    flat = M.tfm_init(0, TFM_TINY)
    _, total = M.param_layout(M.tfm_specs(TFM_TINY))
    assert flat.shape == (total,)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, TFM_TINY.vocab, size=(2, TFM_TINY.seq_len)).astype(np.int32))
    logits = M.tfm_forward(flat, toks, TFM_TINY)
    assert logits.shape == (2, TFM_TINY.seq_len, TFM_TINY.vocab)


def test_tfm_causality():
    # Changing a future token must not change past logits.
    flat = M.tfm_init(1, TFM_TINY)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, TFM_TINY.vocab, size=(1, TFM_TINY.seq_len)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % TFM_TINY.vocab
    l1 = M.tfm_forward(flat, jnp.asarray(toks), TFM_TINY)
    l2 = M.tfm_forward(flat, jnp.asarray(toks2), TFM_TINY)
    assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                    rtol=1e-4, atol=1e-5)


def test_tfm_memorizes_sequence():
    flat = M.tfm_init(2, TFM_TINY)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, TFM_TINY.vocab,
                                    size=(2, TFM_TINY.seq_len)).astype(np.int32))
    losses = []
    for _ in range(40):
        g, loss, _, _ = M.tfm_grad(flat, toks, TFM_TINY)
        losses.append(float(loss))
        flat = flat - 0.5 * g
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


# ---------------------------------------------------------------------------
# linear_eval (duality gap pieces)
# ---------------------------------------------------------------------------


def test_linear_eval_masks_padding():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((8, 4)).astype(np.float32)
    y = np.array([1, -1, 1, -1, 0, 0, 0, 0], np.float32)
    alpha = rng.uniform(0, 1, 8).astype(np.float32)
    w = rng.standard_normal(4).astype(np.float32)
    sh, sa, corr, n = M.linear_eval(jnp.asarray(x), jnp.asarray(y),
                                    jnp.asarray(alpha), jnp.asarray(w))
    assert float(n) == 4.0
    margins = y[:4] * (x[:4] @ w)
    assert_allclose(float(sh), np.maximum(0, 1 - margins).sum(), rtol=1e-5)
    assert_allclose(float(sa), alpha[:4].sum(), rtol=1e-5)
    assert float(corr) == float((margins > 0).sum())
