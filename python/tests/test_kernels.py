"""L1 kernel correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes and value ranges; assert_allclose at f32 tolerance.
This is the core correctness signal for everything the rust runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile.kernels import fused_linear, matmul, scd_block
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=200)
small_dims = st.integers(min_value=1, max_value=48)


def rng_arr(rng, shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rng_arr(rng, (m, k)), rng_arr(rng, (k, n))
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    want = ref.ref_matmul(x, w)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matmul_large_blocks():
    # Exercise multiple 128x128 grid tiles including ragged edges.
    rng = np.random.default_rng(0)
    x, w = rng_arr(rng, (300, 70)), rng_arr(rng, (70, 257))
    got = matmul(jnp.asarray(x), jnp.asarray(w))
    assert_allclose(np.asarray(got), np.asarray(ref.ref_matmul(x, w)),
                    rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused_linear forward + backward
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=dims, k=small_dims, n=small_dims,
       act=st.sampled_from(["none", "relu", "gelu"]),
       seed=st.integers(0, 2**31 - 1))
def test_fused_linear_forward(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rng_arr(rng, (m, k)), rng_arr(rng, (k, n)), rng_arr(rng, (n,))
    got = fused_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), act)
    want = ref.ref_fused_linear(x, w, b, act)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=small_dims, k=small_dims, n=small_dims,
       act=st.sampled_from(["none", "relu", "gelu"]),
       seed=st.integers(0, 2**31 - 1))
def test_fused_linear_grad(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rng_arr(rng, (m, k)), rng_arr(rng, (k, n)), rng_arr(rng, (n,))

    def loss_pallas(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.ref_fused_linear(x, w, b, act) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    for a, bb in zip(gp, gr):
        assert_allclose(np.asarray(a), np.asarray(bb), rtol=2e-4, atol=2e-4)


def test_fused_linear_relu_clamps():
    x = jnp.asarray([[-100.0, 0.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    y = fused_linear(x, w, b, "relu")
    assert float(y[0, 0]) == 0.0


# ---------------------------------------------------------------------------
# SCD block
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(s=st.integers(2, 64), f=st.integers(1, 32), h=st.integers(1, 128),
       sigma=st.floats(1.0, 64.0), seed=st.integers(0, 2**31 - 1))
def test_scd_block_matches_ref(s, f, h, sigma, seed):
    rng = np.random.default_rng(seed)
    x = rng_arr(rng, (s, f))
    y = rng.choice([-1.0, 1.0], size=s).astype(np.float32)
    order = rng.integers(0, s, size=h).astype(np.int32)
    alpha = rng.uniform(0, 1, size=s).astype(np.float32)
    v = rng_arr(rng, (f,), scale=0.1)
    lam_n = np.float32(0.01 * 1000)

    got_a, got_dv = scd_block(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(order),
        jnp.asarray(alpha), jnp.asarray(v), lam_n, np.float32(sigma))
    want_a, want_dv = ref.ref_scd_block(x, y, order, alpha, v, lam_n, sigma)
    assert_allclose(np.asarray(got_a), want_a, rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(got_dv), want_dv, rtol=1e-4, atol=1e-6)


def test_scd_alpha_stays_in_box():
    rng = np.random.default_rng(1)
    s, f = 32, 8
    x = rng_arr(rng, (s, f), scale=10.0)
    y = rng.choice([-1.0, 1.0], size=s).astype(np.float32)
    order = np.tile(np.arange(s, dtype=np.int32), 4)
    alpha = np.zeros(s, np.float32)
    v = np.zeros(f, np.float32)
    a, _ = scd_block(jnp.asarray(x), jnp.asarray(y), jnp.asarray(order),
                     jnp.asarray(alpha), jnp.asarray(v),
                     np.float32(0.01 * s), np.float32(4.0))
    a = np.asarray(a)
    assert np.all(a >= 0.0) and np.all(a <= 1.0)


def test_scd_padding_rows_are_noop():
    # Zero-norm rows (chunk padding) must not change alpha or dv.
    s, f = 8, 4
    x = np.zeros((s, f), np.float32)
    x[:4] = np.random.default_rng(2).standard_normal((4, f)).astype(np.float32)
    y = np.array([1, -1, 1, -1, 0, 0, 0, 0], np.float32)
    order = np.arange(s, dtype=np.int32)
    alpha = np.zeros(s, np.float32)
    v = np.zeros(f, np.float32)
    a, dv = scd_block(jnp.asarray(x), jnp.asarray(y), jnp.asarray(order),
                      jnp.asarray(alpha), jnp.asarray(v),
                      np.float32(8 * 0.01), np.float32(1.0))
    a = np.asarray(a)
    assert np.all(a[4:] == 0.0)
    want_a, want_dv = ref.ref_scd_block(x, y, order, alpha, v, 8 * 0.01, 1.0)
    assert_allclose(a, want_a, rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(dv), want_dv, rtol=1e-5, atol=1e-7)


def test_scd_converges_on_separable_data():
    # SDCA on linearly separable data should drive the duality gap near zero.
    rng = np.random.default_rng(3)
    s, f = 128, 8
    w_true = rng.standard_normal(f).astype(np.float32)
    x = rng.standard_normal((s, f)).astype(np.float32)
    y = np.sign(x @ w_true).astype(np.float32)
    y[y == 0] = 1.0
    lam = 0.01
    alpha = np.zeros(s, np.float32)
    v = np.zeros(f, np.float32)
    order = np.arange(s, dtype=np.int32)
    for _ in range(30):
        rng.shuffle(order)
        alpha, dv = scd_block(jnp.asarray(x), jnp.asarray(y), jnp.asarray(order),
                              jnp.asarray(alpha), jnp.asarray(v),
                              np.float32(lam * s), np.float32(1.0))
        alpha = np.asarray(alpha)
        v = v + np.asarray(dv)
    gap = ref.ref_duality_gap(x, y, alpha, v, lam)
    assert gap < 0.05, gap
