//! Benchmarks: the PJRT runtime hot path — HLO execution of the AOT
//! artifacts, including host-tensor marshalling. Skips (with a notice)
//! when artifacts are absent.

use std::path::Path;
use std::time::Duration;

use chicle::runtime::{HloService, HostTensor};
use chicle::util::bench::Bencher;
use chicle::util::Rng;

fn main() {
    if !Path::new("artifacts/manifest.json").exists() {
        println!("bench_runtime: no artifacts (run `make artifacts`); skipping");
        return;
    }
    let service = HloService::spawn(Path::new("artifacts")).expect("spawn service");
    let mut rng = Rng::seed_from_u64(0);
    let mut b = Bencher::new(Duration::from_secs(3)).with_iters(5, 10_000);

    // --- SCD chunk kernel (S=256, F=28) ---
    service.prepare("scd_chunk_s256_f28").unwrap();
    let x: Vec<f32> = (0..256 * 28).map(|_| rng.normal_f32()).collect();
    let y: Vec<f32> = (0..256).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let order: Vec<i32> = (0..256).collect();
    let alpha = vec![0.0f32; 256];
    let v = vec![0.0f32; 28];
    b.bench("hlo/scd_chunk_256x28", || {
        service
            .execute(
                "scd_chunk_s256_f28",
                vec![
                    HostTensor::mat_f32(x.clone(), 256, 28),
                    HostTensor::vec_f32(y.clone()),
                    HostTensor::vec_i32(order.clone()),
                    HostTensor::vec_f32(alpha.clone()),
                    HostTensor::vec_f32(v.clone()),
                    HostTensor::scalar_f32(0.01 * 256.0),
                    HostTensor::scalar_f32(16.0),
                ],
            )
            .unwrap()
            .len()
    });

    // --- linear eval kernel ---
    service.prepare("linear_eval_s256_f28").unwrap();
    b.bench("hlo/linear_eval_256x28", || {
        service
            .execute(
                "linear_eval_s256_f28",
                vec![
                    HostTensor::mat_f32(x.clone(), 256, 28),
                    HostTensor::vec_f32(y.clone()),
                    HostTensor::vec_f32(alpha.clone()),
                    HostTensor::vec_f32(v.clone()),
                ],
            )
            .unwrap()
            .len()
    });

    // --- MLP grad (L=8) — the lSGD inner step ---
    service.prepare("mlp_grad_l8").unwrap();
    let params = service
        .execute("mlp_init", vec![HostTensor::vec_i32(vec![0])])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let bx: Vec<f32> = (0..8 * 784).map(|_| rng.normal_f32()).collect();
    let by: Vec<i32> = (0..8).map(|_| rng.below(10) as i32).collect();
    b.bench("hlo/mlp_grad_L8", || {
        service
            .execute(
                "mlp_grad_l8",
                vec![
                    HostTensor::vec_f32(params.clone()),
                    HostTensor::mat_f32(bx.clone(), 8, 784),
                    HostTensor::vec_i32(by.clone()),
                ],
            )
            .unwrap()
            .len()
    });

    // --- CNN grad (L=8) ---
    service.prepare("cnn_grad_l8").unwrap();
    let cparams = service
        .execute("cnn_init", vec![HostTensor::vec_i32(vec![0])])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let cx: Vec<f32> = (0..8 * 3072).map(|_| rng.normal_f32()).collect();
    let mut b_slow = Bencher::new(Duration::from_secs(4)).with_iters(3, 1000);
    b_slow.bench("hlo/cnn_grad_L8", || {
        service
            .execute(
                "cnn_grad_l8",
                vec![
                    HostTensor::vec_f32(cparams.clone()),
                    HostTensor::mat_f32(cx.clone(), 8, 3072),
                    HostTensor::vec_i32(by.clone()),
                ],
            )
            .unwrap()
            .len()
    });

    // --- marshalling overhead: a no-math round trip is not available, so
    // measure tensor construction alone (the host-side share).
    b.bench("marshal/build_877k_param_tensor", || {
        HostTensor::vec_f32(cparams.clone()).element_count()
    });

    b.write_tsv("results/bench_runtime.tsv").unwrap();
    b_slow.write_tsv("results/bench_runtime_cnn.tsv").unwrap();
}
