//! Benchmarks: the native compute hot paths (solver inner loops).
//!
//! The §Perf log in EXPERIMENTS.md derives per-sample throughput from
//! these (e.g. scd_dense pass time / 4096 samples).

use std::time::Duration;

use chicle::algos::nn::linear::{fused_linear_fwd, Act};
use chicle::algos::nn::NativeModel;
use chicle::algos::svm::{
    scd_pass_dense, scd_pass_dense_scalar, scd_pass_sparse, scd_pass_sparse_scalar,
};
use chicle::data::{synth, FeatureMatrix, SparseVec};
use chicle::util::bench::Bencher;
use chicle::util::{kernels, Rng, Workspace};

fn main() {
    let mut b = Bencher::new(Duration::from_secs(2));
    let mut rng = Rng::seed_from_u64(0);

    // --- SCD (CoCoA inner loop) ---
    let (s, dim) = (4096usize, 28usize);
    let x: Vec<f32> = (0..s * dim).map(|_| rng.normal_f32()).collect();
    let y: Vec<f32> = (0..s).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let order: Vec<usize> = (0..s).collect();
    let lam_n = 0.01 * s as f32;
    b.bench("scd_dense/4096x28_pass", || {
        let mut alpha = vec![0.0f32; s];
        let mut v = vec![0.0f32; dim];
        let mut dv = vec![0.0f32; dim];
        scd_pass_dense(&x, dim, &y, &order, &mut alpha, &mut v, &mut dv, lam_n, 16.0);
        v[0]
    });

    let criteo = synth::criteo_like_with(4096, 50_000, 30, 16, 1);
    let (rows, sdim, ys) = match (&criteo.features, &criteo.labels) {
        (FeatureMatrix::Sparse { rows, dim }, chicle::data::Labels::Binary(yv)) => {
            (rows.clone(), *dim, yv.clone())
        }
        _ => unreachable!(),
    };
    b.bench("scd_sparse/4096x50k_nnz30_pass", || {
        let mut alpha = vec![0.0f32; rows.len()];
        let mut v = vec![0.0f32; sdim];
        let mut dv = vec![0.0f32; sdim];
        scd_pass_sparse(&rows, &ys, &order, &mut alpha, &mut v, &mut dv, lam_n, 16.0);
        v[0]
    });

    // --- fused linear (the Pallas kernel's native mirror) ---
    let (m, k, n) = (64usize, 784usize, 256usize);
    let xx: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
    let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    b.bench("fused_linear/64x784x256_relu", || {
        fused_linear_fwd(&xx, &w, &bias, m, k, n, Act::Relu).0[0]
    });

    // --- scalar/simd kernel pairs (speedup asserted after the TSV) ---
    // Same fused-linear geometry as above, dispatched vs forced-scalar:
    // both run the identical blocked loop, so the pair isolates the
    // kernel speedup (outputs are bit-equal).
    let fl_scalar = b
        .bench("nn/fused_linear_scalar", || {
            kernels::fused_linear_fwd_scalar(&xx, &w, &bias, m, k, n, Act::Relu).0[0]
        })
        .p50;
    let fl_simd = b
        .bench("nn/fused_linear_simd", || {
            fused_linear_fwd(&xx, &w, &bias, m, k, n, Act::Relu).0[0]
        })
        .p50;

    // Packed-B matmul at a width past BLOCK_N (N = 1024 > 512), where
    // the packed panels keep every axpy row contiguous. Output and pack
    // scratch are hoisted so the pair measures pure matmul time.
    let (pm, pk, pn) = (64usize, 256usize, 1024usize);
    let pa: Vec<f32> = (0..pm * pk).map(|_| rng.normal_f32()).collect();
    let pb: Vec<f32> = (0..pk * pn).map(|_| rng.normal_f32()).collect();
    let mut pc = vec![0.0f32; pm * pn];
    let mut pack = vec![0.0f32; kernels::packed_b_len(pk, pn)];
    let mm_scalar = b
        .bench("nn/matmul_packed_scalar", || {
            kernels::matmul_packed_scalar(&pa, &pb, &mut pc, pm, pk, pn, &mut pack);
            pc[0]
        })
        .p50;
    let mm_simd = b
        .bench("nn/matmul_packed_simd", || {
            kernels::matmul_packed(&pa, &pb, &mut pc, pm, pk, pn, &mut pack);
            pc[0]
        })
        .p50;

    // SCD dense pass at a SIMD-friendly width (dim 256; the 28-wide row
    // above stays as the paper-shaped workload).
    let (s2, dim2) = (2048usize, 256usize);
    let x2: Vec<f32> = (0..s2 * dim2).map(|_| rng.normal_f32()).collect();
    let y2: Vec<f32> = (0..s2).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let order2: Vec<usize> = (0..s2).collect();
    let lam_n2 = 0.01 * s2 as f32;
    let scd_scalar = b
        .bench("scd/dense_pass_scalar", || {
            let mut alpha = vec![0.0f32; s2];
            let mut v = vec![0.0f32; dim2];
            let mut dv = vec![0.0f32; dim2];
            scd_pass_dense_scalar(
                &x2, dim2, &y2, &order2, &mut alpha, &mut v, &mut dv, lam_n2, 16.0,
            );
            v[0]
        })
        .p50;
    let scd_simd = b
        .bench("scd/dense_pass_simd", || {
            let mut alpha = vec![0.0f32; s2];
            let mut v = vec![0.0f32; dim2];
            let mut dv = vec![0.0f32; dim2];
            scd_pass_dense(&x2, dim2, &y2, &order2, &mut alpha, &mut v, &mut dv, lam_n2, 16.0);
            v[0]
        })
        .p50;

    // Sparse SCD pass with wide rows (nnz 256 on dim 4096): the
    // gather-dot and scatter-axpy dominate, isolating the sparse kernel
    // speedup. State buffers are hoisted and reset by fill so both
    // sides measure pure pass time.
    let (sn, snnz, ssdim) = (4096usize, 256usize, 4096usize);
    let srows: Vec<SparseVec> = (0..sn)
        .map(|_| {
            let mut idx = 0u32;
            SparseVec::new(
                (0..snnz)
                    .map(|_| {
                        idx += 1 + rng.below(ssdim / snnz - 1) as u32;
                        (idx, rng.normal_f32())
                    })
                    .collect(),
            )
        })
        .collect();
    let sy: Vec<f32> = (0..sn).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let sorder: Vec<usize> = (0..sn).collect();
    let slam_n = 0.01 * sn as f32;
    let mut salpha = vec![0.0f32; sn];
    let mut sv = vec![0.0f32; ssdim];
    let mut sdv = vec![0.0f32; ssdim];
    let sp_scalar = b
        .bench("scd/sparse_pass_scalar", || {
            salpha.fill(0.0);
            sv.fill(0.0);
            sdv.fill(0.0);
            scd_pass_sparse_scalar(
                &srows, &sy, &sorder, &mut salpha, &mut sv, &mut sdv, slam_n, 16.0,
            );
            sv[0]
        })
        .p50;
    let sp_simd = b
        .bench("scd/sparse_pass_simd", || {
            salpha.fill(0.0);
            sv.fill(0.0);
            sdv.fill(0.0);
            scd_pass_sparse(&srows, &sy, &sorder, &mut salpha, &mut sv, &mut sdv, slam_n, 16.0);
            sv[0]
        })
        .p50;

    // --- NN grad steps (lSGD inner loop) ---
    let mlp = NativeModel::mlp_default();
    let mlp_params = mlp.init(1);
    let bx: Vec<f32> = (0..8 * 784).map(|_| rng.normal_f32()).collect();
    let by: Vec<i32> = (0..8).map(|_| rng.below(10) as i32).collect();
    b.bench("mlp_grad/L8", || mlp.grad(&mlp_params, &bx, &by).1);

    let cnn = NativeModel::cnn_default();
    let cnn_params = cnn.init(2);
    let cx: Vec<f32> = (0..8 * 3072).map(|_| rng.normal_f32()).collect();
    let cy: Vec<i32> = (0..8).map(|_| rng.below(10) as i32).collect();
    let mut b_slow = Bencher::new(Duration::from_secs(3)).with_iters(5, 1_000);
    b_slow.bench("cnn_grad/L8", || cnn.grad(&cnn_params, &cx, &cy).1);

    // Fresh-allocation vs warm-workspace CNN step: identical bits (the
    // workspace contract), the pair measures what pooling the ~5 MB of
    // per-step intermediates is worth.
    let cnn_fresh = b_slow
        .bench("nn/cnn_step_fresh", || cnn.grad(&cnn_params, &cx, &cy).1)
        .p50;
    let mut cnn_ws_pool = Workspace::new();
    let cnn_ws = b_slow
        .bench("nn/cnn_step_workspace", || {
            let (g, loss, ..) = cnn.grad_ws(&cnn_params, &cx, &cy, &mut cnn_ws_pool);
            cnn_ws_pool.put(g);
            loss
        })
        .p50;

    // Eval paths.
    let ex: Vec<f32> = (0..256 * 784).map(|_| rng.normal_f32()).collect();
    let ey: Vec<i32> = (0..256).map(|_| rng.below(10) as i32).collect();
    b.bench("mlp_eval/B256", || mlp.eval(&mlp_params, &ex, &ey).0);

    b.write_tsv("results/bench_algos.tsv").unwrap();
    b_slow.write_tsv("results/bench_algos_cnn.tsv").unwrap();

    // In-bench perf gates (PR-3/PR-5 pattern): asserted on the measured
    // p50s only after the TSV artifacts are written, so a failure still
    // leaves the numbers on disk. Skipped when the SIMD path is not live
    // (feature off or no AVX2) — both pair sides would run scalar.
    if kernels::simd_active() {
        assert!(
            fl_simd * 3 <= fl_scalar * 2,
            "fused_linear SIMD p50 {fl_simd:?} not >=1.5x faster than scalar {fl_scalar:?}"
        );
        assert!(
            scd_simd * 3 <= scd_scalar * 2,
            "scd dense-pass SIMD p50 {scd_simd:?} not >=1.5x faster than scalar {scd_scalar:?}"
        );
        assert!(
            mm_simd * 3 <= mm_scalar * 2,
            "packed matmul SIMD p50 {mm_simd:?} not >=1.5x faster than scalar {mm_scalar:?}"
        );
        assert!(
            sp_simd * 3 <= sp_scalar * 2,
            "scd sparse-pass SIMD p50 {sp_simd:?} not >=1.5x faster than scalar {sp_scalar:?}"
        );
    }
    // The workspace CNN step skips ~5 MB of allocation + zeroing per
    // call; it must beat the fresh-allocation step regardless of SIMD.
    assert!(
        cnn_ws < cnn_fresh,
        "workspace CNN step p50 {cnn_ws:?} not faster than fresh-alloc step {cnn_fresh:?}"
    );
}
