//! Benchmarks: the native compute hot paths (solver inner loops).
//!
//! The §Perf log in EXPERIMENTS.md derives per-sample throughput from
//! these (e.g. scd_dense pass time / 4096 samples).

use std::time::Duration;

use chicle::algos::nn::linear::{fused_linear_fwd, Act};
use chicle::algos::nn::NativeModel;
use chicle::algos::svm::{scd_pass_dense, scd_pass_sparse};
use chicle::data::{synth, FeatureMatrix};
use chicle::util::bench::Bencher;
use chicle::util::Rng;

fn main() {
    let mut b = Bencher::new(Duration::from_secs(2));
    let mut rng = Rng::seed_from_u64(0);

    // --- SCD (CoCoA inner loop) ---
    let (s, dim) = (4096usize, 28usize);
    let x: Vec<f32> = (0..s * dim).map(|_| rng.normal_f32()).collect();
    let y: Vec<f32> = (0..s).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let order: Vec<usize> = (0..s).collect();
    let lam_n = 0.01 * s as f32;
    b.bench("scd_dense/4096x28_pass", || {
        let mut alpha = vec![0.0f32; s];
        let mut v = vec![0.0f32; dim];
        let mut dv = vec![0.0f32; dim];
        scd_pass_dense(&x, dim, &y, &order, &mut alpha, &mut v, &mut dv, lam_n, 16.0);
        v[0]
    });

    let criteo = synth::criteo_like_with(4096, 50_000, 30, 16, 1);
    let (rows, sdim, ys) = match (&criteo.features, &criteo.labels) {
        (FeatureMatrix::Sparse { rows, dim }, chicle::data::Labels::Binary(yv)) => {
            (rows.clone(), *dim, yv.clone())
        }
        _ => unreachable!(),
    };
    b.bench("scd_sparse/4096x50k_nnz30_pass", || {
        let mut alpha = vec![0.0f32; rows.len()];
        let mut v = vec![0.0f32; sdim];
        let mut dv = vec![0.0f32; sdim];
        scd_pass_sparse(&rows, &ys, &order, &mut alpha, &mut v, &mut dv, lam_n, 16.0);
        v[0]
    });

    // --- fused linear (the Pallas kernel's native mirror) ---
    let (m, k, n) = (64usize, 784usize, 256usize);
    let xx: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
    let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    b.bench("fused_linear/64x784x256_relu", || {
        fused_linear_fwd(&xx, &w, &bias, m, k, n, Act::Relu).0[0]
    });

    // --- NN grad steps (lSGD inner loop) ---
    let mlp = NativeModel::mlp_default();
    let mlp_params = mlp.init(1);
    let bx: Vec<f32> = (0..8 * 784).map(|_| rng.normal_f32()).collect();
    let by: Vec<i32> = (0..8).map(|_| rng.below(10) as i32).collect();
    b.bench("mlp_grad/L8", || mlp.grad(&mlp_params, &bx, &by).1);

    let cnn = NativeModel::cnn_default();
    let cnn_params = cnn.init(2);
    let cx: Vec<f32> = (0..8 * 3072).map(|_| rng.normal_f32()).collect();
    let cy: Vec<i32> = (0..8).map(|_| rng.below(10) as i32).collect();
    let mut b_slow = Bencher::new(Duration::from_secs(3)).with_iters(5, 1_000);
    b_slow.bench("cnn_grad/L8", || cnn.grad(&cnn_params, &cx, &cy).1);

    // Eval paths.
    let ex: Vec<f32> = (0..256 * 784).map(|_| rng.normal_f32()).collect();
    let ey: Vec<i32> = (0..256).map(|_| rng.below(10) as i32).collect();
    b.bench("mlp_eval/B256", || mlp.eval(&mlp_params, &ex, &ey).0);

    b.write_tsv("results/bench_algos.tsv").unwrap();
    b_slow.write_tsv("results/bench_algos_cnn.tsv").unwrap();
}
