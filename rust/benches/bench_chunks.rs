//! Benchmarks: chunk substrate — chunking, store ops, transfer model.

use std::time::Duration;

use chicle::chunks::chunker::{make_chunks, make_chunks_shuffled};
use chicle::chunks::{ChunkStore, NetworkModel};
use chicle::data::synth;
use chicle::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new(Duration::from_secs(2));
    let higgs = synth::higgs_like(20_000, 1);
    let criteo = synth::criteo_like_with(20_000, 50_000, 30, 16, 2);

    b.bench("make_chunks/higgs_20k_64KiB", || make_chunks(&higgs, 64 * 1024).len());
    b.bench("make_chunks/criteo_20k_64KiB", || make_chunks(&criteo, 64 * 1024).len());
    b.bench("make_chunks_shuffled/higgs_20k", || {
        make_chunks_shuffled(&higgs, 64 * 1024, 7).len()
    });

    let chunks = make_chunks(&higgs, 16 * 1024);
    println!("  ({} chunks of ~16KiB)", chunks.len());
    b.bench("store/add_remove_100", || {
        let mut store = ChunkStore::new();
        for c in chunks.iter().take(100) {
            store.add(c.clone());
        }
        for c in chunks.iter().take(100) {
            store.remove(c.id);
        }
        store.n_chunks()
    });
    let store = ChunkStore::from_chunks(chunks.clone());
    b.bench("store/locate_mid", || store.locate(store.n_samples() / 2));
    b.bench("store/n_samples", || store.n_samples());

    let net = NetworkModel::default();
    b.bench("net/transfer_cost_1MiB", || net.transfer_cost(1 << 20));
    let sizes: Vec<usize> = chunks.iter().map(|c| c.size_bytes()).collect();
    b.bench("net/bulk_cost_all_chunks", || net.bulk_cost(&sizes));

    // The cost the paper quotes: ~16 MiB model exchange per task (§4.3),
    // now charged as a tree reduce (2·⌈log2 k⌉ rounds, not 2k).
    b.bench("net/model_exchange_tree_16MiB_k16", || {
        net.model_exchange_cost(16 << 20, 16)
    });

    b.write_tsv("results/bench_chunks.tsv").unwrap();
}
