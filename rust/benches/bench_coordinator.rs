//! Benchmarks: coordinator-side costs — weighted merge, policy decisions,
//! chunk redistribution, projection model. These must stay off the
//! critical path (target: ≪ one solver iteration).

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::nn::NativeModel;
use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, LsgdAlgo};
use chicle::chunks::chunker::make_chunks;
use chicle::chunks::{Chunk, ChunkStore, NetworkModel, SharedStore};
use chicle::exec::{ReduceOptions, WorkerPool};
use chicle::cluster::NodeSpec;
use chicle::config::{AlgoConfig, CocoaConfig, ModelKind, SessionConfig};
use chicle::coordinator::policy::{
    redistribute_for_new_tasks, Policy, PolicyCtx, RebalancePolicy,
};
use chicle::coordinator::{TaskState, Trainer};
use chicle::data::{synth, FeatureMatrix, Labels};
use chicle::sim::{makespan, microtask_iteration_time};
use chicle::transport::AllreduceKind;
use chicle::util::bench::Bencher;
use chicle::util::{kernels, Rng};

/// An eval-every-iteration lSGD/MLP trainer (235k-parameter model, well
/// above the parallel-merge threshold) for the eval-overlap benches:
/// every `step` runs one full iteration *including* the test-set
/// evaluation, pipelined or barriered per `overlap`.
fn eval_overlap_trainer(overlap: bool, tasks: usize) -> Trainer {
    let ds = synth::fmnist_like(1024, 3);
    let mut cfg = SessionConfig::lsgd("bench-eval-overlap", ModelKind::Mlp, tasks)
        .with_overlap(overlap);
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = usize::MAX;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 1;
        l.target_acc = 2.0; // unreachable: benches drive the step loop
    }
    let (train, test) = ds.split_test(cfg.test_frac);
    let (tx, ty) = match (&test.features, &test.labels) {
        (FeatureMatrix::Dense { data, .. }, Labels::Class(y)) => (data.clone(), y.clone()),
        _ => unreachable!("fmnist_like is dense-classed"),
    };
    let lcfg = match &cfg.algo {
        AlgoConfig::Lsgd(l) => l.clone(),
        _ => unreachable!(),
    };
    let algo = std::sync::Arc::new(
        LsgdAlgo::new_classif(
            lcfg,
            Backend::native_nn(NativeModel::mlp_default()),
            train.dim(),
            tx,
            ty,
            cfg.seed,
        )
        .unwrap(),
    );
    let chunks = make_chunks(&train, cfg.chunk_bytes);
    Trainer::new(cfg, algo, chunks).unwrap()
}

fn tasks_with_chunks(k: usize, n_samples: usize) -> Vec<TaskState> {
    let ds = synth::higgs_like(n_samples, 1);
    let chunks = make_chunks(&ds, 16 * 1024);
    let mut tasks: Vec<TaskState> = (0..k)
        .map(|i| TaskState::new(NodeSpec::new(i as u32, 1.0), 3))
        .collect();
    for (i, c) in chunks.into_iter().enumerate() {
        tasks[i % k].store.add(c);
    }
    for t in &mut tasks {
        t.record_time(1e-6);
    }
    tasks
}

fn main() {
    let mut b = Bencher::new(Duration::from_secs(2));

    // --- weighted merge of K updates over a large model (CNN size) ---
    let model_len = 876_714usize;
    let algo = CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 16_000, model_len);
    let updates: Vec<LocalUpdate> = (0..16)
        .map(|i| LocalUpdate {
            delta: vec![i as f32 * 1e-6; model_len],
            samples: 1000,
            loss_sum: 0.0,
        })
        .collect();
    let mut model = vec![0.0f32; model_len];
    b.bench("merge/16_updates_877k_params", || {
        algo.merge(&mut model, &updates, 16);
        model[0]
    });

    // --- merge-fold kernel pair: the elementwise weighted fold that
    // merge_shard runs per shard, dispatched vs forced-scalar, at an
    // L1-resident shard geometry (8 updates × 4096 f32 = 16 KiB shard +
    // 16 KiB streamed delta) so the pair measures kernel throughput, not
    // DRAM bandwidth. Fold order per element is identical on both sides
    // (lane-per-element), so outputs are bit-equal; the ≥1.5× speedup is
    // asserted after the TSV is written. ---
    let fold_len = 4096usize;
    let fold_deltas: Vec<Vec<f32>> =
        (0..8).map(|i| vec![1e-6 * (i + 1) as f32; fold_len]).collect();
    let mut fold_shard = vec![0.0f32; fold_len];
    let fold_scalar = b
        .bench("merge/fold_scalar", || {
            for (i, d) in fold_deltas.iter().enumerate() {
                kernels::scalar::axpy(&mut fold_shard, 1.0 / (i + 1) as f32, d);
            }
            fold_shard[0]
        })
        .p50;
    let fold_simd = b
        .bench("merge/fold_simd", || {
            for (i, d) in fold_deltas.iter().enumerate() {
                kernels::axpy(&mut fold_shard, 1.0 / (i + 1) as f32, d);
            }
            fold_shard[0]
        })
        .p50;

    // --- merge phase: serial fold vs work-stealing sharded reduction
    // through the worker pool (same updates, same model size). The pool
    // path should win from 4 workers up; the CI bench gate pins each
    // row's median against the committed baseline so neither path
    // regresses silently (the serial-vs-pool comparison itself is read
    // off the bench output / TSV artifact). ---
    let merge_algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        CocoaConfig::default(),
        Backend::native_cocoa(),
        16_000,
        model_len,
    ));
    let updates_arc = Arc::new(updates.clone());
    let model_arc = Arc::new(vec![0.0f32; model_len]);
    for w in [2usize, 4, 8] {
        let mut reduce_pool = WorkerPool::new(Arc::clone(&merge_algo));
        for i in 0..w {
            reduce_pool.spawn_worker(1000 + i as u32, SharedStore::new());
        }
        b.bench(&format!("merge/pool_reduce_{w}w_16upd_877k"), || {
            reduce_pool
                .reduce_model(&model_arc, Arc::clone(&updates_arc), 16, ReduceOptions::default())
                .unwrap()
                .0
                .len()
        });
    }

    // --- straggler resilience: one worker reduces 60 ns/element slower
    // (a ~6× straggler: the 16-update fold itself costs ~10 ns/element).
    // With the fixed one-shard-per-worker assignment it drags the whole
    // barrier for its len/4 shard; with stealing (16 shards/worker) it
    // holds at most a few small shards while the fast workers drain the
    // rest. The steal row's median should sit ≥2× below the fixed row's —
    // the gate pins both. ---
    for (label, opts) in [
        ("fixed", ReduceOptions { shards_per_worker: 1, stealing: false }),
        ("steal", ReduceOptions { shards_per_worker: 16, stealing: true }),
    ] {
        let mut slow_pool = WorkerPool::new(Arc::clone(&merge_algo));
        for i in 0..4u32 {
            slow_pool.spawn_worker(2000 + i, SharedStore::new());
        }
        slow_pool.set_reduce_slowdown(2000, 60).unwrap();
        b.bench(&format!("merge/slow1_4w_{label}_16upd_877k"), || {
            slow_pool
                .reduce_model(&model_arc, Arc::clone(&updates_arc), 16, opts)
                .unwrap()
                .0
                .len()
        });
    }

    // --- merge strategy head-to-head: coordinator-side sharded reduce vs
    // peer-to-peer ring-allreduce, k updates over k workers (one update
    // per rank, as a collective requires). In-process the ring pays
    // 2(k−1) serialized segment rounds — measured and asserted below,
    // the same figure the metrics log reports per iteration — against
    // the coordinator's single work-stealing fan-out, so these rows are
    // an honest accounting of protocol overhead, not a claimed win: the
    // ring's payoff is removing the coordinator from the data path, not
    // in-process wallclock. The collective clones the updates per call
    // exactly as `Trainer::phase_merge` does in production. ---
    for w in [4usize, 8] {
        let k_updates: Vec<LocalUpdate> = updates[..w].to_vec();
        let order: Vec<u32> = (0..w as u32).map(|i| 3000 + i).collect();
        let mut coord_pool = WorkerPool::new(Arc::clone(&merge_algo));
        for &n in &order {
            coord_pool.spawn_worker(n, SharedStore::new());
        }
        let k_arc = Arc::new(k_updates.clone());
        b.bench(&format!("merge/coord_reduce_{w}w_{w}upd_877k"), || {
            coord_pool
                .reduce_model(&model_arc, Arc::clone(&k_arc), w, ReduceOptions::default())
                .unwrap()
                .0
                .len()
        });
        let mut ring_pool = WorkerPool::new(Arc::clone(&merge_algo));
        for &n in &order {
            ring_pool.spawn_worker(n, SharedStore::new());
        }
        let out = ring_pool
            .allreduce_model(&order, &model_arc, k_updates.clone(), w, AllreduceKind::Ring, 0)
            .unwrap();
        assert_eq!(out.rounds, 2 * (w - 1), "measured ring transport rounds");
        b.bench(&format!("merge/allreduce_ring_{w}w_{w}upd_877k"), || {
            ring_pool
                .allreduce_model(&order, &model_arc, k_updates.clone(), w, AllreduceKind::Ring, 1)
                .unwrap()
                .model
                .len()
        });
    }

    // --- eval-spanning overlap: one full eval-point iteration (compute +
    // merge + test-set evaluation), pipelined vs barriered. Barriered
    // pays the full pipeline flush — reduce round-trip, then evaluation,
    // then the next dispatch all sequential on the critical path. The
    // pipelined row dispatches the next iteration behind the in-flight
    // reduce and evaluates against the completed buffer while the workers
    // are already computing, so its median must sit visibly below the
    // barriered row's (the gate pins both). ---
    let mut tr_piped = eval_overlap_trainer(true, 4);
    let mut iter_p = 0usize;
    b.bench("merge/eval_overlap_mlp_4w_pipelined", || {
        let m = tr_piped.step(iter_p).unwrap();
        iter_p += 1;
        m.is_some()
    });
    drop(tr_piped); // a speculative iteration may be in flight; drop settles it
    let mut tr_barr = eval_overlap_trainer(false, 4);
    let mut iter_b = 0usize;
    b.bench("merge/eval_overlap_mlp_4w_barriered", || {
        let m = tr_barr.step(iter_b).unwrap();
        iter_b += 1;
        m.is_some()
    });

    // --- zero-copy chunk data plane: elastic migration round-trip and
    // the eval snapshot, each as an Arc-sharing vs deep-copy pair. The
    // `arc` rows are the production paths (`Chunk::clone` bumps the
    // payload refcount and copies only per-sample state); the `deepcopy`
    // rows are the pre-split reference (private payload per copy). The
    // gate pins each row against its baseline; the ≥5× arc-vs-deepcopy
    // ratio — the data plane's actual claim — is asserted on the
    // measured medians at the end of main, after the TSV artifact is
    // safely written. ---
    let mig_ds = synth::higgs_like(50_000, 5); // ≈ 5.8 MiB payload, 200 KiB state
    let mig_chunks = make_chunks(&mig_ds, 64 * 1024);
    let deal4 = |chunks: &[Chunk]| -> Vec<ChunkStore> {
        let mut stores: Vec<ChunkStore> = (0..4).map(|_| ChunkStore::new()).collect();
        for (i, c) in chunks.iter().enumerate() {
            stores[i % 4].add(c.clone());
        }
        stores
    };
    // A 4→2→4 elastic round-trip in which the coordinator retains a copy
    // of every migrated chunk (what a real cross-node transfer, or a
    // crash-safe handoff, must do): revoke stores 2 and 3 onto the
    // survivors, then scale back out by moving half of each survivor's
    // chunks to two fresh stores.
    fn migrate_roundtrip(stores: &mut [ChunkStore], copy: impl Fn(&Chunk) -> Chunk) -> usize {
        let orphans: Vec<Chunk> = {
            let (a, b) = (stores[2].drain(), stores[3].drain());
            a.into_iter().chain(b).collect()
        };
        for (i, c) in orphans.iter().enumerate() {
            stores[i % 2].add(copy(c));
        }
        for s in 0..2usize {
            let ids = stores[s].chunk_ids();
            for id in ids.into_iter().step_by(2) {
                let c = stores[s].remove(id).unwrap();
                stores[2 + s].add(copy(&c));
            }
        }
        stores.iter().map(|s| s.n_chunks()).sum()
    }
    // Store construction stays outside the timed body: a round-trip
    // leaves the stores in another valid 4-way layout (counts conserved,
    // ids disjoint, stores 2/3 repopulated), so the next iteration
    // migrates a steady ~1.5× dataset volume and only the migration
    // itself is measured.
    let mut mig_stores_arc = deal4(&mig_chunks);
    let mig_arc = b
        .bench("chunks/migrate_revoke_install_arc", || {
            migrate_roundtrip(&mut mig_stores_arc, Chunk::clone)
        })
        .p50;
    let mut mig_stores_deep = deal4(&mig_chunks);
    let mig_deep = b
        .bench("chunks/migrate_revoke_install_deepcopy", || {
            migrate_roundtrip(&mut mig_stores_deep, Chunk::deep_clone)
        })
        .p50;

    // The eval snapshot of a chunk-reading (CoCoA-style) evaluator: clone
    // every chunk of every task store in visit order — exactly what
    // `Trainer::snapshot_eval_chunks` does at an overlapped eval point.
    let snap_stores: Vec<SharedStore> = {
        let mut parts: Vec<Vec<Chunk>> = (0..4).map(|_| Vec::new()).collect();
        for (i, c) in mig_chunks.iter().enumerate() {
            parts[i % 4].push(c.clone());
        }
        parts.into_iter().map(SharedStore::from_chunks).collect()
    };
    let snap_arc = b
        .bench("merge/eval_snapshot_cocoa_arc", || {
            let mut all: Vec<Chunk> = Vec::new();
            for s in &snap_stores {
                all.extend(s.lock().iter().cloned());
            }
            all.len()
        })
        .p50;
    let snap_deep = b
        .bench("merge/eval_snapshot_cocoa_deepcopy", || {
            let mut all: Vec<Chunk> = Vec::new();
            for s in &snap_stores {
                all.extend(s.lock().iter().map(Chunk::deep_clone));
            }
            all.len()
        })
        .p50;

    // --- rebalance decision over 16 tasks ---
    b.bench("rebalance/decision_16_tasks", || {
        let mut tasks = tasks_with_chunks(16, 16_000);
        // Make task 0 look slow so there is a decision to make.
        tasks[0].clear_history();
        tasks[0].record_time(3e-6);
        let net = NetworkModel::default();
        let mut rng = Rng::seed_from_u64(0);
        let mut p = RebalancePolicy::new(4);
        let mut ctx = PolicyCtx {
            tasks: &mut tasks,
            iter: 1,
            net: &net,
            moved_bytes: 0,
            moved_chunks: 0,
            residency: chicle::transport::Residency::default(),
            rng: &mut rng,
        };
        p.apply(&mut ctx).unwrap();
        ctx.moved_chunks
    });

    // --- scale-out redistribution 8 → 16 tasks ---
    b.bench("elastic/redistribute_8_to_16", || {
        let mut tasks = tasks_with_chunks(8, 16_000);
        for i in 8..16 {
            tasks.push(TaskState::new(NodeSpec::new(i as u32, 1.0), 3));
        }
        let mut rng = Rng::seed_from_u64(1);
        redistribute_for_new_tasks(&mut tasks, &mut rng)
    });

    // --- projection model ---
    let hetero = NodeSpec::heterogeneous(8, 8, 1.5);
    b.bench("projection/makespan_k64_16nodes", || makespan(64, 0.25, &hetero));
    b.bench("projection/micro_iter_time_k64", || {
        microtask_iteration_time(64, 16.0, &hetero)
    });

    // --- per-iteration dispatch overhead: the seed's spawn-per-iteration
    // scheme (spawn + join K threads every iteration) vs one command
    // round-trip through the persistent worker pool. Both run a no-op
    // task body so only the lifecycle/dispatch machinery is timed. ---
    let k = 16usize;
    b.bench("dispatch/spawn_per_iteration_16tasks", || {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..k).map(|i| scope.spawn(move || i)).collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
        })
    });
    let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        CocoaConfig::default(),
        Backend::native_cocoa(),
        1000,
        28,
    ));
    let mut pool = WorkerPool::new(Arc::clone(&algo));
    for i in 0..k {
        // Empty stores: workers take the zero-sample fast path.
        pool.spawn_worker(i as u32, SharedStore::new());
    }
    let model = Arc::new(vec![0.0f32; 28]);
    let plan: Vec<(u32, u64)> = (0..k).map(|i| (i as u32, i as u64)).collect();
    b.bench("dispatch/persistent_pool_16tasks", || {
        pool.run_iteration(&plan, Arc::clone(&model), k, None)
            .unwrap()
            .len()
    });

    b.write_tsv("results/bench_coordinator.tsv").unwrap();

    // The data plane's ≥5× arc-vs-deepcopy claim, enforced on the
    // measured medians — checked *after* the TSV is written, so a noisy
    // runner that trips it still leaves the full artifact for the gate
    // job and its delta table. (The per-row gate alone can't see pair
    // ratios: a baseline re-pin could absorb a regressed clone path.)
    assert!(
        mig_arc * 5 <= mig_deep,
        "zero-copy migration {mig_arc:?} must be ≥5× cheaper than deep-copy {mig_deep:?}"
    );
    assert!(
        snap_arc * 5 <= snap_deep,
        "state-only snapshot {snap_arc:?} must be ≥5× cheaper than deep-copy {snap_deep:?}"
    );

    // Merge-fold kernel speedup, skipped when the SIMD path is not live
    // (feature off or no AVX2 — both pair sides ran the scalar kernel).
    if kernels::simd_active() {
        assert!(
            fold_simd * 3 <= fold_scalar * 2,
            "merge fold SIMD p50 {fold_simd:?} not >=1.5x faster than scalar {fold_scalar:?}"
        );
    }
}
