//! Benchmarks: end-to-end training iterations through the full
//! coordinator stack — one entry per paper-evaluation configuration
//! family (CoCoA rigid/elastic/heterogeneous, lSGD, micro-task
//! emulation). These are the numbers the §Perf optimization loop tracks.

use std::time::Duration;

use chicle::config::{AlgoConfig, ElasticSpec, ModelKind, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::util::bench::Bencher;

fn cocoa_iter_bench(name: &str, cfg_fn: impl Fn() -> SessionConfig, b: &mut Bencher) {
    // Benchmark = construct once, then time per-iteration stepping.
    let ds = synth::higgs_like(16_000, 1);
    let mut session = TrainingSession::new(cfg_fn(), ds).expect(name);
    let mut iter = 0usize;
    b.bench(name, || {
        session.step(iter).unwrap();
        iter += 1;
        iter
    });
}

fn main() {
    let mut b = Bencher::new(Duration::from_secs(3)).with_iters(5, 500);

    // Table/Fig 4 family: rigid & elastic CoCoA.
    cocoa_iter_bench(
        "e2e/cocoa_rigid_16tasks_iter",
        || {
            let mut c = SessionConfig::cocoa("bench", 16);
            c.chunk_bytes = 24 * 1024;
            c.max_iters = usize::MAX;
            c
        },
        &mut b,
    );

    // Fig 5 family: heterogeneous + rebalance.
    cocoa_iter_bench(
        "e2e/cocoa_hetero_rebalance_iter",
        || {
            let mut c = SessionConfig::cocoa("bench", 16);
            c.chunk_bytes = 24 * 1024;
            c.elastic = ElasticSpec::Heterogeneous { fast: 8, slow: 8, factor: 1.5 };
            c.policies.rebalance = true;
            c
        },
        &mut b,
    );

    // Micro-task emulation (K=64) — scheduling-side overhead.
    cocoa_iter_bench(
        "e2e/cocoa_micro64_iter",
        || {
            let mut c = SessionConfig::cocoa("bench", 16).with_microtasks(64);
            c.chunk_bytes = 24 * 1024;
            c
        },
        &mut b,
    );

    // Fig 7 family: lSGD MLP iteration (native backend).
    {
        let ds = synth::fmnist_like(4_000, 2);
        let mut cfg = SessionConfig::lsgd("bench", ModelKind::Mlp, 8);
        cfg.chunk_bytes = 48 * 1024;
        if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
            l.eval_every = usize::MAX; // time pure training iterations
        }
        let mut session = TrainingSession::new(cfg, ds).unwrap();
        let mut iter = 1usize; // skip iter 0 (iter % eval_every == 0)
        b.bench("e2e/lsgd_mlp_8tasks_iter", || {
            session.step(iter).unwrap();
            iter += 1;
            iter
        });
    }

    b.write_tsv("results/bench_e2e.tsv").unwrap();
}
