//! Experiment-harness utilities shared by the `examples/` figure
//! regenerators: result files, table rendering, and scenario presets
//! matching the paper's evaluation setup (§5.1).

use std::path::PathBuf;

use crate::config::{
    AlgoConfig, ElasticSpec, ModelKind, PolicyConfig, SessionConfig, TaskModel,
};
use crate::data::{synth, Dataset};
use crate::metrics::MetricsLog;
use crate::Result;

/// Where figure TSVs land (`results/`).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write a TSV under results/ and echo the path.
pub fn write_tsv(name: &str, content: &str) -> Result<PathBuf> {
    let path = results_dir().join(name);
    std::fs::write(&path, content)?;
    println!("  wrote {}", path.display());
    Ok(path)
}

/// Quick-run mode: `CHICLE_FAST=1` shrinks datasets/iterations so every
/// figure harness finishes in seconds (used by CI and smoke tests).
pub fn fast_mode() -> bool {
    std::env::var("CHICLE_FAST").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Scale a sample count down in fast mode.
pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 8).max(200)
    } else {
        n
    }
}

/// Render an aligned text table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

/// The paper's four workloads (Table 1), synthesized at a scale this
/// testbed trains in minutes. `seed` controls generation.
pub enum Workload {
    HiggsLike,
    CriteoLike,
    CifarLike,
    FmnistLike,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        match self {
            Workload::HiggsLike => "higgs_like",
            Workload::CriteoLike => "criteo_like",
            Workload::CifarLike => "cifar_like",
            Workload::FmnistLike => "fmnist_like",
        }
    }

    /// Default evaluation-scale dataset.
    pub fn dataset(&self, seed: u64) -> Dataset {
        match self {
            Workload::HiggsLike => synth::higgs_like(scaled(24_000), seed),
            Workload::CriteoLike => synth::criteo_like(scaled(24_000), seed),
            Workload::CifarLike => synth::cifar_like(scaled(4_000), seed),
            Workload::FmnistLike => synth::fmnist_like(scaled(6_000), seed),
        }
    }

    /// Session config with the paper's hyper-parameters for this
    /// workload (rigid `nodes`-node cluster; callers override elasticity
    /// and task model).
    pub fn session(&self, name: &str, nodes: usize) -> SessionConfig {
        match self {
            Workload::HiggsLike | Workload::CriteoLike => {
                let mut cfg = SessionConfig::cocoa(name, nodes);
                // Evaluation-scale chunks: plenty of chunks per task.
                cfg.chunk_bytes = 24 * 1024;
                cfg.max_iters = if fast_mode() { 15 } else { 60 };
                cfg
            }
            Workload::CifarLike | Workload::FmnistLike => {
                let model = if matches!(self, Workload::CifarLike) {
                    ModelKind::Cnn
                } else {
                    ModelKind::Mlp
                };
                let mut cfg = SessionConfig::lsgd(name, model, nodes);
                cfg.chunk_bytes = 48 * 1024;
                cfg.max_iters = if fast_mode() { 60 } else { 1200 };
                if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
                    l.lr = if matches!(self, Workload::CifarLike) { 2e-3 } else { 4e-3 };
                    l.eval_every = 10;
                    l.target_acc = if matches!(self, Workload::CifarLike) { 0.62 } else { 0.80 };
                }
                cfg
            }
        }
    }

    /// Epoch horizon for the convergence-curve figures, budgeted for the
    /// 2-core testbed (CNN epochs are ~50× costlier than CoCoA epochs).
    pub fn horizon_epochs(&self) -> f64 {
        let full = match self {
            Workload::HiggsLike | Workload::CriteoLike => 40.0,
            Workload::CifarLike => 12.0,
            Workload::FmnistLike => 20.0,
        };
        if fast_mode() {
            6.0
        } else {
            full
        }
    }

    /// The duality-gap / accuracy target used for "epochs to converge".
    pub fn target(&self) -> f64 {
        match self {
            Workload::HiggsLike => 2e-3,
            Workload::CriteoLike => 1e-2,
            Workload::CifarLike => 0.62,
            Workload::FmnistLike => 0.80,
        }
    }
}

/// A (label, config-mutator) pair describing a task-model variant: the
/// uni-tasks system plus the paper's micro-task emulation points.
pub fn task_model_variants(micro_ks: &[usize]) -> Vec<(String, TaskModel)> {
    let mut v = vec![("uni".to_string(), TaskModel::UniTasks)];
    for &k in micro_ks {
        v.push((format!("micro({k})"), TaskModel::MicroTasks { k }));
    }
    v
}

/// Disable adaptive policies (rigid-framework emulation).
pub fn rigid_policies() -> PolicyConfig {
    PolicyConfig {
        rebalance: false,
        shuffle: false,
        straggler: false,
        ..PolicyConfig::default()
    }
}

/// Summarize a run for comparison tables: epochs/time to target and
/// final metric.
pub fn summarize(log: &MetricsLog, target: f64) -> (String, String, String) {
    let epochs = log
        .epochs_to_target(target)
        .map_or("—".into(), |e| format!("{e:.1}"));
    let time = log
        .time_to_target(target)
        .map_or("—".into(), |t| format!("{:.1}", t.as_secs_f64()));
    let last = log
        .records
        .iter()
        .rev()
        .find_map(|r| r.metric)
        .map_or("—".into(), |m| format!("{:.4}", m.value()));
    (epochs, time, last)
}

/// Convenience: elastic scenarios from the paper (§5.3).
pub fn scale_in_spec() -> ElasticSpec {
    ElasticSpec::Gradual { from: 16, to: 2, interval_s: 20.0 }
}

pub fn scale_out_spec() -> ElasticSpec {
    ElasticSpec::Gradual { from: 2, to: 16, interval_s: 20.0 }
}

/// §5.4 scenario 1: 8 fast + 8 slow (1.5×).
pub fn heterogeneous_spec() -> ElasticSpec {
    ElasticSpec::Heterogeneous { fast: 8, slow: 8, factor: 1.5 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_include_uni_and_micros() {
        let v = task_model_variants(&[16, 64]);
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].0, "uni");
        assert!(matches!(v[2].1, TaskModel::MicroTasks { k: 64 }));
    }

    #[test]
    fn workload_configs_match_paper_params() {
        let cfg = Workload::CifarLike.session("x", 16);
        if let AlgoConfig::Lsgd(l) = &cfg.algo {
            assert_eq!((l.l, l.h, l.momentum), (8, 16, 0.9));
            assert!(l.scale_lr);
        } else {
            panic!();
        }
        let c = Workload::HiggsLike.session("y", 4);
        assert!(matches!(c.algo, AlgoConfig::Cocoa(_)));
    }

    #[test]
    fn summarize_formats() {
        let log = MetricsLog::new();
        let (e, t, l) = summarize(&log, 0.5);
        assert_eq!((e.as_str(), t.as_str(), l.as_str()), ("—", "—", "—"));
    }
}
