//! The persistent uni-task executor.
//!
//! Chicle's core architectural claim is that elasticity should be built on
//! *uni-tasks*: exactly one long-lived, multi-threaded task per node that
//! survives across iterations, with only data (chunks) and roles moving on
//! scaling events (paper §3). This module is that runtime:
//!
//! * [`worker`] — one OS thread per uni-task, spawned once when the node is
//!   assigned and alive until revocation or session end. The thread owns a
//!   handle to the task's [`crate::chunks::SharedStore`] and executes
//!   solver iterations against it.
//! * [`pool`] — the coordinator-side [`WorkerPool`]: spawns workers, routes
//!   commands, and collects completions in a deterministic order.
//!
//! ## Command protocol
//!
//! Each worker is driven by a command channel and answers on its own
//! completion channel (one pair per worker, so collection order is fixed
//! by the coordinator, not by which worker finishes first):
//!
//! | command                                      | reply                |
//! |----------------------------------------------|----------------------|
//! | `RunIteration { model, k_tasks, seed, budget }` | `Iteration(TaskRun)` |
//! | `ReduceShard { model, updates, offset, len, k_tasks }` | `Shard { offset, data }` |
//! | `InstallChunks(chunks)`                      | — (fire and forget)  |
//! | `DrainChunks`                                | `Drained(chunks)`    |
//! | `Shutdown`                                   | — (thread exits)     |
//!
//! The trainer itself moves chunks by writing the task's shared store
//! directly between iterations (the scheduler's ownership window), so
//! `InstallChunks` is the channel-only alternative for coordinators that
//! do not hold a store handle; `DrainChunks`/`Shutdown` are the
//! revocation path either way.
//!
//! The shared model is published to workers as an `Arc<ModelVec>` snapshot
//! per iteration; workers drop their reference before signalling
//! completion, so the driver's `Arc::make_mut` merge never copies.
//!
//! ## Lifecycle under elasticity
//!
//! On a resource-manager `Assigned` event the trainer spawns a worker for
//! the new node; on a `RevokeNotice` it issues `DrainChunks` followed by
//! `Shutdown` — the drained chunks (with their per-sample optimizer state)
//! are redistributed to the survivors, whose compute state is untouched.
//!
//! ## Sharded model reduction
//!
//! The merge phase reuses the same pool: [`WorkerPool::reduce_model`]
//! splits the model into contiguous shards, sends each resident worker one
//! `ReduceShard` command, and reassembles the replies at their fixed
//! offsets. The shard→slot order is a pure function of `(model_len,
//! worker_count)` and `Algorithm::merge_shard` is elementwise, so the
//! merged model is bit-identical to the serial fold for every worker
//! count — including across elastic resizes mid-run.
//!
//! ## Determinism
//!
//! Task execution is deterministic regardless of worker scheduling: each
//! task's RNG stream is keyed by `(seed, task index, iteration)`, chunk
//! stores are only mutated by their own worker during an iteration, and
//! results are merged in task order (sharded reduction preserves this —
//! see above). Two runs with the same seed produce identical `MetricsLog`
//! records (modulo measured wallclock).

pub mod pool;
pub mod worker;

pub use pool::WorkerPool;
pub use worker::{Command, Reply, TaskRun};
