//! The persistent uni-task executor.
//!
//! Chicle's core architectural claim is that elasticity should be built on
//! *uni-tasks*: exactly one long-lived, multi-threaded task per node that
//! survives across iterations, with only data (chunks) and roles moving on
//! scaling events (paper §3). This module is that runtime:
//!
//! * [`worker`] — one OS thread per worker, spawned once when the node is
//!   assigned and alive until revocation or session end. The thread owns a
//!   *set* of logical-task contexts — each a handle to that task's
//!   [`crate::chunks::SharedStore`] — and executes solver iterations
//!   against them round-robin in slot order. The legacy coupling is the
//!   one-context case (the logical task index is the node id); the
//!   decoupled schedule multiplexes K logical tasks over W ≤ K threads,
//!   with `InstallTask`/`RevokeTask` rebinding tasks between threads.
//! * [`pool`] — the coordinator-side [`WorkerPool`]: spawns workers, routes
//!   commands, and collects completions in a deterministic order.
//! * [`reduce`] — the work-stealing sharded-reduction primitives: the
//!   shard claim queue, the shared output buffer, and the pending-model
//!   reference that lets the next iteration's dispatch overlap an
//!   in-flight merge.
//!
//! ## Command protocol
//!
//! Each worker is driven by a command channel and answers on its own
//! completion channel (one pair per worker, so collection order is fixed
//! by the coordinator, not by which worker finishes first):
//!
//! | command                                      | reply                |
//! |----------------------------------------------|----------------------|
//! | `RunIteration { model: ModelRef, k_tasks, slots, budget }` | `Iteration(Vec<TaskRun>)` |
//! | `ReduceShards { model, updates, queue, buf, slot, k_tasks }` | `ShardsDone { shards, steals }` |
//! | `Allreduce { model, parts, k_tasks, order, epoch, iter, kind }` | `AllreduceDone(AllreduceRun)` |
//! | `InstallTask { task, store }`                | — (fire and forget)  |
//! | `RevokeTask { task }`                        | — (fire and forget)  |
//! | `SetReduceSlowdown(ns_per_elem)`             | — (fire and forget)  |
//! | `InstallChunks(chunks)`                      | — (fire and forget)  |
//! | `DrainChunks`                                | `Drained(chunks)`    |
//! | `Shutdown`                                   | — (thread exits)     |
//!
//! The trainer itself moves chunks by writing the task's shared store
//! directly between iterations (the scheduler's ownership window), so
//! `InstallChunks` is the channel-only alternative for coordinators that
//! do not hold a store handle; `DrainChunks`/`Shutdown` are the
//! revocation path either way.
//!
//! ## Work-stealing sharded reduction
//!
//! The merge phase reuses the same pool: [`WorkerPool::begin_reduce`]
//! tiles the model into `S ≫ workers` small shards with fixed offsets and
//! hands every worker one `ReduceShards` command over a shared
//! [`ShardQueue`]. Workers claim shards — own block first, then stealing
//! from the others' remainders — and write merged shards straight into
//! the shared [`ReduceBuf`], so a straggling worker holds the barrier up
//! by at most one small shard. Shard geometry is a pure function of
//! `(model_len, shard_count)` and `Algorithm::merge_shard` is
//! elementwise, so the merged model is bit-identical to the serial fold
//! for every worker count, shard count, and claim interleaving —
//! including across elastic resizes mid-run, and even a revoke *during*
//! an in-flight reduction (the revoked worker finishes its claims before
//! draining; its completion is stashed for `collect_reduce`).
//!
//! The *granularity* of the tiling can adapt at runtime: with
//! [`WorkerPool::enable_adaptive_spw`], every collected reduction feeds
//! its observed steal count into a clamped feedback controller
//! ([`SpwController`]) that widens `shards_per_worker` while a straggler
//! is shedding work (heavy stealing) and narrows it when the pool is
//! balanced (zero steals — queue overhead is then pure cost). The block
//! *layout* adapts too: the queue attributes every steal to the block
//! owner it was taken from, and the worker that lost the most shards —
//! the straggler — is handed the smallest fixed-offset block of the next
//! reduction ([`WorkerPool::steal_victim`]), so fast workers start with
//! the oversized blocks instead of winning them one steal at a time.
//! Because geometry never affects the merged bits, both adaptations are
//! invisible to the trajectory.
//!
//! ## Peer-to-peer merge collectives
//!
//! `SessionConfig::merge_strategy` can swap the coordinator-side sharded
//! reduction for a transport-level collective: [`WorkerPool::begin_allreduce`]
//! hands every rank its *own* `(task_idx, update)` parts — one per hosted
//! logical task — and the rank order, and the workers run ring- or
//! tree-allreduce among themselves over their [`crate::transport`]
//! endpoints (joined at spawn, left at thread exit).
//! The ring's segments reuse the fixed-offset geometry above and each
//! segment's owner folds all `k_tasks` update slices in task order, so the
//! collective result is bit-identical to the serial fold too — the same
//! invariant, a different wire. Collectives are barriered (every rank
//! both sends and receives), so the reduce/dispatch overlap below applies
//! only to the default coordinator strategy. A mid-collective revoke is
//! safe the same way a mid-reduce one is: FIFO ordering makes the revoked
//! rank finish the collective its peers are blocked on before draining,
//! and its completion is stashed for [`WorkerPool::collect_allreduce`].
//!
//! ## Reduce/dispatch overlap
//!
//! `RunIteration` takes a [`ModelRef`]: either a ready snapshot or the
//! [`ReduceBuf`] of a reduction still in flight. The coordinator can
//! therefore enqueue iteration *i+1* right behind iteration *i*'s
//! `ReduceShards` — each worker finishes its share of the merge, then
//! blocks on the buffer's remaining-shards counter and starts computing
//! the instant the last shard lands, with no coordinator round-trip on
//! the critical path. The trainer uses this to hide its bookkeeping
//! (accounting, swimlanes, logging) behind the merge+compute pipeline —
//! and, at evaluation points, to run the convergence metric on the
//! coordinator against the completed buffer (plus a pre-dispatch chunk
//! snapshot) while the workers are already computing the next iteration
//! (see `coordinator::trainer`'s eval-spanning overlap).
//!
//! ## Lifecycle under elasticity
//!
//! On a resource-manager `Assigned` event the trainer spawns a worker for
//! the new node; on a `RevokeNotice` it issues `DrainChunks` followed by
//! `Shutdown` — the drained chunks (with their per-sample optimizer state)
//! are redistributed to the survivors, whose compute state is untouched.
//!
//! ## Determinism
//!
//! Task execution is deterministic regardless of worker scheduling: each
//! task's RNG stream is keyed by `(seed, task index, iteration)` via its
//! slot — never by the hosting thread — chunk stores are only mutated by
//! their own worker during an iteration, and results are merged in task
//! order (sharded stealing reduction preserves this — see above). Two
//! runs with the same seed produce identical `MetricsLog` records (modulo
//! measured wallclock), with or without the overlap pipeline — and, under
//! the decoupled schedule, for any worker-thread count `1 ≤ W ≤ K`
//! (`tests/logical_tasks.rs` pins the W-sweep bit-for-bit).

pub mod pool;
pub mod reduce;
pub mod worker;

pub use pool::{AllreduceOutcome, PendingAllreduce, PendingIteration, PendingReduce, WorkerPool};
pub use reduce::{
    ModelRef, ReduceBuf, ReduceOptions, ReduceStats, ShardQueue, SpwController, SPW_MAX, SPW_MIN,
};
pub use worker::{Command, Reply, TaskRun, TaskSlot};
