//! Work-stealing multi-shard reduction primitives.
//!
//! The merge phase splits the model into `S >> workers` small shards with
//! *fixed* offsets (shard `i` always covers `[i·per, min((i+1)·per, len))`),
//! so the reassembled model is bit-identical to the serial fold no matter
//! which worker reduces which shard — `Algorithm::merge_shard` is
//! elementwise. What stealing changes is only *who* does the work: each
//! worker owns a contiguous block of shard indices and, when its block
//! drains, pulls from other workers' remainders. A straggling worker
//! therefore holds the barrier up by at most one small shard instead of
//! `len / workers` elements (Chicle §4's load-balancing argument applied
//! to the reduction itself).
//!
//! Three pieces:
//!
//! * [`ShardQueue`] — the shared claim structure: per-worker atomic
//!   cursors over disjoint blocks of shard indices. `claim` pops from the
//!   worker's own block first, then scans the other blocks (a steal).
//! * [`ReduceBuf`] — the shared output buffer. Workers write their merged
//!   shards directly at the shard's fixed offset (ranges are disjoint by
//!   construction) and decrement a remaining-shards counter with release
//!   ordering; a reader that observes zero with acquire ordering sees
//!   every shard's bytes. This is what lets the *next* iteration start on
//!   a worker the instant the last shard lands, without a coordinator
//!   round-trip.
//! * [`ModelRef`] — the model argument of `RunIteration`: either a ready
//!   snapshot (`Arc<ModelVec>`) or a pending [`ReduceBuf`] that the worker
//!   blocks on before computing. This is the reduce/dispatch overlap: the
//!   coordinator may enqueue iteration *i+1* while iteration *i*'s merge
//!   is still in flight.
//!
//! # The fixed-offset geometry invariant
//!
//! This is the canonical statement of the invariant every parallel merge
//! in the system is built on. Tile a `model_len`-element model into `n`
//! ranges with `per = ⌈model_len / n⌉`; range `i` covers exactly
//! `[i·per, min((i+1)·per, model_len))`. The geometry is a **pure
//! function of `(model_len, n)`** — independent of worker count, claim
//! order, stealing, block layout, or OS scheduling — and
//! [`crate::algos::Algorithm::merge_shard`] is elementwise with updates
//! folded in task order, so merging each range independently and
//! reassembling at the same offsets is bit-identical to the serial fold.
//!
//! Two consumers share the invariant: [`ShardQueue::shard_range`] here
//! (at `n = shards_per_worker × workers`, granularity a free tuning
//! knob) and the transport layer's ring-allreduce segments
//! ([`crate::transport::segment_range`], pinned at exactly `n = k` ranks
//! so every rank owns one segment). A new consumer of model tiling
//! should define its ranges in these terms rather than invent a second
//! geometry — the property tests (`tests/prop_merge_equivalence.rs`,
//! `tests/transport_allreduce.rs`) all lean on this one definition.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::algos::ModelVec;

/// Default shard granularity: the single source of truth shared by
/// [`ReduceOptions::default`] and `SessionConfig`'s constructors/JSON
/// fallback, so a round-tripped legacy config trains with the same
/// reduction geometry as a freshly constructed one.
pub const DEFAULT_SHARDS_PER_WORKER: usize = 8;

/// Lower clamp of the adaptive shards-per-worker controller: one shard
/// per worker is the fixed static assignment — the cheapest possible
/// queue traffic.
pub const SPW_MIN: usize = 1;

/// Upper clamp of the adaptive shards-per-worker controller. Beyond this
/// the per-shard claim/dispatch overhead dominates any straggler
/// insurance the finer granules buy.
pub const SPW_MAX: usize = 64;

/// Consecutive zero-steal reductions the controller waits for before
/// narrowing the granularity (hysteresis: one calm iteration is not
/// evidence the straggler is gone).
const SPW_CALM_ROUNDS: u32 = 2;

/// Feedback controller for the reduction's shard granularity
/// (`shards_per_worker`), fed by each reduction's observed steal count.
///
/// The trade-off it walks: *finer* shards (higher `spw`) shrink the
/// granule a straggler can hold the barrier on, but cost more claim/queue
/// traffic; *coarser* shards minimize overhead when the pool is balanced.
/// The steal count is a direct signal for which regime the pool is in —
/// heavy stealing means fast workers are draining a straggler's block,
/// zero stealing means every worker finished its own block unassisted:
///
/// * `steals ≥ workers` (on average every worker stole — a straggler is
///   shedding a whole block's worth of work) → **widen**: double `spw`.
/// * `steals == 0` for `SPW_CALM_ROUNDS` consecutive reductions (the
///   pool is balanced; the queue overhead is pure cost) → **narrow**:
///   halve `spw`.
/// * anything in between → hold.
///
/// Always clamped to `[SPW_MIN, SPW_MAX]` (or the clamps given to
/// [`SpwController::with_clamps`]). The controller only ever changes the
/// *granularity* of the reduction, never its result: shard geometry is a
/// pure function of `(model_len, shard count)` and the merge rule is
/// elementwise, so every `spw` value produces bit-identical merged
/// models (`tests/prop_merge_equivalence.rs` pins this). Steal counts
/// are scheduling-dependent, so the `spw` trajectory may differ between
/// runs — which is exactly why it must (and does) stay out of virtual
/// time and the iterate trajectory.
#[derive(Clone, Copy, Debug)]
pub struct SpwController {
    spw: usize,
    lo: usize,
    hi: usize,
    calm: u32,
}

impl SpwController {
    /// A controller starting at `start`, clamped to `[SPW_MIN, SPW_MAX]`.
    pub fn new(start: usize) -> Self {
        Self::with_clamps(start, SPW_MIN, SPW_MAX)
    }

    /// A controller with explicit clamps (`lo` is raised to at least 1;
    /// `hi` to at least `lo`).
    pub fn with_clamps(start: usize, lo: usize, hi: usize) -> Self {
        let lo = lo.max(1);
        let hi = hi.max(lo);
        SpwController { spw: start.clamp(lo, hi), lo, hi, calm: 0 }
    }

    /// The granularity the next reduction should use.
    pub fn current(&self) -> usize {
        self.spw
    }

    /// Feed one completed reduction's outcome into the controller.
    /// Deterministic: the `spw` trajectory is a pure function of the
    /// observation sequence.
    pub fn observe(&mut self, steals: usize, workers: usize) {
        if workers < 2 {
            // A single-worker reduction can neither steal nor straggle
            // against itself; no signal.
            return;
        }
        if steals >= workers {
            self.spw = (self.spw * 2).min(self.hi);
            self.calm = 0;
        } else if steals == 0 {
            self.calm += 1;
            if self.calm >= SPW_CALM_ROUNDS {
                self.spw = (self.spw / 2).max(self.lo);
                self.calm = 0;
            }
        } else {
            self.calm = 0;
        }
    }
}

/// Tuning knobs for one sharded reduction.
#[derive(Clone, Copy, Debug)]
pub struct ReduceOptions {
    /// Target shards per worker. 1 reproduces the fixed one-shard-per-
    /// worker assignment of PR 2; larger values shrink the granule a
    /// straggler can hold the barrier on.
    pub shards_per_worker: usize,
    /// Whether a worker whose own block drained may claim shards from
    /// other workers' blocks. Off = the fixed static assignment (useful
    /// as a baseline in benches; the trainer always steals).
    pub stealing: bool,
}

impl Default for ReduceOptions {
    fn default() -> Self {
        ReduceOptions { shards_per_worker: DEFAULT_SHARDS_PER_WORKER, stealing: true }
    }
}

/// Aggregate outcome of one sharded reduction.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReduceStats {
    /// Shards reduced in total (== the queue's shard count on success).
    pub shards: usize,
    /// Shards a worker claimed from another worker's block.
    pub steals: usize,
    /// Workers that participated.
    pub workers: usize,
}

/// Distribute the `extra` oversized (base + 1) blocks over the workers,
/// skipping `small_slot` — the steal-aware layout. With `small_slot =
/// None` this reproduces the historical layout (the first `extra` slots
/// get the oversized blocks); with a slot set, that worker is guaranteed
/// the *smallest* block the geometry allows, so a straggler that was
/// stolen from last round starts the next reduction with the least work.
/// Only the partition of shard *indices* into blocks changes — shard
/// offsets stay a pure function of `(model_len, shard count)`, so the
/// merged bits are untouched whatever the layout.
fn block_sizes(n_shards: usize, n_workers: usize, small_slot: Option<usize>) -> Vec<usize> {
    let base = n_shards / n_workers;
    let extra = n_shards % n_workers;
    let mut sizes = vec![base; n_workers];
    // `extra < n_workers` always, and at most one slot is skipped, so the
    // ring walk below always finds enough slots to take the `+1`s.
    let skip = small_slot.filter(|s| *s < n_workers && n_workers > 1);
    let mut given = 0usize;
    let mut w = 0usize;
    while given < extra {
        let idx = w % n_workers;
        w += 1;
        if Some(idx) == skip {
            continue;
        }
        sizes[idx] += 1;
        given += 1;
    }
    sizes
}

/// The shared shard-claim queue for one reduction.
///
/// Shard geometry is a pure function of `(model_len, n_shards)` and never
/// depends on which worker claims what, so any claim order yields the
/// same set of `(offset, len)` ranges — the determinism invariant. The
/// block *layout* (which worker starts on which shard indices) may vary
/// — e.g. the steal-aware layout hands a known straggler the smallest
/// block — without touching that invariant.
pub struct ShardQueue {
    model_len: usize,
    /// Fixed shard length (last shard may be shorter).
    per: usize,
    n_shards: usize,
    stealing: bool,
    /// Per-worker block `[block_start[w], block_start[w+1])` of shard
    /// indices; `cursors[w]` is the next unclaimed index in block `w`.
    /// `fetch_add` makes every claim unique even under contention.
    block_start: Vec<usize>,
    cursors: Vec<AtomicUsize>,
    /// Steals suffered per block owner: `stolen_from[v]` counts shards of
    /// block `v` claimed by some other worker. The victim with the most
    /// losses is the straggler the next layout shrinks.
    stolen_from: Vec<AtomicUsize>,
}

impl ShardQueue {
    /// Lay out `~shards_per_worker × n_workers` fixed-offset shards over a
    /// `model_len`-element model, split into `n_workers` contiguous blocks
    /// of shard indices (historical near-equal layout).
    pub fn new(model_len: usize, n_workers: usize, opts: ReduceOptions) -> Self {
        Self::new_with_layout(model_len, n_workers, opts, None)
    }

    /// Like [`ShardQueue::new`], but hand worker `small_slot` the smallest
    /// block (steal-aware layout for a known straggler).
    pub fn new_with_layout(
        model_len: usize,
        n_workers: usize,
        opts: ReduceOptions,
        small_slot: Option<usize>,
    ) -> Self {
        assert!(n_workers > 0 && model_len > 0);
        let target = (n_workers * opts.shards_per_worker.max(1)).min(model_len);
        let per = model_len.div_ceil(target);
        let n_shards = model_len.div_ceil(per);
        let sizes = block_sizes(n_shards, n_workers, small_slot);
        let mut block_start = Vec::with_capacity(n_workers + 1);
        let mut at = 0usize;
        for &sz in &sizes {
            block_start.push(at);
            at += sz;
        }
        block_start.push(at);
        debug_assert_eq!(at, n_shards);
        let cursors = block_start[..n_workers]
            .iter()
            .map(|&s| AtomicUsize::new(s))
            .collect();
        ShardQueue {
            model_len,
            per,
            n_shards,
            stealing: opts.stealing,
            block_start,
            cursors,
            stolen_from: (0..n_workers).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Shards lost per block owner this reduction (index = worker slot).
    /// Scheduling-dependent — like steal counts, this may only influence
    /// *who* does future work (the steal-aware layout), never virtual
    /// time or the merged bits.
    pub fn stolen_from(&self) -> Vec<usize> {
        self.stolen_from
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Number of shard indices in worker `slot`'s own block.
    pub fn block_len(&self, slot: usize) -> usize {
        self.block_start[slot + 1] - self.block_start[slot]
    }

    /// Fixed `(offset, len)` range of shard `idx` — an instance of the
    /// [fixed-offset geometry invariant](self#the-fixed-offset-geometry-invariant):
    /// a pure function of `(model_len, n_shards)`, never of who claims
    /// the shard. [`crate::transport::segment_range`] computes the same
    /// ranges at one shard per rank.
    pub fn shard_range(&self, idx: usize) -> (usize, usize) {
        let offset = idx * self.per;
        (offset, self.per.min(self.model_len - offset))
    }

    /// Claim the next shard for worker `slot`: own block first, then (if
    /// stealing) the other blocks in ring order. Returns the shard index
    /// and whether the claim was a steal. Every shard index is handed out
    /// exactly once across all workers.
    pub fn claim(&self, slot: usize) -> Option<(usize, bool)> {
        let w = self.cursors.len();
        for k in 0..w {
            let v = (slot + k) % w;
            if k > 0 && !self.stealing {
                break;
            }
            let end = self.block_start[v + 1];
            // Monotonic cursor: a fetch_add past `end` wastes nothing but
            // the increment — the claim is simply not ours.
            if self.cursors[v].load(Ordering::Relaxed) < end {
                let idx = self.cursors[v].fetch_add(1, Ordering::Relaxed);
                if idx < end {
                    if k > 0 {
                        // Block `v`'s owner lost this shard to a thief —
                        // the signal the steal-aware layout feeds on.
                        self.stolen_from[v].fetch_add(1, Ordering::Relaxed);
                    }
                    return Some((idx, k > 0));
                }
            }
        }
        None
    }
}

/// The shared output buffer of one in-flight reduction.
///
/// Workers write disjoint shard ranges (disjointness is guaranteed by the
/// queue handing out each shard index exactly once) and count shards down
/// with `Release`; `wait`/`complete` observe zero with `Acquire`, which
/// makes every shard's bytes visible to the reader. `poison` unblocks
/// waiters when a reduction is abandoned on an error path.
pub struct ReduceBuf {
    data: UnsafeCell<ModelVec>,
    /// Base pointer of `data`, captured at construction (the vector is
    /// never resized). Writers go through this raw pointer so no `&mut`
    /// to the vector is ever formed while other writers are live.
    base: *mut f32,
    len: usize,
    remaining: AtomicUsize,
    poisoned: AtomicBool,
}

// SAFETY: the only mutable accesses are `write_shard` raw-pointer writes
// over disjoint ranges before `remaining` reaches zero; shared reads only
// happen after an Acquire load observes zero (or never, if poisoned).
unsafe impl Sync for ReduceBuf {}
unsafe impl Send for ReduceBuf {}

impl ReduceBuf {
    pub fn new(model_len: usize, n_shards: usize) -> Self {
        let mut data = vec![0.0f32; model_len];
        let base = data.as_mut_ptr();
        ReduceBuf {
            data: UnsafeCell::new(data),
            base,
            len: model_len,
            remaining: AtomicUsize::new(n_shards),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Write one merged shard at its fixed offset and retire it.
    ///
    /// Must be called at most once per claimed shard index; the caller
    /// (the worker loop) gets each index from [`ShardQueue::claim`], which
    /// hands every index out exactly once — so concurrent writes cover
    /// disjoint ranges.
    pub fn write_shard(&self, offset: usize, shard: &[f32]) {
        assert!(offset + shard.len() <= self.len, "shard out of bounds");
        // SAFETY: in-bounds (asserted), ranges from distinct claims are
        // disjoint, writes go through the raw base pointer (no aliasing
        // `&mut`), and no reader exists until `remaining` hits zero
        // (Release below / Acquire in the readers).
        unsafe {
            std::ptr::copy_nonoverlapping(shard.as_ptr(), self.base.add(offset), shard.len());
        }
        let prev = self.remaining.fetch_sub(1, Ordering::Release);
        debug_assert!(prev > 0, "more shards written than scheduled");
    }

    /// All shards written (Acquire: the caller may now read the model).
    pub fn complete(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    /// Unblock any waiter without completing (error paths only).
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    /// Block until the reduction completes; `None` if it was poisoned.
    /// Spin-then-yield: the tail of a reduction is microseconds away in
    /// the common case, so parking machinery would only add latency.
    pub fn wait(&self) -> Option<&ModelVec> {
        let mut spins = 0u32;
        loop {
            if self.complete() {
                // SAFETY: remaining == 0 (Acquire) ⇒ all writers done.
                return Some(unsafe { &*self.data.get() });
            }
            if self.poisoned.load(Ordering::Acquire) {
                return None;
            }
            spins += 1;
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }

    /// Extract the merged model. Zero-copy when this is the last handle
    /// (the usual case: workers drop theirs before replying), otherwise a
    /// clone. Panics if the reduction has not completed.
    pub fn into_model(self: Arc<Self>) -> ModelVec {
        assert!(self.complete(), "reduction still in flight");
        match Arc::try_unwrap(self) {
            Ok(buf) => buf.data.into_inner(),
            // SAFETY: complete ⇒ no writers remain; concurrent readers
            // (workers iterating on the merged model) are fine.
            Err(arc) => unsafe { (*arc.data.get()).clone() },
        }
    }
}

/// The model input of a `RunIteration` command: a ready snapshot, or the
/// output buffer of a reduction still in flight (the overlap path).
#[derive(Clone)]
pub enum ModelRef {
    Ready(Arc<ModelVec>),
    Pending(Arc<ReduceBuf>),
}

impl ModelRef {
    /// Resolve to the model, blocking on a pending reduction. `None` if a
    /// pending reduction was poisoned.
    pub fn wait(&self) -> Option<&ModelVec> {
        match self {
            ModelRef::Ready(m) => Some(m),
            ModelRef::Pending(buf) => buf.wait(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_geometry_is_fixed_and_covering() {
        let q = ShardQueue::new(1000, 3, ReduceOptions { shards_per_worker: 4, stealing: true });
        // Shards tile [0, 1000) exactly, in index order.
        let mut at = 0;
        for i in 0..q.n_shards() {
            let (offset, len) = q.shard_range(i);
            assert_eq!(offset, at);
            assert!(len > 0);
            at += len;
        }
        assert_eq!(at, 1000);
    }

    #[test]
    fn claims_hand_out_every_shard_exactly_once() {
        for stealing in [false, true] {
            let q = ShardQueue::new(997, 4, ReduceOptions { shards_per_worker: 4, stealing });
            let mut seen = vec![false; q.n_shards()];
            for slot in 0..4 {
                while let Some((idx, _)) = q.claim(slot) {
                    assert!(!seen[idx], "shard {idx} claimed twice");
                    seen[idx] = true;
                }
            }
            // Without stealing each worker drains only its own block, but
            // all blocks together still cover every shard.
            assert!(seen.iter().all(|&s| s), "stealing={stealing}");
        }
    }

    #[test]
    fn stealing_lets_one_worker_drain_everything() {
        let q = ShardQueue::new(100, 4, ReduceOptions { shards_per_worker: 2, stealing: true });
        let mut claimed = 0;
        let mut steals = 0;
        while let Some((_, stolen)) = q.claim(2) {
            claimed += 1;
            steals += usize::from(stolen);
        }
        assert_eq!(claimed, q.n_shards());
        assert!(steals > 0, "claims outside slot 2's block are steals");
    }

    #[test]
    fn more_workers_than_elements_degrades_gracefully() {
        let q = ShardQueue::new(3, 8, ReduceOptions::default());
        assert_eq!(q.n_shards(), 3);
        let total: usize = (0..8)
            .map(|s| {
                let mut n = 0;
                while q.claim(s).is_some() {
                    n += 1;
                }
                n
            })
            .sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn steal_aware_layout_never_changes_coverage_or_geometry() {
        // Whatever slot gets the small block, every shard is still handed
        // out exactly once and every shard's (offset, len) is identical to
        // the default layout's — the bit-identity precondition.
        for (len, w, spw) in [(997usize, 4usize, 4usize), (1000, 3, 1), (5, 8, 2), (64, 2, 16)] {
            let opts = ReduceOptions { shards_per_worker: spw, stealing: true };
            let reference = ShardQueue::new(len, w, opts);
            for small in std::iter::once(None).chain((0..w).map(Some)) {
                let q = ShardQueue::new_with_layout(len, w, opts, small);
                assert_eq!(q.n_shards(), reference.n_shards(), "len={len} w={w}");
                for i in 0..q.n_shards() {
                    assert_eq!(q.shard_range(i), reference.shard_range(i), "shard {i}");
                }
                let mut seen = vec![false; q.n_shards()];
                for slot in 0..w {
                    while let Some((idx, _)) = q.claim(slot) {
                        assert!(!seen[idx], "shard {idx} claimed twice (small={small:?})");
                        seen[idx] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "coverage hole (small={small:?})");
                // Block sizes always sum to the shard count.
                let total: usize = (0..w).map(|s| q.block_len(s)).sum();
                assert_eq!(total, q.n_shards());
            }
        }
    }

    #[test]
    fn victim_slot_gets_floor_sized_block() {
        // 13 shards over 4 workers: base 3, extra 1. The victim must get
        // the floor size; some other slot absorbs the +1.
        let opts = ReduceOptions { shards_per_worker: 1, stealing: true };
        for victim in 0..4usize {
            let q = ShardQueue::new_with_layout(13, 4, opts, Some(victim));
            assert_eq!(q.block_len(victim), 3, "victim {victim} must get the floor");
            let max = (0..4).map(|s| q.block_len(s)).max().unwrap();
            assert_eq!(max, 4, "someone else takes the oversized block");
        }
        // Without a victim, the historical layout: first slot oversized.
        let q = ShardQueue::new_with_layout(13, 4, opts, None);
        assert_eq!(q.block_len(0), 4);
        assert_eq!(q.block_len(3), 3);
    }

    #[test]
    fn stolen_from_attributes_losses_to_block_owners() {
        let q = ShardQueue::new(100, 4, ReduceOptions { shards_per_worker: 2, stealing: true });
        // Slot 2 drains everything: its own block first (no steal), then
        // the other three blocks (all steals, attributed to their owners).
        while q.claim(2).is_some() {}
        let losses = q.stolen_from();
        assert_eq!(losses[2], 0, "own-block claims are not steals");
        let total_lost: usize = losses.iter().sum();
        assert_eq!(
            total_lost,
            q.n_shards() - q.block_len(2),
            "every foreign shard is attributed to its block owner"
        );
        assert!(losses.iter().enumerate().all(|(s, &l)| s == 2 || l == q.block_len(s)));
    }

    #[test]
    fn buf_completes_after_all_shards() {
        let buf = ReduceBuf::new(10, 2);
        assert!(!buf.complete());
        buf.write_shard(0, &[1.0; 5]);
        assert!(!buf.complete());
        buf.write_shard(5, &[2.0; 5]);
        assert!(buf.complete());
        let model = Arc::new(buf).into_model();
        assert_eq!(&model[..5], &[1.0; 5]);
        assert_eq!(&model[5..], &[2.0; 5]);
    }

    #[test]
    fn spw_controller_widens_under_stealing_and_narrows_when_calm() {
        // A deterministic synthetic steal sequence: a straggler appears
        // (heavy stealing), then disappears (calm). The controller must
        // ride up to the upper clamp and back down to the lower clamp,
        // never leaving [SPW_MIN, SPW_MAX].
        let mut c = SpwController::new(DEFAULT_SHARDS_PER_WORKER);
        assert_eq!(c.current(), DEFAULT_SHARDS_PER_WORKER);
        let workers = 4;
        // Heavy stealing: doubles per observation, clamped at SPW_MAX.
        let mut seen = vec![c.current()];
        for _ in 0..6 {
            c.observe(workers, workers); // steals == workers → widen
            assert!(c.current() >= SPW_MIN && c.current() <= SPW_MAX);
            seen.push(c.current());
        }
        assert_eq!(c.current(), SPW_MAX, "heavy stealing must reach the clamp");
        assert!(seen.windows(2).all(|w| w[1] >= w[0]), "monotone on the way up");
        // Calm: narrows only after SPW_CALM_ROUNDS consecutive zeros.
        c.observe(0, workers);
        assert_eq!(c.current(), SPW_MAX, "one calm round is not enough");
        c.observe(0, workers);
        assert_eq!(c.current(), SPW_MAX / 2, "second calm round halves");
        // A lone steal burst resets the calm streak without widening.
        c.observe(1, workers);
        c.observe(0, workers);
        assert_eq!(c.current(), SPW_MAX / 2, "streak was reset");
        // Sustained calm rides all the way down to the lower clamp.
        for _ in 0..20 {
            c.observe(0, workers);
            assert!(c.current() >= SPW_MIN && c.current() <= SPW_MAX);
        }
        assert_eq!(c.current(), SPW_MIN, "sustained calm must reach the floor");
    }

    #[test]
    fn spw_controller_ignores_single_worker_pools_and_respects_clamps() {
        let mut c = SpwController::with_clamps(100, 2, 32);
        assert_eq!(c.current(), 32, "start is clamped into range");
        c.observe(8, 1); // single worker: no signal
        assert_eq!(c.current(), 32);
        let mut c = SpwController::with_clamps(0, 0, 0);
        assert_eq!(c.current(), 1, "degenerate clamps collapse to [1, 1]");
        c.observe(10, 4);
        assert_eq!(c.current(), 1);
    }

    #[test]
    fn poisoned_buf_unblocks_waiters() {
        let buf = Arc::new(ReduceBuf::new(4, 1));
        let r = ModelRef::Pending(Arc::clone(&buf));
        buf.poison();
        assert!(r.wait().is_none());
    }
}
