//! The uni-task worker loop: one persistent thread per task.
//!
//! A worker is spawned once (node assignment or session start) and then
//! processes [`Command`]s until `Shutdown` or channel disconnect. It holds
//! a clone of the task's [`SharedStore`] and locks it only while running
//! an iteration — the ownership window the coordinator grants it.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, SharedStore};

/// Commands the coordinator sends a uni-task worker.
pub enum Command {
    /// Run one solver iteration against the published model snapshot.
    RunIteration {
        model: Arc<ModelVec>,
        k_tasks: usize,
        seed: u64,
        budget: Option<usize>,
    },
    /// Reduce one contiguous model shard: fold `updates[..]` restricted to
    /// `offset .. offset + len` into that slice of the model snapshot and
    /// reply with the merged values. The pool guarantees the range is in
    /// bounds for the model and every update delta.
    ReduceShard {
        model: Arc<ModelVec>,
        updates: Arc<Vec<LocalUpdate>>,
        offset: usize,
        len: usize,
        k_tasks: usize,
    },
    /// Add chunks to the worker's store over the channel. The trainer
    /// installs chunks by writing the shared store directly between
    /// iterations; this command serves coordinators without a store
    /// handle.
    InstallChunks(Vec<Chunk>),
    /// Hand every local chunk back to the coordinator (revocation drain).
    DrainChunks,
    /// Exit the worker loop.
    Shutdown,
}

/// Replies a worker sends on its completion channel.
pub enum Reply {
    Iteration(Result<TaskRun>),
    /// One reduced model shard: the merged values for
    /// `model[offset .. offset + data.len()]`.
    Shard { offset: usize, data: Vec<f32> },
    Drained(Vec<Chunk>),
}

/// One completed task iteration.
#[derive(Clone, Debug)]
pub struct TaskRun {
    pub update: LocalUpdate,
    /// Wallclock compute time of the task body.
    pub wall: Duration,
}

/// The long-lived worker loop (runs on the worker's own thread).
pub(crate) fn worker_loop(
    algo: Arc<dyn Algorithm>,
    store: SharedStore,
    commands: Receiver<Command>,
    replies: Sender<Reply>,
) {
    while let Ok(cmd) = commands.recv() {
        match cmd {
            Command::RunIteration { model, k_tasks, seed, budget } => {
                let result = run_iteration(algo.as_ref(), &store, &model, k_tasks, seed, budget);
                // Release the model snapshot before signalling completion so
                // the driver's Arc::make_mut merge never needs a copy.
                drop(model);
                if replies.send(Reply::Iteration(result)).is_err() {
                    break;
                }
            }
            Command::ReduceShard { model, updates, offset, len, k_tasks } => {
                let mut data = model[offset..offset + len].to_vec();
                algo.merge_shard(&mut data, offset, &updates, k_tasks);
                // Release both snapshots before signalling completion so no
                // worker-side reference outlives the merge phase.
                drop(model);
                drop(updates);
                if replies.send(Reply::Shard { offset, data }).is_err() {
                    break;
                }
            }
            Command::InstallChunks(chunks) => {
                let mut store = store.lock();
                for chunk in chunks {
                    store.add(chunk);
                }
            }
            Command::DrainChunks => {
                let drained = store.lock().drain();
                if replies.send(Reply::Drained(drained)).is_err() {
                    break;
                }
            }
            Command::Shutdown => break,
        }
    }
}

fn run_iteration(
    algo: &dyn Algorithm,
    store: &SharedStore,
    model: &ModelVec,
    k_tasks: usize,
    seed: u64,
    budget: Option<usize>,
) -> Result<TaskRun> {
    let mut store = store.lock();
    if store.n_samples() == 0 {
        // A task without chunks contributes a zero update (it can receive
        // chunks next boundary — e.g. a freshly assigned node).
        return Ok(TaskRun {
            update: LocalUpdate {
                delta: vec![0.0; algo.model_len()],
                samples: 0,
                loss_sum: 0.0,
            },
            wall: Duration::ZERO,
        });
    }
    let t0 = Instant::now();
    let update = algo.task_iterate(store.chunks_mut(), model, k_tasks, seed, budget)?;
    Ok(TaskRun { update, wall: t0.elapsed() })
}
