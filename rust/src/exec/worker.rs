//! The worker loop: one persistent thread hosting a *set* of logical
//! uni-task contexts.
//!
//! A worker is spawned once (node assignment or session start) and then
//! processes [`Command`]s until `Shutdown` or channel disconnect. It holds
//! the contexts of the logical tasks currently bound to it — each context
//! is a `(task index, SharedStore)` pair — and runs them round-robin
//! within an iteration, locking each store only while running that task's
//! body. In the legacy one-task-per-thread schedule a worker hosts
//! exactly one context; with `SessionConfig::logical_tasks` the trainer
//! multiplexes K contexts onto W ≤ K threads and rebinds them with
//! [`Command::InstallTask`]/[`Command::RevokeTask`] as threads come and
//! go — the tasks (and their chunk stores) never notice.
//!
//! # Protocol invariants
//!
//! The command/reply discipline the pool relies on (and that the
//! pipelined trainer's error paths are careful to preserve):
//!
//! * **FIFO per worker** — commands are processed strictly in send
//!   order. This is what makes a mid-reduce revoke safe: the
//!   `DrainChunks` queued behind a `ReduceShards` cannot overtake it,
//!   so the revoked worker always finishes its shard claims first. The
//!   same rule covers a mid-*collective* revoke: a `DrainChunks` behind
//!   an `Allreduce` waits for the collective to finish — which it must,
//!   because the revoked rank's peers are blocked on its slices. Task
//!   rebinds obey it too: an `InstallTask` sent after a `RunIteration`
//!   cannot add a context to an iteration already dispatched.
//! * **Exactly one reply per replying command** — `RunIteration` ⇒
//!   `Iteration` (one reply carrying one [`TaskRun`] per hosted slot),
//!   `ReduceShards` ⇒ `ShardsDone`, `Allreduce` ⇒ `AllreduceDone`,
//!   `DrainChunks` ⇒ `Drained`;
//!   `InstallTask`/`RevokeTask`/`InstallChunks`/`SetReduceSlowdown`/
//!   `Shutdown` never reply. Every dispatched replying command must
//!   eventually be collected, even on error paths — an uncollected reply
//!   desyncs the worker's whole channel.
//! * **Handles dropped before replying** — a worker releases its model /
//!   reduce-buffer handles before signalling completion, so the
//!   coordinator's collect can reclaim buffers zero-copy.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, SharedStore};
use crate::cluster::NodeId;
use crate::transport::{
    ring_allreduce, tree_allreduce, AllreduceKind, AllreduceRun, CollectiveCtx, Transport,
};
use crate::util::Workspace;

use super::reduce::{ModelRef, ReduceBuf, ShardQueue};

/// One logical task's slot in a worker's round-robin iteration plan: the
/// task's index (its position in the merge fold order) plus the seed its
/// solver body draws from this iteration. Seeds are keyed by *task*, not
/// thread, so the K per-task sample streams are identical at any W.
#[derive(Clone, Copy, Debug)]
pub struct TaskSlot {
    /// Logical task index — the fold position in `merge_shard`.
    pub task: usize,
    /// This task's iteration seed.
    pub seed: u64,
}

/// Commands the coordinator sends a worker.
pub enum Command {
    /// Run one solver iteration for every listed slot, round-robin in
    /// slot order, against the model snapshot — which may be the output
    /// buffer of a reduction still in flight ([`ModelRef::Pending`]): the
    /// worker then blocks until the last shard lands and starts computing
    /// without a coordinator round-trip. Slots must name tasks this
    /// worker currently hosts.
    RunIteration {
        model: ModelRef,
        k_tasks: usize,
        slots: Vec<TaskSlot>,
        budget: Option<usize>,
    },
    /// Participate in a work-stealing sharded reduction: claim shards from
    /// `queue` (own block first, then steal), fold `updates` restricted to
    /// each claimed shard's fixed range into a copy of that slice of
    /// `model`, and write the result into `buf` at the same offset. Ends
    /// with one `ShardsDone` reply once the queue has drained.
    ReduceShards {
        model: Arc<ModelVec>,
        updates: Arc<Vec<LocalUpdate>>,
        queue: Arc<ShardQueue>,
        buf: Arc<ReduceBuf>,
        /// This worker's block index in the queue.
        slot: usize,
        k_tasks: usize,
    },
    /// Participate in a peer-to-peer merge collective over the worker's
    /// transport endpoint: ring- or tree-allreduce of every rank's parts
    /// into the replicated model, bit-identical to the serial fold (see
    /// [`crate::transport::allreduce`]). `order` is the rank order;
    /// `epoch` the membership snapshot the collective validates incoming
    /// traffic against. Ends with one `AllreduceDone` reply carrying this
    /// rank's merged model and measured transport stats.
    Allreduce {
        /// The replicated pre-merge model (every rank holds these bits).
        model: Arc<ModelVec>,
        /// The `(task_idx, update)` parts this rank carries into the fold
        /// — one per logical task the thread hosts (exactly one in the
        /// legacy schedule). Collectives move updates peer-to-peer, never
        /// through the coordinator.
        parts: Vec<(usize, LocalUpdate)>,
        /// Total logical tasks K across all ranks (the merge normalizer).
        k_tasks: usize,
        order: Arc<Vec<NodeId>>,
        epoch: u64,
        iter: u64,
        kind: AllreduceKind,
    },
    /// Bind a logical task's context to this worker (decoupled schedule;
    /// fire-and-forget). Idempotent: re-installing a task replaces its
    /// store handle.
    InstallTask { task: usize, store: SharedStore },
    /// Unbind a logical task's context (its store lives on — the trainer
    /// shares it — and is typically re-installed on another worker in the
    /// same boundary). Fire-and-forget; unknown tasks are a no-op.
    RevokeTask { task: usize },
    /// Simulate a slow node: busy the worker for this many nanoseconds per
    /// model element before reducing each claimed shard (straggler benches
    /// and tests; 0 = full speed). Applies until overwritten.
    SetReduceSlowdown(u64),
    /// Add chunks to the worker's *first* hosted context over the channel
    /// (the legacy one-task-per-thread path, where it is the only one).
    /// The trainer installs chunks by writing the shared store directly
    /// between iterations; this command serves coordinators without a
    /// store handle. Zero-copy either way: the `Chunk` values move, and
    /// their immutable payloads are `Arc`-shared — a coordinator that
    /// retains copies (clone before install) pays only the per-sample
    /// state.
    InstallChunks(Vec<Chunk>),
    /// Hand every local chunk — across *all* hosted contexts — back to
    /// the coordinator (revocation drain). The chunks move out with their
    /// payload `Arc`s intact — an elastic revoke/reinstall round-trip
    /// never touches sample bytes.
    DrainChunks,
    /// Exit the worker loop.
    Shutdown,
}

/// Replies a worker sends on its completion channel.
pub enum Reply {
    /// One `TaskRun` per slot of the triggering `RunIteration`, in slot
    /// order.
    Iteration(Result<Vec<TaskRun>>),
    /// This worker's share of a sharded reduction is done (its claims are
    /// already written to the shared buffer).
    ShardsDone { shards: usize, steals: usize },
    /// This rank's side of a merge collective completed (or failed): the
    /// merged model — every rank ends with the full result — plus the
    /// measured transport rounds/bytes.
    AllreduceDone(Result<AllreduceRun>),
    Drained(Vec<Chunk>),
}

/// One logical task's worker-resident context: its index, its shared
/// chunk store, and its private scratch [`Workspace`]. The workspace is
/// keyed by *task*, not thread or slot — PR-8 oversubscription (K tasks
/// round-robin on W ≤ K threads) reuses a task's scratch across its
/// slots every iteration, which is what makes steady-state iterations
/// allocation-free. A task migrated to another worker starts with a
/// fresh workspace there; since workspace reuse is bit-invisible (see
/// [`Workspace`]), rebinding never perturbs the trajectory.
struct TaskCtx {
    task: usize,
    store: SharedStore,
    ws: Workspace,
}

/// One completed logical-task iteration.
#[derive(Clone, Debug)]
pub struct TaskRun {
    /// The logical task this run belongs to (its `TaskSlot::task`).
    pub task: usize,
    pub update: LocalUpdate,
    /// Wallclock compute time of the task body (excludes any wait on an
    /// in-flight reduction).
    pub wall: Duration,
}

/// The long-lived worker loop (runs on the worker's own thread).
///
/// `contexts` are the logical tasks bound at spawn; `InstallTask` /
/// `RevokeTask` rebind them later. `transport` is this worker's endpoint
/// in the session's peer group; the worker owns it for its whole life, so
/// dropping out of this loop (shutdown or channel disconnect) is what
/// leaves the group — after any in-flight collective has completed, never
/// during one.
pub(crate) fn worker_loop(
    algo: Arc<dyn Algorithm>,
    contexts: Vec<(usize, SharedStore)>,
    mut transport: Box<dyn Transport>,
    commands: Receiver<Command>,
    replies: Sender<Reply>,
) {
    let mut contexts: Vec<TaskCtx> = contexts
        .into_iter()
        .map(|(task, store)| TaskCtx { task, store, ws: Workspace::new() })
        .collect();
    // Artificial per-element reduce delay (straggler simulation).
    let mut slow_ns_per_elem = 0u64;
    while let Ok(cmd) = commands.recv() {
        match cmd {
            Command::RunIteration { model, k_tasks, slots, budget } => {
                let result = match model.wait() {
                    Some(m) => run_slots(algo.as_ref(), &mut contexts, m, k_tasks, &slots, budget),
                    None => Err(anyhow!("model reduction was abandoned")),
                };
                // Release the model snapshot before signalling completion
                // so the coordinator can reclaim the buffer without a copy.
                drop(model);
                if replies.send(Reply::Iteration(result)).is_err() {
                    break;
                }
            }
            Command::ReduceShards { model, updates, queue, buf, slot, k_tasks } => {
                let mut shards = 0usize;
                let mut steals = 0usize;
                while let Some((idx, stolen)) = queue.claim(slot) {
                    let (offset, len) = queue.shard_range(idx);
                    if slow_ns_per_elem > 0 {
                        spin_for(Duration::from_nanos(slow_ns_per_elem * len as u64));
                    }
                    let mut data = model[offset..offset + len].to_vec();
                    algo.merge_shard(&mut data, offset, &updates, k_tasks);
                    buf.write_shard(offset, &data);
                    shards += 1;
                    steals += usize::from(stolen);
                }
                // Release every reduction handle before signalling, so no
                // worker-side reference outlives the merge phase.
                drop(model);
                drop(updates);
                drop(queue);
                drop(buf);
                if replies.send(Reply::ShardsDone { shards, steals }).is_err() {
                    break;
                }
            }
            Command::Allreduce { model, parts, k_tasks, order, epoch, iter, kind } => {
                let me = transport.node();
                let ctx = CollectiveCtx {
                    algo: algo.as_ref(),
                    model: &model,
                    parts: &parts,
                    k_tasks,
                    order: &order,
                    epoch,
                    iter,
                };
                // Framing overhead is a property of the endpoint, not the
                // collective: snapshot the counter around the run so each
                // rank's stats carry only this collective's framing bytes.
                let frames_before = transport.frame_bytes();
                let result = match kind {
                    AllreduceKind::Ring => ring_allreduce(transport.as_mut(), &ctx),
                    AllreduceKind::Tree => tree_allreduce(transport.as_mut(), &ctx),
                }
                .map(|mut run| {
                    run.stats.frame_bytes = transport.frame_bytes() - frames_before;
                    run
                })
                .map_err(|e| anyhow!("{kind:?} allreduce node {me}: {e}"));
                drop(model);
                drop(order);
                if replies.send(Reply::AllreduceDone(result)).is_err() {
                    break;
                }
            }
            Command::InstallTask { task, store } => {
                match contexts.iter_mut().find(|c| c.task == task) {
                    // Re-install: replace the store handle, keep the
                    // task's warmed workspace.
                    Some(ctx) => ctx.store = store,
                    None => contexts.push(TaskCtx { task, store, ws: Workspace::new() }),
                }
            }
            Command::RevokeTask { task } => contexts.retain(|c| c.task != task),
            Command::SetReduceSlowdown(ns) => slow_ns_per_elem = ns,
            Command::InstallChunks(chunks) => {
                if let Some(ctx) = contexts.first() {
                    let mut store = ctx.store.lock();
                    for chunk in chunks {
                        store.add(chunk);
                    }
                }
            }
            Command::DrainChunks => {
                let mut drained = Vec::new();
                for ctx in &contexts {
                    drained.extend(ctx.store.lock().drain());
                }
                if replies.send(Reply::Drained(drained)).is_err() {
                    break;
                }
            }
            Command::Shutdown => break,
        }
    }
}

/// Simulated straggler delay. Sleeps for delays long enough that timer
/// granularity is noise (freeing the core for the fast workers, as a real
/// slow node would); busy-waits below that so tiny delays stay faithful.
fn spin_for(d: Duration) {
    if d >= Duration::from_micros(200) {
        std::thread::sleep(d);
        return;
    }
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Run every slot of one `RunIteration`, in slot order, each against its
/// own hosted context. A slot naming a task this worker does not host is
/// a dispatch bug and errors the whole command (never a silent skip — a
/// missing run would shrink the fold).
fn run_slots(
    algo: &dyn Algorithm,
    contexts: &mut [TaskCtx],
    model: &ModelVec,
    k_tasks: usize,
    slots: &[TaskSlot],
    budget: Option<usize>,
) -> Result<Vec<TaskRun>> {
    let mut runs = Vec::with_capacity(slots.len());
    for slot in slots {
        let ctx = contexts
            .iter_mut()
            .find(|c| c.task == slot.task)
            .ok_or_else(|| anyhow!("logical task {} is not hosted by this worker", slot.task))?;
        runs.push(run_iteration(algo, ctx, model, k_tasks, slot, budget)?);
    }
    Ok(runs)
}

fn run_iteration(
    algo: &dyn Algorithm,
    ctx: &mut TaskCtx,
    model: &ModelVec,
    k_tasks: usize,
    slot: &TaskSlot,
    budget: Option<usize>,
) -> Result<TaskRun> {
    let mut store = ctx.store.lock();
    if store.n_samples() == 0 {
        // A task without chunks contributes a zero update (it can receive
        // chunks next boundary — e.g. a freshly assigned node). Not a
        // steady-state path, so plain allocation is fine here.
        return Ok(TaskRun {
            task: slot.task,
            update: LocalUpdate {
                delta: vec![0.0; algo.model_len()],
                samples: 0,
                loss_sum: 0.0,
            },
            wall: Duration::ZERO,
        });
    }
    let t0 = Instant::now();
    let update = algo.task_iterate_ws(
        store.chunks_mut(),
        model,
        k_tasks,
        slot.seed,
        budget,
        &mut ctx.ws,
    )?;
    Ok(TaskRun { task: slot.task, update, wall: t0.elapsed() })
}
