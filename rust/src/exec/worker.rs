//! The uni-task worker loop: one persistent thread per task.
//!
//! A worker is spawned once (node assignment or session start) and then
//! processes [`Command`]s until `Shutdown` or channel disconnect. It holds
//! a clone of the task's [`SharedStore`] and locks it only while running
//! an iteration — the ownership window the coordinator grants it.
//!
//! # Protocol invariants
//!
//! The command/reply discipline the pool relies on (and that the
//! pipelined trainer's error paths are careful to preserve):
//!
//! * **FIFO per worker** — commands are processed strictly in send
//!   order. This is what makes a mid-reduce revoke safe: the
//!   `DrainChunks` queued behind a `ReduceShards` cannot overtake it,
//!   so the revoked worker always finishes its shard claims first. The
//!   same rule covers a mid-*collective* revoke: a `DrainChunks` behind
//!   an `Allreduce` waits for the collective to finish — which it must,
//!   because the revoked rank's peers are blocked on its slices.
//! * **Exactly one reply per replying command** — `RunIteration` ⇒
//!   `Iteration`, `ReduceShards` ⇒ `ShardsDone`, `Allreduce` ⇒
//!   `AllreduceDone`, `DrainChunks` ⇒ `Drained`;
//!   `InstallChunks`/`SetReduceSlowdown`/`Shutdown` never
//!   reply. Every dispatched replying command must eventually be
//!   collected, even on error paths — an uncollected reply desyncs the
//!   worker's whole channel.
//! * **Handles dropped before replying** — a worker releases its model /
//!   reduce-buffer handles before signalling completion, so the
//!   coordinator's collect can reclaim buffers zero-copy.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, SharedStore};
use crate::cluster::NodeId;
use crate::transport::{
    ring_allreduce, tree_allreduce, AllreduceKind, AllreduceRun, CollectiveCtx, Transport,
};

use super::reduce::{ModelRef, ReduceBuf, ShardQueue};

/// Commands the coordinator sends a uni-task worker.
pub enum Command {
    /// Run one solver iteration against the model snapshot — which may be
    /// the output buffer of a reduction still in flight
    /// ([`ModelRef::Pending`]): the worker then blocks until the last
    /// shard lands and starts computing without a coordinator round-trip.
    RunIteration {
        model: ModelRef,
        k_tasks: usize,
        seed: u64,
        budget: Option<usize>,
    },
    /// Participate in a work-stealing sharded reduction: claim shards from
    /// `queue` (own block first, then steal), fold `updates` restricted to
    /// each claimed shard's fixed range into a copy of that slice of
    /// `model`, and write the result into `buf` at the same offset. Ends
    /// with one `ShardsDone` reply once the queue has drained.
    ReduceShards {
        model: Arc<ModelVec>,
        updates: Arc<Vec<LocalUpdate>>,
        queue: Arc<ShardQueue>,
        buf: Arc<ReduceBuf>,
        /// This worker's block index in the queue.
        slot: usize,
        k_tasks: usize,
    },
    /// Participate in a peer-to-peer merge collective over the worker's
    /// transport endpoint: ring- or tree-allreduce of every rank's update
    /// into the replicated model, bit-identical to the serial fold (see
    /// [`crate::transport::allreduce`]). `order` is the rank order — the
    /// task order of the fold — and `epoch` the membership snapshot the
    /// collective validates incoming traffic against. Ends with one
    /// `AllreduceDone` reply carrying this rank's merged model and
    /// measured transport stats.
    Allreduce {
        /// The replicated pre-merge model (every rank holds these bits).
        model: Arc<ModelVec>,
        /// This rank's own update — collectives move updates peer-to-peer,
        /// never through the coordinator.
        update: Box<LocalUpdate>,
        /// This rank's position in the task-order fold.
        task_idx: usize,
        k_tasks: usize,
        order: Arc<Vec<NodeId>>,
        epoch: u64,
        iter: u64,
        kind: AllreduceKind,
    },
    /// Simulate a slow node: busy the worker for this many nanoseconds per
    /// model element before reducing each claimed shard (straggler benches
    /// and tests; 0 = full speed). Applies until overwritten.
    SetReduceSlowdown(u64),
    /// Add chunks to the worker's store over the channel. The trainer
    /// installs chunks by writing the shared store directly between
    /// iterations; this command serves coordinators without a store
    /// handle. Zero-copy either way: the `Chunk` values move, and their
    /// immutable payloads are `Arc`-shared — a coordinator that retains
    /// copies (clone before install) pays only the per-sample state.
    InstallChunks(Vec<Chunk>),
    /// Hand every local chunk back to the coordinator (revocation drain).
    /// The chunks move out with their payload `Arc`s intact — an elastic
    /// revoke/reinstall round-trip never touches sample bytes.
    DrainChunks,
    /// Exit the worker loop.
    Shutdown,
}

/// Replies a worker sends on its completion channel.
pub enum Reply {
    Iteration(Result<TaskRun>),
    /// This worker's share of a sharded reduction is done (its claims are
    /// already written to the shared buffer).
    ShardsDone { shards: usize, steals: usize },
    /// This rank's side of a merge collective completed (or failed): the
    /// merged model — every rank ends with the full result — plus the
    /// measured transport rounds/bytes.
    AllreduceDone(Result<AllreduceRun>),
    Drained(Vec<Chunk>),
}

/// One completed task iteration.
#[derive(Clone, Debug)]
pub struct TaskRun {
    pub update: LocalUpdate,
    /// Wallclock compute time of the task body (excludes any wait on an
    /// in-flight reduction).
    pub wall: Duration,
}

/// The long-lived worker loop (runs on the worker's own thread).
///
/// `transport` is this uni-task's endpoint in the session's peer group;
/// the worker owns it for its whole life, so dropping out of this loop
/// (shutdown or channel disconnect) is what leaves the group — after any
/// in-flight collective has completed, never during one.
pub(crate) fn worker_loop(
    algo: Arc<dyn Algorithm>,
    store: SharedStore,
    mut transport: Box<dyn Transport>,
    commands: Receiver<Command>,
    replies: Sender<Reply>,
) {
    // Artificial per-element reduce delay (straggler simulation).
    let mut slow_ns_per_elem = 0u64;
    while let Ok(cmd) = commands.recv() {
        match cmd {
            Command::RunIteration { model, k_tasks, seed, budget } => {
                let result = match model.wait() {
                    Some(m) => run_iteration(algo.as_ref(), &store, m, k_tasks, seed, budget),
                    None => Err(anyhow!("model reduction was abandoned")),
                };
                // Release the model snapshot before signalling completion
                // so the coordinator can reclaim the buffer without a copy.
                drop(model);
                if replies.send(Reply::Iteration(result)).is_err() {
                    break;
                }
            }
            Command::ReduceShards { model, updates, queue, buf, slot, k_tasks } => {
                let mut shards = 0usize;
                let mut steals = 0usize;
                while let Some((idx, stolen)) = queue.claim(slot) {
                    let (offset, len) = queue.shard_range(idx);
                    if slow_ns_per_elem > 0 {
                        spin_for(Duration::from_nanos(slow_ns_per_elem * len as u64));
                    }
                    let mut data = model[offset..offset + len].to_vec();
                    algo.merge_shard(&mut data, offset, &updates, k_tasks);
                    buf.write_shard(offset, &data);
                    shards += 1;
                    steals += usize::from(stolen);
                }
                // Release every reduction handle before signalling, so no
                // worker-side reference outlives the merge phase.
                drop(model);
                drop(updates);
                drop(queue);
                drop(buf);
                if replies.send(Reply::ShardsDone { shards, steals }).is_err() {
                    break;
                }
            }
            Command::Allreduce { model, update, task_idx, k_tasks, order, epoch, iter, kind } => {
                let ctx = CollectiveCtx {
                    algo: algo.as_ref(),
                    model: &model,
                    update: update.as_ref(),
                    task_idx,
                    k_tasks,
                    order: &order,
                    epoch,
                    iter,
                };
                let result = match kind {
                    AllreduceKind::Ring => ring_allreduce(transport.as_mut(), &ctx),
                    AllreduceKind::Tree => tree_allreduce(transport.as_mut(), &ctx),
                }
                .map_err(|e| anyhow!("{kind:?} allreduce rank {task_idx}: {e}"));
                drop(model);
                drop(order);
                if replies.send(Reply::AllreduceDone(result)).is_err() {
                    break;
                }
            }
            Command::SetReduceSlowdown(ns) => slow_ns_per_elem = ns,
            Command::InstallChunks(chunks) => {
                let mut store = store.lock();
                for chunk in chunks {
                    store.add(chunk);
                }
            }
            Command::DrainChunks => {
                let drained = store.lock().drain();
                if replies.send(Reply::Drained(drained)).is_err() {
                    break;
                }
            }
            Command::Shutdown => break,
        }
    }
}

/// Simulated straggler delay. Sleeps for delays long enough that timer
/// granularity is noise (freeing the core for the fast workers, as a real
/// slow node would); busy-waits below that so tiny delays stay faithful.
fn spin_for(d: Duration) {
    if d >= Duration::from_micros(200) {
        std::thread::sleep(d);
        return;
    }
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

fn run_iteration(
    algo: &dyn Algorithm,
    store: &SharedStore,
    model: &ModelVec,
    k_tasks: usize,
    seed: u64,
    budget: Option<usize>,
) -> Result<TaskRun> {
    let mut store = store.lock();
    if store.n_samples() == 0 {
        // A task without chunks contributes a zero update (it can receive
        // chunks next boundary — e.g. a freshly assigned node).
        return Ok(TaskRun {
            update: LocalUpdate {
                delta: vec![0.0; algo.model_len()],
                samples: 0,
                loss_sum: 0.0,
            },
            wall: Duration::ZERO,
        });
    }
    let t0 = Instant::now();
    let update = algo.task_iterate(store.chunks_mut(), model, k_tasks, seed, budget)?;
    Ok(TaskRun { update, wall: t0.elapsed() })
}
