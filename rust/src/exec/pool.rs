//! The coordinator-side worker pool: spawn, command and collect from the
//! persistent uni-task workers.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, SharedStore};
use crate::cluster::NodeId;
use crate::config::TransportKind;
use crate::transport::{AllreduceKind, AllreduceRun, GroupHandle, Residency};

use super::reduce::{ModelRef, ReduceBuf, ReduceOptions, ReduceStats, ShardQueue, SpwController};
use super::worker::{worker_loop, Command, Reply, TaskRun, TaskSlot};

/// Channels + join handle of one resident worker.
struct WorkerHandle {
    node: NodeId,
    commands: Sender<Command>,
    replies: Receiver<Reply>,
    thread: Option<JoinHandle<()>>,
}

/// A sharded reduction in flight: which workers owe a `ShardsDone` reply,
/// and the shared queue/buffer they are working against.
pub struct PendingReduce {
    /// `(node, dispatched)` in dispatch order; an undispatched entry means
    /// the worker's thread was already gone at dispatch time.
    nodes: Vec<(NodeId, bool)>,
    queue: Arc<ShardQueue>,
    buf: Arc<ReduceBuf>,
}

impl PendingReduce {
    /// The shared output buffer (hand [`ModelRef::Pending`] of this to
    /// `dispatch_iteration` to overlap the next iteration with the merge).
    pub fn buf(&self) -> Arc<ReduceBuf> {
        Arc::clone(&self.buf)
    }
}

impl Drop for PendingReduce {
    /// Poison the buffer when the handle dies: a caller that drops an
    /// uncollected reduction (early `?` return, API misuse) must not
    /// leave workers spinning forever on a buffer that never completes.
    /// Harmless after a successful `collect_reduce` — waiters check
    /// completion before the poison flag, and completion is permanent.
    fn drop(&mut self) {
        self.buf.poison();
    }
}

/// An iteration in flight: which workers owe an `Iteration` reply.
pub struct PendingIteration {
    nodes: Vec<(NodeId, bool)>,
}

/// A merge collective in flight: which ranks owe an `AllreduceDone`
/// reply, in rank (= task) order.
pub struct PendingAllreduce {
    nodes: Vec<(NodeId, bool)>,
}

/// Coordinator-side outcome of one merge collective.
pub struct AllreduceOutcome {
    /// The merged model (bit-identical to the serial fold).
    pub model: ModelVec,
    /// Sequential protocol rounds the collective took — the same on every
    /// rank (`2(k−1)` ring, `2·⌊log2 k⌋` tree), surfaced so the metrics
    /// log can put *measured* transport reality next to the simulated
    /// `NetworkModel::reduce_rounds` charge.
    pub rounds: usize,
    /// Payload bytes put on the wire, summed over all ranks.
    pub bytes: usize,
    /// Non-payload framing bytes the backend added (length prefixes,
    /// tags, handshakes), summed over all ranks. Zero for the in-process
    /// channel backend, which has no wire format.
    pub frame_bytes: usize,
}

/// One long-lived worker per uni-task, addressed by node id.
///
/// All methods are called from the coordinator thread between iterations;
/// the pool never exposes worker internals, only the command protocol.
pub struct WorkerPool {
    algo: Arc<dyn Algorithm>,
    workers: Vec<WorkerHandle>,
    /// The session's transport group: every worker joins on spawn and
    /// holds its endpoint until its thread exits, so membership — and the
    /// payload [`Residency`] the scheduler prices warm transfers from —
    /// tracks the live pool exactly. Backend-erased: in-process channels
    /// or loopback TCP, per `SessionConfig::transport`.
    group: GroupHandle,
    /// `ShardsDone` replies swallowed by `shutdown_worker` while a
    /// reduction was in flight (mid-reduce revoke): `collect_reduce`
    /// counts them in place of the departed worker's reply.
    stashed_shards: Vec<(NodeId, usize, usize)>,
    /// `AllreduceDone` replies swallowed by `shutdown_worker` while a
    /// collective was in flight (FIFO guarantees the revoked rank
    /// finished the collective before draining): `collect_allreduce`
    /// consumes them in place of the departed rank's reply.
    stashed_allreduce: Vec<(NodeId, Result<AllreduceRun>)>,
    /// Adaptive shards-per-worker controller, fed by every collected
    /// reduction's steal count (see [`SpwController`]). `None` = fixed
    /// granularity (callers pass whatever `ReduceOptions` they like).
    spw_ctl: Option<SpwController>,
    /// The worker most stolen *from* in the last clean reduction — the
    /// straggler. The next reduction's layout hands it the smallest
    /// fixed-offset block, so it starts with the least owned work while
    /// the fast workers absorb the oversized blocks. Purely a *who does
    /// what* decision: shard geometry (and the merged bits) are layout-
    /// independent.
    steal_victim: Option<NodeId>,
}

impl WorkerPool {
    pub fn new(algo: Arc<dyn Algorithm>) -> Self {
        Self::new_with_transport(algo, TransportKind::Channel)
    }

    /// A pool whose workers join the given transport backend. The backend
    /// changes how collective bytes move (in-process queues vs real
    /// framed sockets), never what is computed — the conformance suite
    /// pins bit-identical merges across backends.
    pub fn new_with_transport(algo: Arc<dyn Algorithm>, transport: TransportKind) -> Self {
        let group = match transport {
            TransportKind::Channel => GroupHandle::channel(),
            TransportKind::Tcp => GroupHandle::tcp(),
        };
        WorkerPool {
            algo,
            workers: Vec::new(),
            group,
            stashed_shards: Vec::new(),
            stashed_allreduce: Vec::new(),
            spw_ctl: None,
            steal_victim: None,
        }
    }

    /// The transport group's payload-residency map: which immutable chunk
    /// payloads each live member has ever hosted. Handed to the policy
    /// layer so chunk moves to a node that already holds the payload are
    /// priced warm (state-only) instead of always cold.
    pub fn residency(&self) -> Residency {
        self.group.residency().clone()
    }

    /// The current transport membership epoch (tests/diagnostics).
    pub fn transport_epoch(&self) -> u64 {
        self.group.membership().epoch
    }

    /// The straggler identified by the last clean reduction (most shards
    /// stolen from its block), if any. Fed into the next reduction's
    /// steal-aware block layout.
    pub fn steal_victim(&self) -> Option<NodeId> {
        self.steal_victim
    }

    /// Enable the adaptive shards-per-worker feedback loop, starting at
    /// `start` (clamped to `[SPW_MIN, SPW_MAX]`). Every subsequent
    /// successfully collected reduction feeds its steal count into the
    /// controller; read the adapted granularity back with
    /// [`WorkerPool::adaptive_spw`] when building [`ReduceOptions`].
    pub fn enable_adaptive_spw(&mut self, start: usize) {
        self.spw_ctl = Some(SpwController::new(start));
    }

    /// Current granularity recommended by the adaptive controller
    /// (`None` when adaptation is disabled).
    pub fn adaptive_spw(&self) -> Option<usize> {
        self.spw_ctl.as_ref().map(|c| c.current())
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn has_worker(&self, node: NodeId) -> bool {
        self.workers.iter().any(|w| w.node == node)
    }

    /// Spawn the persistent worker thread for one uni-task (the legacy
    /// one-task-per-thread schedule: the logical task index is the node
    /// id). `store` is the same shared handle the coordinator's
    /// `TaskState` keeps, so chunks installed by policies between
    /// iterations are immediately visible.
    pub fn spawn_worker(&mut self, node: NodeId, store: SharedStore) {
        self.spawn_worker_with_tasks(node, vec![(node as usize, store)]);
    }

    /// Spawn a worker thread hosting an explicit set of logical-task
    /// contexts (the decoupled schedule; may be empty — a freshly
    /// assigned thread gets its share via [`WorkerPool::install_task`]).
    pub fn spawn_worker_with_tasks(&mut self, node: NodeId, contexts: Vec<(usize, SharedStore)>) {
        assert!(!self.has_worker(node), "worker for node {node} already exists");
        let (cmd_tx, cmd_rx) = channel();
        let (reply_tx, reply_rx) = channel();
        let algo = Arc::clone(&self.algo);
        // The worker owns its transport endpoint for life: the endpoint's
        // drop (thread exit) is what leaves the group, so membership can
        // never outlive — or predecease — the rank it belongs to.
        let endpoint = self.group.join(node);
        let thread = std::thread::Builder::new()
            .name(format!("uni-task-{node}"))
            .spawn(move || worker_loop(algo, contexts, endpoint, cmd_rx, reply_tx))
            .expect("spawn uni-task worker thread");
        self.workers.push(WorkerHandle {
            node,
            commands: cmd_tx,
            replies: reply_rx,
            thread: Some(thread),
        });
    }

    /// Bind logical task `task`'s context to `node`'s worker (decoupled
    /// schedule). Fire-and-forget and idempotent — re-installing replaces
    /// the store handle. FIFO ordering makes the rebind race-free: the
    /// context is in place before any iteration dispatched after this.
    pub fn install_task(&self, node: NodeId, task: usize, store: SharedStore) -> Result<()> {
        self.worker(node)?
            .commands
            .send(Command::InstallTask { task, store })
            .map_err(|_| anyhow!("worker for node {node} is gone"))
    }

    /// Unbind logical task `task` from `node`'s worker (the other half of
    /// a task→thread rebind). The store survives — the trainer shares it.
    pub fn revoke_task(&self, node: NodeId, task: usize) -> Result<()> {
        self.worker(node)?
            .commands
            .send(Command::RevokeTask { task })
            .map_err(|_| anyhow!("worker for node {node} is gone"))
    }

    /// Install chunks into a worker's store through the command channel.
    pub fn install_chunks(&self, node: NodeId, chunks: Vec<Chunk>) -> Result<()> {
        self.worker(node)?
            .commands
            .send(Command::InstallChunks(chunks))
            .map_err(|_| anyhow!("worker for node {node} is gone"))
    }

    /// Simulate a slow node: the worker busies itself for `ns_per_elem`
    /// nanoseconds per model element before reducing each claimed shard
    /// (straggler benches/tests; 0 restores full speed).
    pub fn set_reduce_slowdown(&self, node: NodeId, ns_per_elem: u64) -> Result<()> {
        self.worker(node)?
            .commands
            .send(Command::SetReduceSlowdown(ns_per_elem))
            .map_err(|_| anyhow!("worker for node {node} is gone"))
    }

    /// Drain a worker's chunks and shut it down (the revocation path):
    /// the chunks — with their per-sample optimizer state — survive, the
    /// thread exits, and every other worker's compute state is untouched.
    ///
    /// Safe to call while a sharded reduction is in flight: commands are
    /// FIFO per worker, so the worker finishes its reduce claims first;
    /// its `ShardsDone` reply is stashed here and handed to the eventual
    /// `collect_reduce`. (A pending *iteration* on this worker is not
    /// supported — the trainer never revokes mid-iteration.)
    pub fn shutdown_worker(&mut self, node: NodeId) -> Result<Vec<Chunk>> {
        let idx = self
            .workers
            .iter()
            .position(|w| w.node == node)
            .ok_or_else(|| anyhow!("no worker for node {node}"))?;
        // Remove the handle up front: whatever the drain outcome, this
        // worker must stop being addressable (a dead entry would collide
        // with a future re-assignment of the same node id).
        let mut w = self.workers.remove(idx);
        // Same reasoning for the layout feedback: a fresh worker that
        // later reuses this node id must not inherit the departed
        // straggler's smallest-block penalty.
        if self.steal_victim == Some(node) {
            self.steal_victim = None;
        }
        let result = match w.commands.send(Command::DrainChunks) {
            Err(_) => Err(anyhow!("worker for node {node} is gone")),
            Ok(()) => loop {
                match w.replies.recv() {
                    Ok(Reply::Drained(chunks)) => break Ok(chunks),
                    // Mid-reduce revoke: keep the reduction accountable.
                    Ok(Reply::ShardsDone { shards, steals }) => {
                        self.stashed_shards.push((node, shards, steals));
                    }
                    // Mid-collective revoke: the rank finished its side of
                    // the allreduce before draining (FIFO); its completion
                    // belongs to the eventual `collect_allreduce`.
                    Ok(Reply::AllreduceDone(run)) => {
                        self.stashed_allreduce.push((node, run));
                    }
                    Ok(_) => break Err(anyhow!("unexpected reply during drain")),
                    Err(_) => break Err(anyhow!("worker {node} died during drain")),
                }
            },
        };
        let _ = w.commands.send(Command::Shutdown);
        if let Some(t) = w.thread.take() {
            let _ = t.join();
        }
        result
    }

    /// Shut a worker thread down *without* draining its chunk stores —
    /// the decoupled trainer's thread-revocation path. Every hosted
    /// context's store is shared with the trainer's `TaskState`, so the
    /// chunks never move: the thread is released and the logical tasks
    /// are rebound to survivors via [`WorkerPool::install_task`].
    ///
    /// Mirrors [`WorkerPool::shutdown_worker`]'s stash discipline: any
    /// `ShardsDone`/`AllreduceDone` the thread sent before exiting (the
    /// `Shutdown` queues FIFO behind in-flight commands) is stashed for
    /// the eventual collect.
    pub fn release_worker(&mut self, node: NodeId) -> Result<()> {
        let idx = self
            .workers
            .iter()
            .position(|w| w.node == node)
            .ok_or_else(|| anyhow!("no worker for node {node}"))?;
        let mut w = self.workers.remove(idx);
        if self.steal_victim == Some(node) {
            self.steal_victim = None;
        }
        let _ = w.commands.send(Command::Shutdown);
        if let Some(t) = w.thread.take() {
            let _ = t.join();
        }
        while let Ok(reply) = w.replies.try_recv() {
            match reply {
                Reply::ShardsDone { shards, steals } => {
                    self.stashed_shards.push((node, shards, steals));
                }
                Reply::AllreduceDone(run) => {
                    self.stashed_allreduce.push((node, run));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Dispatch one iteration to every worker in `plan` order — each
    /// entry is a worker node plus the logical-task slots it hosts, run
    /// round-robin in slot order (the legacy schedule is the
    /// one-slot-per-entry case). The model may be a pending reduction
    /// ([`ModelRef::Pending`]): workers then start the instant its last
    /// shard lands, with no coordinator round-trip in between.
    pub fn dispatch_tasks(
        &self,
        plan: &[(NodeId, Vec<TaskSlot>)],
        model: ModelRef,
        k_tasks: usize,
        budget: Option<usize>,
    ) -> Result<PendingIteration> {
        // Resolve every worker before dispatching anything: an unknown
        // node must not leave part of the pool mid-iteration.
        let handles = plan
            .iter()
            .map(|(node, _)| self.worker(*node))
            .collect::<Result<Vec<_>>>()?;
        // A failed send means that worker's thread is gone; remember it
        // and keep dispatching so every live worker still gets exactly
        // one command this round.
        let mut nodes = Vec::with_capacity(plan.len());
        for (handle, (node, slots)) in handles.iter().zip(plan) {
            let dispatched = handle
                .commands
                .send(Command::RunIteration {
                    model: model.clone(),
                    k_tasks,
                    slots: slots.clone(),
                    budget,
                })
                .is_ok();
            nodes.push((*node, dispatched));
        }
        drop(model);
        Ok(PendingIteration { nodes })
    }

    /// Legacy dispatch: each plan entry is `(node, task_seed)` and the
    /// node hosts exactly the task its own `spawn_worker` registered.
    pub fn dispatch_iteration(
        &self,
        plan: &[(NodeId, u64)],
        model: ModelRef,
        k_tasks: usize,
        budget: Option<usize>,
    ) -> Result<PendingIteration> {
        let plan: Vec<(NodeId, Vec<TaskSlot>)> = plan
            .iter()
            .map(|&(node, seed)| (node, vec![TaskSlot { task: node as usize, seed }]))
            .collect();
        self.dispatch_tasks(&plan, model, k_tasks, budget)
    }

    /// Collect the replies of a dispatched iteration: one reply per
    /// worker, in dispatch order, flattened into the runs of every hosted
    /// slot (still in slot order within each worker). Per-worker
    /// completion channels make collection deterministic regardless of
    /// which worker finishes first. Every reply is drained before
    /// surfacing any error — returning early would leave replies queued
    /// and desync the per-worker command/reply protocol.
    pub fn collect_iteration(&self, pending: PendingIteration) -> Result<Vec<TaskRun>> {
        let mut results = Vec::with_capacity(pending.nodes.len());
        for (node, dispatched) in &pending.nodes {
            results.push(if !dispatched {
                Err(anyhow!("worker for node {node} is gone"))
            } else {
                match self.worker(*node).map(|w| w.replies.recv()) {
                    Ok(Ok(Reply::Iteration(result))) => result,
                    Ok(Ok(_)) => Err(anyhow!("unexpected reply from worker {node}")),
                    Ok(Err(_)) => Err(anyhow!("worker for node {node} died mid-iteration")),
                    Err(e) => Err(e),
                }
            });
        }
        let mut runs = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for r in results {
            match r {
                Ok(worker_runs) => runs.extend(worker_runs),
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(runs),
        }
    }

    /// Dispatch + collect one iteration against a ready model snapshot
    /// (legacy plan shape).
    pub fn run_iteration(
        &self,
        plan: &[(NodeId, u64)],
        model: Arc<ModelVec>,
        k_tasks: usize,
        budget: Option<usize>,
    ) -> Result<Vec<TaskRun>> {
        let pending = self.dispatch_iteration(plan, ModelRef::Ready(model), k_tasks, budget)?;
        self.collect_iteration(pending)
    }

    /// Start a work-stealing sharded reduction of `updates` into `model`
    /// across every resident worker.
    ///
    /// The model is tiled into `~opts.shards_per_worker × workers` shards
    /// with *fixed* offsets; each worker claims shards from its own block
    /// first, then steals from the others' remainders, writing merged
    /// shards straight into the shared [`ReduceBuf`]. Because
    /// [`crate::algos::Algorithm::merge_shard`] is elementwise and shard
    /// geometry never depends on the claim order, the assembled model is
    /// bit-identical to the serial `merge` fold regardless of worker
    /// count, shard count, OS scheduling, stealing, or an elastic resize
    /// having changed the pool since the last iteration.
    pub fn begin_reduce(
        &mut self,
        model: &Arc<ModelVec>,
        updates: Arc<Vec<LocalUpdate>>,
        k_tasks: usize,
        opts: ReduceOptions,
    ) -> Result<PendingReduce> {
        anyhow::ensure!(!self.workers.is_empty(), "no workers to reduce over");
        anyhow::ensure!(!model.is_empty(), "empty model");
        // A stash entry can only be valid between this reduction's
        // dispatch and collect; anything older belongs to an abandoned
        // reduction (or a re-assigned node id) and must not shadow a
        // future worker's real reply.
        self.stashed_shards.clear();
        // Steal-aware layout: the worker most stolen from last round gets
        // the smallest block (None if it was revoked since, or the last
        // round was calm).
        let small_slot = self
            .steal_victim
            .and_then(|v| self.workers.iter().position(|w| w.node == v));
        let queue = Arc::new(ShardQueue::new_with_layout(
            model.len(),
            self.workers.len(),
            opts,
            small_slot,
        ));
        let buf = Arc::new(ReduceBuf::new(model.len(), queue.n_shards()));
        let mut nodes = Vec::with_capacity(self.workers.len());
        for (slot, w) in self.workers.iter().enumerate() {
            let dispatched = w
                .commands
                .send(Command::ReduceShards {
                    model: Arc::clone(model),
                    updates: Arc::clone(&updates),
                    queue: Arc::clone(&queue),
                    buf: Arc::clone(&buf),
                    slot,
                    k_tasks,
                })
                .is_ok();
            nodes.push((w.node, dispatched));
        }
        drop(updates);
        Ok(PendingReduce { nodes, queue, buf })
    }

    /// Collect every worker's `ShardsDone` reply (stashed replies from a
    /// mid-reduce revoke included) and verify the buffer completed. On
    /// failure the buffer is poisoned so any overlapped iteration waiting
    /// on it unblocks with an error instead of deadlocking.
    pub fn collect_reduce(&mut self, pending: PendingReduce) -> Result<ReduceStats> {
        let mut stats = ReduceStats::default();
        let mut first_err: Option<anyhow::Error> = None;
        for (node, dispatched) in &pending.nodes {
            if !dispatched {
                // With stealing on, live workers absorb a dead worker's
                // block; completeness is checked on the buffer below.
                continue;
            }
            let done = if let Some(i) =
                self.stashed_shards.iter().position(|(n, _, _)| n == node)
            {
                let (_, shards, steals) = self.stashed_shards.swap_remove(i);
                Some((shards, steals))
            } else {
                match self.worker(*node).map(|w| w.replies.recv()) {
                    Ok(Ok(Reply::ShardsDone { shards, steals })) => Some((shards, steals)),
                    Ok(Ok(_)) => {
                        first_err.get_or_insert(anyhow!(
                            "unexpected reply from worker {node} during reduce"
                        ));
                        None
                    }
                    Ok(Err(_)) | Err(_) => {
                        first_err
                            .get_or_insert(anyhow!("worker {node} died during reduce"));
                        None
                    }
                }
            };
            if let Some((shards, steals)) = done {
                stats.shards += shards;
                stats.steals += steals;
                stats.workers += 1;
            }
        }
        if first_err.is_none() && !pending.buf.complete() {
            first_err = Some(anyhow!(
                "reduction incomplete: {} of {} shards written",
                stats.shards,
                pending.queue.n_shards()
            ));
        }
        match first_err {
            Some(e) => {
                pending.buf.poison();
                self.steal_victim = None;
                Err(e)
            }
            None => {
                debug_assert_eq!(stats.shards, pending.queue.n_shards());
                // Close the adaptive-granularity feedback loop: only
                // clean reductions are a trustworthy steal signal.
                if let Some(ctl) = &mut self.spw_ctl {
                    ctl.observe(stats.steals, stats.workers);
                }
                // Steal-aware layout feedback: remember who was stolen
                // from the most (the straggler) so the next layout hands
                // it the smallest block. Slot order == dispatch order ==
                // `pending.nodes` order.
                let losses = pending.queue.stolen_from();
                self.steal_victim = losses
                    .iter()
                    .copied()
                    .enumerate()
                    .max_by_key(|&(_, l)| l)
                    .filter(|&(_, l)| l > 0)
                    .and_then(|(slot, _)| pending.nodes.get(slot).map(|(n, _)| *n));
                Ok(stats)
            }
        }
    }

    /// Sharded work-stealing reduction, barriered: fan out, collect, and
    /// reassemble the merged model on the coordinator.
    ///
    /// A pool with fewer than two workers (or an empty model) reduces
    /// inline — the same fold, without the dispatch round-trip.
    pub fn reduce_model(
        &mut self,
        model: &Arc<ModelVec>,
        updates: Arc<Vec<LocalUpdate>>,
        k_tasks: usize,
        opts: ReduceOptions,
    ) -> Result<(ModelVec, ReduceStats)> {
        if self.workers.len() <= 1 || model.is_empty() {
            let mut out = (**model).clone();
            self.algo.merge_shard(&mut out, 0, &updates, k_tasks);
            return Ok((out, ReduceStats::default()));
        }
        let pending = self.begin_reduce(model, updates, k_tasks, opts)?;
        let buf = pending.buf();
        let stats = self.collect_reduce(pending)?;
        Ok((buf.into_model(), stats))
    }

    /// Start a peer-to-peer merge collective (ring- or tree-allreduce)
    /// across the ranks in `order` — which must be the *task order*:
    /// `updates[i]` is rank `i`'s own update and `order[i]` its node
    /// (the legacy one-task-per-rank schedule). The coordinator only
    /// dispatches and collects; update data moves worker-to-worker over
    /// the transport, and the result is bit-identical to the serial fold
    /// (see [`crate::transport::allreduce`]).
    ///
    /// Safe to revoke a rank while the collective is in flight: commands
    /// are FIFO per worker, so the rank completes the collective — its
    /// peers are blocked on its slices — before draining; its
    /// `AllreduceDone` is stashed for [`WorkerPool::collect_allreduce`].
    pub fn begin_allreduce(
        &mut self,
        order: &[NodeId],
        model: &Arc<ModelVec>,
        updates: Vec<LocalUpdate>,
        k_tasks: usize,
        kind: AllreduceKind,
        iter: u64,
    ) -> Result<PendingAllreduce> {
        anyhow::ensure!(
            order.len() == updates.len(),
            "rank order and updates must align ({} vs {})",
            order.len(),
            updates.len()
        );
        let parts = updates.into_iter().enumerate().map(|(i, u)| vec![(i, u)]).collect();
        self.begin_allreduce_parts(order, model, parts, k_tasks, kind, iter)
    }

    /// Start a merge collective where each rank may carry *several*
    /// logical tasks' updates (the decoupled schedule): `parts[r]` is
    /// rank `r`'s `(task_idx, update)` parts, and `k_tasks` the total
    /// part count K across all ranks. A thread hosting m tasks
    /// contributes m slices to every fold; owners still sort all K parts
    /// into task order before the single `merge_shard`, so the result is
    /// bit-identical to the serial fold at any rank count.
    pub fn begin_allreduce_parts(
        &mut self,
        order: &[NodeId],
        model: &Arc<ModelVec>,
        parts: Vec<Vec<(usize, LocalUpdate)>>,
        k_tasks: usize,
        kind: AllreduceKind,
        iter: u64,
    ) -> Result<PendingAllreduce> {
        anyhow::ensure!(!order.is_empty(), "no ranks to allreduce over");
        anyhow::ensure!(
            order.len() == parts.len(),
            "rank order and parts must align ({} vs {})",
            order.len(),
            parts.len()
        );
        // Resolve every rank before dispatching anything: a collective
        // with a missing rank deadlocks its peers, so unlike an
        // iteration there is no partial dispatch to fall back on.
        for node in order {
            self.worker(*node)?;
        }
        self.stashed_allreduce.clear();
        let epoch = self.group.membership().epoch;
        let order_arc = Arc::new(order.to_vec());
        let mut nodes = Vec::with_capacity(order.len());
        for (node, rank_parts) in order.iter().zip(parts) {
            let dispatched = self
                .worker(*node)?
                .commands
                .send(Command::Allreduce {
                    model: Arc::clone(model),
                    parts: rank_parts,
                    k_tasks,
                    order: Arc::clone(&order_arc),
                    epoch,
                    iter,
                    kind,
                })
                .is_ok();
            nodes.push((*node, dispatched));
        }
        Ok(PendingAllreduce { nodes })
    }

    /// Collect every rank's `AllreduceDone` (stashed replies from a
    /// mid-collective revoke included). The returned model is rank 0's;
    /// every rank finishes with the same bits by construction, and the
    /// transport tests assert it.
    pub fn collect_allreduce(&mut self, pending: PendingAllreduce) -> Result<AllreduceOutcome> {
        let mut model = None;
        let mut rounds = 0usize;
        let mut bytes = 0usize;
        let mut frame_bytes = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for (i, (node, dispatched)) in pending.nodes.iter().enumerate() {
            if !dispatched {
                first_err.get_or_insert(anyhow!("rank {i} (node {node}) was never dispatched"));
                continue;
            }
            let reply = if let Some(j) =
                self.stashed_allreduce.iter().position(|(n, _)| n == node)
            {
                self.stashed_allreduce.swap_remove(j).1
            } else {
                match self.worker(*node).map(|w| w.replies.recv()) {
                    Ok(Ok(Reply::AllreduceDone(r))) => r,
                    Ok(Ok(_)) => Err(anyhow!("unexpected reply from rank {i} (node {node})")),
                    Ok(Err(_)) | Err(_) => {
                        Err(anyhow!("rank {i} (node {node}) died mid-collective"))
                    }
                }
            };
            match reply {
                Ok(run) => {
                    rounds = rounds.max(run.stats.rounds);
                    bytes += run.stats.bytes_sent;
                    frame_bytes += run.stats.frame_bytes;
                    if i == 0 {
                        model = Some(run.model);
                    }
                }
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        match (first_err, model) {
            (Some(e), _) => Err(e),
            (None, Some(model)) => Ok(AllreduceOutcome { model, rounds, bytes, frame_bytes }),
            (None, None) => Err(anyhow!("collective produced no model")),
        }
    }

    /// Barriered merge collective: dispatch, collect, return the merged
    /// model. A single-rank order folds inline on the coordinator — the
    /// same bits, without a transport round (mirroring
    /// [`WorkerPool::reduce_model`]'s small-pool path).
    pub fn allreduce_model(
        &mut self,
        order: &[NodeId],
        model: &Arc<ModelVec>,
        updates: Vec<LocalUpdate>,
        k_tasks: usize,
        kind: AllreduceKind,
        iter: u64,
    ) -> Result<AllreduceOutcome> {
        let parts = updates.into_iter().enumerate().map(|(i, u)| vec![(i, u)]).collect();
        self.allreduce_model_parts(order, model, parts, k_tasks, kind, iter)
    }

    /// Barriered multi-part merge collective (decoupled schedule; see
    /// [`WorkerPool::begin_allreduce_parts`]). A single-rank order folds
    /// inline on the coordinator — all its parts sorted into task order,
    /// one `merge_shard`, zero rounds and bytes — which is exactly the
    /// W = 1 case of the decoupled trainer under a collective strategy.
    pub fn allreduce_model_parts(
        &mut self,
        order: &[NodeId],
        model: &Arc<ModelVec>,
        parts: Vec<Vec<(usize, LocalUpdate)>>,
        k_tasks: usize,
        kind: AllreduceKind,
        iter: u64,
    ) -> Result<AllreduceOutcome> {
        if order.len() <= 1 {
            let mut all: Vec<(usize, LocalUpdate)> = parts.into_iter().flatten().collect();
            all.sort_by_key(|(task_idx, _)| *task_idx);
            let updates: Vec<LocalUpdate> = all.into_iter().map(|(_, u)| u).collect();
            let mut out = (**model).clone();
            self.algo.merge_shard(&mut out, 0, &updates, k_tasks);
            return Ok(AllreduceOutcome { model: out, rounds: 0, bytes: 0, frame_bytes: 0 });
        }
        let pending = self.begin_allreduce_parts(order, model, parts, k_tasks, kind, iter)?;
        self.collect_allreduce(pending)
    }

    fn worker(&self, node: NodeId) -> Result<&WorkerHandle> {
        self.workers
            .iter()
            .find(|w| w.node == node)
            .ok_or_else(|| anyhow!("no worker for node {node}"))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.commands.send(Command::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Backend, CocoaAlgo};
    use crate::config::CocoaConfig;

    fn pool() -> WorkerPool {
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            100,
            4,
        ));
        WorkerPool::new(algo)
    }

    #[test]
    fn empty_store_yields_zero_update() {
        let mut p = pool();
        p.spawn_worker(3, SharedStore::new());
        assert!(p.has_worker(3));
        assert_eq!(p.len(), 1);
        let model = Arc::new(vec![0.0f32; 4]);
        let runs = p.run_iteration(&[(3, 1)], model, 1, None).unwrap();
        assert_eq!(runs.len(), 1);
        // Legacy schedule: the logical task index is the node id.
        assert_eq!(runs[0].task, 3);
        assert_eq!(runs[0].update.samples, 0);
        assert_eq!(runs[0].update.delta, vec![0.0; 4]);
    }

    #[test]
    fn multi_context_worker_runs_hosted_slots_in_order() {
        let mut p = pool();
        // One thread hosting tasks {0, 2}; a second hosting {1}.
        p.spawn_worker_with_tasks(7, vec![(0, SharedStore::new()), (2, SharedStore::new())]);
        p.spawn_worker_with_tasks(8, vec![(1, SharedStore::new())]);
        assert_eq!(p.len(), 2);
        let model = Arc::new(vec![0.0f32; 4]);
        let plan: Vec<(NodeId, Vec<TaskSlot>)> = vec![
            (7, vec![TaskSlot { task: 0, seed: 10 }, TaskSlot { task: 2, seed: 12 }]),
            (8, vec![TaskSlot { task: 1, seed: 11 }]),
        ];
        let pending = p
            .dispatch_tasks(&plan, ModelRef::Ready(Arc::clone(&model)), 3, None)
            .unwrap();
        let runs = p.collect_iteration(pending).unwrap();
        // Flattened in dispatch order, slot order within each worker.
        assert_eq!(runs.iter().map(|r| r.task).collect::<Vec<_>>(), vec![0, 2, 1]);

        // Rebind task 2 onto the other thread: the old host must no
        // longer accept it, the new one must.
        p.revoke_task(7, 2).unwrap();
        p.install_task(8, 2, SharedStore::new()).unwrap();
        let stale: Vec<(NodeId, Vec<TaskSlot>)> =
            vec![(7, vec![TaskSlot { task: 2, seed: 0 }])];
        assert!(p
            .dispatch_tasks(&stale, ModelRef::Ready(Arc::clone(&model)), 3, None)
            .and_then(|pend| p.collect_iteration(pend))
            .is_err());
        let rebound: Vec<(NodeId, Vec<TaskSlot>)> = vec![
            (7, vec![TaskSlot { task: 0, seed: 20 }]),
            (8, vec![TaskSlot { task: 1, seed: 21 }, TaskSlot { task: 2, seed: 22 }]),
        ];
        let runs = p
            .dispatch_tasks(&rebound, ModelRef::Ready(model), 3, None)
            .and_then(|pend| p.collect_iteration(pend))
            .unwrap();
        assert_eq!(runs.iter().map(|r| r.task).collect::<Vec<_>>(), vec![0, 1, 2]);

        // Releasing a thread keeps the pool addressable and consistent.
        p.release_worker(7).unwrap();
        assert!(!p.has_worker(7));
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn unknown_node_errors() {
        let p = pool();
        let model = Arc::new(vec![0.0f32; 4]);
        assert!(p.run_iteration(&[(9, 0)], model, 1, None).is_err());
        assert!(p.install_chunks(9, vec![]).is_err());
    }

    #[test]
    fn reduce_model_matches_serial_merge() {
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            100,
            5,
        ));
        let updates = Arc::new(vec![
            LocalUpdate { delta: vec![0.5; 5], samples: 10, loss_sum: 0.0 },
            LocalUpdate { delta: vec![-0.25; 5], samples: 5, loss_sum: 0.0 },
        ]);
        let model = Arc::new(vec![1.0f32, 2.0, 3.0, 4.0, 5.0]);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 2);
        // More workers than elements, odd splits, single worker: all must
        // reproduce the serial fold exactly.
        for n_workers in [1usize, 2, 3, 7] {
            let mut p = WorkerPool::new(Arc::clone(&algo));
            for i in 0..n_workers {
                p.spawn_worker(i as u32, SharedStore::new());
            }
            let (merged, _) = p
                .reduce_model(&model, Arc::clone(&updates), 2, ReduceOptions::default())
                .unwrap();
            assert_eq!(merged, serial, "{n_workers} workers");
        }
    }

    #[test]
    fn overlapped_iteration_waits_for_reduce() {
        // Dispatch an iteration against a pending reduction: the worker
        // must block until the merge lands, then run on the merged model.
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            100,
            6,
        ));
        let mut p = WorkerPool::new(Arc::clone(&algo));
        for i in 0..3 {
            p.spawn_worker(i, SharedStore::new());
        }
        let model = Arc::new(vec![1.0f32; 6]);
        let updates = Arc::new(vec![LocalUpdate {
            delta: vec![2.0; 6],
            samples: 4,
            loss_sum: 0.0,
        }]);
        let pending = p
            .begin_reduce(&model, Arc::clone(&updates), 1, ReduceOptions::default())
            .unwrap();
        let plan: Vec<(NodeId, u64)> = (0..3u32).map(|i| (i, i as u64)).collect();
        let iter_pending = p
            .dispatch_iteration(&plan, ModelRef::Pending(pending.buf()), 1, None)
            .unwrap();
        let buf = pending.buf();
        p.collect_reduce(pending).unwrap();
        let runs = p.collect_iteration(iter_pending).unwrap();
        assert_eq!(runs.len(), 3);
        // Empty stores → zero updates, but the dispatch must have resolved.
        assert!(runs.iter().all(|r| r.update.samples == 0));
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 1);
        assert_eq!(buf.into_model(), serial);
    }

    #[test]
    fn adaptive_spw_is_off_by_default_and_reports_when_enabled() {
        let mut p = pool();
        assert_eq!(p.adaptive_spw(), None);
        p.enable_adaptive_spw(8);
        assert_eq!(p.adaptive_spw(), Some(8));
        // Clamped on entry, like the controller itself.
        p.enable_adaptive_spw(10_000);
        assert_eq!(p.adaptive_spw(), Some(crate::exec::SPW_MAX));
    }

    #[test]
    fn steal_victim_tracks_straggler_and_survives_resizes() {
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            10_000,
            200_000,
        ));
        let mut p = WorkerPool::new(Arc::clone(&algo));
        for i in 0..4u32 {
            p.spawn_worker(i, SharedStore::new());
        }
        assert_eq!(p.steal_victim(), None);
        // Node 0 reduces 100 ns/element slower: if any stealing happens at
        // all, the thieves drain node 0's block, so the recorded victim —
        // when there is one — can only be node 0 (with 2+ fast workers a
        // fast block may also lose the odd shard, but never more than the
        // straggler's; ties resolve among actual losers).
        p.set_reduce_slowdown(0, 100).unwrap();
        let model = Arc::new(vec![0.1f32; 200_000]);
        let updates = Arc::new(vec![
            LocalUpdate { delta: vec![1e-3; 200_000], samples: 10, loss_sum: 0.0 };
            3
        ]);
        let opts = ReduceOptions { shards_per_worker: 16, stealing: true };
        let (merged, _) = p
            .reduce_model(&model, Arc::clone(&updates), 3, opts)
            .unwrap();
        assert_eq!(merged.len(), 200_000);
        // Scheduling-dependent, so only a sanity constraint: the victim is
        // a live node (or none, if the round was calm).
        if let Some(v) = p.steal_victim() {
            assert!(p.has_worker(v), "victim must be a resident worker");
        }
        // A revoked victim must not panic the next layout: it simply maps
        // to no slot.
        p.shutdown_worker(0).unwrap();
        let (merged2, _) = p
            .reduce_model(&model, Arc::clone(&updates), 3, opts)
            .unwrap();
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 3);
        assert_eq!(merged2, serial, "layout feedback must never change the bits");
    }

    #[test]
    fn shutdown_removes_worker() {
        let mut p = pool();
        p.spawn_worker(0, SharedStore::new());
        let drained = p.shutdown_worker(0).unwrap();
        assert!(drained.is_empty());
        assert!(!p.has_worker(0));
        assert!(p.is_empty());
    }
}
