//! The coordinator-side worker pool: spawn, command and collect from the
//! persistent uni-task workers.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, SharedStore};
use crate::cluster::NodeId;

use super::worker::{worker_loop, Command, Reply, TaskRun};

/// Channels + join handle of one resident worker.
struct WorkerHandle {
    node: NodeId,
    commands: Sender<Command>,
    replies: Receiver<Reply>,
    thread: Option<JoinHandle<()>>,
}

/// One long-lived worker per uni-task, addressed by node id.
///
/// All methods are called from the coordinator thread between iterations;
/// the pool never exposes worker internals, only the command protocol.
pub struct WorkerPool {
    algo: Arc<dyn Algorithm>,
    workers: Vec<WorkerHandle>,
}

impl WorkerPool {
    pub fn new(algo: Arc<dyn Algorithm>) -> Self {
        WorkerPool { algo, workers: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn has_worker(&self, node: NodeId) -> bool {
        self.workers.iter().any(|w| w.node == node)
    }

    /// Spawn the persistent worker thread for one uni-task. `store` is the
    /// same shared handle the coordinator's `TaskState` keeps, so chunks
    /// installed by policies between iterations are immediately visible.
    pub fn spawn_worker(&mut self, node: NodeId, store: SharedStore) {
        assert!(!self.has_worker(node), "worker for node {node} already exists");
        let (cmd_tx, cmd_rx) = channel();
        let (reply_tx, reply_rx) = channel();
        let algo = Arc::clone(&self.algo);
        let thread = std::thread::Builder::new()
            .name(format!("uni-task-{node}"))
            .spawn(move || worker_loop(algo, store, cmd_rx, reply_tx))
            .expect("spawn uni-task worker thread");
        self.workers.push(WorkerHandle {
            node,
            commands: cmd_tx,
            replies: reply_rx,
            thread: Some(thread),
        });
    }

    /// Install chunks into a worker's store through the command channel.
    pub fn install_chunks(&self, node: NodeId, chunks: Vec<Chunk>) -> Result<()> {
        self.worker(node)?
            .commands
            .send(Command::InstallChunks(chunks))
            .map_err(|_| anyhow!("worker for node {node} is gone"))
    }

    /// Drain a worker's chunks and shut it down (the revocation path):
    /// the chunks — with their per-sample optimizer state — survive, the
    /// thread exits, and every other worker's compute state is untouched.
    pub fn shutdown_worker(&mut self, node: NodeId) -> Result<Vec<Chunk>> {
        let idx = self
            .workers
            .iter()
            .position(|w| w.node == node)
            .ok_or_else(|| anyhow!("no worker for node {node}"))?;
        // Remove the handle up front: whatever the drain outcome, this
        // worker must stop being addressable (a dead entry would collide
        // with a future re-assignment of the same node id).
        let mut w = self.workers.remove(idx);
        let result = match w.commands.send(Command::DrainChunks) {
            Err(_) => Err(anyhow!("worker for node {node} is gone")),
            Ok(()) => match w.replies.recv() {
                Ok(Reply::Drained(chunks)) => Ok(chunks),
                Ok(_) => Err(anyhow!("unexpected reply during drain")),
                Err(_) => Err(anyhow!("worker {node} died during drain")),
            },
        };
        let _ = w.commands.send(Command::Shutdown);
        if let Some(t) = w.thread.take() {
            let _ = t.join();
        }
        result
    }

    /// Dispatch one iteration to every worker in `plan` order — each entry
    /// is `(node, task_seed)` — then collect results in the same order.
    /// Per-worker completion channels make collection deterministic
    /// regardless of which worker finishes first.
    pub fn run_iteration(
        &self,
        plan: &[(NodeId, u64)],
        model: Arc<ModelVec>,
        k_tasks: usize,
        budget: Option<usize>,
    ) -> Result<Vec<TaskRun>> {
        // Resolve every worker before dispatching anything: an unknown
        // node must not leave part of the pool mid-iteration.
        let handles = plan
            .iter()
            .map(|(node, _)| self.worker(*node))
            .collect::<Result<Vec<_>>>()?;
        // A failed send means that worker's thread is gone; remember it
        // and keep dispatching so every live worker still gets exactly
        // one command this round.
        let mut dispatched = vec![false; plan.len()];
        for (i, (handle, (_, seed))) in handles.iter().zip(plan).enumerate() {
            dispatched[i] = handle
                .commands
                .send(Command::RunIteration {
                    model: Arc::clone(&model),
                    k_tasks,
                    seed: *seed,
                    budget,
                })
                .is_ok();
        }
        drop(model);
        // Collect every reply before surfacing any error — returning
        // early would leave replies queued and desync the per-worker
        // command/reply protocol for later calls.
        let mut results = Vec::with_capacity(plan.len());
        for (i, (handle, (node, _))) in handles.iter().zip(plan).enumerate() {
            results.push(if !dispatched[i] {
                Err(anyhow!("worker for node {node} is gone"))
            } else {
                match handle.replies.recv() {
                    Ok(Reply::Iteration(result)) => result,
                    Ok(_) => Err(anyhow!("unexpected reply from worker {node}")),
                    Err(_) => Err(anyhow!("worker for node {node} died mid-iteration")),
                }
            });
        }
        results.into_iter().collect()
    }

    /// Sharded parallel model reduction: fan the merge of `updates` into
    /// `model` out across the resident workers and reassemble the merged
    /// model on the coordinator.
    ///
    /// The model is split into contiguous shards of `ceil(len / workers)`
    /// elements; shard `i` always covers the fixed range
    /// `[i·per, min((i+1)·per, len))` and is written back at exactly that
    /// offset, and each worker receives at most one `ReduceShard` command
    /// (so its private reply channel sees exactly one reply). Because
    /// [`crate::algos::Algorithm::merge_shard`] is elementwise, the
    /// reassembled model is bit-identical to the serial `merge` fold
    /// regardless of worker count, OS scheduling, or an elastic resize
    /// having changed the pool since the last iteration.
    ///
    /// A pool with fewer than two workers (or an empty model) reduces
    /// inline — the same fold, without the dispatch round-trip.
    pub fn reduce_model(
        &self,
        model: &Arc<ModelVec>,
        updates: Arc<Vec<LocalUpdate>>,
        k_tasks: usize,
    ) -> Result<ModelVec> {
        let len = model.len();
        if self.workers.len() <= 1 || len == 0 {
            let mut out = (**model).clone();
            self.algo.merge_shard(&mut out, 0, &updates, k_tasks);
            return Ok(out);
        }
        let per = len.div_ceil(self.workers.len().min(len));
        let n_shards = len.div_ceil(per);
        // Dispatch shard i to worker i. A failed send means that worker's
        // thread is gone; remember it and keep going so the per-worker
        // command/reply protocol stays in sync for every live worker.
        let mut dispatched = vec![false; n_shards];
        for (i, (w, d)) in self.workers.iter().zip(&mut dispatched).enumerate() {
            let offset = i * per;
            *d = w
                .commands
                .send(Command::ReduceShard {
                    model: Arc::clone(model),
                    updates: Arc::clone(&updates),
                    offset,
                    len: per.min(len - offset),
                    k_tasks,
                })
                .is_ok();
        }
        drop(updates);
        // Collect every reply before surfacing any error; shard offsets fix
        // the slot each result lands in, so assembly order is irrelevant.
        let mut merged = vec![0.0f32; len];
        let mut first_err: Option<anyhow::Error> = None;
        for (w, &ok) in self.workers.iter().zip(&dispatched) {
            if !ok {
                if first_err.is_none() {
                    first_err = Some(anyhow!("worker for node {} is gone", w.node));
                }
                continue;
            }
            match w.replies.recv() {
                Ok(Reply::Shard { offset, data }) => {
                    merged[offset..offset + data.len()].copy_from_slice(&data);
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err =
                            Some(anyhow!("unexpected reply from worker {} during reduce", w.node));
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!("worker {} died during reduce", w.node));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(merged),
        }
    }

    fn worker(&self, node: NodeId) -> Result<&WorkerHandle> {
        self.workers
            .iter()
            .find(|w| w.node == node)
            .ok_or_else(|| anyhow!("no worker for node {node}"))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.commands.send(Command::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Backend, CocoaAlgo};
    use crate::config::CocoaConfig;

    fn pool() -> WorkerPool {
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            100,
            4,
        ));
        WorkerPool::new(algo)
    }

    #[test]
    fn empty_store_yields_zero_update() {
        let mut p = pool();
        p.spawn_worker(3, SharedStore::new());
        assert!(p.has_worker(3));
        assert_eq!(p.len(), 1);
        let model = Arc::new(vec![0.0f32; 4]);
        let runs = p.run_iteration(&[(3, 1)], model, 1, None).unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].update.samples, 0);
        assert_eq!(runs[0].update.delta, vec![0.0; 4]);
    }

    #[test]
    fn unknown_node_errors() {
        let p = pool();
        let model = Arc::new(vec![0.0f32; 4]);
        assert!(p.run_iteration(&[(9, 0)], model, 1, None).is_err());
        assert!(p.install_chunks(9, vec![]).is_err());
    }

    #[test]
    fn reduce_model_matches_serial_merge() {
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            100,
            5,
        ));
        let updates = Arc::new(vec![
            LocalUpdate { delta: vec![0.5; 5], samples: 10, loss_sum: 0.0 },
            LocalUpdate { delta: vec![-0.25; 5], samples: 5, loss_sum: 0.0 },
        ]);
        let model = Arc::new(vec![1.0f32, 2.0, 3.0, 4.0, 5.0]);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 2);
        // More workers than elements, odd splits, single worker: all must
        // reproduce the serial fold exactly.
        for n_workers in [1usize, 2, 3, 7] {
            let mut p = WorkerPool::new(Arc::clone(&algo));
            for i in 0..n_workers {
                p.spawn_worker(i as u32, SharedStore::new());
            }
            let merged = p.reduce_model(&model, Arc::clone(&updates), 2).unwrap();
            assert_eq!(merged, serial, "{n_workers} workers");
        }
    }

    #[test]
    fn shutdown_removes_worker() {
        let mut p = pool();
        p.spawn_worker(0, SharedStore::new());
        let drained = p.shutdown_worker(0).unwrap();
        assert!(drained.is_empty());
        assert!(!p.has_worker(0));
        assert!(p.is_empty());
    }
}
