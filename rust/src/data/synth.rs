//! Synthetic dataset generators standing in for the paper's corpora.
//!
//! Each generator preserves the properties the evaluation depends on
//! (DESIGN.md §Substitutions):
//!
//! * [`higgs_like`] — dense, 28 features, binary: a partially overlapping
//!   Gaussian mixture, so SVM training has a non-trivial optimum.
//! * [`criteo_like`] — sparse, power-law feature frequencies, binary, with
//!   *correlated contiguous blocks*: consecutive samples share "session"
//!   features, which reproduces Criteo's sensitivity to contiguous
//!   partitioning (paper §A.1: Snap ML's contiguous split converges slower
//!   than Chicle's random chunk assignment).
//! * [`cifar_like`] / [`fmnist_like`] — 10-class template images + noise,
//!   so mSGD shows the convergence-vs-batch-size degradation of Fig 1a.
//! * [`token_corpus`] — a noisy affine Markov chain over the vocabulary:
//!   learnable next-token structure for the transformer e2e workload.

use crate::util::Rng;

use super::{Dataset, FeatureMatrix, Labels, SparseVec};

fn rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

/// HIGGS-like: `n` dense samples, 28 features, labels ±1.
///
/// Two Gaussian clusters at ±mu with unit noise; `sep` controls class
/// overlap (default gives ~90% linear separability, similar in difficulty
/// to HIGGS for a linear SVM).
pub fn higgs_like(n: usize, seed: u64) -> Dataset {
    higgs_like_with(n, 28, 1.0, seed)
}

pub fn higgs_like_with(n: usize, dim: usize, sep: f32, seed: u64) -> Dataset {
    let mut r = rng(seed);
    // Class-mean direction, normalized.
    let mut mu: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
    let norm = mu.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
    mu.iter_mut().for_each(|v| *v = *v / norm * sep);

    let mut data = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let y: f32 = if r.bool(0.5) { 1.0 } else { -1.0 };
        for j in 0..dim {
            data.push(mu[j] * y + r.normal_f32());
        }
        labels.push(y);
    }
    Dataset {
        name: "higgs_like".into(),
        features: FeatureMatrix::Dense { data, dim },
        labels: Labels::Binary(labels),
    }
}

/// Criteo-like: `n` sparse samples over `dim` hash buckets, ~`nnz` non-zeros
/// each, labels ±1, generated in correlated "sessions" of consecutive
/// samples sharing a session feature set.
pub fn criteo_like(n: usize, seed: u64) -> Dataset {
    criteo_like_with(n, 50_000, 30, 16, seed)
}

pub fn criteo_like_with(n: usize, dim: usize, nnz: usize, session: usize, seed: u64) -> Dataset {
    let mut r = rng(seed);
    // Ground-truth weight vector spanning the whole feature space.
    let mut w_true = vec![0.0f32; dim];
    for w in w_true.iter_mut() {
        *w = r.normal_f32();
    }

    // Temporal drift: the active feature region rotates across the
    // dataset (CTR logs drift over time). Contiguous partitioning gives
    // each worker only its region's coordinates — exactly the Snap-ML
    // sensitivity the paper reports on Criteo (SSA.1).
    let n_regions = 8usize;
    let region_stride = (dim / n_regions).max(2);

    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut session_feats: Vec<u32> = Vec::new();
    for i in 0..n {
        let region_offset = ((i * n_regions) / n.max(1)) * region_stride;
        let mut draw = |r: &mut Rng| -> u32 {
            let z = (r.zipf(region_stride as u64, 1.1) as usize - 1).min(region_stride - 1);
            ((z + region_offset) % dim) as u32
        };
        if i % session == 0 {
            // New session: a shared set of features for the next `session`
            // consecutive samples.
            session_feats = (0..nnz / 2).map(|_| draw(&mut r)).collect();
        }
        let mut pairs: Vec<(u32, f32)> =
            session_feats.iter().map(|&f| (f, 1.0f32)).collect();
        for _ in 0..(nnz - session_feats.len()).max(1) {
            let f = draw(&mut r);
            pairs.push((f, 1.0));
        }
        let row = SparseVec::new(pairs);
        let score: f32 = row.dot_dense(&w_true) + r.normal_f32() * 0.5;
        labels.push(if score >= 0.0 { 1.0 } else { -1.0 });
        rows.push(row);
    }
    Dataset {
        name: "criteo_like".into(),
        features: FeatureMatrix::Sparse { rows, dim },
        labels: Labels::Binary(labels),
    }
}

/// Shared implementation for the template-image generators.
fn template_images(
    name: &str,
    n: usize,
    dim: usize,
    n_classes: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut r = rng(seed);
    // Smooth-ish class templates: random low-frequency signal per class.
    let mut templates = vec![vec![0.0f32; dim]; n_classes];
    for t in templates.iter_mut() {
        let k = 8;
        let coefs: Vec<(f32, f32, f32)> = (0..k)
            .map(|_| {
                (
                    r.normal_f32(),
                    r.range(0.5, 8.0) as f32,
                    r.range(0.0, std::f64::consts::TAU) as f32,
                )
            })
            .collect();
        for (j, v) in t.iter_mut().enumerate() {
            let x = j as f32 / dim as f32;
            *v = coefs
                .iter()
                .map(|(a, f, p)| a * (f * std::f32::consts::TAU * x + p).sin())
                .sum::<f32>()
                / (k as f32).sqrt();
        }
    }

    let mut data = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let y = r.below(n_classes);
        for j in 0..dim {
            data.push(templates[y][j] + r.normal_f32() * noise);
        }
        labels.push(y as i32);
    }
    Dataset {
        name: name.into(),
        features: FeatureMatrix::Dense { data, dim },
        labels: Labels::Class(labels),
    }
}

/// CIFAR-10-like: 32x32x3 flattened images, 10 classes.
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    template_images("cifar_like", n, 32 * 32 * 3, 10, 1.0, seed)
}

/// Fashion-MNIST-like: 28x28 flattened images, 10 classes.
pub fn fmnist_like(n: usize, seed: u64) -> Dataset {
    template_images("fmnist_like", n, 28 * 28, 10, 0.8, seed)
}

/// Token sequences from a noisy affine Markov chain:
/// `t_{i+1} = (a * t_i + b) mod vocab` with probability `1 - eps`, else
/// uniform. Learnable by a small LM; loss floor ≈ entropy of the mix.
pub fn token_corpus(n_seqs: usize, seq_len: usize, vocab: i32, seed: u64) -> Dataset {
    let mut r = rng(seed);
    let (a, b) = (31i64, 17i64);
    let mut data = Vec::with_capacity(n_seqs * seq_len);
    for _ in 0..n_seqs {
        let mut t = r.below(vocab as usize) as i64;
        data.push(t as i32);
        for _ in 1..seq_len {
            t = if r.bool(0.9) {
                (a * t + b).rem_euclid(vocab as i64)
            } else {
                r.below(vocab as usize) as i64
            };
            data.push(t as i32);
        }
    }
    Dataset {
        name: "token_corpus".into(),
        features: FeatureMatrix::Tokens { data, seq_len },
        labels: Labels::None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn higgs_like_shapes_and_balance() {
        let d = higgs_like(2000, 1);
        assert_eq!(d.n_samples(), 2000);
        assert_eq!(d.dim(), 28);
        if let Labels::Binary(y) = &d.labels {
            let pos = y.iter().filter(|&&v| v > 0.0).count();
            assert!(pos > 700 && pos < 1300, "unbalanced: {pos}");
        } else {
            panic!("wrong labels");
        }
    }

    #[test]
    fn higgs_like_is_mostly_separable() {
        // The generating direction itself should classify most samples.
        let d = higgs_like_with(4000, 28, 1.5, 7);
        // Estimate mu from class means.
        let mut mu = vec![0.0f64; 28];
        for i in 0..d.n_samples() {
            let y = d.binary_label(i) as f64;
            for (m, &x) in mu.iter_mut().zip(d.dense_row(i)) {
                *m += y * x as f64;
            }
        }
        let correct = (0..d.n_samples())
            .filter(|&i| {
                let s: f64 = mu
                    .iter()
                    .zip(d.dense_row(i))
                    .map(|(m, &x)| m * x as f64)
                    .sum();
                (s >= 0.0) == (d.binary_label(i) > 0.0)
            })
            .count();
        assert!(correct as f64 / 4000.0 > 0.85, "{correct}");
    }

    #[test]
    fn criteo_like_is_sparse_and_sessioned() {
        let d = criteo_like_with(256, 10_000, 20, 16, 3);
        assert_eq!(d.n_samples(), 256);
        if let FeatureMatrix::Sparse { rows, .. } = &d.features {
            assert!(rows.iter().all(|r| r.nnz() <= 30 && r.nnz() >= 5));
            // Consecutive samples within a session share features...
            let shared = rows[0]
                .indices
                .iter()
                .filter(|i| rows[1].indices.contains(i))
                .count();
            assert!(shared >= 5, "sessions not correlated: {shared}");
            // ...while samples from different sessions share almost none.
            let cross = rows[0]
                .indices
                .iter()
                .filter(|i| rows[200].indices.contains(i))
                .count();
            assert!(cross < shared, "cross={cross} shared={shared}");
        } else {
            panic!("not sparse");
        }
    }

    #[test]
    fn images_have_class_structure() {
        let d = cifar_like(300, 5);
        assert_eq!(d.dim(), 3072);
        assert_eq!(d.n_classes(), 10);
        // Same-class samples must be closer than cross-class on average.
        let (mut same, mut cross, mut ns, mut nc) = (0.0f64, 0.0f64, 0, 0);
        if let Labels::Class(y) = &d.labels {
            for i in 0..40 {
                for j in (i + 1)..40 {
                    let dist: f64 = d
                        .dense_row(i)
                        .iter()
                        .zip(d.dense_row(j))
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum();
                    if y[i] == y[j] {
                        same += dist;
                        ns += 1;
                    } else {
                        cross += dist;
                        nc += 1;
                    }
                }
            }
        }
        if ns > 0 && nc > 0 {
            assert!(same / (ns as f64) < cross / (nc as f64));
        }
    }

    #[test]
    fn token_corpus_follows_chain() {
        let d = token_corpus(10, 64, 256, 9);
        assert_eq!(d.n_samples(), 10);
        if let FeatureMatrix::Tokens { data, seq_len } = &d.features {
            let mut hits = 0;
            let mut total = 0;
            for s in 0..10 {
                for t in 0..seq_len - 1 {
                    let cur = data[s * seq_len + t] as i64;
                    let nxt = data[s * seq_len + t + 1] as i64;
                    if (31 * cur + 17).rem_euclid(256) == nxt {
                        hits += 1;
                    }
                    total += 1;
                }
            }
            // ~90% of transitions follow the chain.
            assert!(hits as f64 / total as f64 > 0.8, "{hits}/{total}");
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = higgs_like(100, 42);
        let b = higgs_like(100, 42);
        assert_eq!(a.dense_row(7), b.dense_row(7));
        let c = higgs_like(100, 43);
        assert_ne!(a.dense_row(7), c.dense_row(7));
    }
}
