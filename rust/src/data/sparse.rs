//! Sparse sample vectors (the Criteo-like workload).
//!
//! Stored per sample as parallel `(indices, values)` arrays, which is also
//! the wire layout inside data chunks: serialization-free, as required for
//! one-sided RDMA-style chunk moves (paper §4.4).

/// A sparse feature vector with sorted, unique indices.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    pub fn new(mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|p| p.0);
        pairs.dedup_by_key(|p| p.0);
        SparseVec {
            indices: pairs.iter().map(|p| p.0).collect(),
            values: pairs.iter().map(|p| p.1).collect(),
        }
    }

    pub fn empty() -> Self {
        SparseVec { indices: Vec::new(), values: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Squared L2 norm.
    pub fn sq_norm(&self) -> f32 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Dot product against a dense vector.
    #[inline]
    pub fn dot_dense(&self, dense: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            acc += v * dense[i as usize];
        }
        acc
    }

    /// `dense[i] += scale * self[i]` for all stored entries.
    #[inline]
    pub fn axpy_into(&self, scale: f32, dense: &mut [f32]) {
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            dense[i as usize] += scale * v;
        }
    }

    /// Densify into a freshly allocated vector of length `dim`.
    pub fn to_dense(&self, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0; dim];
        self.axpy_into(1.0, &mut out);
        out
    }

    /// Approximate in-memory footprint in bytes (u32 index + f32 value).
    pub fn size_bytes(&self) -> usize {
        self.nnz() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sorts_and_dedups() {
        let v = SparseVec::new(vec![(5, 1.0), (1, 2.0), (5, 9.0), (3, 4.0)]);
        assert_eq!(v.indices, vec![1, 3, 5]);
        assert_eq!(v.values, vec![2.0, 4.0, 1.0]); // first occurrence wins
    }

    #[test]
    fn dot_and_axpy_match_dense() {
        let v = SparseVec::new(vec![(0, 2.0), (3, -1.0)]);
        let dense = vec![1.0, 10.0, 10.0, 4.0];
        assert_eq!(v.dot_dense(&dense), 2.0 - 4.0);
        let mut acc = vec![0.0; 4];
        v.axpy_into(0.5, &mut acc);
        assert_eq!(acc, vec![1.0, 0.0, 0.0, -0.5]);
        assert_eq!(v.to_dense(4), vec![2.0, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn norms_and_sizes() {
        let v = SparseVec::new(vec![(2, 3.0), (7, 4.0)]);
        assert_eq!(v.sq_norm(), 25.0);
        assert_eq!(v.nnz(), 2);
        assert_eq!(v.size_bytes(), 16);
        assert_eq!(SparseVec::empty().nnz(), 0);
    }
}
