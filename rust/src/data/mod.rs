//! Training data: in-memory datasets, sparse vectors, synthetic generators.
//!
//! The paper evaluates on HIGGS, Criteo, CIFAR-10 and Fashion-MNIST
//! (Table 1). Those corpora are not redistributable here, so [`synth`]
//! provides synthetic equivalents that preserve the properties the
//! evaluation depends on (dense vs. sparse access, partitioning
//! sensitivity, convergence-vs-batch-size degradation) — see
//! DESIGN.md §Substitutions.

pub mod sparse;
pub mod synth;

pub use sparse::SparseVec;

/// A labelled in-memory training set. Feature storage is columnar per
/// sample ("row major"): the layouts mirror Chicle's chunk format so
/// chunking is a cheap copy (paper §4.4).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub features: FeatureMatrix,
    pub labels: Labels,
}

/// Sample payloads. `Tokens` covers the LM end-to-end workload where a
/// "sample" is one sequence.
#[derive(Clone, Debug)]
pub enum FeatureMatrix {
    /// Row-major dense matrix: `data[i*dim..(i+1)*dim]` is sample `i`.
    Dense { data: Vec<f32>, dim: usize },
    /// One sparse vector per sample.
    Sparse { rows: Vec<SparseVec>, dim: usize },
    /// Fixed-length token sequences: `data[i*seq_len..]` is sequence `i`.
    Tokens { data: Vec<i32>, seq_len: usize },
}

/// Labels: `Binary` (±1) for GLM/SVM workloads, `Class` for NN
/// classification, `None` for self-supervised LM sequences.
#[derive(Clone, Debug)]
pub enum Labels {
    Binary(Vec<f32>),
    Class(Vec<i32>),
    None,
}

impl Dataset {
    pub fn n_samples(&self) -> usize {
        match &self.features {
            FeatureMatrix::Dense { data, dim } => data.len() / dim.max(&1),
            FeatureMatrix::Sparse { rows, .. } => rows.len(),
            FeatureMatrix::Tokens { data, seq_len } => data.len() / seq_len.max(&1),
        }
    }

    pub fn dim(&self) -> usize {
        match &self.features {
            FeatureMatrix::Dense { dim, .. } => *dim,
            FeatureMatrix::Sparse { dim, .. } => *dim,
            FeatureMatrix::Tokens { seq_len, .. } => *seq_len,
        }
    }

    /// Approximate in-memory size (Table 1's "Size" column).
    pub fn size_bytes(&self) -> usize {
        let feat = match &self.features {
            FeatureMatrix::Dense { data, .. } => data.len() * 4,
            FeatureMatrix::Sparse { rows, .. } => {
                rows.iter().map(|r| r.nnz() * 8).sum()
            }
            FeatureMatrix::Tokens { data, .. } => data.len() * 4,
        };
        let lab = match &self.labels {
            Labels::Binary(v) => v.len() * 4,
            Labels::Class(v) => v.len() * 4,
            Labels::None => 0,
        };
        feat + lab
    }

    /// Number of distinct classes (0 for binary/LM workloads).
    pub fn n_classes(&self) -> usize {
        match &self.labels {
            Labels::Class(v) => v.iter().copied().max().map_or(0, |m| m as usize + 1),
            _ => 0,
        }
    }

    /// Binary label of sample `i` (panics for non-binary datasets).
    pub fn binary_label(&self, i: usize) -> f32 {
        match &self.labels {
            Labels::Binary(v) => v[i],
            _ => panic!("dataset {} has no binary labels", self.name),
        }
    }

    /// Split off the last `frac` of samples as a held-out test set.
    pub fn split_test(mut self, frac: f64) -> (Dataset, Dataset) {
        let n = self.n_samples();
        let n_test = ((n as f64) * frac).round() as usize;
        let n_train = n - n_test;
        let test_features = match &mut self.features {
            FeatureMatrix::Dense { data, dim } => {
                let tail = data.split_off(n_train * *dim);
                FeatureMatrix::Dense { data: tail, dim: *dim }
            }
            FeatureMatrix::Sparse { rows, dim } => {
                let tail = rows.split_off(n_train);
                FeatureMatrix::Sparse { rows: tail, dim: *dim }
            }
            FeatureMatrix::Tokens { data, seq_len } => {
                let tail = data.split_off(n_train * *seq_len);
                FeatureMatrix::Tokens { data: tail, seq_len: *seq_len }
            }
        };
        let test_labels = match &mut self.labels {
            Labels::Binary(v) => Labels::Binary(v.split_off(n_train)),
            Labels::Class(v) => Labels::Class(v.split_off(n_train)),
            Labels::None => Labels::None,
        };
        let test = Dataset {
            name: format!("{}-test", self.name),
            features: test_features,
            labels: test_labels,
        };
        (self, test)
    }

    /// Dense row accessor (panics for sparse/token datasets).
    pub fn dense_row(&self, i: usize) -> &[f32] {
        match &self.features {
            FeatureMatrix::Dense { data, dim } => &data[i * dim..(i + 1) * dim],
            _ => panic!("dataset {} is not dense", self.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            features: FeatureMatrix::Dense { data: (0..20).map(|v| v as f32).collect(), dim: 2 },
            labels: Labels::Binary(vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0]),
        }
    }

    #[test]
    fn counts_and_rows() {
        let d = tiny();
        assert_eq!(d.n_samples(), 10);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.dense_row(3), &[6.0, 7.0]);
        assert_eq!(d.size_bytes(), 20 * 4 + 10 * 4);
    }

    #[test]
    fn split_test_partitions_samples() {
        let (train, test) = tiny().split_test(0.2);
        assert_eq!(train.n_samples(), 8);
        assert_eq!(test.n_samples(), 2);
        assert_eq!(test.dense_row(0), &[16.0, 17.0]);
        assert_eq!(test.binary_label(1), -1.0);
    }

    #[test]
    fn n_classes_from_class_labels() {
        let d = Dataset {
            name: "c".into(),
            features: FeatureMatrix::Dense { data: vec![0.0; 12], dim: 4 },
            labels: Labels::Class(vec![0, 2, 1]),
        };
        assert_eq!(d.n_classes(), 3);
    }
}
