//! Configuration system: everything a training session needs, loadable
//! from JSON (the launcher's input) or built programmatically.
//!
//! (De)serialization is hand-rolled over [`crate::util::json`] — this
//! repo builds fully offline without serde; see `util` module docs.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cluster::rm::TracePoint;
use crate::cluster::{NodeSpec, TraceResourceManager};
use crate::exec::reduce::DEFAULT_SHARDS_PER_WORKER;
use crate::util::Json;

/// How iteration time is charged (DESIGN.md §Substitutions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TimeModel {
    /// The paper's normalized projection (§5.3): per-iteration time from
    /// the wave/balance model, 1 unit = 1/16 of data on a unit-speed node.
    #[default]
    Projected,
    /// Wallclock compute time divided by node speed (swimlane experiments).
    Measured,
}

/// Which compute path the solvers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ComputeBackend {
    /// Pure-rust math (fast; verified against the HLO path by tests).
    #[default]
    Native,
    /// AOT-compiled HLO executed via PJRT (the production path).
    Hlo,
}

/// Uni-tasks (the paper's contribution) or emulated micro-tasks (§5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskModel {
    /// K always equals the number of currently-assigned nodes.
    UniTasks,
    /// K fixed regardless of node count (micro-task emulation; time is
    /// projected with the wave model).
    MicroTasks { k: usize },
}

/// How the per-iteration merge of task updates runs (see
/// `docs/TRANSPORT.md`). Every strategy produces *bit-identical* merged
/// models — the elementwise `merge_shard` invariant guarantees it and
/// `tests/merge_strategies.rs` asserts it — so the choice trades only
/// wall-clock shape and wire pattern, never the trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MergeStrategy {
    /// The coordinator-side work-stealing sharded reduction (the default;
    /// the only strategy that supports the reduce/dispatch overlap).
    #[default]
    Coordinator,
    /// Peer-to-peer ring-allreduce over the transport layer: `2(k−1)`
    /// rounds of segment-sized messages, no coordinator on the data path.
    Ring,
    /// Peer-to-peer tree-allreduce: gather to rank 0, fold, broadcast —
    /// `2·⌊log2 k⌋` rounds of full-model messages.
    Tree,
}

impl MergeStrategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            MergeStrategy::Coordinator => "coordinator",
            MergeStrategy::Ring => "ring",
            MergeStrategy::Tree => "tree",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "coordinator" => MergeStrategy::Coordinator,
            "ring" => MergeStrategy::Ring,
            "tree" => MergeStrategy::Tree,
            other => bail!("unknown merge strategy {other:?}"),
        })
    }

    /// `CHICLE_MERGE_STRATEGY` override (read by the programmatic
    /// constructors only, like `CHICLE_FAST`): lets CI exercise a whole
    /// tier-1 leg under `ring` without touching any config file. An unset
    /// or empty variable means no override; an unknown value fails loudly
    /// rather than silently training on the wrong strategy.
    fn env_override() -> Option<Self> {
        match std::env::var("CHICLE_MERGE_STRATEGY") {
            Ok(s) if !s.is_empty() => {
                Some(Self::parse(&s).expect("CHICLE_MERGE_STRATEGY must be coordinator|ring|tree"))
            }
            _ => None,
        }
    }
}

/// Which [`Transport`](crate::transport::Transport) backend the session's
/// worker pool joins its ranks into (see `docs/TRANSPORT.md`). Both
/// backends satisfy the same wire contract and the backend-generic
/// conformance suite pins bit-identical collectives across them, so the
/// choice trades only how bytes physically move — in-process queues vs
/// real framed sockets — never the trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// In-process mpsc channels (the default: zero-copy, no framing).
    #[default]
    Channel,
    /// Loopback TCP sockets with length-prefixed framing — every
    /// collective byte is really encoded, written, read, and decoded;
    /// the measured framing overhead lands in the
    /// `transport_frame_bytes` TSV column.
    Tcp,
}

impl TransportKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "channel" => TransportKind::Channel,
            "tcp" => TransportKind::Tcp,
            other => bail!("unknown transport backend {other:?}"),
        })
    }

    /// `CHICLE_TRANSPORT` override (programmatic constructors only,
    /// mirroring [`MergeStrategy::env_override`]): lets CI run a whole
    /// tier-1 leg over real sockets without touching any config file.
    /// Unset or empty means no override; junk fails loudly rather than
    /// silently training over the wrong wire.
    fn env_override() -> Option<Self> {
        match std::env::var("CHICLE_TRANSPORT") {
            Ok(s) if !s.is_empty() => {
                Some(Self::parse(&s).expect("CHICLE_TRANSPORT must be channel|tcp"))
            }
            _ => None,
        }
    }
}

/// `CHICLE_LOGICAL_TASKS` override (programmatic constructors only,
/// mirroring [`MergeStrategy::env_override`]): lets CI run a whole tier-1
/// leg with K logical uni-tasks multiplexed onto however many worker
/// threads each test's elastic spec provides, without touching any config
/// file. Unset, empty, or `0` means no override; junk fails loudly rather
/// than silently training at the wrong parallelism degree.
fn logical_tasks_env() -> Option<usize> {
    match std::env::var("CHICLE_LOGICAL_TASKS") {
        Ok(s) if !s.is_empty() => Some(
            s.parse()
                .expect("CHICLE_LOGICAL_TASKS must be a non-negative integer"),
        ),
        _ => None,
    }
}

/// Sample→chunk placement (paper §A.1: Snap ML splits contiguously, Chicle
/// assigns randomly — this is the Criteo difference).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Partitioning {
    #[default]
    RandomChunks,
    Contiguous,
}

/// Node-availability schedule, serializable for JSON configs.
#[derive(Clone, Debug)]
pub enum ElasticSpec {
    /// Fixed homogeneous allocation.
    Rigid { nodes: usize },
    /// Fixed heterogeneous allocation: (fast, slow, slowdown factor).
    Heterogeneous { fast: usize, slow: usize, factor: f64 },
    /// The paper's ±2-nodes-every-`interval_s` scenario (§5.3).
    Gradual { from: usize, to: usize, interval_s: f64 },
    /// Arbitrary trace: (at_seconds, node speeds).
    Trace { points: Vec<(f64, Vec<f64>)> },
}

impl ElasticSpec {
    /// Materialize the trace-driven resource manager.
    pub fn build_rm(&self) -> TraceResourceManager {
        match self {
            ElasticSpec::Rigid { nodes } => {
                TraceResourceManager::rigid(NodeSpec::homogeneous(*nodes))
            }
            ElasticSpec::Heterogeneous { fast, slow, factor } => {
                TraceResourceManager::rigid(NodeSpec::heterogeneous(*fast, *slow, *factor))
            }
            ElasticSpec::Gradual { from, to, interval_s } => {
                TraceResourceManager::gradual(*from, *to, Duration::from_secs_f64(*interval_s))
            }
            ElasticSpec::Trace { points } => {
                let trace = points
                    .iter()
                    .map(|(at, speeds)| TracePoint {
                        at: Duration::from_secs_f64(*at),
                        nodes: speeds
                            .iter()
                            .enumerate()
                            .map(|(i, s)| NodeSpec::new(i as u32, *s))
                            .collect(),
                    })
                    .collect();
                TraceResourceManager::new(trace)
            }
        }
    }

    /// Maximum concurrent node count over the whole schedule.
    pub fn max_nodes(&self) -> usize {
        match self {
            ElasticSpec::Rigid { nodes } => *nodes,
            ElasticSpec::Heterogeneous { fast, slow, .. } => fast + slow,
            ElasticSpec::Gradual { from, to, .. } => (*from).max(*to),
            ElasticSpec::Trace { points } => {
                points.iter().map(|(_, s)| s.len()).max().unwrap_or(0)
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            ElasticSpec::Rigid { nodes } => Json::obj(vec![
                ("kind", Json::str("rigid")),
                ("nodes", Json::num(*nodes as f64)),
            ]),
            ElasticSpec::Heterogeneous { fast, slow, factor } => Json::obj(vec![
                ("kind", Json::str("heterogeneous")),
                ("fast", Json::num(*fast as f64)),
                ("slow", Json::num(*slow as f64)),
                ("factor", Json::num(*factor)),
            ]),
            ElasticSpec::Gradual { from, to, interval_s } => Json::obj(vec![
                ("kind", Json::str("gradual")),
                ("from", Json::num(*from as f64)),
                ("to", Json::num(*to as f64)),
                ("interval_s", Json::num(*interval_s)),
            ]),
            ElasticSpec::Trace { points } => Json::obj(vec![
                ("kind", Json::str("trace")),
                (
                    "points",
                    Json::Arr(
                        points
                            .iter()
                            .map(|(at, speeds)| {
                                Json::Arr(vec![
                                    Json::num(*at),
                                    Json::Arr(speeds.iter().map(|s| Json::num(*s)).collect()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(match v.get("kind")?.as_str()? {
            "rigid" => ElasticSpec::Rigid { nodes: v.get("nodes")?.as_usize()? },
            "heterogeneous" => ElasticSpec::Heterogeneous {
                fast: v.get("fast")?.as_usize()?,
                slow: v.get("slow")?.as_usize()?,
                factor: v.get("factor")?.as_f64()?,
            },
            "gradual" => ElasticSpec::Gradual {
                from: v.get("from")?.as_usize()?,
                to: v.get("to")?.as_usize()?,
                interval_s: v.get("interval_s")?.as_f64()?,
            },
            "trace" => {
                let points = v
                    .get("points")?
                    .as_arr()?
                    .iter()
                    .map(|p| {
                        let pair = p.as_arr()?;
                        let at = pair[0].as_f64()?;
                        let speeds = pair[1]
                            .as_arr()?
                            .iter()
                            .map(|s| s.as_f64())
                            .collect::<Result<Vec<f64>>>()?;
                        Ok((at, speeds))
                    })
                    .collect::<Result<Vec<_>>>()?;
                ElasticSpec::Trace { points }
            }
            other => bail!("unknown elastic kind {other:?}"),
        })
    }
}

/// NN architectures with AOT artifacts (prefixes must match the manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Cnn,
    TfmSmall,
    TfmE2e,
}

impl ModelKind {
    pub fn artifact_prefix(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Cnn => "cnn",
            ModelKind::TfmSmall => "tfm_small",
            ModelKind::TfmE2e => "tfm_e2e",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "mlp" => ModelKind::Mlp,
            "cnn" => ModelKind::Cnn,
            "tfm_small" => ModelKind::TfmSmall,
            "tfm_e2e" => ModelKind::TfmE2e,
            other => bail!("unknown model kind {other:?}"),
        })
    }
}

/// CoCoA hyper-parameters (paper §5.1). The objective is the *normalized*
/// SVM primal `lambda/2 ||w||^2 + 1/n sum hinge_i`; the paper's
/// "λ = #samples × 0.01" refers to the unnormalized objective and maps to
/// `lambda = 0.01` here (DESIGN.md §Substitutions).
#[derive(Clone, Debug)]
pub struct CocoaConfig {
    pub lambda: f64,
    /// Fraction of each task's local samples visited per iteration
    /// (paper: H = |local samples| → 1.0).
    pub local_passes: f64,
    /// Convergence target on the duality gap.
    pub target_gap: f64,
}

impl Default for CocoaConfig {
    fn default() -> Self {
        CocoaConfig { lambda: 0.01, local_passes: 1.0, target_gap: 1e-3 }
    }
}

/// Local-SGD hyper-parameters (paper §5.1: L=8, H=16, momentum 0.9,
/// lr scaled by sqrt(K)).
#[derive(Clone, Debug)]
pub struct LsgdConfig {
    pub model: ModelKind,
    /// Mini-batch size of one local step.
    pub l: usize,
    /// Local steps per iteration (H=1 degrades to mSGD).
    pub h: usize,
    /// Base learning rate α; effective α' = α·√K when `scale_lr` is set.
    pub lr: f64,
    pub momentum: f64,
    pub scale_lr: bool,
    /// Convergence target on test accuracy.
    pub target_acc: f64,
    /// Evaluate the test metric every this many iterations.
    pub eval_every: usize,
}

impl LsgdConfig {
    pub fn paper_defaults(model: ModelKind) -> Self {
        LsgdConfig {
            model,
            l: 8,
            h: 16,
            lr: 1e-4,
            momentum: 0.9,
            scale_lr: true,
            target_acc: 0.55,
            eval_every: 10,
        }
    }
}

/// Which training algorithm a session runs.
#[derive(Clone, Debug)]
pub enum AlgoConfig {
    Cocoa(CocoaConfig),
    Lsgd(LsgdConfig),
}

/// Policy toggles (paper §4.5).
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Move chunks from slow to fast nodes based on learned task runtimes.
    pub rebalance: bool,
    /// Iterations of history for the runtime estimate (paper's `I`).
    pub rebalance_window: usize,
    /// Max chunks moved per task per iteration ("gradually, across
    /// multiple iterations").
    pub rebalance_step: usize,
    /// Background global shuffling of chunks between tasks.
    pub shuffle: bool,
    pub shuffle_every: usize,
    /// Straggler mitigation: flag tasks slower than median × factor.
    pub straggler: bool,
    pub straggler_factor: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            rebalance: true,
            rebalance_window: 3,
            rebalance_step: 4,
            shuffle: false,
            shuffle_every: 10,
            straggler: false,
            straggler_factor: 2.0,
        }
    }
}

/// Full session description.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub name: String,
    pub algo: AlgoConfig,
    pub elastic: ElasticSpec,
    pub task_model: TaskModel,
    pub partitioning: Partitioning,
    pub backend: ComputeBackend,
    pub time_model: TimeModel,
    pub policies: PolicyConfig,
    /// Chunk size budget in bytes (paper: 1 MiB CoCoA, 200 KiB lSGD).
    pub chunk_bytes: usize,
    pub seed: u64,
    /// Stop conditions (whichever hits first).
    pub max_iters: usize,
    pub max_epochs: f64,
    /// Normalization constant for projected time (the paper's 16).
    pub ref_nodes: usize,
    /// Where the AOT artifacts live (HLO backend only).
    pub artifacts_dir: PathBuf,
    /// Held-out fraction for test metrics (lSGD).
    pub test_frac: f64,
    /// Pipeline the merge with the next iteration's dispatch on non-eval
    /// iterations (reduce/dispatch overlap). Trajectory-identical to the
    /// barriered schedule; disable to force a barrier after every merge.
    pub overlap: bool,
    /// Target shards per worker for the work-stealing pool reduction
    /// (larger = finer stealing granules; 1 = fixed one-shard-per-worker).
    /// With `adaptive_spw` set this is only the *starting* value.
    pub shards_per_worker: usize,
    /// Adapt `shards_per_worker` at runtime from observed steal counts
    /// (widen while a straggler sheds work, narrow when the pool is
    /// balanced), clamped to `[exec::SPW_MIN, exec::SPW_MAX]`. Never
    /// affects the trained model's bits — only the reduction's
    /// granularity; the value used each iteration lands in the `spw`
    /// TSV column. Constructors default this on; a JSON config that
    /// pins `shards_per_worker` without an `adaptive_spw` key keeps its
    /// fixed granularity (the pin is honored, not demoted to a seed).
    pub adaptive_spw: bool,
    /// How the per-iteration merge runs: the coordinator-side sharded
    /// reduction (default), or a peer-to-peer ring/tree allreduce over
    /// the transport layer. Bit-identical results either way; collectives
    /// are barriered, so `overlap` only takes effect under `Coordinator`.
    pub merge_strategy: MergeStrategy,
    /// Decouple the algorithmic parallelism degree K from the worker
    /// thread count W (uni-tasks only): `logical_tasks = K > 0` fixes K
    /// logical uni-tasks that are multiplexed round-robin onto however
    /// many worker threads the elastic schedule currently provides, and
    /// the iterate trajectory is bit-identical across any 1 ≤ W at that
    /// fixed K (threads beyond K sit idle). 0 — the default — keeps the
    /// legacy coupling where one task owns one thread and K tracks the
    /// node count. Ignored under micro-task emulation, which already
    /// fixes K its own way (and pays the wave model for it).
    pub logical_tasks: usize,
    /// Which transport backend the pool's ranks join: in-process
    /// channels (default) or loopback TCP with real framed sockets.
    /// Bit-identical trajectory either way (the conformance suite pins
    /// it); the `CHICLE_TRANSPORT` env var steers freshly constructed
    /// configs, which is how CI runs the `tier1-tcp` leg.
    pub transport: TransportKind,
}

impl SessionConfig {
    /// A rigid CoCoA session on `nodes` homogeneous nodes.
    pub fn cocoa(name: &str, nodes: usize) -> Self {
        SessionConfig {
            name: name.into(),
            algo: AlgoConfig::Cocoa(CocoaConfig::default()),
            elastic: ElasticSpec::Rigid { nodes },
            task_model: TaskModel::UniTasks,
            partitioning: Partitioning::RandomChunks,
            backend: ComputeBackend::Native,
            time_model: TimeModel::Projected,
            policies: PolicyConfig::default(),
            chunk_bytes: 1 << 20,
            seed: 42,
            max_iters: 200,
            max_epochs: f64::INFINITY,
            ref_nodes: 16,
            artifacts_dir: PathBuf::from("artifacts"),
            test_frac: 0.0,
            overlap: true,
            shards_per_worker: DEFAULT_SHARDS_PER_WORKER,
            adaptive_spw: true,
            merge_strategy: MergeStrategy::env_override().unwrap_or_default(),
            logical_tasks: logical_tasks_env().unwrap_or(0),
            transport: TransportKind::env_override().unwrap_or_default(),
        }
    }

    /// A rigid lSGD session with the paper's hyper-parameters.
    pub fn lsgd(name: &str, model: ModelKind, nodes: usize) -> Self {
        SessionConfig {
            name: name.into(),
            algo: AlgoConfig::Lsgd(LsgdConfig::paper_defaults(model)),
            elastic: ElasticSpec::Rigid { nodes },
            task_model: TaskModel::UniTasks,
            partitioning: Partitioning::RandomChunks,
            backend: ComputeBackend::Native,
            time_model: TimeModel::Projected,
            policies: PolicyConfig::default(),
            chunk_bytes: 200 * 1024,
            seed: 42,
            max_iters: 500,
            max_epochs: f64::INFINITY,
            ref_nodes: 16,
            artifacts_dir: PathBuf::from("artifacts"),
            test_frac: 0.15,
            overlap: true,
            shards_per_worker: DEFAULT_SHARDS_PER_WORKER,
            adaptive_spw: true,
            merge_strategy: MergeStrategy::env_override().unwrap_or_default(),
            logical_tasks: logical_tasks_env().unwrap_or(0),
            transport: TransportKind::env_override().unwrap_or_default(),
        }
    }

    /// The decoupled logical-task count, when task/thread decoupling is
    /// active: uni-tasks with `logical_tasks > 0`. Micro-task emulation
    /// and the legacy one-task-per-thread schedule both return `None`.
    pub fn decoupled_tasks(&self) -> Option<usize> {
        match self.task_model {
            TaskModel::UniTasks if self.logical_tasks > 0 => Some(self.logical_tasks),
            _ => None,
        }
    }

    pub fn with_elastic(mut self, spec: ElasticSpec) -> Self {
        self.elastic = spec;
        self
    }

    pub fn with_microtasks(mut self, k: usize) -> Self {
        self.task_model = TaskModel::MicroTasks { k };
        self
    }

    pub fn with_backend(mut self, backend: ComputeBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_overlap(mut self, overlap: bool) -> Self {
        self.overlap = overlap;
        self
    }

    pub fn with_merge_strategy(mut self, strategy: MergeStrategy) -> Self {
        self.merge_strategy = strategy;
        self
    }

    /// Pin the transport backend explicitly (wins over the
    /// `CHICLE_TRANSPORT` env override the constructors read).
    pub fn with_transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    /// Pin the logical-task count K explicitly (wins over the
    /// `CHICLE_LOGICAL_TASKS` env override the constructors read).
    pub fn with_logical_tasks(mut self, k: usize) -> Self {
        self.logical_tasks = k;
        self
    }

    // ---------------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        let algo = match &self.algo {
            AlgoConfig::Cocoa(c) => Json::obj(vec![
                ("kind", Json::str("cocoa")),
                ("lambda", Json::num(c.lambda)),
                ("local_passes", Json::num(c.local_passes)),
                ("target_gap", Json::num(c.target_gap)),
            ]),
            AlgoConfig::Lsgd(c) => Json::obj(vec![
                ("kind", Json::str("lsgd")),
                ("model", Json::str(c.model.artifact_prefix())),
                ("l", Json::num(c.l as f64)),
                ("h", Json::num(c.h as f64)),
                ("lr", Json::num(c.lr)),
                ("momentum", Json::num(c.momentum)),
                ("scale_lr", Json::Bool(c.scale_lr)),
                ("target_acc", Json::num(c.target_acc)),
                ("eval_every", Json::num(c.eval_every as f64)),
            ]),
        };
        let task_model = match self.task_model {
            TaskModel::UniTasks => Json::str("uni"),
            TaskModel::MicroTasks { k } => Json::obj(vec![
                ("kind", Json::str("micro")),
                ("k", Json::num(k as f64)),
            ]),
        };
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("algo", algo),
            ("elastic", self.elastic.to_json()),
            ("task_model", task_model),
            (
                "partitioning",
                Json::str(match self.partitioning {
                    Partitioning::RandomChunks => "random_chunks",
                    Partitioning::Contiguous => "contiguous",
                }),
            ),
            (
                "backend",
                Json::str(match self.backend {
                    ComputeBackend::Native => "native",
                    ComputeBackend::Hlo => "hlo",
                }),
            ),
            (
                "time_model",
                Json::str(match self.time_model {
                    TimeModel::Projected => "projected",
                    TimeModel::Measured => "measured",
                }),
            ),
            (
                "policies",
                Json::obj(vec![
                    ("rebalance", Json::Bool(self.policies.rebalance)),
                    ("rebalance_window", Json::num(self.policies.rebalance_window as f64)),
                    ("rebalance_step", Json::num(self.policies.rebalance_step as f64)),
                    ("shuffle", Json::Bool(self.policies.shuffle)),
                    ("shuffle_every", Json::num(self.policies.shuffle_every as f64)),
                    ("straggler", Json::Bool(self.policies.straggler)),
                    ("straggler_factor", Json::num(self.policies.straggler_factor)),
                ]),
            ),
            ("chunk_bytes", Json::num(self.chunk_bytes as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("max_iters", Json::num(self.max_iters as f64)),
            (
                "max_epochs",
                if self.max_epochs.is_finite() {
                    Json::num(self.max_epochs)
                } else {
                    Json::Null
                },
            ),
            ("ref_nodes", Json::num(self.ref_nodes as f64)),
            ("artifacts_dir", Json::str(&self.artifacts_dir.to_string_lossy())),
            ("test_frac", Json::num(self.test_frac)),
            ("overlap", Json::Bool(self.overlap)),
            ("shards_per_worker", Json::num(self.shards_per_worker as f64)),
            ("adaptive_spw", Json::Bool(self.adaptive_spw)),
            ("merge_strategy", Json::str(self.merge_strategy.as_str())),
            ("logical_tasks", Json::num(self.logical_tasks as f64)),
            ("transport", Json::str(self.transport.as_str())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let algo_v = v.get("algo")?;
        let algo = match algo_v.get("kind")?.as_str()? {
            "cocoa" => AlgoConfig::Cocoa(CocoaConfig {
                lambda: algo_v.get("lambda")?.as_f64()?,
                local_passes: algo_v.get("local_passes")?.as_f64()?,
                target_gap: algo_v.get("target_gap")?.as_f64()?,
            }),
            "lsgd" => AlgoConfig::Lsgd(LsgdConfig {
                model: ModelKind::parse(algo_v.get("model")?.as_str()?)?,
                l: algo_v.get("l")?.as_usize()?,
                h: algo_v.get("h")?.as_usize()?,
                lr: algo_v.get("lr")?.as_f64()?,
                momentum: algo_v.get("momentum")?.as_f64()?,
                scale_lr: algo_v.get("scale_lr")?.as_bool()?,
                target_acc: algo_v.get("target_acc")?.as_f64()?,
                eval_every: algo_v.get("eval_every")?.as_usize()?,
            }),
            other => bail!("unknown algo kind {other:?}"),
        };
        let task_model = match v.get("task_model")? {
            Json::Str(s) if s == "uni" => TaskModel::UniTasks,
            tm => TaskModel::MicroTasks { k: tm.get("k")?.as_usize()? },
        };
        let p = v.get("policies")?;
        Ok(SessionConfig {
            name: v.get("name")?.as_str()?.to_string(),
            algo,
            elastic: ElasticSpec::from_json(v.get("elastic")?)?,
            task_model,
            partitioning: match v.get("partitioning")?.as_str()? {
                "random_chunks" => Partitioning::RandomChunks,
                "contiguous" => Partitioning::Contiguous,
                other => bail!("unknown partitioning {other:?}"),
            },
            backend: match v.get("backend")?.as_str()? {
                "native" => ComputeBackend::Native,
                "hlo" => ComputeBackend::Hlo,
                other => bail!("unknown backend {other:?}"),
            },
            time_model: match v.get("time_model")?.as_str()? {
                "projected" => TimeModel::Projected,
                "measured" => TimeModel::Measured,
                other => bail!("unknown time model {other:?}"),
            },
            policies: PolicyConfig {
                rebalance: p.get("rebalance")?.as_bool()?,
                rebalance_window: p.get("rebalance_window")?.as_usize()?,
                rebalance_step: p.get("rebalance_step")?.as_usize()?,
                shuffle: p.get("shuffle")?.as_bool()?,
                shuffle_every: p.get("shuffle_every")?.as_usize()?,
                straggler: p.get("straggler")?.as_bool()?,
                straggler_factor: p.get("straggler_factor")?.as_f64()?,
            },
            chunk_bytes: v.get("chunk_bytes")?.as_usize()?,
            seed: v.get("seed")?.as_f64()? as u64,
            max_iters: v.get("max_iters")?.as_usize()?,
            max_epochs: match v.get("max_epochs")? {
                Json::Null => f64::INFINITY,
                n => n.as_f64()?,
            },
            ref_nodes: v.get("ref_nodes")?.as_usize()?,
            artifacts_dir: PathBuf::from(v.get("artifacts_dir")?.as_str()?),
            test_frac: v.get("test_frac")?.as_f64()?,
            // Absent in configs written before the overlap pipeline.
            overlap: v.opt("overlap").map(Json::as_bool).transpose()?.unwrap_or(true),
            shards_per_worker: v
                .opt("shards_per_worker")
                .map(Json::as_usize)
                .transpose()?
                .unwrap_or(DEFAULT_SHARDS_PER_WORKER),
            // Missing-key default: a config that *explicitly pinned*
            // shards_per_worker (but predates adaptive_spw) keeps its
            // fixed granularity — the pin meant something; only configs
            // that never chose a granularity get adaptation by default.
            adaptive_spw: v
                .opt("adaptive_spw")
                .map(Json::as_bool)
                .transpose()?
                .unwrap_or(v.opt("shards_per_worker").is_none()),
            // Absent in configs written before the transport layer; a
            // saved config pins its strategy, so no env override here.
            merge_strategy: v
                .opt("merge_strategy")
                .map(|m| MergeStrategy::parse(m.as_str()?))
                .transpose()?
                .unwrap_or_default(),
            // Absent in configs written before task/thread decoupling; a
            // saved config pins its K, so no env override here either.
            logical_tasks: v
                .opt("logical_tasks")
                .map(Json::as_usize)
                .transpose()?
                .unwrap_or(0),
            // Absent in configs written before the TCP backend; a saved
            // config pins its backend, so no env override here either.
            transport: v
                .opt("transport")
                .map(|t| TransportKind::parse(t.as_str()?))
                .transpose()?
                .unwrap_or_default(),
        })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ResourceManager as _;

    #[test]
    fn json_roundtrip_cocoa() {
        let cfg = SessionConfig::cocoa("t", 4);
        let back = SessionConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.name, "t");
        assert!(matches!(back.algo, AlgoConfig::Cocoa(_)));
        assert!(matches!(back.elastic, ElasticSpec::Rigid { nodes: 4 }));
        assert!(back.max_epochs.is_infinite());
        assert!(back.overlap);
        assert_eq!(back.shards_per_worker, DEFAULT_SHARDS_PER_WORKER);
    }

    #[test]
    fn overlap_fields_default_when_absent_from_json() {
        // Configs written before the overlap pipeline lack both keys.
        let legacy = match SessionConfig::cocoa("legacy", 2).to_json() {
            Json::Obj(mut o) => {
                o.remove("overlap");
                o.remove("shards_per_worker");
                o.remove("adaptive_spw");
                Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let back = SessionConfig::from_json(&legacy).unwrap();
        assert!(back.overlap, "missing key defaults to enabled");
        assert_eq!(back.shards_per_worker, DEFAULT_SHARDS_PER_WORKER);
        assert!(back.adaptive_spw, "no granularity chosen → adaptive by default");

        // A legacy config that *pinned* shards_per_worker (but predates
        // adaptive_spw) must keep its fixed granularity.
        let pinned = match SessionConfig::cocoa("pinned", 2).to_json() {
            Json::Obj(mut o) => {
                o.remove("adaptive_spw");
                Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let back = SessionConfig::from_json(&pinned).unwrap();
        assert!(!back.adaptive_spw, "explicit spw pin must stay fixed");
        assert_eq!(back.shards_per_worker, DEFAULT_SHARDS_PER_WORKER);
    }

    #[test]
    fn merge_strategy_roundtrips_and_defaults() {
        let cfg = SessionConfig::cocoa("ring", 4).with_merge_strategy(MergeStrategy::Ring);
        let back = SessionConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.merge_strategy, MergeStrategy::Ring);

        // Configs written before the transport layer lack the key.
        let legacy = match SessionConfig::cocoa("legacy", 2).to_json() {
            Json::Obj(mut o) => {
                o.remove("merge_strategy");
                Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let back = SessionConfig::from_json(&legacy).unwrap();
        assert_eq!(back.merge_strategy, MergeStrategy::Coordinator);

        assert!(MergeStrategy::parse("butterfly").is_err());
        assert_eq!(MergeStrategy::parse("tree").unwrap().as_str(), "tree");
    }

    #[test]
    fn transport_roundtrips_and_defaults() {
        // The env-override precedence is covered by CI's tier1-tcp leg
        // (its own process) — mutating CHICLE_TRANSPORT here could race
        // parallel unit tests that construct configs through the
        // env-reading paths.
        let cfg = SessionConfig::cocoa("tcp", 4).with_transport(TransportKind::Tcp);
        let back = SessionConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.transport, TransportKind::Tcp);

        // Configs written before the TCP backend lack the key.
        let legacy = match SessionConfig::cocoa("legacy", 2).to_json() {
            Json::Obj(mut o) => {
                o.remove("transport");
                Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let back = SessionConfig::from_json(&legacy).unwrap();
        assert_eq!(back.transport, TransportKind::Channel);

        assert!(TransportKind::parse("udp").is_err());
        assert_eq!(TransportKind::parse("tcp").unwrap().as_str(), "tcp");
    }

    #[test]
    fn logical_tasks_roundtrips_and_defaults() {
        // The env-override precedence itself is covered in
        // tests/logical_tasks.rs (its own process, like the merge-strategy
        // env test) — mutating the variable here could race parallel unit
        // tests that construct configs through the env-reading paths.
        let cfg = SessionConfig::cocoa("k8", 4).with_logical_tasks(8);
        assert_eq!(cfg.decoupled_tasks(), Some(8));
        let back = SessionConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.logical_tasks, 8);

        // Micro-task emulation fixes K its own way; decoupling stands down.
        assert_eq!(cfg.with_microtasks(16).decoupled_tasks(), None);

        // Configs written before task/thread decoupling lack the key.
        let legacy = match SessionConfig::cocoa("legacy", 2).to_json() {
            Json::Obj(mut o) => {
                o.remove("logical_tasks");
                Json::Obj(o)
            }
            _ => unreachable!(),
        };
        let back = SessionConfig::from_json(&legacy).unwrap();
        assert_eq!(back.logical_tasks, 0);
        assert_eq!(back.decoupled_tasks(), None);
    }

    #[test]
    fn json_roundtrip_lsgd_micro() {
        let cfg = SessionConfig::lsgd("x", ModelKind::Cnn, 16).with_microtasks(32);
        let back =
            SessionConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert!(matches!(back.task_model, TaskModel::MicroTasks { k: 32 }));
        if let AlgoConfig::Lsgd(l) = &back.algo {
            assert_eq!((l.l, l.h), (8, 16));
            assert_eq!(l.model, ModelKind::Cnn);
        } else {
            panic!();
        }
    }

    #[test]
    fn elastic_specs_build_rms() {
        let rm = ElasticSpec::Gradual { from: 2, to: 8, interval_s: 20.0 }.build_rm();
        assert_eq!(rm.allocation_at(Duration::ZERO).len(), 2);
        assert_eq!(rm.allocation_at(Duration::from_secs(100)).len(), 8);
        let het = ElasticSpec::Heterogeneous { fast: 2, slow: 2, factor: 1.5 }.build_rm();
        assert_eq!(het.assigned().len(), 4);
        assert!(het.assigned()[3].speed < 1.0);
    }

    #[test]
    fn elastic_trace_json_roundtrip() {
        let spec = ElasticSpec::Trace {
            points: vec![(0.0, vec![1.0, 0.5]), (10.0, vec![1.0, 0.5, 1.0])],
        };
        let back = ElasticSpec::from_json(&spec.to_json()).unwrap();
        match back {
            ElasticSpec::Trace { points } => {
                assert_eq!(points.len(), 2);
                assert_eq!(points[1].1.len(), 3);
            }
            _ => panic!(),
        }
        assert_eq!(spec.max_nodes(), 3);
    }
}
