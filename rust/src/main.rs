//! Chicle launcher: run training sessions from JSON configs or built-in
//! presets.
//!
//! ```text
//! chicle train --config session.json            # JSON session config
//! chicle train --preset cocoa-higgs [--nodes 4] [--backend hlo] ...
//! chicle inspect --artifacts artifacts          # list AOT artifacts
//! chicle emit-config --preset cocoa-higgs       # dump a config to edit
//! ```
//!
//! (Arg parsing is hand-rolled: this repo builds fully offline without
//! clap — see `util` module docs.)

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use chicle::config::{AlgoConfig, ComputeBackend, ElasticSpec, ModelKind, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::{synth, Dataset};
use chicle::runtime::Manifest;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> chicle::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "train" => train(&flags),
        "inspect" => inspect(&flags),
        "emit-config" => emit_config(&flags),
        "-h" | "--help" | "help" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?} (try --help)"),
    }
}

fn print_usage() {
    println!(
        "chicle — elastic distributed ML training with uni-tasks\n\n\
         USAGE:\n  chicle train --config <file.json>\n  \
         chicle train --preset <name> [--nodes N] [--backend native|hlo]\n                \
         [--samples N] [--iters N] [--seed N] [--elastic from:to:interval]\n  \
         chicle inspect [--artifacts DIR]\n  \
         chicle emit-config --preset <name>\n\n\
         PRESETS:\n  cocoa-higgs    CoCoA/SCD SVM on higgs_like (dense)\n  \
         cocoa-criteo   CoCoA/SCD SVM on criteo_like (sparse)\n  \
         lsgd-cifar     local SGD, paper CNN, cifar_like\n  \
         lsgd-fmnist    local SGD, MLP, fmnist_like\n  \
         lsgd-lm        local SGD, transformer LM, token corpus (hlo only)"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

/// Build (config, dataset) for a named preset.
fn preset(name: &str, flags: &HashMap<String, String>) -> chicle::Result<(SessionConfig, Dataset)> {
    let samples: usize = flags
        .get("samples")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(20_000);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let nodes: usize = flags.get("nodes").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let (mut cfg, ds) = match name {
        "cocoa-higgs" => (
            SessionConfig::cocoa("cocoa-higgs", nodes),
            synth::higgs_like(samples, seed),
        ),
        "cocoa-criteo" => {
            let mut c = SessionConfig::cocoa("cocoa-criteo", nodes);
            c.chunk_bytes = 64 * 1024;
            (c, synth::criteo_like(samples, seed))
        }
        "lsgd-cifar" => (
            SessionConfig::lsgd("lsgd-cifar", ModelKind::Cnn, nodes),
            synth::cifar_like(samples.min(8000), seed),
        ),
        "lsgd-fmnist" => (
            SessionConfig::lsgd("lsgd-fmnist", ModelKind::Mlp, nodes),
            synth::fmnist_like(samples.min(12_000), seed),
        ),
        "lsgd-lm" => {
            let mut c = SessionConfig::lsgd("lsgd-lm", ModelKind::TfmSmall, nodes);
            c.backend = ComputeBackend::Hlo;
            c.chunk_bytes = 16 * 1024;
            (c, synth::token_corpus(samples.min(2000), 64, 1024, seed))
        }
        other => anyhow::bail!("unknown preset {other:?}"),
    };
    cfg.seed = seed;
    if let Some(b) = flags.get("backend") {
        cfg.backend = match b.as_str() {
            "native" => ComputeBackend::Native,
            "hlo" => ComputeBackend::Hlo,
            other => anyhow::bail!("unknown backend {other:?}"),
        };
    }
    if let Some(it) = flags.get("iters") {
        cfg.max_iters = it.parse()?;
    }
    if let Some(el) = flags.get("elastic") {
        let parts: Vec<&str> = el.split(':').collect();
        anyhow::ensure!(parts.len() == 3, "--elastic expects from:to:interval_s");
        cfg.elastic = ElasticSpec::Gradual {
            from: parts[0].parse()?,
            to: parts[1].parse()?,
            interval_s: parts[2].parse()?,
        };
    }
    if let Some(dir) = flags.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(dir);
    }
    Ok((cfg, ds))
}

fn dataset_for(cfg: &SessionConfig, samples: usize) -> Dataset {
    // Used for --config runs: pick the generator matching the algo/model.
    match &cfg.algo {
        AlgoConfig::Cocoa(_) => synth::higgs_like(samples, cfg.seed),
        AlgoConfig::Lsgd(l) => match l.model {
            ModelKind::Mlp => synth::fmnist_like(samples, cfg.seed),
            ModelKind::Cnn => synth::cifar_like(samples, cfg.seed),
            ModelKind::TfmSmall | ModelKind::TfmE2e => {
                synth::token_corpus(samples, 64, 1024, cfg.seed)
            }
        },
    }
}

fn train(flags: &HashMap<String, String>) -> chicle::Result<()> {
    let (cfg, ds) = if let Some(path) = flags.get("config") {
        let cfg = SessionConfig::load(Path::new(path))?;
        let samples: usize = flags
            .get("samples")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(20_000);
        let ds = dataset_for(&cfg, samples);
        (cfg, ds)
    } else if let Some(name) = flags.get("preset") {
        preset(name, flags)?
    } else {
        anyhow::bail!("train needs --config <file> or --preset <name>");
    };

    println!(
        "session {:?}: {} samples ({}), backend {:?}",
        cfg.name,
        ds.n_samples(),
        ds.name,
        cfg.backend
    );
    let mut session = TrainingSession::new(cfg, ds)?;
    let log = session.run()?;
    print!("{}", log.to_tsv());
    eprintln!(
        "done: {} iterations, {:.2} epochs, vtime {:.2}s, wall {:.2}s",
        log.records.len(),
        log.total_epochs(),
        log.total_vtime().as_secs_f64(),
        log.total_wall().as_secs_f64()
    );
    if let Some(g) = log.last_gap() {
        eprintln!("final duality gap: {g:.6}");
    }
    if let Some(a) = log.last_accuracy() {
        eprintln!("final test accuracy: {a:.4}");
    }
    Ok(())
}

fn inspect(flags: &HashMap<String, String>) -> chicle::Result<()> {
    let dir = flags
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let m = Manifest::load(&dir)?;
    println!("{} artifacts in {}:", m.artifacts.len(), dir.display());
    let mut names: Vec<&String> = m.artifacts.keys().collect();
    names.sort();
    for name in names {
        let a = &m.artifacts[name];
        println!(
            "  {:<28} {} inputs -> {} outputs ({})",
            name,
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    println!("{} models:", m.models.len());
    for (name, model) in &m.models {
        println!("  {:<28} {} params, {} tensors", name, model.param_count, model.params.len());
    }
    Ok(())
}

fn emit_config(flags: &HashMap<String, String>) -> chicle::Result<()> {
    let name = flags
        .get("preset")
        .ok_or_else(|| anyhow::anyhow!("emit-config needs --preset"))?;
    let (cfg, _) = preset(name, flags)?;
    println!("{}", cfg.to_json().to_string_pretty());
    Ok(())
}
