//! Virtual clock for deterministic, trace-driven experiments.
//!
//! The evaluation projects convergence over time from per-epoch
//! measurements plus a schedule model (paper §5.3 "Methodology") — the
//! clock is advanced by the model, not by wallclock.

use std::time::Duration;

/// Monotonic virtual clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualClock {
    now: Duration,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: Duration::ZERO }
    }

    pub fn now(&self) -> Duration {
        self.now
    }

    pub fn advance(&mut self, by: Duration) {
        self.now += by;
    }

    pub fn advance_secs(&mut self, by: f64) {
        assert!(by >= 0.0, "cannot advance clock backwards ({by})");
        self.now += Duration::from_secs_f64(by);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_secs(2));
        c.advance_secs(0.5);
        assert_eq!(c.now(), Duration::from_millis(2500));
    }

    #[test]
    #[should_panic]
    fn rejects_negative_advance() {
        VirtualClock::new().advance_secs(-1.0);
    }
}
