//! The paper's time-projection model (§5.3 "Methodology", §5.4).
//!
//! Time is normalized so that *one task processing 1/16th of the dataset on
//! a unit-speed node takes one time unit* (16 = the reference cluster
//! size). The evaluation measures convergence per epoch with real training
//! and *projects* convergence over time with this model — we implement it
//! verbatim:
//!
//! * **micro-tasks**: K tasks on N nodes need `ceil(K/N)` task waves; on a
//!   homogeneous cluster an iteration takes `16/K * ceil(K/N)` units. On a
//!   heterogeneous cluster the optimal schedule is the minimal makespan of
//!   K identical tasks over the node speeds (the paper's
//!   `max(i*1.5, j*1.0) * 16/K` example generalized).
//! * **uni-tasks**: load is rebalanced so every node finishes together: an
//!   iteration covering `total_units` of work takes
//!   `total_units / sum(speeds)` units.
//!
//! Data-transfer overheads are deliberately excluded — as in the paper,
//! which notes this *favors micro-tasks*.

use crate::cluster::NodeSpec;

/// Minimal makespan of `k` identical tasks, each costing `task_units /
/// speed(n)` on node `n`. For identical tasks the greedy "next task to the
/// node with least resulting finish time" assignment is optimal.
pub fn makespan(k: usize, task_units: f64, nodes: &[NodeSpec]) -> f64 {
    assert!(!nodes.is_empty(), "makespan over empty cluster");
    if k == 0 {
        return 0.0;
    }
    let mut counts = vec![0usize; nodes.len()];
    for _ in 0..k {
        // Node that minimizes its finish time after taking one more task.
        let (best, _) = counts
            .iter()
            .zip(nodes)
            .enumerate()
            .map(|(i, (c, n))| (i, (*c as f64 + 1.0) * task_units / n.speed))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        counts[best] += 1;
    }
    counts
        .iter()
        .zip(nodes)
        .map(|(c, n)| *c as f64 * task_units / n.speed)
        .fold(0.0, f64::max)
}

/// Iteration time for K micro-tasks on `nodes`, where the whole iteration
/// comprises `iter_units` units of work split evenly across the K tasks
/// (CoCoA: `iter_units = 16`; lSGD: `iter_units = K` since every task
/// processes one L×H batch = one unit).
pub fn microtask_iteration_time(k: usize, iter_units: f64, nodes: &[NodeSpec]) -> f64 {
    makespan(k, iter_units / k as f64, nodes)
}

/// Iteration time for uni-tasks with perfect chunk-level load balancing:
/// `total_units / sum(speeds)` (paper §5.3: `16/N` on homogeneous nodes;
/// §5.4: `1.2s` on 8 fast + 8 slow).
pub fn uni_iteration_time(total_units: f64, nodes: &[NodeSpec]) -> f64 {
    let speed_sum: f64 = nodes.iter().map(|n| n.speed).sum();
    assert!(speed_sum > 0.0);
    total_units / speed_sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_32_tasks_14_nodes() {
        // §5.3: K=32 on N=14 → 3 waves → 16/32 * 3 = 1.5 units.
        let nodes = NodeSpec::homogeneous(14);
        let t = microtask_iteration_time(32, 16.0, &nodes);
        assert!((t - 1.5).abs() < 1e-9, "{t}");
    }

    #[test]
    fn paper_example_uni_14_nodes() {
        // §5.3: uni-tasks on 14 nodes → 16/14 ≈ 1.14 units.
        let nodes = NodeSpec::homogeneous(14);
        let t = uni_iteration_time(16.0, &nodes);
        assert!((t - 16.0 / 14.0).abs() < 1e-9);
    }

    #[test]
    fn paper_example_heterogeneous_64_tasks() {
        // §5.4: K=64 on 8 fast + 8 slow(1.5×): optimal is 3 tasks/slow,
        // 5 tasks/fast → max(3*1.5, 5*1.0) * 16/64 = 1.25.
        let nodes = NodeSpec::heterogeneous(8, 8, 1.5);
        let t = microtask_iteration_time(64, 16.0, &nodes);
        assert!((t - 1.25).abs() < 1e-9, "{t}");
    }

    #[test]
    fn paper_example_heterogeneous_uni() {
        // §5.4: uni-tasks → 16 / (8 + 8/1.5) = 1.2.
        let nodes = NodeSpec::heterogeneous(8, 8, 1.5);
        let t = uni_iteration_time(16.0, &nodes);
        assert!((t - 1.2).abs() < 1e-9, "{t}");
    }

    #[test]
    fn section23_worked_example() {
        // §2.3: 256 tasks on 128 nodes → 2 waves → 2 units/epoch (epoch =
        // one iteration at 16/K normalization... the example uses 1 s per
        // epoch at 256 nodes). With our units: 16/256 * ceil(256/128) =
        // 0.125; relative slowdown vs full parallelism = 2×. 128 tasks on
        // 128 nodes = 16/128 = 0.125 — same per-iteration, but 8 epochs vs
        // 10 epochs is the algorithmic side.
        let n128 = NodeSpec::homogeneous(128);
        let t256 = microtask_iteration_time(256, 16.0, &n128);
        let t128 = microtask_iteration_time(128, 16.0, &n128);
        assert!((t256 / t128 - 1.0).abs() < 1e-9); // same time per iteration
        let n256 = NodeSpec::homogeneous(256);
        let t256_full = microtask_iteration_time(256, 16.0, &n256);
        assert!((t256 / t256_full - 2.0).abs() < 1e-9); // 2 waves when halved
    }

    #[test]
    fn microtasks_equal_nodes_match_uni_homogeneous() {
        let nodes = NodeSpec::homogeneous(16);
        let micro = microtask_iteration_time(16, 16.0, &nodes);
        let uni = uni_iteration_time(16.0, &nodes);
        assert!((micro - uni).abs() < 1e-12);
    }

    #[test]
    fn makespan_zero_tasks_is_zero() {
        assert_eq!(makespan(0, 1.0, &NodeSpec::homogeneous(4)), 0.0);
    }

    #[test]
    fn uni_always_leq_micro() {
        // Uni-task balancing can never be slower than the best micro-task
        // schedule of the same total work.
        for &k in &[16usize, 24, 32, 64] {
            for n in [3usize, 5, 8, 13, 16] {
                let nodes = NodeSpec::heterogeneous(n / 2, n - n / 2, 1.5);
                let micro = microtask_iteration_time(k, 16.0, &nodes);
                let uni = uni_iteration_time(16.0, &nodes);
                assert!(uni <= micro + 1e-9, "k={k} n={n}: {uni} > {micro}");
            }
        }
    }
}
