//! Time simulation: virtual clock + the paper's projection methodology.

pub mod clock;
pub mod projection;

pub use clock::VirtualClock;
pub use projection::{makespan, microtask_iteration_time, uni_iteration_time};
