//! Hinge-loss SVM dual math: native SDCA over chunks + duality gap.
//!
//! Mirrors the L1 Pallas kernel (`python/compile/kernels/scd.py`) exactly:
//!
//! ```text
//! primal  P(w) = λ/2 ||w||² + 1/n Σ max(0, 1 − y_i x_i·w)
//! dual    D(α) = 1/n Σ α_i − λ/2 ||w(α)||²,   α_i ∈ [0, 1]
//! w(α)    = 1/(λn) Σ α_i y_i x_i
//! step    Δ = (1 − y_i x_i·w_loc) / (σ'·‖x_i‖²/(λn)), α_i ← clip(α_i+Δ, 0, 1)
//!          with w_loc = w + σ'·dv (CoCoA+ local subproblem view)
//! gap     P − D = 1/n Σ (hinge_i − α_i) + λ‖w‖²
//! ```

use crate::chunks::{Chunk, Samples};
use crate::util::kernels;

type DotFn = fn(&[f32], &[f32]) -> f32;
type FusedAxpy2Fn = fn(&mut [f32], &mut [f32], f32, f32, &[f32]);

/// Shared dense-pass body, parameterized over the dot / fused-axpy
/// kernels so the dispatched and scalar-reference entry points run the
/// exact same row loop (and therefore produce bit-identical α, v, dv —
/// the bench pair below measures pure kernel speedup).
#[allow(clippy::too_many_arguments)]
fn scd_pass_dense_with(
    dot_fn: DotFn,
    fax2: FusedAxpy2Fn,
    x: &[f32],
    dim: usize,
    y: &[f32],
    order: &[usize],
    alpha: &mut [f32],
    v: &mut [f32],
    dv: &mut [f32],
    lam_n: f32,
    sigma: f32,
) {
    for &i in order {
        let xi = &x[i * dim..(i + 1) * dim];
        let sq = dot_fn(xi, xi);
        if sq <= 0.0 {
            continue;
        }
        let margin = y[i] * dot_fn(xi, v);
        let step = (1.0 - margin) / (sigma * sq / lam_n);
        let a_new = (alpha[i] + step).clamp(0.0, 1.0);
        if a_new == alpha[i] {
            // Clipped no-op (α pinned at its box bound) — skip the axpy.
            continue;
        }
        let scale = (a_new - alpha[i]) * y[i] / lam_n;
        alpha[i] = a_new;
        // Fused axpy into both v (σ'-scaled CoCoA+ local view) and dv
        // (raw delta for the global merge).
        fax2(v, dv, sigma, scale, xi);
    }
}

/// One local SDCA pass over a dense chunk: visit rows in `order`, mutate
/// `alpha` (chunk state) and `v` in place, and accumulate the delta in
/// `dv`. Identical math to the Pallas kernel (incl. the zero-norm guard
/// for padding rows).
#[allow(clippy::too_many_arguments)]
pub fn scd_pass_dense(
    x: &[f32],
    dim: usize,
    y: &[f32],
    order: &[usize],
    alpha: &mut [f32],
    v: &mut [f32],
    dv: &mut [f32],
    lam_n: f32,
    sigma: f32,
) {
    scd_pass_dense_with(
        kernels::dot,
        kernels::fused_axpy2,
        x,
        dim,
        y,
        order,
        alpha,
        v,
        dv,
        lam_n,
        sigma,
    )
}

/// Scalar-reference twin of [`scd_pass_dense`] (bench pairing / parity):
/// same row loop, forced onto the scalar kernels. Bit-identical output.
#[allow(clippy::too_many_arguments)]
pub fn scd_pass_dense_scalar(
    x: &[f32],
    dim: usize,
    y: &[f32],
    order: &[usize],
    alpha: &mut [f32],
    v: &mut [f32],
    dv: &mut [f32],
    lam_n: f32,
    sigma: f32,
) {
    scd_pass_dense_with(
        kernels::scalar::dot,
        kernels::scalar::fused_axpy2,
        x,
        dim,
        y,
        order,
        alpha,
        v,
        dv,
        lam_n,
        sigma,
    )
}

type SparseDotFn = fn(&[u32], &[f32], &[f32]) -> f32;
type SparseFusedAxpy2Fn = fn(&mut [f32], &mut [f32], f32, f32, &[u32], &[f32]);

/// Shared sparse-pass body, parameterized over the dense-dot (for the
/// row self-product), sparse-dot (gather), and sparse fused-axpy
/// (scatter) kernels — the dispatched and scalar-reference entry points
/// run the exact same row loop and produce bit-identical α, v, dv.
///
/// Note: unlike the dense pass there is no clipped-no-op skip here; the
/// sparse scatter is cheap enough that the branch costs more than it
/// saves, and keeping the loop unconditional preserves the historical
/// trajectory bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn scd_pass_sparse_with(
    dot_fn: DotFn,
    sdot: SparseDotFn,
    sfax2: SparseFusedAxpy2Fn,
    rows: &[crate::data::SparseVec],
    y: &[f32],
    order: &[usize],
    alpha: &mut [f32],
    v: &mut [f32],
    dv: &mut [f32],
    lam_n: f32,
    sigma: f32,
) {
    for &i in order {
        let row = &rows[i];
        let sq = dot_fn(&row.values, &row.values);
        if sq <= 0.0 {
            continue;
        }
        let margin = y[i] * sdot(&row.indices, &row.values, v);
        let step = (1.0 - margin) / (sigma * sq / lam_n);
        let a_new = (alpha[i] + step).clamp(0.0, 1.0);
        let scale = (a_new - alpha[i]) * y[i] / lam_n;
        alpha[i] = a_new;
        // CoCoA+ local view: own updates enter v scaled by sigma', the
        // raw delta accumulates in dv for the global merge.
        sfax2(v, dv, sigma, scale, &row.indices, &row.values);
    }
}

/// Sparse-row variant (Criteo-like workload): gather dot for the margin,
/// scatter fused-axpy for the update, both runtime-dispatched.
#[allow(clippy::too_many_arguments)]
pub fn scd_pass_sparse(
    rows: &[crate::data::SparseVec],
    y: &[f32],
    order: &[usize],
    alpha: &mut [f32],
    v: &mut [f32],
    dv: &mut [f32],
    lam_n: f32,
    sigma: f32,
) {
    scd_pass_sparse_with(
        kernels::dot,
        kernels::sparse_dot,
        kernels::sparse_fused_axpy2,
        rows,
        y,
        order,
        alpha,
        v,
        dv,
        lam_n,
        sigma,
    )
}

/// Scalar-reference twin of [`scd_pass_sparse`] (bench pairing / parity):
/// same row loop, forced onto the scalar kernels. Bit-identical output.
#[allow(clippy::too_many_arguments)]
pub fn scd_pass_sparse_scalar(
    rows: &[crate::data::SparseVec],
    y: &[f32],
    order: &[usize],
    alpha: &mut [f32],
    v: &mut [f32],
    dv: &mut [f32],
    lam_n: f32,
    sigma: f32,
) {
    scd_pass_sparse_with(
        kernels::scalar::dot,
        kernels::scalar::sparse_dot,
        kernels::scalar::sparse_fused_axpy2,
        rows,
        y,
        order,
        alpha,
        v,
        dv,
        lam_n,
        sigma,
    )
}

/// Per-chunk duality-gap contributions: (Σ hinge, Σ α, Σ correct, n).
pub fn gap_contributions(chunk: &Chunk, w: &[f32]) -> (f64, f64, f64, usize) {
    let (mut hinge, mut alpha_sum, mut correct) = (0.0f64, 0.0f64, 0.0f64);
    let mut n = 0usize;
    match chunk.samples() {
        Samples::DenseBinary { x, dim, y } => {
            for (i, &yi) in y.iter().enumerate() {
                if yi == 0.0 {
                    continue;
                }
                let margin = yi * dot(&x[i * dim..(i + 1) * dim], w);
                hinge += (1.0 - margin).max(0.0) as f64;
                alpha_sum += chunk.state[i] as f64;
                if margin > 0.0 {
                    correct += 1.0;
                }
                n += 1;
            }
        }
        Samples::SparseBinary { rows, y, .. } => {
            for (i, &yi) in y.iter().enumerate() {
                if yi == 0.0 {
                    continue;
                }
                let margin = yi * rows[i].dot_dense(w);
                hinge += (1.0 - margin).max(0.0) as f64;
                alpha_sum += chunk.state[i] as f64;
                if margin > 0.0 {
                    correct += 1.0;
                }
                n += 1;
            }
        }
        _ => panic!("gap_contributions on non-binary chunk"),
    }
    (hinge, alpha_sum, correct, n)
}

/// Combine per-chunk contributions: gap = (Σhinge − Σα)/n + λ‖w‖².
pub fn duality_gap(total_hinge: f64, total_alpha: f64, n: usize, w: &[f32], lambda: f64) -> f64 {
    let w_sq: f64 = w.iter().map(|&v| (v as f64) * (v as f64)).sum();
    (total_hinge - total_alpha) / n as f64 + lambda * w_sq
}

/// Deterministic dot product (fixed-lane-split kernel: identical bits
/// run-to-run and between the scalar and SIMD paths; see
/// [`crate::util::kernels`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    kernels::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::chunker::make_chunks;
    use crate::data::synth;
    use crate::util::Rng;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..103).map(|i| (i % 7) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn scd_alpha_in_box_and_v_consistent() {
        let mut r = Rng::seed_from_u64(0);
        let (s, dim) = (64usize, 8usize);
        let x: Vec<f32> = (0..s * dim).map(|_| r.normal_f32()).collect();
        let y: Vec<f32> = (0..s).map(|_| if r.bool(0.5) { 1.0 } else { -1.0 }).collect();
        let mut alpha = vec![0.0f32; s];
        let mut v = vec![0.0f32; dim];
        let mut dv = vec![0.0f32; dim];
        let order: Vec<usize> = (0..s).collect();
        let lam_n = 0.01 * s as f32;
        scd_pass_dense(&x, dim, &y, &order, &mut alpha, &mut v, &mut dv, lam_n, 1.0);
        assert!(alpha.iter().all(|&a| (0.0..=1.0).contains(&a)));
        // v must equal w(alpha) = 1/(λn) Σ α_i y_i x_i after starting from 0.
        let mut w = vec![0.0f32; dim];
        for i in 0..s {
            for j in 0..dim {
                w[j] += alpha[i] * y[i] * x[i * dim + j] / lam_n;
            }
        }
        for j in 0..dim {
            assert!((w[j] - v[j]).abs() < 1e-4, "{} vs {}", w[j], v[j]);
        }
        assert_eq!(v, dv); // started from v = 0
    }

    #[test]
    fn gap_decreases_and_reaches_zero_on_separable() {
        let mut r = Rng::seed_from_u64(1);
        let (s, dim) = (256usize, 8usize);
        let w_true: Vec<f32> = (0..dim).map(|_| r.normal_f32()).collect();
        let x: Vec<f32> = (0..s * dim).map(|_| r.normal_f32()).collect();
        let y: Vec<f32> = (0..s)
            .map(|i| if dot(&x[i * dim..(i + 1) * dim], &w_true) >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        let lambda = 0.01f64;
        let lam_n = (lambda * s as f64) as f32;
        let mut alpha = vec![0.0f32; s];
        let mut v = vec![0.0f32; dim];
        let mut dv = vec![0.0f32; dim];
        let mut order: Vec<usize> = (0..s).collect();
        let mut gaps = Vec::new();
        for _ in 0..40 {
            r.shuffle(&mut order);
            scd_pass_dense(&x, dim, &y, &order, &mut alpha, &mut v, &mut dv, lam_n, 1.0);
            let mut hinge = 0.0;
            let mut asum = 0.0;
            for i in 0..s {
                let m = y[i] * dot(&x[i * dim..(i + 1) * dim], &v);
                hinge += (1.0 - m).max(0.0) as f64;
                asum += alpha[i] as f64;
            }
            gaps.push(duality_gap(hinge, asum, s, &v, lambda));
        }
        assert!(gaps[39] < 0.05, "final gap {}", gaps[39]);
        assert!(gaps[39] < gaps[0] * 0.1, "{} -> {}", gaps[0], gaps[39]);
    }

    #[test]
    fn sparse_and_dense_passes_agree_on_densified_data() {
        let ds = synth::criteo_like_with(128, 500, 10, 8, 2);
        let chunks = make_chunks(&ds, usize::MAX);
        let chunk = &chunks[0];
        let (rows, dim, y) = match chunk.samples() {
            Samples::SparseBinary { rows, dim, y } => (rows, *dim, y),
            _ => panic!(),
        };
        let dense: Vec<f32> = rows.iter().flat_map(|r| r.to_dense(dim)).collect();
        let order: Vec<usize> = (0..y.len()).collect();
        let lam_n = 0.01 * y.len() as f32;

        let mut a1 = vec![0.0f32; y.len()];
        let mut v1 = vec![0.0f32; dim];
        let mut dv1 = vec![0.0f32; dim];
        scd_pass_sparse(rows, y, &order, &mut a1, &mut v1, &mut dv1, lam_n, 2.0);

        let mut a2 = vec![0.0f32; y.len()];
        let mut v2 = vec![0.0f32; dim];
        let mut dv2 = vec![0.0f32; dim];
        scd_pass_dense(&dense, dim, y, &order, &mut a2, &mut v2, &mut dv2, lam_n, 2.0);

        for (p, q) in a1.iter().zip(&a2) {
            assert!((p - q).abs() < 1e-5);
        }
        for (p, q) in v1.iter().zip(&v2) {
            assert!((p - q).abs() < 1e-5);
        }
    }

    #[test]
    fn gap_contributions_skip_padding() {
        let ds = synth::higgs_like(10, 3);
        let chunks = make_chunks(&ds, usize::MAX);
        // Payloads are immutable post-chunking, so a padded variant is a
        // *new* chunk built from edited sample data, not an in-place edit.
        let src = &chunks[0];
        let mut samples = src.samples().clone();
        if let Samples::DenseBinary { y, .. } = &mut samples {
            y[0] = 0.0; // mark padding
        }
        let mut chunk = crate::chunks::Chunk::new(src.id, samples, src.global_ids().to_vec());
        chunk.init_state();
        let w = vec![0.0f32; 28];
        let (h, a, _c, n) = gap_contributions(&chunk, &w);
        assert_eq!(n, 9);
        assert!((h - 9.0).abs() < 1e-9); // w=0 → hinge=1 each
        assert_eq!(a, 0.0);
    }
}
