//! Synchronous local SGD (Lin et al. 2018; paper §2.2, §5.1).
//!
//! Per iteration each task runs H local steps of momentum SGD on
//! mini-batches of L samples drawn from its local chunks, then ships the
//! parameter delta. The driver acts as a synchronous parameter server and
//! merges deltas weighted by samples processed (Stich 2018 — the paper's
//! eq. 2 weighting). H = 1 degrades to mini-batch SGD, which is what the
//! PyTorch baseline comparison uses (paper §A.1).
//!
//! Learning-rate scaling: α' = α·√K (paper §5.1 "according to best
//! practice"). Local momentum state is task-local and reset at iteration
//! boundaries (it cannot move with chunks, and tasks may appear/disappear
//! under elasticity).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::chunks::{Chunk, Samples};
use crate::config::LsgdConfig;
use crate::metrics::Metric;
use crate::util::{kernels, Rng};

use super::{Algorithm, Backend, LocalUpdate, ModelVec};

/// Held-out test set for the convergence metric (paper: test accuracy).
pub enum TestSet {
    Classif { x: Vec<f32>, y: Vec<i32>, dim: usize },
    Tokens { data: Vec<i32>, n_seqs: usize },
}

/// Local-SGD algorithm instance.
pub struct LsgdAlgo {
    cfg: LsgdConfig,
    backend: Arc<Backend>,
    param_count: usize,
    input_dim: usize,
    seq_len: usize,
    is_lm: bool,
    test: TestSet,
    init_seed: u64,
}

impl LsgdAlgo {
    /// Classification workload (MLP/CNN over dense-class chunks).
    pub fn new_classif(
        cfg: LsgdConfig,
        backend: Backend,
        input_dim: usize,
        test_x: Vec<f32>,
        test_y: Vec<i32>,
        init_seed: u64,
    ) -> Result<Self> {
        if let Some(b) = backend.nn_grad_batch() {
            if b != cfg.l {
                bail!("HLO grad artifact batch {b} != configured L {}", cfg.l);
            }
        }
        let param_count = backend.nn_param_count()?;
        Ok(LsgdAlgo {
            cfg,
            backend: Arc::new(backend),
            param_count,
            input_dim,
            seq_len: 0,
            is_lm: false,
            test: TestSet::Classif { x: test_x, y: test_y, dim: input_dim },
            init_seed,
        })
    }

    /// LM workload (transformer over token chunks; HLO backend only).
    pub fn new_lm(
        cfg: LsgdConfig,
        backend: Backend,
        seq_len: usize,
        test_tokens: Vec<i32>,
        init_seed: u64,
    ) -> Result<Self> {
        let param_count = backend.nn_param_count()?;
        let n_seqs = test_tokens.len() / seq_len.max(1);
        Ok(LsgdAlgo {
            cfg,
            backend: Arc::new(backend),
            param_count,
            input_dim: 0,
            seq_len,
            is_lm: true,
            test: TestSet::Tokens { data: test_tokens, n_seqs },
            init_seed,
        })
    }

    pub fn config(&self) -> &LsgdConfig {
        &self.cfg
    }

    /// Assemble one (x, y) mini-batch of `l` samples from local chunks.
    /// The batch buffers come from `ws` (empty but capacity-retaining),
    /// so warm iterations fill them without allocating; callers `put`
    /// them back after the grad step.
    fn sample_batch_classif_ws(
        &self,
        chunks: &[Chunk],
        rng: &mut Rng,
        l: usize,
        ws: &mut crate::util::Workspace,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        if total == 0 {
            bail!("task has no local samples");
        }
        let mut x = ws.take_cleared();
        let mut y = ws.take_i32_cleared();
        x.reserve(l * self.input_dim);
        y.reserve(l);
        for _ in 0..l {
            let mut k = rng.below(total);
            for chunk in chunks {
                let n = chunk.n_samples();
                if k < n {
                    match chunk.samples() {
                        Samples::DenseClass { x: cx, dim, y: cy } => {
                            x.extend_from_slice(&cx[k * dim..(k + 1) * dim]);
                            y.push(cy[k]);
                        }
                        _ => bail!("lSGD classif requires dense-class chunks"),
                    }
                    break;
                }
                k -= n;
            }
        }
        Ok((x, y))
    }

    fn sample_batch_tokens(
        &self,
        chunks: &[Chunk],
        rng: &mut Rng,
        l: usize,
    ) -> Result<Vec<i32>> {
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        if total == 0 {
            bail!("task has no local samples");
        }
        let mut out = Vec::with_capacity(l * self.seq_len);
        for _ in 0..l {
            let mut k = rng.below(total);
            for chunk in chunks {
                let n = chunk.n_samples();
                if k < n {
                    match chunk.samples() {
                        Samples::Tokens { data, seq_len } => {
                            out.extend_from_slice(&data[k * seq_len..(k + 1) * seq_len]);
                        }
                        _ => bail!("lSGD LM requires token chunks"),
                    }
                    break;
                }
                k -= n;
            }
        }
        Ok(out)
    }
}

impl Algorithm for LsgdAlgo {
    fn model_len(&self) -> usize {
        self.param_count
    }

    fn init_model(&self) -> Result<ModelVec> {
        self.backend.nn_init(self.init_seed)
    }

    fn task_iterate(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
    ) -> Result<LocalUpdate> {
        self.task_iterate_ws(
            chunks,
            model,
            k_tasks,
            task_seed,
            budget_samples,
            &mut crate::util::Workspace::new(),
        )
    }

    fn task_iterate_ws(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
        ws: &mut crate::util::Workspace,
    ) -> Result<LocalUpdate> {
        let mut rng = Rng::seed_from_u64(task_seed);
        let lr = (if self.cfg.scale_lr {
            self.cfg.lr * (k_tasks.max(1) as f64).sqrt()
        } else {
            self.cfg.lr
        }) as f32;
        let mu = self.cfg.momentum as f32;
        let l = self.cfg.l;
        let h = match budget_samples {
            Some(b) => (b / l.max(1)).max(1),
            None => self.cfg.h,
        };

        let mut params = ws.take_copy(model);
        let mut momentum = ws.take_zeroed(self.param_count);
        let mut loss_sum = 0.0f64;
        for _ in 0..h {
            let loss = if self.is_lm {
                // LM workloads are HLO-only (transfer-dominated); keep the
                // allocating path.
                let tokens = self.sample_batch_tokens(chunks, &mut rng, l)?;
                let (g, loss) = self.backend.lm_grad(&params, &tokens, l)?;
                kernels::scale_add(&mut momentum, mu, &g);
                loss
            } else {
                let (x, y) = self.sample_batch_classif_ws(chunks, &mut rng, l, ws)?;
                let (g, loss, _correct) = self.backend.nn_grad_ws(&params, &x, &y, ws)?;
                ws.put(x);
                ws.put_i32(y);
                kernels::scale_add(&mut momentum, mu, &g);
                ws.put(g);
                loss
            };
            loss_sum += loss;
            // m ← µ·m + g (folded above), then p ← p + (−lr)·m.
            // Elementwise kernels; (−lr)·m is the exact IEEE negation of
            // lr·m, so this is bit-identical to the fused `p -= lr * m`
            // loop it replaces.
            kernels::axpy(&mut params, -lr, &momentum);
        }
        // The delta is handed off inside LocalUpdate: the one allocation
        // per steady-state iteration.
        let delta: Vec<f32> = params
            .iter()
            .zip(model)
            .map(|(p, m)| p - m)
            .collect();
        ws.put(momentum);
        ws.put(params);
        // Report the *mean* local-step loss (comparable across H values).
        Ok(LocalUpdate { delta, samples: l * h, loss_sum: loss_sum / h as f64 })
    }

    fn merge_shard(
        &self,
        shard: &mut [f32],
        offset: usize,
        updates: &[LocalUpdate],
        _k_tasks: usize,
    ) {
        // Weighted average by samples processed (eq. 2 / Stich'18). The
        // weights depend only on the shard-independent sample totals, so
        // every shard applies exactly the serial fold's arithmetic.
        let total: usize = updates.iter().map(|u| u.samples).sum();
        if total == 0 {
            return;
        }
        let end = offset + shard.len();
        for u in updates {
            let w = u.samples as f32 / total as f32;
            // Lane-per-element axpy: fold order per element is exactly
            // this update loop, so the merge stays elementwise and
            // bit-identical to the serial fold at any shard geometry.
            kernels::axpy(shard, w, &u.delta[offset..end]);
        }
    }

    fn evaluate(&self, model: &ModelVec, _all_chunks: &[&Chunk]) -> Result<Metric> {
        match &self.test {
            TestSet::Classif { x, y, dim } => {
                let (_loss, correct, n) = self.backend.nn_eval(model, x, y, *dim)?;
                Ok(Metric::TestAccuracy(correct / n.max(1.0)))
            }
            TestSet::Tokens { data, n_seqs } => {
                let loss = self.backend.lm_eval(model, data, *n_seqs)?;
                Ok(Metric::EvalLoss(loss))
            }
        }
    }

    fn eval_reads_chunks(&self) -> bool {
        // Evaluation runs over the held-out test set stored in `self.test`
        // and ignores the chunk argument, so the trainer's eval-spanning
        // overlap can skip cloning chunk state for the snapshot.
        false
    }

    fn samples_per_iteration(&self, _local_samples: usize) -> usize {
        self.cfg.l * self.cfg.h
    }

    fn unit_samples(&self, _n_total: usize, _ref_nodes: usize) -> f64 {
        (self.cfg.l * self.cfg.h) as f64
    }

    fn target(&self) -> Option<f64> {
        Some(self.cfg.target_acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::nn::NativeModel;
    use crate::chunks::chunker::make_chunks;
    use crate::config::ModelKind;
    use crate::data::synth;

    fn setup(k: usize) -> (LsgdAlgo, Vec<Vec<Chunk>>) {
        let ds = synth::fmnist_like(1200, 11);
        let (train, test) = ds.split_test(0.2);
        let (tx, ty) = match (&test.features, &test.labels) {
            (crate::data::FeatureMatrix::Dense { data, .. }, crate::data::Labels::Class(y)) => {
                (data.clone(), y.clone())
            }
            _ => panic!(),
        };
        let mut cfg = LsgdConfig::paper_defaults(ModelKind::Mlp);
        cfg.lr = 5e-3;
        let algo = LsgdAlgo::new_classif(
            cfg,
            Backend::native_nn(NativeModel::mlp_default()),
            784,
            tx,
            ty,
            42,
        )
        .unwrap();
        let chunks = make_chunks(&train, 64 * 1024);
        let mut parts: Vec<Vec<Chunk>> = (0..k).map(|_| Vec::new()).collect();
        for (i, c) in chunks.into_iter().enumerate() {
            parts[i % k].push(c);
        }
        (algo, parts)
    }

    #[test]
    fn local_steps_reduce_loss_and_accuracy_improves() {
        let (algo, mut parts) = setup(2);
        let mut model = algo.init_model().unwrap();
        let acc0 = match algo.evaluate(&model, &[]).unwrap() {
            Metric::TestAccuracy(a) => a,
            _ => panic!(),
        };
        let mut first_loss = None;
        let mut last_loss = 0.0;
        for it in 0..30 {
            let updates: Vec<LocalUpdate> = parts
                .iter_mut()
                .enumerate()
                .map(|(t, chunks)| {
                    algo.task_iterate(chunks, &model, 2, (it * 13 + t) as u64, None).unwrap()
                })
                .collect();
            let mean_loss: f64 =
                updates.iter().map(|u| u.loss_sum).sum::<f64>() / updates.len() as f64;
            first_loss.get_or_insert(mean_loss);
            last_loss = mean_loss;
            algo.merge(&mut model, &updates, 2);
        }
        let acc = match algo.evaluate(&model, &[]).unwrap() {
            Metric::TestAccuracy(a) => a,
            _ => panic!(),
        };
        assert!(last_loss < first_loss.unwrap() * 0.9, "{first_loss:?} -> {last_loss}");
        assert!(acc > acc0 + 0.2, "acc {acc0} -> {acc}");
    }

    #[test]
    fn merge_weights_by_samples() {
        let (algo, _) = setup(1);
        let mut model = vec![0.0f32; algo.model_len()];
        let u1 = LocalUpdate { delta: vec![1.0; algo.model_len()], samples: 300, loss_sum: 0.0 };
        let u2 = LocalUpdate { delta: vec![-1.0; algo.model_len()], samples: 100, loss_sum: 0.0 };
        algo.merge(&mut model, &[u1, u2], 2);
        // 0.75*1 + 0.25*(-1) = 0.5
        assert!((model[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn budget_controls_local_steps() {
        let (algo, mut parts) = setup(2);
        let model = algo.init_model().unwrap();
        let u = algo
            .task_iterate(&mut parts[0], &model, 2, 0, Some(3 * algo.config().l))
            .unwrap();
        assert_eq!(u.samples, 3 * algo.config().l);
    }

    #[test]
    fn deterministic_given_seed() {
        let (algo, mut parts) = setup(2);
        let model = algo.init_model().unwrap();
        let u1 = algo.task_iterate(&mut parts[0], &model, 2, 99, None).unwrap();
        let u2 = algo.task_iterate(&mut parts[0], &model, 2, 99, None).unwrap();
        assert_eq!(u1.delta, u2.delta);
        assert!((u1.loss_sum - u2.loss_sum).abs() < 1e-12);
    }
}
