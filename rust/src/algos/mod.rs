//! Training algorithms: CoCoA/SCD for GLMs and local SGD for NNs
//! (paper §2.2), over two interchangeable compute backends.
//!
//! * [`backend`] — the compute abstraction: `Native` (pure-rust math,
//!   mirrors the L1/L2 graphs bit-for-bit in structure) and `Hlo`
//!   (AOT-compiled JAX/Pallas artifacts via PJRT). Tests assert the two
//!   agree numerically.
//! * [`svm`] — hinge-loss SVM dual math: native SDCA over dense and sparse
//!   chunks, duality gap.
//! * [`nn`] — native NN substrate: fused linear, conv2d, maxpool,
//!   softmax-CE, and the paper's CNN/MLP models over flat parameters.
//! * [`cocoa`] / [`lsgd`] — the distributed algorithms proper: per-task
//!   solver state and the trainer-side merge rules.

pub mod backend;
pub mod cocoa;
pub mod lsgd;
pub mod nn;
pub mod svm;

pub use backend::Backend;
pub use cocoa::CocoaAlgo;
pub use lsgd::LsgdAlgo;

use crate::chunks::Chunk;
use crate::metrics::Metric;
use crate::util::Workspace;
use crate::Result;

/// The shared model vector exchanged between driver and tasks each
/// iteration (CoCoA: v = w; lSGD: flat NN parameters).
pub type ModelVec = Vec<f32>;

/// What one uni-task returns from one iteration.
#[derive(Clone, Debug)]
pub struct LocalUpdate {
    /// Model delta to merge (same length as the shared model).
    pub delta: ModelVec,
    /// Samples this task processed this iteration (merge weight, Stich'18).
    pub samples: usize,
    /// Sum of local training losses (diagnostics).
    pub loss_sum: f64,
}

/// A distributed training algorithm: how tasks compute and how the driver
/// merges. Implementations are stateless aside from configuration; all
/// mutable state lives in chunks (per-sample state) and the shared model.
pub trait Algorithm: Send + Sync {
    /// Length of the shared model vector.
    fn model_len(&self) -> usize;

    /// Initial shared model.
    fn init_model(&self) -> Result<ModelVec>;

    /// One task-local iteration over the task's chunks.
    ///
    /// `task_seed` makes sample orders deterministic per (task, iter);
    /// `budget_samples` caps how many samples to process (None = the
    /// algorithm's default, e.g. one local pass for CoCoA, L×H for lSGD).
    fn task_iterate(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
    ) -> Result<LocalUpdate>;

    /// Workspace-backed variant of [`Algorithm::task_iterate`]: identical
    /// math and RNG draws, but scratch buffers (local model copies,
    /// permutations, gradients, per-chunk deltas) are checked out of the
    /// caller's per-task [`Workspace`] so steady-state iterations stop
    /// allocating. The default implementation ignores the workspace and
    /// delegates, so third-party / test algorithms keep working unchanged;
    /// the built-in algorithms override it. Workspace reuse is
    /// bit-invisible: `tests/kernel_parity.rs` asserts a dirty workspace
    /// yields the same bits as a fresh one.
    fn task_iterate_ws(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
        _ws: &mut Workspace,
    ) -> Result<LocalUpdate> {
        self.task_iterate(chunks, model, k_tasks, task_seed, budget_samples)
    }

    /// Merge one contiguous model shard: fold the sub-range
    /// `offset .. offset + shard.len()` of every task update into `shard`
    /// (which aliases `model[offset ..]` on the caller's side).
    ///
    /// # The elementwise-merge invariant (ROADMAP, do not weaken)
    ///
    /// `merge_shard` stays *elementwise* (element `i` of the merged model
    /// depends only on element `i` of the inputs plus shard-independent
    /// scalars, updates folded in task order), so any contiguous sharding
    /// — any shard count, any claim interleaving, resizes and mid-reduce
    /// revokes included — is bit-identical to the serial fold, and the
    /// overlapped schedule reproduces the barriered trajectory exactly.
    ///
    /// This is the contract every implementation must uphold: it is what
    /// lets the trainer fan the merge out across however many workers the
    /// elastic schedule currently provides, lets the work-stealing
    /// reducer hand shards to whichever worker is free, and lets the
    /// reduce/dispatch overlap span evaluation points — all without
    /// perturbing the trajectory. An implementation that, say, computed a
    /// *per-shard* normalizer would silently break bit-identity for every
    /// shard count but one. `tests/prop_merge_equivalence.rs` and
    /// `tests/overlap_pipeline.rs` enforce it.
    ///
    /// Every update's `delta` must cover `offset + shard.len()` elements.
    ///
    /// # Example
    ///
    /// Any split into contiguous shards — merged in any order — composes
    /// to the exact bits of the whole-model fold:
    ///
    /// ```
    /// use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate};
    /// use chicle::config::CocoaConfig;
    ///
    /// let algo = CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, 8);
    /// let updates = vec![
    ///     LocalUpdate { delta: vec![0.25; 8], samples: 10, loss_sum: 0.0 },
    ///     LocalUpdate { delta: vec![-0.5; 8], samples: 5, loss_sum: 0.0 },
    /// ];
    ///
    /// let mut serial = vec![1.0f32; 8];
    /// algo.merge(&mut serial, &updates, 2);
    ///
    /// // Two uneven shards, merged back-to-front.
    /// let mut sharded = vec![1.0f32; 8];
    /// let (lo, hi) = sharded.split_at_mut(5);
    /// algo.merge_shard(hi, 5, &updates, 2);
    /// algo.merge_shard(lo, 0, &updates, 2);
    ///
    /// assert_eq!(serial, sharded);
    /// ```
    fn merge_shard(
        &self,
        shard: &mut [f32],
        offset: usize,
        updates: &[LocalUpdate],
        k_tasks: usize,
    );

    /// Merge task updates into the shared model (driver side): the serial
    /// fold — one shard spanning the whole model.
    fn merge(&self, model: &mut ModelVec, updates: &[LocalUpdate], k_tasks: usize) {
        self.merge_shard(&mut model[..], 0, updates, k_tasks);
    }

    /// Global convergence metric over all chunks (+ optional held-out set).
    fn evaluate(&self, model: &ModelVec, all_chunks: &[&Chunk]) -> Result<Metric>;

    /// Does [`Algorithm::evaluate`] actually read the chunks it is handed?
    ///
    /// The trainer's eval-spanning overlap consults this to decide whether
    /// an evaluation snapshot must capture the chunk state before the next
    /// iteration's workers start mutating it: CoCoA's duality gap reads
    /// the per-sample α state co-located in the chunks (default `true`),
    /// while lSGD evaluates a held-out test set stored in the algorithm
    /// itself and ignores the chunk argument entirely (`false` — the
    /// snapshot is then skipped). The snapshot itself is *state-only*:
    /// `Chunk::clone` shares the immutable payload by `Arc` and copies
    /// just the per-sample state, so even chunk-reading evaluators pay
    /// O(per-sample state), not O(dataset).
    fn eval_reads_chunks(&self) -> bool {
        true
    }

    /// Samples one task processes per iteration given its local count
    /// (CoCoA: all local samples; lSGD: L×H regardless of locality).
    fn samples_per_iteration(&self, local_samples: usize) -> usize;

    /// The sample count that defines one normalized time unit for the
    /// paper's projection model (§5.3): CoCoA normalizes to 1/16th of the
    /// dataset on one node (`n_total / ref_nodes`); lSGD normalizes to one
    /// task's L×H batch.
    fn unit_samples(&self, n_total: usize, ref_nodes: usize) -> f64;

    /// The configured convergence target (gap / accuracy), if any.
    fn target(&self) -> Option<f64>;
}
