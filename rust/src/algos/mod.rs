//! Training algorithms: CoCoA/SCD for GLMs and local SGD for NNs
//! (paper §2.2), over two interchangeable compute backends.
//!
//! * [`backend`] — the compute abstraction: `Native` (pure-rust math,
//!   mirrors the L1/L2 graphs bit-for-bit in structure) and `Hlo`
//!   (AOT-compiled JAX/Pallas artifacts via PJRT). Tests assert the two
//!   agree numerically.
//! * [`svm`] — hinge-loss SVM dual math: native SDCA over dense and sparse
//!   chunks, duality gap.
//! * [`nn`] — native NN substrate: fused linear, conv2d, maxpool,
//!   softmax-CE, and the paper's CNN/MLP models over flat parameters.
//! * [`cocoa`] / [`lsgd`] — the distributed algorithms proper: per-task
//!   solver state and the trainer-side merge rules.

pub mod backend;
pub mod cocoa;
pub mod lsgd;
pub mod nn;
pub mod svm;

pub use backend::Backend;
pub use cocoa::CocoaAlgo;
pub use lsgd::LsgdAlgo;

use crate::chunks::Chunk;
use crate::metrics::Metric;
use crate::Result;

/// The shared model vector exchanged between driver and tasks each
/// iteration (CoCoA: v = w; lSGD: flat NN parameters).
pub type ModelVec = Vec<f32>;

/// What one uni-task returns from one iteration.
#[derive(Clone, Debug)]
pub struct LocalUpdate {
    /// Model delta to merge (same length as the shared model).
    pub delta: ModelVec,
    /// Samples this task processed this iteration (merge weight, Stich'18).
    pub samples: usize,
    /// Sum of local training losses (diagnostics).
    pub loss_sum: f64,
}

/// A distributed training algorithm: how tasks compute and how the driver
/// merges. Implementations are stateless aside from configuration; all
/// mutable state lives in chunks (per-sample state) and the shared model.
pub trait Algorithm: Send + Sync {
    /// Length of the shared model vector.
    fn model_len(&self) -> usize;

    /// Initial shared model.
    fn init_model(&self) -> Result<ModelVec>;

    /// One task-local iteration over the task's chunks.
    ///
    /// `task_seed` makes sample orders deterministic per (task, iter);
    /// `budget_samples` caps how many samples to process (None = the
    /// algorithm's default, e.g. one local pass for CoCoA, L×H for lSGD).
    fn task_iterate(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
    ) -> Result<LocalUpdate>;

    /// Merge one contiguous model shard: fold the sub-range
    /// `offset .. offset + shard.len()` of every task update into `shard`
    /// (which aliases `model[offset ..]` on the caller's side).
    ///
    /// The contract that makes sharded reduction exact: the merge rule must
    /// be *elementwise* — element `i` of the merged model may depend only
    /// on element `i` of the inputs plus shard-independent scalars (e.g.
    /// total sample counts), and updates must be folded in slice order.
    /// Any partition of the model into contiguous shards then composes to
    /// bit-identical results with the serial fold, for any shard count
    /// *and any shard→worker assignment* — which is what lets the trainer
    /// fan the merge out across however many workers the elastic schedule
    /// currently provides, and lets the work-stealing reducer hand shards
    /// to whichever worker is free without perturbing the trajectory
    /// (`tests/prop_merge_equivalence.rs` enforces this).
    ///
    /// Every update's `delta` must cover `offset + shard.len()` elements.
    fn merge_shard(
        &self,
        shard: &mut [f32],
        offset: usize,
        updates: &[LocalUpdate],
        k_tasks: usize,
    );

    /// Merge task updates into the shared model (driver side): the serial
    /// fold — one shard spanning the whole model.
    fn merge(&self, model: &mut ModelVec, updates: &[LocalUpdate], k_tasks: usize) {
        self.merge_shard(&mut model[..], 0, updates, k_tasks);
    }

    /// Global convergence metric over all chunks (+ optional held-out set).
    fn evaluate(&self, model: &ModelVec, all_chunks: &[&Chunk]) -> Result<Metric>;

    /// Samples one task processes per iteration given its local count
    /// (CoCoA: all local samples; lSGD: L×H regardless of locality).
    fn samples_per_iteration(&self, local_samples: usize) -> usize;

    /// The sample count that defines one normalized time unit for the
    /// paper's projection model (§5.3): CoCoA normalizes to 1/16th of the
    /// dataset on one node (`n_total / ref_nodes`); lSGD normalizes to one
    /// task's L×H batch.
    fn unit_samples(&self, n_total: usize, ref_nodes: usize) -> f64;

    /// The configured convergence target (gap / accuracy), if any.
    fn target(&self) -> Option<f64>;
}
