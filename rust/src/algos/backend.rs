//! Compute backend: the solvers' math, either native rust or AOT HLO.
//!
//! The `Hlo` variant is the production path: it executes the JAX/Pallas
//! artifacts through PJRT ([`crate::runtime`]). The `Native` variant
//! mirrors the same computations in pure rust so the figure harnesses can
//! run hundreds of trainings concurrently without queueing on the single
//! CPU PJRT engine. `rust/tests/hlo_native_equivalence.rs` asserts the two
//! agree numerically on identical inputs.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::chunks::{Chunk, Samples};
use crate::runtime::{HloService, HostTensor, Manifest};
use crate::util::Workspace;

use super::nn::NativeModel;
use super::svm;

/// NN compute plumbing for the HLO path (artifact names + signatures).
#[derive(Clone)]
pub struct HloNn {
    pub grad_artifact: String,
    pub grad_batch: usize,
    pub eval_artifact: String,
    pub eval_batch: usize,
    pub init_artifact: String,
    pub param_count: usize,
    pub input_dim: usize,
    /// Token-LM models take (params, tokens) instead of (params, x, y).
    pub is_lm: bool,
    pub seq_len: usize,
}

/// CoCoA compute plumbing for the HLO path.
#[derive(Clone)]
pub struct HloScd {
    pub scd_artifact: String,
    pub eval_artifact: String,
    /// Fixed chunk-block sample capacity (S) and feature width (F).
    pub s: usize,
    pub f: usize,
}

/// One of the two compute paths. Cheap to clone (Arc/strings).
#[derive(Clone)]
pub enum Backend {
    Native {
        /// NN model for lSGD workloads (None for CoCoA-only sessions).
        nn: Option<Arc<NativeModel>>,
    },
    Hlo {
        service: HloService,
        nn: Option<HloNn>,
        scd: Option<HloScd>,
    },
}

impl Backend {
    pub fn native_cocoa() -> Backend {
        Backend::Native { nn: None }
    }

    pub fn native_nn(model: NativeModel) -> Backend {
        Backend::Native { nn: Some(Arc::new(model)) }
    }

    /// HLO backend for CoCoA over dense (S, F) chunk blocks.
    pub fn hlo_cocoa(
        service: HloService,
        manifest: &Manifest,
        s: usize,
        f: usize,
    ) -> Result<Backend> {
        let scd_artifact = format!("scd_chunk_s{s}_f{f}");
        let eval_artifact = format!("linear_eval_s{s}_f{f}");
        manifest.artifact(&scd_artifact)?;
        manifest.artifact(&eval_artifact)?;
        Ok(Backend::Hlo {
            service,
            nn: None,
            scd: Some(HloScd { scd_artifact, eval_artifact, s, f }),
        })
    }

    /// HLO backend for an NN model (lSGD / LM workloads).
    pub fn hlo_nn(service: HloService, manifest: &Manifest, prefix: &str) -> Result<Backend> {
        let (grad_artifact, grad_batch) = manifest.grad_artifact(prefix)?;
        let (eval_artifact, eval_batch) = manifest.eval_artifact(prefix)?;
        let init_artifact = manifest.init_artifact(prefix)?;
        let model = manifest.model(prefix)?;
        let grad_meta = manifest.artifact(&grad_artifact)?;
        // LM models: grad takes (params, tokens); classifiers take (params, x, y).
        let is_lm = grad_meta.inputs.len() == 2;
        let (input_dim, seq_len) = if is_lm {
            (0, grad_meta.inputs[1].shape[1])
        } else {
            (grad_meta.inputs[1].shape[1], 0)
        };
        Ok(Backend::Hlo {
            service,
            scd: None,
            nn: Some(HloNn {
                grad_artifact,
                grad_batch,
                eval_artifact,
                eval_batch,
                init_artifact,
                param_count: model.param_count,
                input_dim,
                is_lm,
                seq_len,
            }),
        })
    }

    pub fn is_hlo(&self) -> bool {
        matches!(self, Backend::Hlo { .. })
    }

    // ------------------------------------------------------------- CoCoA

    /// One local-SCD pass over a dense-binary chunk against `v`.
    ///
    /// Mutates the chunk's per-sample dual state in place, adds the model
    /// delta into `v` and returns it. `order` indexes rows of the chunk.
    /// Allocating wrapper over [`Backend::scd_chunk_ws`].
    pub fn scd_chunk(
        &self,
        chunk: &mut Chunk,
        order: &[usize],
        v: &mut [f32],
        lam_n: f32,
        sigma: f32,
    ) -> Result<Vec<f32>> {
        self.scd_chunk_ws(chunk, order, v, lam_n, sigma, &mut Workspace::new())
    }

    /// Workspace-backed [`Backend::scd_chunk`]: on the native path the
    /// returned `dv` buffer is checked out of `ws` (callers `put` it back
    /// once folded into their delta, making steady-state passes
    /// allocation-free). The HLO path is transfer-dominated and keeps its
    /// allocating block loop.
    pub fn scd_chunk_ws(
        &self,
        chunk: &mut Chunk,
        order: &[usize],
        v: &mut [f32],
        lam_n: f32,
        sigma: f32,
        ws: &mut Workspace,
    ) -> Result<Vec<f32>> {
        match self {
            Backend::Native { .. } => {
                let mut dv = ws.take_zeroed(v.len());
                // Split borrow: read-only sample data, mutable α state.
                let (samples, state) = chunk.samples_and_state_mut();
                match samples {
                    Samples::DenseBinary { x, dim, y } => {
                        svm::scd_pass_dense(
                            x, *dim, y, order, state, v, &mut dv, lam_n, sigma,
                        );
                    }
                    Samples::SparseBinary { rows, y, .. } => {
                        svm::scd_pass_sparse(
                            rows, y, order, state, v, &mut dv, lam_n, sigma,
                        );
                    }
                    _ => bail!("scd_chunk on unsupported payload"),
                }
                Ok(dv)
            }
            Backend::Hlo { service, scd, .. } => {
                let scd = scd.as_ref().context("backend has no SCD artifacts")?;
                let (samples, state) = chunk.samples_and_state_mut();
                let (x, dim, y) = match samples {
                    Samples::DenseBinary { x, dim, y } => (x, *dim, y),
                    _ => bail!("HLO scd_chunk requires dense-binary chunks"),
                };
                if dim != scd.f {
                    bail!("chunk dim {dim} != artifact feature width {}", scd.f);
                }
                let n = y.len();
                let mut total_dv = vec![0.0f32; v.len()];
                // Process in windows of at most S rows; the kernel's v is
                // refreshed between windows so sequential semantics hold.
                for window_start in (0..n).step_by(scd.s) {
                    let wn = (n - window_start).min(scd.s);
                    let range = window_start..window_start + wn;
                    // Pad the block to exactly (S, F).
                    let mut xb = vec![0.0f32; scd.s * dim];
                    xb[..wn * dim]
                        .copy_from_slice(&x[range.start * dim..range.end * dim]);
                    let mut yb = vec![0.0f32; scd.s];
                    yb[..wn].copy_from_slice(&y[range.clone()]);
                    let mut ab = vec![0.0f32; scd.s];
                    ab[..wn].copy_from_slice(&state[range.clone()]);
                    // Window-local visit order: entries of `order` falling in
                    // this window, padded with a zero row (no-op updates).
                    let pad_row = if wn < scd.s { wn } else { 0 };
                    let mut ob: Vec<i32> = order
                        .iter()
                        .filter(|&&i| range.contains(&i))
                        .map(|&i| (i - window_start) as i32)
                        .collect();
                    let real_steps = ob.len();
                    if real_steps == 0 {
                        continue;
                    }
                    ob.resize(scd.s, pad_row as i32);
                    if wn == scd.s && real_steps < scd.s {
                        // No zero row available: repeat-visit is NOT a no-op,
                        // so fall back to truncating at real steps by pointing
                        // extras at the first visited row *after* convergence
                        // of its own update (idempotent: a second visit with
                        // unchanged v moves α by ~0 only if converged).
                        // Instead, keep exact semantics: temporarily zero a
                        // sacrificial row is not possible — use full perm.
                        // In practice order covers all rows (full local pass),
                        // so real_steps == wn here.
                        bail!("partial orders on full blocks unsupported on HLO path");
                    }
                    let out = service.execute(
                        &scd.scd_artifact,
                        vec![
                            HostTensor::mat_f32(xb, scd.s, dim),
                            HostTensor::vec_f32(yb),
                            HostTensor::vec_i32(ob),
                            HostTensor::vec_f32(ab),
                            HostTensor::vec_f32(v.to_vec()),
                            HostTensor::scalar_f32(lam_n),
                            HostTensor::scalar_f32(sigma),
                        ],
                    )?;
                    let alpha_out = out[0].as_f32()?;
                    state[range.clone()].copy_from_slice(&alpha_out[..wn]);
                    let dv = out[1].as_f32()?;
                    // Same convention as the kernel/native pass: the local
                    // view v accumulates sigma'-scaled updates (CoCoA+),
                    // while dv stays unscaled for the global merge.
                    // fused_axpy2 with scale = 1.0: u = 1.0·d is bitwise d,
                    // so this matches the old elementwise loop exactly.
                    crate::util::kernels::fused_axpy2(v, &mut total_dv, sigma, 1.0, dv);
                }
                Ok(total_dv)
            }
        }
    }

    /// Duality-gap contributions of one chunk: (Σhinge, Σα, Σcorrect, n).
    pub fn gap_contributions(&self, chunk: &Chunk, w: &[f32]) -> Result<(f64, f64, f64, usize)> {
        match self {
            Backend::Native { .. } => Ok(svm::gap_contributions(chunk, w)),
            Backend::Hlo { service, scd, .. } => {
                let scd = scd.as_ref().context("backend has no SCD artifacts")?;
                let (x, dim, y) = match chunk.samples() {
                    Samples::DenseBinary { x, dim, y } => (x, *dim, y),
                    // Sparse gap eval has no HLO artifact; use native math.
                    _ => return Ok(svm::gap_contributions(chunk, w)),
                };
                let n = y.len();
                let (mut th, mut ta, mut tc, mut tn) = (0.0, 0.0, 0.0, 0usize);
                for window_start in (0..n).step_by(scd.s) {
                    let wn = (n - window_start).min(scd.s);
                    let range = window_start..window_start + wn;
                    let mut xb = vec![0.0f32; scd.s * dim];
                    xb[..wn * dim]
                        .copy_from_slice(&x[range.start * dim..range.end * dim]);
                    let mut yb = vec![0.0f32; scd.s];
                    yb[..wn].copy_from_slice(&y[range.clone()]);
                    let mut ab = vec![0.0f32; scd.s];
                    ab[..wn].copy_from_slice(&chunk.state[range]);
                    let out = service.execute(
                        &scd.eval_artifact,
                        vec![
                            HostTensor::mat_f32(xb, scd.s, dim),
                            HostTensor::vec_f32(yb),
                            HostTensor::vec_f32(ab),
                            HostTensor::vec_f32(w.to_vec()),
                        ],
                    )?;
                    th += out[0].scalar_value()?;
                    ta += out[1].scalar_value()?;
                    tc += out[2].scalar_value()?;
                    tn += out[3].scalar_value()? as usize;
                }
                Ok((th, ta, tc, tn))
            }
        }
    }

    // --------------------------------------------------------------- NN

    pub fn nn_param_count(&self) -> Result<usize> {
        match self {
            Backend::Native { nn } => {
                Ok(nn.as_ref().context("no NN model")?.param_count())
            }
            Backend::Hlo { nn, .. } => Ok(nn.as_ref().context("no NN artifacts")?.param_count),
        }
    }

    /// Mini-batch size the grad path requires (HLO: fixed by the artifact;
    /// native: any, returns None).
    pub fn nn_grad_batch(&self) -> Option<usize> {
        match self {
            Backend::Native { .. } => None,
            Backend::Hlo { nn, .. } => nn.as_ref().map(|n| n.grad_batch),
        }
    }

    pub fn nn_init(&self, seed: u64) -> Result<Vec<f32>> {
        match self {
            Backend::Native { nn } => Ok(nn.as_ref().context("no NN model")?.init(seed)),
            Backend::Hlo { service, nn, .. } => {
                let nn = nn.as_ref().context("no NN artifacts")?;
                let out = service.execute(
                    &nn.init_artifact,
                    vec![HostTensor::vec_i32(vec![seed as i32])],
                )?;
                out.into_iter().next().unwrap().into_f32()
            }
        }
    }

    /// Loss + grads on one mini-batch: returns (grads, loss, correct).
    /// Allocating wrapper over [`Backend::nn_grad_ws`].
    pub fn nn_grad(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<(Vec<f32>, f64, f64)> {
        self.nn_grad_ws(params, x, y, &mut Workspace::new())
    }

    /// Workspace-backed [`Backend::nn_grad`]: on the native path all
    /// intermediates and the returned gradient vector come from `ws`
    /// (callers `put` the grads back once consumed). The HLO path
    /// round-trips through PJRT and keeps its allocating transfers.
    pub fn nn_grad_ws(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> Result<(Vec<f32>, f64, f64)> {
        match self {
            Backend::Native { nn } => {
                let model = nn.as_ref().context("no NN model")?;
                let (g, loss, correct, _) = model.grad_ws(params, x, y, ws);
                Ok((g, loss, correct))
            }
            Backend::Hlo { service, nn, .. } => {
                let nn = nn.as_ref().context("no NN artifacts")?;
                if y.len() != nn.grad_batch {
                    bail!("HLO grad batch must be {} (got {})", nn.grad_batch, y.len());
                }
                let out = service.execute(
                    &nn.grad_artifact,
                    vec![
                        HostTensor::vec_f32(params.to_vec()),
                        HostTensor::mat_f32(x.to_vec(), y.len(), nn.input_dim),
                        HostTensor::vec_i32(y.to_vec()),
                    ],
                )?;
                let loss = out[1].scalar_value()?;
                let correct = out[2].scalar_value()?;
                let grads = out.into_iter().next().unwrap().into_f32()?;
                Ok((grads, loss, correct))
            }
        }
    }

    /// LM grad step on one token batch: returns (grads, loss).
    pub fn lm_grad(&self, params: &[f32], tokens: &[i32], batch: usize) -> Result<(Vec<f32>, f64)> {
        match self {
            Backend::Native { .. } => bail!("LM workloads require the HLO backend"),
            Backend::Hlo { service, nn, .. } => {
                let nn = nn.as_ref().context("no NN artifacts")?;
                if !nn.is_lm {
                    bail!("model is not an LM");
                }
                let out = service.execute(
                    &nn.grad_artifact,
                    vec![
                        HostTensor::vec_f32(params.to_vec()),
                        HostTensor::mat_i32(tokens.to_vec(), batch, nn.seq_len),
                    ],
                )?;
                let loss = out[1].scalar_value()?;
                let grads = out.into_iter().next().unwrap().into_f32()?;
                Ok((grads, loss))
            }
        }
    }

    /// Eval on a labelled set: returns (loss_mean, correct, n). Handles
    /// batching/padding internally.
    pub fn nn_eval(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        dim: usize,
    ) -> Result<(f64, f64, f64)> {
        match self {
            Backend::Native { nn } => {
                let model = nn.as_ref().context("no NN model")?;
                // Batch to bound peak memory.
                let bs = 256usize;
                let (mut loss_sum, mut correct, mut n) = (0.0, 0.0, 0.0);
                for start in (0..y.len()).step_by(bs) {
                    let end = (start + bs).min(y.len());
                    let (l, c, nb) =
                        model.eval(params, &x[start * dim..end * dim], &y[start..end]);
                    loss_sum += l * nb;
                    correct += c;
                    n += nb;
                }
                Ok((loss_sum / n.max(1.0), correct, n))
            }
            Backend::Hlo { service, nn, .. } => {
                let nn = nn.as_ref().context("no NN artifacts")?;
                let bs = nn.eval_batch;
                let (mut loss_sum, mut correct, mut n) = (0.0, 0.0, 0.0);
                for start in (0..y.len()).step_by(bs) {
                    let end = (start + bs).min(y.len());
                    let wn = end - start;
                    let mut xb = vec![0.0f32; bs * dim];
                    xb[..wn * dim].copy_from_slice(&x[start * dim..end * dim]);
                    let mut yb = vec![-1i32; bs];
                    yb[..wn].copy_from_slice(&y[start..end]);
                    let out = service.execute(
                        &nn.eval_artifact,
                        vec![
                            HostTensor::vec_f32(params.to_vec()),
                            HostTensor::mat_f32(xb, bs, dim),
                            HostTensor::vec_i32(yb),
                        ],
                    )?;
                    let l = out[0].scalar_value()?;
                    let c = out[1].scalar_value()?;
                    let nb = out[2].scalar_value()?;
                    loss_sum += l * nb;
                    correct += c;
                    n += nb;
                }
                Ok((loss_sum / n.max(1.0), correct, n))
            }
        }
    }

    /// LM eval loss over token sequences: returns mean loss.
    pub fn lm_eval(&self, params: &[f32], tokens: &[i32], n_seqs: usize) -> Result<f64> {
        match self {
            Backend::Native { .. } => bail!("LM workloads require the HLO backend"),
            Backend::Hlo { service, nn, .. } => {
                let nn = nn.as_ref().context("no NN artifacts")?;
                let bs = nn.eval_batch.max(1);
                let t = nn.seq_len;
                let (mut loss_sum, mut n) = (0.0, 0.0);
                for start in (0..n_seqs).step_by(bs) {
                    let end = (start + bs).min(n_seqs);
                    let wn = end - start;
                    if wn < bs {
                        // Pad by repeating the first sequence of the window
                        // and average only real rows below.
                        let mut tb = vec![0i32; bs * t];
                        tb[..wn * t].copy_from_slice(&tokens[start * t..end * t]);
                        for row in wn..bs {
                            tb.copy_within(0..t, row * t);
                        }
                        let out = service.execute(
                            &nn.eval_artifact,
                            vec![
                                HostTensor::vec_f32(params.to_vec()),
                                HostTensor::mat_i32(tb, bs, t),
                            ],
                        )?;
                        // Padded rows bias the mean; weight by wn/bs only.
                        loss_sum += out[0].scalar_value()? * wn as f64;
                        n += wn as f64;
                    } else {
                        let out = service.execute(
                            &nn.eval_artifact,
                            vec![
                                HostTensor::vec_f32(params.to_vec()),
                                HostTensor::mat_i32(
                                    tokens[start * t..end * t].to_vec(),
                                    bs,
                                    t,
                                ),
                            ],
                        )?;
                        loss_sum += out[0].scalar_value()? * wn as f64;
                        n += wn as f64;
                    }
                }
                Ok(loss_sum / n.max(1.0))
            }
        }
    }
}
