//! Masked softmax cross-entropy (native mirror of `_softmax_xent` in
//! python/compile/model.py): labels < 0 are padding and contribute
//! nothing; loss is normalized by the number of valid rows.

/// Forward + backward in one pass, writing ∂loss_mean/∂logits into a
/// caller buffer (fully overwritten: zero-seeded, valid rows then
/// filled — the exact state the allocating form returns). Returns
/// (loss_mean, correct_count, n_valid).
pub fn softmax_xent_into(
    logits: &[f32],
    labels: &[i32],
    n_classes: usize,
    dlogits: &mut [f32],
) -> (f64, f64, f64) {
    let rows = labels.len();
    assert_eq!(logits.len(), rows * n_classes);
    assert_eq!(dlogits.len(), logits.len());
    let n_valid = labels.iter().filter(|&&l| l >= 0).count().max(1) as f32;
    dlogits.fill(0.0);
    let (mut loss_sum, mut correct) = (0.0f64, 0.0f64);
    let mut actually_valid = 0.0f64;
    for (i, &label) in labels.iter().enumerate() {
        if label < 0 {
            continue;
        }
        actually_valid += 1.0;
        let row = &logits[i * n_classes..(i + 1) * n_classes];
        let max = crate::util::kernels::vmax(row);
        // One fused pass per row: shifted exponentials (stashed in the
        // grad row and reused by the backward, so each exp is computed
        // once), their sum, and the argmax. The `is_ge` update keeps the
        // last maximum on ties, matching Iterator::max_by + total_cmp.
        let drow = &mut dlogits[i * n_classes..(i + 1) * n_classes];
        let mut sum = 0.0f32;
        let mut best = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, (&v, dv)) in row.iter().zip(drow.iter_mut()).enumerate() {
            let e = (v - max).exp();
            sum += e;
            *dv = e;
            if v.total_cmp(&best).is_ge() {
                best = v;
                argmax = j;
            }
        }
        let log_sum = sum.ln() + max;
        let li = label as usize;
        loss_sum += (log_sum - row[li]) as f64;
        if argmax == li {
            correct += 1.0;
        }
        for (j, dv) in drow.iter_mut().enumerate() {
            let p = *dv / sum;
            *dv = (p - if j == li { 1.0 } else { 0.0 }) / n_valid;
        }
    }
    let loss_mean = loss_sum / actually_valid.max(1.0);
    (loss_mean, correct, actually_valid)
}

/// Forward + backward in one pass.
///
/// Returns (loss_mean, correct_count, n_valid, dlogits) where `dlogits`
/// is ∂loss_mean/∂logits — i.e. (softmax − onehot) / n_valid on valid
/// rows. Allocating wrapper over [`softmax_xent_into`].
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    n_classes: usize,
) -> (f64, f64, f64, Vec<f32>) {
    let mut dlogits = vec![0.0f32; logits.len()];
    let (loss_mean, correct, n_valid) = softmax_xent_into(logits, labels, n_classes, &mut dlogits);
    (loss_mean, correct, n_valid, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_c() {
        let logits = vec![0.0f32; 4 * 3];
        let labels = vec![0, 1, 2, 0];
        let (loss, _correct, n, d) = softmax_xent(&logits, &labels, 3);
        assert!((loss - (3.0f64).ln()).abs() < 1e-6);
        assert_eq!(n, 4.0);
        // grads sum to zero per row
        for i in 0..4 {
            let s: f32 = d[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn confident_correct_prediction_low_loss() {
        let logits = vec![10.0f32, -10.0, 0.0];
        let (loss, correct, n, _) = softmax_xent(&logits, &[0], 3);
        assert!(loss < 1e-3);
        assert_eq!(correct, 1.0);
        assert_eq!(n, 1.0);
    }

    #[test]
    fn padding_rows_ignored() {
        let logits = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let (loss_a, correct_a, n, d) = softmax_xent(&logits, &[2, -1], 3);
        assert_eq!(n, 1.0);
        let (loss_b, correct_b, _, _) = softmax_xent(&logits[..3], &[2], 3);
        assert!((loss_a - loss_b).abs() < 1e-6);
        assert_eq!(correct_a, correct_b);
        assert!(d[3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn grad_matches_finite_difference() {
        let logits = vec![0.3f32, -0.5, 1.2, 0.0, 0.7, -1.0];
        let labels = vec![2, 0];
        let (_, _, _, d) = softmax_xent(&logits, &labels, 3);
        let eps = 1e-3f32;
        for idx in 0..6 {
            let mut lp = logits.clone();
            lp[idx] += eps;
            let (la, _, _, _) = softmax_xent(&lp, &labels, 3);
            let (lb, _, _, _) = softmax_xent(&logits, &labels, 3);
            let fd = (la - lb) / eps as f64;
            assert!((fd - d[idx] as f64).abs() < 1e-3, "idx {idx}: {fd} vs {}", d[idx]);
        }
    }
}
