//! Dense linear algebra: matmul variants and the fused linear layer
//! (native mirror of the Pallas `fused_linear` kernel).
//!
//! As of the SIMD-kernel port this module is a thin facade over
//! [`crate::util::kernels`], which holds the cache-blocked,
//! runtime-dispatched implementations. The old scalar ikj matmul here
//! carried a per-element `av == 0.0` skip that pessimized dense inputs
//! (a data-dependent branch per A element); the dense path is now
//! branch-free and the skip lives in the explicit
//! [`kernels::matmul_zero_skip`] sparse variant.

use crate::util::kernels;

pub use crate::util::kernels::{gelu, Act};

/// C(m,n) = A(m,k) · B(k,n), dense, cache-blocked; `c` is overwritten.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    kernels::matmul(a, b, c, m, k, n);
}

/// Sparse-A variant: skips B rows whose A coefficient is exactly zero.
/// Use for post-ReLU activations and other zero-heavy operands; the
/// dense [`matmul`] is faster when A is dense.
pub fn matmul_zero_skip(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    kernels::matmul_zero_skip(a, b, c, m, k, n);
}

/// C(m,n) = Aᵀ(m,k stored k,m) · B(k,n) — i.e. A is (k, m) and we compute
/// AᵀB. Used for dW = Xᵀ·dY.
///
/// (There is deliberately no `matmul_a_bt` twin here: the transposed-B
/// product is only used by kernel-layer consumers, which call
/// [`kernels::matmul_a_bt`] directly.)
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    kernels::matmul_at_b(a, b, c, k, m, n);
}

/// Forward fused linear: y(m,n) = act(x(m,k)·w(k,n) + bias). Returns the
/// pre-activation too (needed for gelu backward).
pub fn fused_linear_fwd(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>) {
    kernels::fused_linear_fwd(x, w, bias, m, k, n, act)
}

/// Backward fused linear given upstream grad `dy`:
/// returns (dx, dw, db). `pre` is the forward pre-activation.
#[allow(clippy::too_many_arguments)]
pub fn fused_linear_bwd(
    x: &[f32],
    w: &[f32],
    pre: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    kernels::fused_linear_bwd(x, w, pre, dy, m, k, n, act)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, a);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        let mut r = Rng::seed_from_u64(0);
        let (m, k, n) = (5, 7, 3);
        let a = randv(&mut r, k * m); // (k, m)
        let b = randv(&mut r, k * n); // (k, n)
        let mut c1 = vec![0.0; m * n];
        matmul_at_b(&a, &b, &mut c1, k, m, n);
        // explicit transpose of a -> (m, k)
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul(&at, &b, &mut c2, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }

        let x = randv(&mut r, m * n); // (m, n)
        let w = randv(&mut r, k * n); // (k, n) -> wT is (n, k)
        let mut d1 = vec![0.0; m * k];
        kernels::matmul_a_bt(&x, &w, &mut d1, m, n, k);
        let mut wt = vec![0.0f32; n * k];
        for j in 0..k {
            for p in 0..n {
                wt[p * k + j] = w[j * n + p];
            }
        }
        let mut d2 = vec![0.0; m * k];
        matmul(&x, &wt, &mut d2, m, n, k);
        for (p, q) in d1.iter().zip(&d2) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn fused_linear_grad_matches_finite_difference() {
        let mut r = Rng::seed_from_u64(1);
        let (m, k, n) = (3, 4, 2);
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let x = randv(&mut r, m * k);
            let w = randv(&mut r, k * n);
            let b = randv(&mut r, n);
            // loss = sum(y^2)/2
            let loss = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
                let (y, _) = fused_linear_fwd(x, w, b, m, k, n, act);
                y.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
            };
            let (y, pre) = fused_linear_fwd(&x, &w, &b, m, k, n, act);
            let dy = y.clone(); // d(sum y²/2)/dy = y
            let (dx, dw, db) = fused_linear_bwd(&x, &w, &pre, &dy, m, k, n, act);
            let eps = 1e-3f32;
            // check a few coordinates of each grad
            for idx in [0usize, m * k / 2, m * k - 1] {
                let mut xp = x.clone();
                xp[idx] += eps;
                let fd = (loss(&xp, &w, &b) - loss(&x, &w, &b)) / eps as f64;
                assert!(
                    (fd - dx[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dx[{idx}]: fd={fd} an={}",
                    dx[idx]
                );
            }
            for idx in [0usize, k * n - 1] {
                let mut wp = w.clone();
                wp[idx] += eps;
                let fd = (loss(&x, &wp, &b) - loss(&x, &w, &b)) / eps as f64;
                assert!(
                    (fd - dw[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dw[{idx}]: fd={fd} an={}",
                    dw[idx]
                );
            }
            let mut bp = b.clone();
            bp[0] += eps;
            let fd = (loss(&x, &w, &bp) - loss(&x, &w, &b)) / eps as f64;
            assert!((fd - db[0] as f64).abs() < 2e-2 * (1.0 + fd.abs()), "{act:?} db");
        }
    }

    #[test]
    fn gelu_matches_reference_points() {
        // jax.nn.gelu(1.0) ≈ 0.841192, gelu(-1.0) ≈ -0.158808 (tanh approx)
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert_eq!(gelu(0.0), 0.0);
    }
}
