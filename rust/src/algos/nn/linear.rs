//! Dense linear algebra: matmul variants and the fused linear layer
//! (native mirror of the Pallas `fused_linear` kernel).

/// Activation of a fused linear layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Gelu,
}

impl Act {
    #[inline]
    fn apply(&self, v: f32) -> f32 {
        match self {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Gelu => gelu(v),
        }
    }
}

/// tanh-free exact GELU: x·Φ(x) with Φ via erf — matches jax.nn.gelu
/// (approximate=True default uses tanh; jax default IS approximate).
/// We mirror jax's default tanh approximation.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[inline]
fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let x3 = x * x * x;
    let t = (C * (x + 0.044715 * x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// C(m,n) = A(m,k) · B(k,n). Cache-friendly ikj loop; `c` is overwritten.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C(m,n) = Aᵀ(m,k stored k,m) · B(k,n) — i.e. A is (k, m) and we compute
/// AᵀB. Used for dW = Xᵀ·dY.
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C(m,k) = A(m,n) · Bᵀ(n,k stored k,n). Used for dX = dY·Wᵀ.
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = crate::algos::svm::dot(arow, &b[j * n..(j + 1) * n]);
        }
    }
}

/// Forward fused linear: y(m,n) = act(x(m,k)·w(k,n) + bias). Returns the
/// pre-activation too (needed for gelu backward).
pub fn fused_linear_fwd(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>) {
    let mut pre = vec![0.0f32; m * n];
    matmul(x, w, &mut pre, m, k, n);
    for row in 0..m {
        for (j, &bv) in bias.iter().enumerate() {
            pre[row * n + j] += bv;
        }
    }
    let y: Vec<f32> = pre.iter().map(|&v| act.apply(v)).collect();
    (y, pre)
}

/// Backward fused linear given upstream grad `dy`:
/// returns (dx, dw, db). `pre` is the forward pre-activation.
#[allow(clippy::too_many_arguments)]
pub fn fused_linear_bwd(
    x: &[f32],
    w: &[f32],
    pre: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    // d(pre) = dy ⊙ act'(pre)
    let dpre: Vec<f32> = match act {
        Act::None => dy.to_vec(),
        Act::Relu => dy
            .iter()
            .zip(pre)
            .map(|(&g, &p)| if p > 0.0 { g } else { 0.0 })
            .collect(),
        Act::Gelu => dy.iter().zip(pre).map(|(&g, &p)| g * gelu_grad(p)).collect(),
    };
    let mut dx = vec![0.0f32; m * k];
    matmul_a_bt(&dpre, w, &mut dx, m, n, k);
    let mut dw = vec![0.0f32; k * n];
    matmul_at_b(x, &dpre, &mut dw, m, k, n);
    let mut db = vec![0.0f32; n];
    for row in 0..m {
        for (j, dbv) in db.iter_mut().enumerate() {
            *dbv += dpre[row * n + j];
        }
    }
    (dx, dw, db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, a);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        let mut r = Rng::seed_from_u64(0);
        let (m, k, n) = (5, 7, 3);
        let a = randv(&mut r, k * m); // (k, m)
        let b = randv(&mut r, k * n); // (k, n)
        let mut c1 = vec![0.0; m * n];
        matmul_at_b(&a, &b, &mut c1, k, m, n);
        // explicit transpose of a -> (m, k)
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        let mut c2 = vec![0.0; m * n];
        matmul(&at, &b, &mut c2, m, k, n);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-4);
        }

        let x = randv(&mut r, m * n); // (m, n)
        let w = randv(&mut r, k * n); // (k, n) -> wT is (n, k)
        let mut d1 = vec![0.0; m * k];
        matmul_a_bt(&x, &w, &mut d1, m, n, k);
        let mut wt = vec![0.0f32; n * k];
        for j in 0..k {
            for p in 0..n {
                wt[p * k + j] = w[j * n + p];
            }
        }
        let mut d2 = vec![0.0; m * k];
        matmul(&x, &wt, &mut d2, m, n, k);
        for (p, q) in d1.iter().zip(&d2) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn fused_linear_grad_matches_finite_difference() {
        let mut r = Rng::seed_from_u64(1);
        let (m, k, n) = (3, 4, 2);
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let x = randv(&mut r, m * k);
            let w = randv(&mut r, k * n);
            let b = randv(&mut r, n);
            // loss = sum(y^2)/2
            let loss = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
                let (y, _) = fused_linear_fwd(x, w, b, m, k, n, act);
                y.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
            };
            let (y, pre) = fused_linear_fwd(&x, &w, &b, m, k, n, act);
            let dy = y.clone(); // d(sum y²/2)/dy = y
            let (dx, dw, db) = fused_linear_bwd(&x, &w, &pre, &dy, m, k, n, act);
            let eps = 1e-3f32;
            // check a few coordinates of each grad
            for idx in [0usize, m * k / 2, m * k - 1] {
                let mut xp = x.clone();
                xp[idx] += eps;
                let fd = (loss(&xp, &w, &b) - loss(&x, &w, &b)) / eps as f64;
                assert!(
                    (fd - dx[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dx[{idx}]: fd={fd} an={}",
                    dx[idx]
                );
            }
            for idx in [0usize, k * n - 1] {
                let mut wp = w.clone();
                wp[idx] += eps;
                let fd = (loss(&x, &wp, &b) - loss(&x, &w, &b)) / eps as f64;
                assert!(
                    (fd - dw[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{act:?} dw[{idx}]: fd={fd} an={}",
                    dw[idx]
                );
            }
            let mut bp = b.clone();
            bp[0] += eps;
            let fd = (loss(&x, &w, &bp) - loss(&x, &w, &b)) / eps as f64;
            assert!((fd - db[0] as f64).abs() < 2e-2 * (1.0 + fd.abs()), "{act:?} db");
        }
    }

    #[test]
    fn gelu_matches_reference_points() {
        // jax.nn.gelu(1.0) ≈ 0.841192, gelu(-1.0) ≈ -0.158808 (tanh approx)
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert_eq!(gelu(0.0), 0.0);
    }
}
