//! 2-D convolution (SAME padding, stride 1, NHWC/HWIO) and 2×2 max-pool —
//! the native mirror of the L2 CNN graph (`lax.conv_general_dilated` +
//! `lax.reduce_window`), implemented via im2col + matmul.

use super::linear::matmul;
use crate::util::kernels;

/// im2col for SAME padding, stride 1: output (n·h·w, ks·ks·c).
pub fn im2col(x: &[f32], n: usize, h: usize, w: usize, c: usize, ks: usize) -> Vec<f32> {
    let pad = ks / 2;
    let cols = ks * ks * c;
    let mut out = vec![0.0f32; n * h * w * cols];
    for img in 0..n {
        let base = img * h * w * c;
        for oy in 0..h {
            for ox in 0..w {
                let row = ((img * h + oy) * w + ox) * cols;
                for ky in 0..ks {
                    let iy = oy as isize + ky as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..ks {
                        let ix = ox as isize + kx as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = base + ((iy as usize * w) + ix as usize) * c;
                        let dst = row + (ky * ks + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// Scatter-add of an im2col-shaped gradient back to image layout
/// (the adjoint of [`im2col`]).
pub fn col2im(
    dcol: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    ks: usize,
) -> Vec<f32> {
    let pad = ks / 2;
    let cols = ks * ks * c;
    let mut out = vec![0.0f32; n * h * w * c];
    for img in 0..n {
        let base = img * h * w * c;
        for oy in 0..h {
            for ox in 0..w {
                let row = ((img * h + oy) * w + ox) * cols;
                for ky in 0..ks {
                    let iy = oy as isize + ky as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..ks {
                        let ix = ox as isize + kx as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let dst = base + ((iy as usize * w) + ix as usize) * c;
                        let src = row + (ky * ks + kx) * c;
                        kernels::acc(&mut out[dst..dst + c], &dcol[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// conv2d SAME/stride-1 forward: x (n,h,w,cin) · w (ks,ks,cin,cout) + b.
/// Returns (y (n,h,w,cout), im2col matrix — kept as the backward residual).
pub fn conv2d_fwd(
    x: &[f32],
    wk: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    ks: usize,
    cout: usize,
) -> (Vec<f32>, Vec<f32>) {
    let col = im2col(x, n, h, w, cin, ks);
    let rows = n * h * w;
    let inner = ks * ks * cin;
    let mut y = vec![0.0f32; rows * cout];
    // wk is (ks,ks,cin,cout) = (inner, cout) row-major already.
    matmul(&col, wk, &mut y, rows, inner, cout);
    for row in y.chunks_exact_mut(cout) {
        kernels::acc(row, b);
    }
    (y, col)
}

/// conv2d backward: returns (dx, dw, db).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd(
    col: &[f32],
    wk: &[f32],
    dy: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    ks: usize,
    cout: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let rows = n * h * w;
    let inner = ks * ks * cin;
    // dW(inner, cout) = colᵀ(rows, inner)ᵀ · dy(rows, cout)
    let mut dw = vec![0.0f32; inner * cout];
    super::linear::matmul_at_b(col, dy, &mut dw, rows, inner, cout);
    // dcol(rows, inner) = dy · wkᵀ
    let mut dcol = vec![0.0f32; rows * inner];
    super::linear::matmul_a_bt(dy, wk, &mut dcol, rows, cout, inner);
    let dx = col2im(&dcol, n, h, w, cin, ks);
    let mut db = vec![0.0f32; cout];
    for row in dy.chunks_exact(cout) {
        kernels::acc(&mut db, row);
    }
    (dx, dw, db)
}

/// 2×2 max-pool, stride 2, VALID. Returns (y (n,h/2,w/2,c), argmax indices
/// into the input for the backward pass).
pub fn maxpool2_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
) -> (Vec<f32>, Vec<u32>) {
    let (oh, ow) = (h / 2, w / 2);
    let mut y = vec![0.0f32; n * oh * ow * c];
    let mut arg = vec![0u32; n * oh * ow * c];
    for img in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_idx = 0u32;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let iy = oy * 2 + dy;
                            let ix = ox * 2 + dx;
                            let idx = ((img * h + iy) * w + ix) * c + ch;
                            if x[idx] > best {
                                best = x[idx];
                                best_idx = idx as u32;
                            }
                        }
                    }
                    let o = ((img * oh + oy) * ow + ox) * c + ch;
                    y[o] = best;
                    arg[o] = best_idx;
                }
            }
        }
    }
    (y, arg)
}

/// max-pool backward: route dy to the argmax inputs.
pub fn maxpool2_bwd(dy: &[f32], arg: &[u32], input_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; input_len];
    for (g, &a) in dy.iter().zip(arg) {
        dx[a as usize] += g;
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn im2col_identity_kernel_1x1() {
        let mut r = Rng::seed_from_u64(0);
        let x = randv(&mut r, 2 * 3 * 3 * 2);
        let col = im2col(&x, 2, 3, 3, 2, 1);
        assert_eq!(col, x); // 1x1 im2col is the identity
    }

    #[test]
    fn conv_identity_kernel_passes_through() {
        // 3x3 kernel with only the center weight = 1 on one channel.
        let mut r = Rng::seed_from_u64(1);
        let (n, h, w, cin, ks, cout) = (1, 4, 4, 1, 3, 1);
        let x = randv(&mut r, n * h * w * cin);
        let mut wk = vec![0.0f32; ks * ks * cin * cout];
        wk[(1 * 3 + 1) * cin * cout] = 1.0; // center tap
        let b = vec![0.0f32];
        let (y, _) = conv2d_fwd(&x, &wk, &b, n, h, w, cin, ks, cout);
        for (a, bb) in x.iter().zip(&y) {
            assert!((a - bb).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_grads_match_finite_difference() {
        let mut r = Rng::seed_from_u64(2);
        let (n, h, w, cin, ks, cout) = (1, 4, 4, 2, 3, 2);
        let x = randv(&mut r, n * h * w * cin);
        let wk = randv(&mut r, ks * ks * cin * cout);
        let b = randv(&mut r, cout);
        let loss = |x: &[f32], wk: &[f32], b: &[f32]| -> f64 {
            let (y, _) = conv2d_fwd(x, wk, b, n, h, w, cin, ks, cout);
            y.iter().map(|&v| (v as f64).powi(2) / 2.0).sum()
        };
        let (y, col) = conv2d_fwd(&x, &wk, &b, n, h, w, cin, ks, cout);
        let (dx, dw, db) = conv2d_bwd(&col, &wk, &y, n, h, w, cin, ks, cout);
        let eps = 1e-3f32;
        for idx in [0usize, 7, x.len() - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let fd = (loss(&xp, &wk, &b) - loss(&x, &wk, &b)) / eps as f64;
            assert!((fd - dx[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()), "dx[{idx}]");
        }
        for idx in [0usize, dw.len() / 2, dw.len() - 1] {
            let mut wp = wk.to_vec();
            wp[idx] += eps;
            let fd = (loss(&x, &wp, &b) - loss(&x, &wk, &b)) / eps as f64;
            assert!((fd - dw[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()), "dw[{idx}]");
        }
        let mut bp = b.clone();
        bp[1] += eps;
        let fd = (loss(&x, &wk, &bp) - loss(&x, &wk, &b)) / eps as f64;
        assert!((fd - db[1] as f64).abs() < 3e-2 * (1.0 + fd.abs()), "db");
    }

    #[test]
    fn maxpool_fwd_bwd() {
        // 2x2 image, 1 channel: pool picks max; grad routes to argmax.
        let x = vec![1.0f32, 3.0, 2.0, 0.5];
        let (y, arg) = maxpool2_fwd(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![3.0]);
        assert_eq!(arg, vec![1]);
        let dx = maxpool2_bwd(&[2.0], &arg, 4);
        assert_eq!(dx, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y.
        let mut r = Rng::seed_from_u64(3);
        let (n, h, w, c, ks) = (1, 3, 3, 2, 3);
        let x = randv(&mut r, n * h * w * c);
        let y = randv(&mut r, n * h * w * ks * ks * c);
        let ax = im2col(&x, n, h, w, c, ks);
        let aty = col2im(&y, n, h, w, c, ks);
        let lhs: f64 = ax.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.iter().zip(&aty).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()));
    }
}
