//! 2-D convolution (SAME padding, stride 1, NHWC/HWIO) and 2×2 max-pool —
//! the native mirror of the L2 CNN graph (`lax.conv_general_dilated` +
//! `lax.reduce_window`), implemented via im2col + matmul.
//!
//! Every entry point comes in two forms: an `_into`/`_ws` variant that
//! writes into caller buffers and checks scratch out of a
//! [`Workspace`] (the allocation-free training hot path), and an
//! allocating wrapper with the original signature. The wrappers run the
//! identical code against a throwaway workspace, so both forms are
//! bit-identical by construction.
//!
//! The im2col/col2im inner loops are span-merged: for stride-1 SAME
//! padding, the valid `kx` range of a fixed `(img, oy, ox, ky)` cell is
//! contiguous in *both* the image (consecutive `ix`) and the column
//! matrix (consecutive `kx`), so the per-tap bounds checks collapse
//! into one `copy_from_slice` (forward) or one [`kernels::acc`]
//! (backward) over `(kx_hi − kx_lo) · c` floats. Each destination
//! element still receives exactly the contributions it did before, in
//! the same outer-loop order — bit-identical, just without the
//! per-element branch.

use crate::util::kernels;
use crate::util::workspace::Workspace;

/// im2col for SAME padding, stride 1, into a caller buffer of shape
/// (n·h·w, ks·ks·c). `out` is fully overwritten (padding taps zeroed).
pub fn im2col_into(x: &[f32], n: usize, h: usize, w: usize, c: usize, ks: usize, out: &mut [f32]) {
    let pad = ks / 2;
    let cols = ks * ks * c;
    assert_eq!(x.len(), n * h * w * c);
    assert_eq!(out.len(), n * h * w * cols);
    out.fill(0.0);
    for img in 0..n {
        let base = img * h * w * c;
        for oy in 0..h {
            for ox in 0..w {
                let row = ((img * h + oy) * w + ox) * cols;
                // Valid kx span: 0 ≤ ox + kx − pad < w.
                let kx_lo = pad.saturating_sub(ox);
                let kx_hi = ks.min(w + pad - ox);
                if kx_lo >= kx_hi {
                    continue;
                }
                let span = (kx_hi - kx_lo) * c;
                let ix0 = ox + kx_lo - pad;
                for ky in 0..ks {
                    let iy = oy as isize + ky as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let src = base + (iy as usize * w + ix0) * c;
                    let dst = row + (ky * ks + kx_lo) * c;
                    out[dst..dst + span].copy_from_slice(&x[src..src + span]);
                }
            }
        }
    }
}

/// im2col for SAME padding, stride 1: output (n·h·w, ks·ks·c).
pub fn im2col(x: &[f32], n: usize, h: usize, w: usize, c: usize, ks: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * h * w * ks * ks * c];
    im2col_into(x, n, h, w, c, ks, &mut out);
    out
}

/// Scatter-add of an im2col-shaped gradient back to image layout (the
/// adjoint of [`im2col`]), into a caller buffer. `out` is zero-seeded
/// then accumulated, so a dirty buffer is fine.
pub fn col2im_into(
    dcol: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    ks: usize,
    out: &mut [f32],
) {
    let pad = ks / 2;
    let cols = ks * ks * c;
    assert_eq!(dcol.len(), n * h * w * cols);
    assert_eq!(out.len(), n * h * w * c);
    out.fill(0.0);
    for img in 0..n {
        let base = img * h * w * c;
        for oy in 0..h {
            for ox in 0..w {
                let row = ((img * h + oy) * w + ox) * cols;
                let kx_lo = pad.saturating_sub(ox);
                let kx_hi = ks.min(w + pad - ox);
                if kx_lo >= kx_hi {
                    continue;
                }
                let span = (kx_hi - kx_lo) * c;
                let ix0 = ox + kx_lo - pad;
                for ky in 0..ks {
                    let iy = oy as isize + ky as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let dst = base + (iy as usize * w + ix0) * c;
                    let src = row + (ky * ks + kx_lo) * c;
                    kernels::acc(&mut out[dst..dst + span], &dcol[src..src + span]);
                }
            }
        }
    }
}

/// Scatter-add of an im2col-shaped gradient back to image layout
/// (the adjoint of [`im2col`]).
pub fn col2im(dcol: &[f32], n: usize, h: usize, w: usize, c: usize, ks: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * h * w * c];
    col2im_into(dcol, n, h, w, c, ks, &mut out);
    out
}

/// conv2d SAME/stride-1 forward: x (n,h,w,cin) · w (ks,ks,cin,cout) + b.
/// Returns (y (n,h,w,cout), im2col matrix — kept as the backward
/// residual). Both buffers are checked out of `ws`; the caller `put`s
/// them back once the backward pass has consumed them.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fwd_ws(
    x: &[f32],
    wk: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    ks: usize,
    cout: usize,
    ws: &mut Workspace,
) -> (Vec<f32>, Vec<f32>) {
    let rows = n * h * w;
    let inner = ks * ks * cin;
    let mut col = ws.take(rows * inner);
    im2col_into(x, n, h, w, cin, ks, &mut col);
    let mut y = ws.take(rows * cout);
    // wk is (ks,ks,cin,cout) = (inner, cout) row-major already.
    kernels::matmul(&col, wk, &mut y, rows, inner, cout);
    for row in y.chunks_exact_mut(cout) {
        kernels::acc(row, b);
    }
    (y, col)
}

/// Allocating wrapper over [`conv2d_fwd_ws`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fwd(
    x: &[f32],
    wk: &[f32],
    b: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    ks: usize,
    cout: usize,
) -> (Vec<f32>, Vec<f32>) {
    conv2d_fwd_ws(x, wk, b, n, h, w, cin, ks, cout, &mut Workspace::new())
}

/// conv2d backward into caller buffers: `dx` (n·h·w·cin), `dw`
/// (ks·ks·cin·cout), `db` (cout) are fully overwritten — `dw`/`db` may
/// be disjoint slices of a flat gradient vector. Scratch from `ws`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_into(
    col: &[f32],
    wk: &[f32],
    dy: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    ks: usize,
    cout: usize,
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
    ws: &mut Workspace,
) {
    let rows = n * h * w;
    let inner = ks * ks * cin;
    // dW(inner, cout) = colᵀ(rows, inner)ᵀ · dy(rows, cout)
    kernels::matmul_at_b_ws(col, dy, dw, rows, inner, cout, ws);
    // dcol(rows, inner) = dy · wkᵀ
    let mut dcol = ws.take(rows * inner);
    kernels::matmul_a_bt(dy, wk, &mut dcol, rows, cout, inner);
    col2im_into(&dcol, n, h, w, cin, ks, dx);
    ws.put(dcol);
    db.fill(0.0);
    for row in dy.chunks_exact(cout) {
        kernels::acc(db, row);
    }
}

/// conv2d backward: returns (dx, dw, db). Allocating wrapper over
/// [`conv2d_bwd_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd(
    col: &[f32],
    wk: &[f32],
    dy: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    ks: usize,
    cout: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let inner = ks * ks * cin;
    let mut dx = vec![0.0f32; n * h * w * cin];
    let mut dw = vec![0.0f32; inner * cout];
    let mut db = vec![0.0f32; cout];
    conv2d_bwd_into(
        col,
        wk,
        dy,
        n,
        h,
        w,
        cin,
        ks,
        cout,
        &mut dx,
        &mut dw,
        &mut db,
        &mut Workspace::new(),
    );
    (dx, dw, db)
}

/// 2×2 max-pool, stride 2, VALID, into caller buffers (`y` and `arg`
/// fully overwritten). The channel loop runs through
/// [`kernels::maxpool4`] — lane-per-channel, candidates in `(dy, dx)`
/// order with strict-`>` first-max-wins tie-breaking, exactly the
/// original scalar semantics.
pub fn maxpool2_fwd_into(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    y: &mut [f32],
    arg: &mut [u32],
) {
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(y.len(), n * oh * ow * c);
    assert_eq!(arg.len(), n * oh * ow * c);
    for img in 0..n {
        for oy in 0..oh {
            let iy = oy * 2;
            for ox in 0..ow {
                let ix = ox * 2;
                let r0 = ((img * h + iy) * w + ix) * c;
                let r1 = r0 + c;
                let r2 = ((img * h + iy + 1) * w + ix) * c;
                let r3 = r2 + c;
                let o = ((img * oh + oy) * ow + ox) * c;
                kernels::maxpool4(
                    &x[r0..r0 + c],
                    &x[r1..r1 + c],
                    &x[r2..r2 + c],
                    &x[r3..r3 + c],
                    [r0 as u32, r1 as u32, r2 as u32, r3 as u32],
                    &mut y[o..o + c],
                    &mut arg[o..o + c],
                );
            }
        }
    }
}

/// 2×2 max-pool, stride 2, VALID. Returns (y (n,h/2,w/2,c), argmax
/// indices into the input for the backward pass).
pub fn maxpool2_fwd(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    let (oh, ow) = (h / 2, w / 2);
    let mut y = vec![0.0f32; n * oh * ow * c];
    let mut arg = vec![0u32; n * oh * ow * c];
    maxpool2_fwd_into(x, n, h, w, c, &mut y, &mut arg);
    (y, arg)
}

/// max-pool backward into a caller buffer: `dx` is zero-seeded, then
/// `dy` routes to the argmax inputs.
pub fn maxpool2_bwd_into(dy: &[f32], arg: &[u32], dx: &mut [f32]) {
    dx.fill(0.0);
    for (g, &a) in dy.iter().zip(arg) {
        dx[a as usize] += g;
    }
}

/// max-pool backward: route dy to the argmax inputs.
pub fn maxpool2_bwd(dy: &[f32], arg: &[u32], input_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; input_len];
    maxpool2_bwd_into(dy, arg, &mut dx);
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn im2col_identity_kernel_1x1() {
        let mut r = Rng::seed_from_u64(0);
        let x = randv(&mut r, 2 * 3 * 3 * 2);
        let col = im2col(&x, 2, 3, 3, 2, 1);
        assert_eq!(col, x); // 1x1 im2col is the identity
    }

    #[test]
    fn im2col_span_merge_matches_per_tap_reference() {
        // The naive per-(ky,kx) loop with isize bounds checks, as the
        // pre-span-merge implementation wrote it.
        fn im2col_naive(x: &[f32], n: usize, h: usize, w: usize, c: usize, ks: usize) -> Vec<f32> {
            let pad = ks / 2;
            let cols = ks * ks * c;
            let mut out = vec![0.0f32; n * h * w * cols];
            for img in 0..n {
                let base = img * h * w * c;
                for oy in 0..h {
                    for ox in 0..w {
                        let row = ((img * h + oy) * w + ox) * cols;
                        for ky in 0..ks {
                            let iy = oy as isize + ky as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..ks {
                                let ix = ox as isize + kx as isize - pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let src = base + ((iy as usize * w) + ix as usize) * c;
                                let dst = row + (ky * ks + kx) * c;
                                out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                            }
                        }
                    }
                }
            }
            out
        }
        let mut r = Rng::seed_from_u64(7);
        for (n, h, w, c, ks) in [(1, 4, 4, 2, 3), (2, 5, 3, 1, 5), (1, 3, 3, 3, 1)] {
            let x = randv(&mut r, n * h * w * c);
            assert_eq!(im2col(&x, n, h, w, c, ks), im2col_naive(&x, n, h, w, c, ks));
        }
    }

    #[test]
    fn conv_identity_kernel_passes_through() {
        // 3x3 kernel with only the center weight = 1 on one channel.
        let mut r = Rng::seed_from_u64(1);
        let (n, h, w, cin, ks, cout) = (1, 4, 4, 1, 3, 1);
        let x = randv(&mut r, n * h * w * cin);
        let mut wk = vec![0.0f32; ks * ks * cin * cout];
        wk[(1 * 3 + 1) * cin * cout] = 1.0; // center tap
        let b = vec![0.0f32];
        let (y, _) = conv2d_fwd(&x, &wk, &b, n, h, w, cin, ks, cout);
        for (a, bb) in x.iter().zip(&y) {
            assert!((a - bb).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_grads_match_finite_difference() {
        let mut r = Rng::seed_from_u64(2);
        let (n, h, w, cin, ks, cout) = (1, 4, 4, 2, 3, 2);
        let x = randv(&mut r, n * h * w * cin);
        let wk = randv(&mut r, ks * ks * cin * cout);
        let b = randv(&mut r, cout);
        let loss = |x: &[f32], wk: &[f32], b: &[f32]| -> f64 {
            let (y, _) = conv2d_fwd(x, wk, b, n, h, w, cin, ks, cout);
            y.iter().map(|&v| (v as f64).powi(2) / 2.0).sum()
        };
        let (y, col) = conv2d_fwd(&x, &wk, &b, n, h, w, cin, ks, cout);
        let (dx, dw, db) = conv2d_bwd(&col, &wk, &y, n, h, w, cin, ks, cout);
        let eps = 1e-3f32;
        for idx in [0usize, 7, x.len() - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let fd = (loss(&xp, &wk, &b) - loss(&x, &wk, &b)) / eps as f64;
            assert!((fd - dx[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()), "dx[{idx}]");
        }
        for idx in [0usize, dw.len() / 2, dw.len() - 1] {
            let mut wp = wk.to_vec();
            wp[idx] += eps;
            let fd = (loss(&x, &wp, &b) - loss(&x, &wk, &b)) / eps as f64;
            assert!((fd - dw[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()), "dw[{idx}]");
        }
        let mut bp = b.clone();
        bp[1] += eps;
        let fd = (loss(&x, &wk, &bp) - loss(&x, &wk, &b)) / eps as f64;
        assert!((fd - db[1] as f64).abs() < 3e-2 * (1.0 + fd.abs()), "db");
    }

    #[test]
    fn maxpool_fwd_bwd() {
        // 2x2 image, 1 channel: pool picks max; grad routes to argmax.
        let x = vec![1.0f32, 3.0, 2.0, 0.5];
        let (y, arg) = maxpool2_fwd(&x, 1, 2, 2, 1);
        assert_eq!(y, vec![3.0]);
        assert_eq!(arg, vec![1]);
        let dx = maxpool2_bwd(&[2.0], &arg, 4);
        assert_eq!(dx, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn maxpool_matches_per_channel_reference() {
        // The pre-kernel scalar loop: per-channel candidate scan in
        // (dy, dx) order, strict > so the first max wins.
        fn maxpool_naive(
            x: &[f32],
            n: usize,
            h: usize,
            w: usize,
            c: usize,
        ) -> (Vec<f32>, Vec<u32>) {
            let (oh, ow) = (h / 2, w / 2);
            let mut y = vec![0.0f32; n * oh * ow * c];
            let mut arg = vec![0u32; n * oh * ow * c];
            for img in 0..n {
                for oy in 0..oh {
                    for ox in 0..ow {
                        for ch in 0..c {
                            let mut best = f32::NEG_INFINITY;
                            let mut best_idx = 0u32;
                            for dy in 0..2 {
                                for dx in 0..2 {
                                    let idx =
                                        ((img * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch;
                                    if x[idx] > best {
                                        best = x[idx];
                                        best_idx = idx as u32;
                                    }
                                }
                            }
                            let o = ((img * oh + oy) * ow + ox) * c + ch;
                            y[o] = best;
                            arg[o] = best_idx;
                        }
                    }
                }
            }
            (y, arg)
        }
        let mut r = Rng::seed_from_u64(9);
        // Channel counts below, at, and above the 8-lane width; repeated
        // values to exercise tie-breaking.
        for (n, h, w, c) in [(1, 4, 4, 1), (2, 4, 6, 8), (1, 6, 4, 17)] {
            let x: Vec<f32> = (0..n * h * w * c).map(|_| (r.below(5) as f32) - 2.0).collect();
            let (y, arg) = maxpool2_fwd(&x, n, h, w, c);
            let (yn, argn) = maxpool_naive(&x, n, h, w, c);
            assert_eq!(y, yn);
            assert_eq!(arg, argn);
        }
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y.
        let mut r = Rng::seed_from_u64(3);
        let (n, h, w, c, ks) = (1, 3, 3, 2, 3);
        let x = randv(&mut r, n * h * w * c);
        let y = randv(&mut r, n * h * w * ks * ks * c);
        let ax = im2col(&x, n, h, w, c, ks);
        let aty = col2im(&y, n, h, w, c, ks);
        let lhs: f64 = ax.iter().zip(&y).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.iter().zip(&aty).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()));
    }
}
