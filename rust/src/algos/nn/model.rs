//! Native NN models over flat parameter vectors.
//!
//! The flat layout matches `python/compile/model.py` exactly (same tensor
//! order, row-major), so parameters produced by the HLO `*_init` artifacts
//! are directly usable here and vice versa — verified by
//! `rust/tests/hlo_native_equivalence.rs`.

use crate::util::Rng;

use super::conv::{conv2d_bwd, conv2d_fwd, maxpool2_bwd, maxpool2_fwd};
use super::linear::{fused_linear_bwd, fused_linear_fwd, Act};
use super::loss::softmax_xent;

/// Geometry of the paper's CNN (§5.1): 2× [conv5x5 SAME + maxpool2 + relu]
/// then 3 FC layers. Mirrors `CnnConfig` in model.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CnnShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub conv1: usize,
    pub conv2: usize,
    pub ks: usize,
    pub fc1: usize,
    pub fc2: usize,
    pub classes: usize,
}

impl Default for CnnShape {
    fn default() -> Self {
        // Must mirror CnnConfig in python/compile/model.py.
        CnnShape { h: 32, w: 32, c: 3, conv1: 8, conv2: 16, ks: 5, fc1: 256, fc2: 128, classes: 10 }
    }
}

impl CnnShape {
    pub fn flat_after_conv(&self) -> usize {
        (self.h / 4) * (self.w / 4) * self.conv2
    }

    pub fn input_dim(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// A natively-computable model over a flat f32 parameter vector.
#[derive(Clone, Debug)]
pub enum NativeModel {
    /// `dims[0] → … → dims.last()`, relu between, none at the end.
    Mlp { dims: Vec<usize> },
    Cnn { shape: CnnShape },
}

impl NativeModel {
    pub fn mlp_default() -> Self {
        NativeModel::Mlp { dims: vec![784, 256, 128, 10] }
    }

    pub fn cnn_default() -> Self {
        NativeModel::Cnn { shape: CnnShape::default() }
    }

    /// (name, element-count) pairs in flat order — mirrors model.py specs.
    pub fn param_sizes(&self) -> Vec<(String, usize)> {
        match self {
            NativeModel::Mlp { dims } => {
                let mut v = Vec::new();
                for i in 0..dims.len() - 1 {
                    v.push((format!("fc{i}.w"), dims[i] * dims[i + 1]));
                    v.push((format!("fc{i}.b"), dims[i + 1]));
                }
                v
            }
            NativeModel::Cnn { shape: s } => vec![
                ("conv1.w".into(), s.ks * s.ks * s.c * s.conv1),
                ("conv1.b".into(), s.conv1),
                ("conv2.w".into(), s.ks * s.ks * s.conv1 * s.conv2),
                ("conv2.b".into(), s.conv2),
                ("fc1.w".into(), s.flat_after_conv() * s.fc1),
                ("fc1.b".into(), s.fc1),
                ("fc2.w".into(), s.fc1 * s.fc2),
                ("fc2.b".into(), s.fc2),
                ("fc3.w".into(), s.fc2 * s.classes),
                ("fc3.b".into(), s.classes),
            ],
        }
    }

    pub fn param_count(&self) -> usize {
        self.param_sizes().iter().map(|(_, s)| s).sum()
    }

    pub fn input_dim(&self) -> usize {
        match self {
            NativeModel::Mlp { dims } => dims[0],
            NativeModel::Cnn { shape } => shape.input_dim(),
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            NativeModel::Mlp { dims } => *dims.last().unwrap(),
            NativeModel::Cnn { shape } => shape.classes,
        }
    }

    /// He-initialized flat parameters (weights ~ N(0, 2/fan_in), zero bias).
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        let mut out = Vec::with_capacity(self.param_count());
        for (name, size) in self.param_sizes() {
            if name.ends_with(".b") {
                out.extend(std::iter::repeat(0.0f32).take(size));
            } else {
                let fan_in = match self {
                    NativeModel::Mlp { dims } => {
                        let i: usize = name[2..3].parse().unwrap();
                        dims[i]
                    }
                    NativeModel::Cnn { shape: s } => match name.as_str() {
                        "conv1.w" => s.ks * s.ks * s.c,
                        "conv2.w" => s.ks * s.ks * s.conv1,
                        "fc1.w" => s.flat_after_conv(),
                        "fc2.w" => s.fc1,
                        "fc3.w" => s.fc2,
                        _ => unreachable!(),
                    },
                };
                let scale = (2.0 / fan_in as f64).sqrt() as f32;
                out.extend((0..size).map(|_| r.normal_f32() * scale));
            }
        }
        out
    }

    /// Forward pass: logits (batch × classes).
    pub fn forward(&self, params: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
        self.forward_full(params, x, batch).0
    }

    /// Loss + grads on a batch. Returns (grads, loss_mean, correct, n_valid).
    pub fn grad(
        &self,
        params: &[f32],
        x: &[f32],
        labels: &[i32],
    ) -> (Vec<f32>, f64, f64, f64) {
        let batch = labels.len();
        match self {
            NativeModel::Mlp { dims } => {
                // Forward, retaining residuals.
                let n_layers = dims.len() - 1;
                let mut offs = Vec::new();
                let mut off = 0usize;
                for i in 0..n_layers {
                    offs.push(off);
                    off += dims[i] * dims[i + 1] + dims[i + 1];
                }
                let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
                let mut pres: Vec<Vec<f32>> = Vec::new();
                for i in 0..n_layers {
                    let (k, n) = (dims[i], dims[i + 1]);
                    let w = &params[offs[i]..offs[i] + k * n];
                    let b = &params[offs[i] + k * n..offs[i] + k * n + n];
                    let act = if i == n_layers - 1 { Act::None } else { Act::Relu };
                    let (y, pre) = fused_linear_fwd(acts[i].as_slice(), w, b, batch, k, n, act);
                    acts.push(y);
                    pres.push(pre);
                }
                let (loss, correct, n_valid, dlogits) =
                    softmax_xent(acts.last().unwrap(), labels, dims[n_layers]);
                // Backward.
                let mut grads = vec![0.0f32; self.param_count()];
                let mut dy = dlogits;
                for i in (0..n_layers).rev() {
                    let (k, n) = (dims[i], dims[i + 1]);
                    let w = &params[offs[i]..offs[i] + k * n];
                    let act = if i == n_layers - 1 { Act::None } else { Act::Relu };
                    let (dx, dw, db) =
                        fused_linear_bwd(&acts[i], w, &pres[i], &dy, batch, k, n, act);
                    grads[offs[i]..offs[i] + k * n].copy_from_slice(&dw);
                    grads[offs[i] + k * n..offs[i] + k * n + n].copy_from_slice(&db);
                    dy = dx;
                }
                (grads, loss, correct, n_valid)
            }
            NativeModel::Cnn { shape: s } => {
                let sizes = self.param_sizes();
                let mut offs = Vec::new();
                let mut off = 0usize;
                for (_, sz) in &sizes {
                    offs.push(off);
                    off += sz;
                }
                let p = |i: usize| &params[offs[i]..offs[i] + sizes[i].1];
                let (n, h, w, c) = (batch, s.h, s.w, s.c);
                // conv1 + pool + relu
                let (c1, col1) = conv2d_fwd(x, p(0), p(1), n, h, w, c, s.ks, s.conv1);
                let (p1, arg1) = maxpool2_fwd(&c1, n, h, w, s.conv1);
                let r1: Vec<f32> = p1.iter().map(|&v| v.max(0.0)).collect();
                let (h2, w2) = (h / 2, w / 2);
                // conv2 + pool + relu
                let (c2, col2) = conv2d_fwd(&r1, p(2), p(3), n, h2, w2, s.conv1, s.ks, s.conv2);
                let (p2, arg2) = maxpool2_fwd(&c2, n, h2, w2, s.conv2);
                let r2: Vec<f32> = p2.iter().map(|&v| v.max(0.0)).collect();
                let flat = s.flat_after_conv();
                // fc1 relu, fc2 relu, fc3 none
                let (f1, pre1) = fused_linear_fwd(&r2, p(4), p(5), n, flat, s.fc1, Act::Relu);
                let (f2, pre2) = fused_linear_fwd(&f1, p(6), p(7), n, s.fc1, s.fc2, Act::Relu);
                let (logits, pre3) =
                    fused_linear_fwd(&f2, p(8), p(9), n, s.fc2, s.classes, Act::None);
                let (loss, correct, n_valid, dlogits) =
                    softmax_xent(&logits, labels, s.classes);
                // Backward.
                let mut grads = vec![0.0f32; self.param_count()];
                let gslice = |grads: &mut Vec<f32>, i: usize, v: &[f32]| {
                    grads[offs[i]..offs[i] + sizes[i].1].copy_from_slice(v);
                };
                let (d_f2, dw3, db3) =
                    fused_linear_bwd(&f2, p(8), &pre3, &dlogits, n, s.fc2, s.classes, Act::None);
                gslice(&mut grads, 8, &dw3);
                gslice(&mut grads, 9, &db3);
                let (d_f1, dw2, db2) =
                    fused_linear_bwd(&f1, p(6), &pre2, &d_f2, n, s.fc1, s.fc2, Act::Relu);
                gslice(&mut grads, 6, &dw2);
                gslice(&mut grads, 7, &db2);
                let (d_r2, dw1, db1) =
                    fused_linear_bwd(&r2, p(4), &pre1, &d_f1, n, flat, s.fc1, Act::Relu);
                gslice(&mut grads, 4, &dw1);
                gslice(&mut grads, 5, &db1);
                // relu' then unpool then conv2 backward
                let d_p2: Vec<f32> = d_r2
                    .iter()
                    .zip(&p2)
                    .map(|(&g, &v)| if v > 0.0 { g } else { 0.0 })
                    .collect();
                let d_c2 = maxpool2_bwd(&d_p2, &arg2, c2.len());
                let (d_r1, dwc2, dbc2) =
                    conv2d_bwd(&col2, p(2), &d_c2, n, h2, w2, s.conv1, s.ks, s.conv2);
                gslice(&mut grads, 2, &dwc2);
                gslice(&mut grads, 3, &dbc2);
                let d_p1: Vec<f32> = d_r1
                    .iter()
                    .zip(&p1)
                    .map(|(&g, &v)| if v > 0.0 { g } else { 0.0 })
                    .collect();
                let d_c1 = maxpool2_bwd(&d_p1, &arg1, c1.len());
                let (_dx, dwc1, dbc1) = conv2d_bwd(&col1, p(0), &d_c1, n, h, w, c, s.ks, s.conv1);
                gslice(&mut grads, 0, &dwc1);
                gslice(&mut grads, 1, &dbc1);
                (grads, loss, correct, n_valid)
            }
        }
    }

    /// Eval on a batch: (loss_mean, correct, n_valid).
    pub fn eval(&self, params: &[f32], x: &[f32], labels: &[i32]) -> (f64, f64, f64) {
        let batch = labels.len();
        let logits = self.forward(params, x, batch);
        let (loss, correct, n, _) = softmax_xent(&logits, labels, self.n_classes());
        (loss, correct, n)
    }

    fn forward_full(&self, params: &[f32], x: &[f32], batch: usize) -> (Vec<f32>, ()) {
        match self {
            NativeModel::Mlp { dims } => {
                let n_layers = dims.len() - 1;
                let mut off = 0usize;
                let mut cur = x.to_vec();
                for i in 0..n_layers {
                    let (k, n) = (dims[i], dims[i + 1]);
                    let w = &params[off..off + k * n];
                    let b = &params[off + k * n..off + k * n + n];
                    off += k * n + n;
                    let act = if i == n_layers - 1 { Act::None } else { Act::Relu };
                    let (y, _) = fused_linear_fwd(&cur, w, b, batch, k, n, act);
                    cur = y;
                }
                (cur, ())
            }
            NativeModel::Cnn { shape: s } => {
                let sizes = self.param_sizes();
                let mut offs = Vec::new();
                let mut off = 0usize;
                for (_, sz) in &sizes {
                    offs.push(off);
                    off += sz;
                }
                let p = |i: usize| &params[offs[i]..offs[i] + sizes[i].1];
                let (n, h, w, c) = (batch, s.h, s.w, s.c);
                let (c1, _) = conv2d_fwd(x, p(0), p(1), n, h, w, c, s.ks, s.conv1);
                let (p1, _) = maxpool2_fwd(&c1, n, h, w, s.conv1);
                let r1: Vec<f32> = p1.iter().map(|&v| v.max(0.0)).collect();
                let (h2, w2) = (h / 2, w / 2);
                let (c2, _) = conv2d_fwd(&r1, p(2), p(3), n, h2, w2, s.conv1, s.ks, s.conv2);
                let (p2, _) = maxpool2_fwd(&c2, n, h2, w2, s.conv2);
                let r2: Vec<f32> = p2.iter().map(|&v| v.max(0.0)).collect();
                let flat = s.flat_after_conv();
                let (f1, _) = fused_linear_fwd(&r2, p(4), p(5), n, flat, s.fc1, Act::Relu);
                let (f2, _) = fused_linear_fwd(&f1, p(6), p(7), n, s.fc1, s.fc2, Act::Relu);
                let (logits, _) =
                    fused_linear_fwd(&f2, p(8), p(9), n, s.fc2, s.classes, Act::None);
                (logits, ())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn param_counts_match_manifest_values() {
        // Values recorded from `make artifacts` output.
        assert_eq!(NativeModel::mlp_default().param_count(), 235_146);
        assert_eq!(NativeModel::cnn_default().param_count(), 300_410);
    }

    #[test]
    fn mlp_grad_matches_finite_difference() {
        let m = NativeModel::Mlp { dims: vec![6, 5, 3] };
        let params = m.init(0);
        let mut r = Rng::seed_from_u64(1);
        let x: Vec<f32> = (0..2 * 6).map(|_| r.normal_f32()).collect();
        let y = vec![1i32, 2];
        let (g, loss, _, n) = m.grad(&params, &x, &y);
        assert_eq!(n, 2.0);
        assert!(loss > 0.0);
        let eps = 1e-3f32;
        for idx in [0usize, 10, g.len() - 1, g.len() / 2] {
            let mut pp = params.clone();
            pp[idx] += eps;
            let (_, lp, _, _) = m.grad(&pp, &x, &y);
            let fd = (lp - loss) / eps as f64;
            assert!(
                (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd={fd} an={}",
                g[idx]
            );
        }
    }

    #[test]
    fn cnn_grad_matches_finite_difference_small() {
        let shape = CnnShape { h: 8, w: 8, c: 1, conv1: 2, conv2: 3, ks: 3, fc1: 6, fc2: 4, classes: 3 };
        let m = NativeModel::Cnn { shape };
        let params = m.init(2);
        let mut r = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..2 * shape.input_dim()).map(|_| r.normal_f32()).collect();
        let y = vec![0i32, 2];
        let (g, loss, _, _) = m.grad(&params, &x, &y);
        let eps = 1e-3f32;
        // One index per tensor family.
        let sizes = m.param_sizes();
        let mut off = 0;
        for (name, sz) in &sizes {
            let idx = off + sz / 2;
            let mut pp = params.clone();
            pp[idx] += eps;
            let (_, lp, _, _) = m.grad(&pp, &x, &y);
            let fd = (lp - loss) / eps as f64;
            assert!(
                (fd - g[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                "{name}[{idx}]: fd={fd} an={}",
                g[idx]
            );
            off += sz;
        }
    }

    #[test]
    fn mlp_learns_two_constant_classes() {
        let m = NativeModel::Mlp { dims: vec![8, 16, 2] };
        let mut params = m.init(4);
        let x: Vec<f32> = (0..4 * 8)
            .map(|i| if i < 2 * 8 { 0.5 } else { -0.5 })
            .collect();
        let y = vec![0i32, 0, 1, 1];
        let mut last_loss = f64::MAX;
        for _ in 0..50 {
            let (g, loss, _, _) = m.grad(&params, &x, &y);
            for (p, gv) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gv;
            }
            last_loss = loss;
        }
        let (_, correct, n) = m.eval(&params, &x, &y);
        assert_eq!(correct, n);
        assert!(last_loss < 0.1, "{last_loss}");
    }

    #[test]
    fn eval_matches_grad_loss() {
        let m = NativeModel::Mlp { dims: vec![4, 3] };
        let params = m.init(5);
        let x = vec![0.1f32; 2 * 4];
        let y = vec![0i32, -1];
        let (_, loss_g, correct_g, n_g) = m.grad(&params, &x, &y);
        let (loss_e, correct_e, n_e) = m.eval(&params, &x, &y);
        assert!((loss_g - loss_e).abs() < 1e-9);
        assert_eq!(correct_g, correct_e);
        assert_eq!(n_g, n_e);
        assert_eq!(n_e, 1.0);
    }
}
