//! Native NN models over flat parameter vectors.
//!
//! The flat layout matches `python/compile/model.py` exactly (same tensor
//! order, row-major), so parameters produced by the HLO `*_init` artifacts
//! are directly usable here and vice versa — verified by
//! `rust/tests/hlo_native_equivalence.rs`.

use crate::util::kernels::{fused_linear_bwd_into, fused_linear_fwd_into};
use crate::util::workspace::Workspace;
use crate::util::Rng;

use super::conv::{
    conv2d_bwd_into, conv2d_fwd, conv2d_fwd_ws, maxpool2_bwd_into, maxpool2_fwd,
    maxpool2_fwd_into,
};
use super::linear::{fused_linear_fwd, Act};
use super::loss::{softmax_xent, softmax_xent_into};

/// Geometry of the paper's CNN (§5.1): 2× [conv5x5 SAME + maxpool2 + relu]
/// then 3 FC layers. Mirrors `CnnConfig` in model.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CnnShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub conv1: usize,
    pub conv2: usize,
    pub ks: usize,
    pub fc1: usize,
    pub fc2: usize,
    pub classes: usize,
}

impl Default for CnnShape {
    fn default() -> Self {
        // Must mirror CnnConfig in python/compile/model.py.
        CnnShape { h: 32, w: 32, c: 3, conv1: 8, conv2: 16, ks: 5, fc1: 256, fc2: 128, classes: 10 }
    }
}

impl CnnShape {
    pub fn flat_after_conv(&self) -> usize {
        (self.h / 4) * (self.w / 4) * self.conv2
    }

    pub fn input_dim(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// A natively-computable model over a flat f32 parameter vector.
#[derive(Clone, Debug)]
pub enum NativeModel {
    /// `dims[0] → … → dims.last()`, relu between, none at the end.
    Mlp { dims: Vec<usize> },
    Cnn { shape: CnnShape },
}

impl NativeModel {
    pub fn mlp_default() -> Self {
        NativeModel::Mlp { dims: vec![784, 256, 128, 10] }
    }

    pub fn cnn_default() -> Self {
        NativeModel::Cnn { shape: CnnShape::default() }
    }

    /// (name, element-count) pairs in flat order — mirrors model.py specs.
    pub fn param_sizes(&self) -> Vec<(String, usize)> {
        match self {
            NativeModel::Mlp { dims } => {
                let mut v = Vec::new();
                for i in 0..dims.len() - 1 {
                    v.push((format!("fc{i}.w"), dims[i] * dims[i + 1]));
                    v.push((format!("fc{i}.b"), dims[i + 1]));
                }
                v
            }
            NativeModel::Cnn { shape: s } => vec![
                ("conv1.w".into(), s.ks * s.ks * s.c * s.conv1),
                ("conv1.b".into(), s.conv1),
                ("conv2.w".into(), s.ks * s.ks * s.conv1 * s.conv2),
                ("conv2.b".into(), s.conv2),
                ("fc1.w".into(), s.flat_after_conv() * s.fc1),
                ("fc1.b".into(), s.fc1),
                ("fc2.w".into(), s.fc1 * s.fc2),
                ("fc2.b".into(), s.fc2),
                ("fc3.w".into(), s.fc2 * s.classes),
                ("fc3.b".into(), s.classes),
            ],
        }
    }

    /// Flat tensor sizes of the CNN in parameter order — the
    /// allocation-free twin of [`NativeModel::param_sizes`] for the hot
    /// path (no name strings).
    fn cnn_sizes(s: &CnnShape) -> [usize; 10] {
        [
            s.ks * s.ks * s.c * s.conv1,
            s.conv1,
            s.ks * s.ks * s.conv1 * s.conv2,
            s.conv2,
            s.flat_after_conv() * s.fc1,
            s.fc1,
            s.fc1 * s.fc2,
            s.fc2,
            s.fc2 * s.classes,
            s.classes,
        ]
    }

    pub fn param_count(&self) -> usize {
        // Computed arithmetically (not via `param_sizes`, whose name
        // strings allocate) so the per-iteration gradient path stays
        // allocation-free.
        match self {
            NativeModel::Mlp { dims } => {
                (0..dims.len() - 1).map(|i| dims[i] * dims[i + 1] + dims[i + 1]).sum()
            }
            NativeModel::Cnn { shape } => Self::cnn_sizes(shape).iter().sum(),
        }
    }

    pub fn input_dim(&self) -> usize {
        match self {
            NativeModel::Mlp { dims } => dims[0],
            NativeModel::Cnn { shape } => shape.input_dim(),
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            NativeModel::Mlp { dims } => *dims.last().unwrap(),
            NativeModel::Cnn { shape } => shape.classes,
        }
    }

    /// He-initialized flat parameters (weights ~ N(0, 2/fan_in), zero bias).
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed_from_u64(seed);
        let mut out = Vec::with_capacity(self.param_count());
        for (name, size) in self.param_sizes() {
            if name.ends_with(".b") {
                out.extend(std::iter::repeat(0.0f32).take(size));
            } else {
                let fan_in = match self {
                    NativeModel::Mlp { dims } => {
                        let i: usize = name[2..3].parse().unwrap();
                        dims[i]
                    }
                    NativeModel::Cnn { shape: s } => match name.as_str() {
                        "conv1.w" => s.ks * s.ks * s.c,
                        "conv2.w" => s.ks * s.ks * s.conv1,
                        "fc1.w" => s.flat_after_conv(),
                        "fc2.w" => s.fc1,
                        "fc3.w" => s.fc2,
                        _ => unreachable!(),
                    },
                };
                let scale = (2.0 / fan_in as f64).sqrt() as f32;
                out.extend((0..size).map(|_| r.normal_f32() * scale));
            }
        }
        out
    }

    /// Forward pass: logits (batch × classes).
    pub fn forward(&self, params: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
        self.forward_full(params, x, batch).0
    }

    /// Loss + grads on a batch. Returns (grads, loss_mean, correct, n_valid).
    /// Allocating wrapper over [`NativeModel::grad_ws`] (runs the same
    /// code against a throwaway workspace — bit-identical).
    pub fn grad(&self, params: &[f32], x: &[f32], labels: &[i32]) -> (Vec<f32>, f64, f64, f64) {
        self.grad_ws(params, x, labels, &mut Workspace::new())
    }

    /// Workspace-backed gradient: every intermediate (im2col matrices,
    /// activations, pre-activations, pool argmaxes, backward deltas, and
    /// the returned gradient vector itself) is checked out of `ws`.
    /// With a warm workspace the steady-state call performs **zero**
    /// heap allocations; callers on the hot path `put` the returned
    /// grads back once consumed. Workspace buffers are fully
    /// overwritten before use, so a dirty workspace is bit-identical to
    /// fresh allocation.
    pub fn grad_ws(
        &self,
        params: &[f32],
        x: &[f32],
        labels: &[i32],
        ws: &mut Workspace,
    ) -> (Vec<f32>, f64, f64, f64) {
        let batch = labels.len();
        match self {
            NativeModel::Mlp { dims } => {
                let n_layers = dims.len() - 1;
                // Parameter offsets, and the offsets of each layer's
                // activation/pre-activation inside one flat buffer:
                // acts[i] (i ≥ 1) and pres[i−1] both have length
                // batch·dims[i] and live at a_off[i−1]; layer 0's input
                // is `x` itself.
                let mut offs = ws.take_usize_cleared();
                let mut off = 0usize;
                for i in 0..n_layers {
                    offs.push(off);
                    off += dims[i] * dims[i + 1] + dims[i + 1];
                }
                let mut a_off = ws.take_usize_cleared();
                let mut total = 0usize;
                for i in 1..=n_layers {
                    a_off.push(total);
                    total += batch * dims[i];
                }
                let mut acts = ws.take(total);
                let mut pres = ws.take(total);
                for i in 0..n_layers {
                    let (k, n) = (dims[i], dims[i + 1]);
                    let w = &params[offs[i]..offs[i] + k * n];
                    let b = &params[offs[i] + k * n..offs[i] + k * n + n];
                    let act = if i == n_layers - 1 { Act::None } else { Act::Relu };
                    let pre = &mut pres[a_off[i]..a_off[i] + batch * n];
                    if i == 0 {
                        let y = &mut acts[..batch * n];
                        fused_linear_fwd_into(x, w, b, batch, k, n, act, y, pre, ws);
                    } else {
                        let (lo, hi) = acts.split_at_mut(a_off[i]);
                        let xin = &lo[a_off[i - 1]..];
                        fused_linear_fwd_into(
                            xin, w, b, batch, k, n, act, &mut hi[..batch * n], pre, ws,
                        );
                    }
                }
                let n_cls = dims[n_layers];
                let logits = &acts[a_off[n_layers - 1]..a_off[n_layers - 1] + batch * n_cls];
                // Ping-pong backward-delta buffers sized to the widest layer.
                let max_dim = dims.iter().copied().max().unwrap_or(0);
                let mut dy = ws.take(batch * max_dim);
                let mut dx = ws.take(batch * max_dim);
                let (loss, correct, n_valid) =
                    softmax_xent_into(logits, labels, n_cls, &mut dy[..batch * n_cls]);
                let mut grads = ws.take_zeroed(self.param_count());
                for i in (0..n_layers).rev() {
                    let (k, n) = (dims[i], dims[i + 1]);
                    let w = &params[offs[i]..offs[i] + k * n];
                    let act = if i == n_layers - 1 { Act::None } else { Act::Relu };
                    let xin: &[f32] =
                        if i == 0 { x } else { &acts[a_off[i - 1]..a_off[i - 1] + batch * k] };
                    let pre = &pres[a_off[i]..a_off[i] + batch * n];
                    let (gw, gb) = grads[offs[i]..offs[i] + k * n + n].split_at_mut(k * n);
                    fused_linear_bwd_into(
                        xin,
                        w,
                        pre,
                        &dy[..batch * n],
                        batch,
                        k,
                        n,
                        act,
                        &mut dx[..batch * k],
                        gw,
                        gb,
                        ws,
                    );
                    std::mem::swap(&mut dy, &mut dx);
                }
                ws.put(dx);
                ws.put(dy);
                ws.put(pres);
                ws.put(acts);
                ws.put_usize(a_off);
                ws.put_usize(offs);
                (grads, loss, correct, n_valid)
            }
            NativeModel::Cnn { shape: s } => {
                let sizes = Self::cnn_sizes(s);
                let mut offs = [0usize; 10];
                let mut off = 0usize;
                for (o, sz) in offs.iter_mut().zip(sizes) {
                    *o = off;
                    off += sz;
                }
                let p = |i: usize| &params[offs[i]..offs[i] + sizes[i]];
                let (n, h, w, c) = (batch, s.h, s.w, s.c);
                // conv1 + pool + relu
                let (c1, col1) = conv2d_fwd_ws(x, p(0), p(1), n, h, w, c, s.ks, s.conv1, ws);
                let (h2, w2) = (h / 2, w / 2);
                let mut p1 = ws.take(n * h2 * w2 * s.conv1);
                let mut arg1 = ws.take_u32(p1.len());
                maxpool2_fwd_into(&c1, n, h, w, s.conv1, &mut p1, &mut arg1);
                let mut r1 = ws.take(p1.len());
                for (r, &v) in r1.iter_mut().zip(&p1) {
                    *r = v.max(0.0);
                }
                // conv2 + pool + relu
                let (c2, col2) =
                    conv2d_fwd_ws(&r1, p(2), p(3), n, h2, w2, s.conv1, s.ks, s.conv2, ws);
                let (h4, w4) = (h2 / 2, w2 / 2);
                let mut p2 = ws.take(n * h4 * w4 * s.conv2);
                let mut arg2 = ws.take_u32(p2.len());
                maxpool2_fwd_into(&c2, n, h2, w2, s.conv2, &mut p2, &mut arg2);
                let mut r2 = ws.take(p2.len());
                for (r, &v) in r2.iter_mut().zip(&p2) {
                    *r = v.max(0.0);
                }
                let flat = s.flat_after_conv();
                // fc1 relu, fc2 relu, fc3 none
                let mut f1 = ws.take(n * s.fc1);
                let mut pre1 = ws.take(n * s.fc1);
                fused_linear_fwd_into(
                    &r2, p(4), p(5), n, flat, s.fc1, Act::Relu, &mut f1, &mut pre1, ws,
                );
                let mut f2 = ws.take(n * s.fc2);
                let mut pre2 = ws.take(n * s.fc2);
                fused_linear_fwd_into(
                    &f1, p(6), p(7), n, s.fc1, s.fc2, Act::Relu, &mut f2, &mut pre2, ws,
                );
                let mut logits = ws.take(n * s.classes);
                let mut pre3 = ws.take(n * s.classes);
                fused_linear_fwd_into(
                    &f2,
                    p(8),
                    p(9),
                    n,
                    s.fc2,
                    s.classes,
                    Act::None,
                    &mut logits,
                    &mut pre3,
                    ws,
                );
                let mut dlogits = ws.take(logits.len());
                let (loss, correct, n_valid) =
                    softmax_xent_into(&logits, labels, s.classes, &mut dlogits);
                // Backward. dw/db write straight into the flat grads
                // vector (zero-seeded overwrites — bit-identical to
                // compute-then-copy); each layer's (w, b) pair is
                // adjacent in the flat layout, so one split_at_mut
                // yields both slices.
                let mut grads = ws.take_zeroed(self.param_count());
                let mut d_f2 = ws.take(n * s.fc2);
                {
                    let (gw, gb) =
                        grads[offs[8]..offs[8] + sizes[8] + sizes[9]].split_at_mut(sizes[8]);
                    fused_linear_bwd_into(
                        &f2,
                        p(8),
                        &pre3,
                        &dlogits,
                        n,
                        s.fc2,
                        s.classes,
                        Act::None,
                        &mut d_f2,
                        gw,
                        gb,
                        ws,
                    );
                }
                let mut d_f1 = ws.take(n * s.fc1);
                {
                    let (gw, gb) =
                        grads[offs[6]..offs[6] + sizes[6] + sizes[7]].split_at_mut(sizes[6]);
                    fused_linear_bwd_into(
                        &f1, p(6), &pre2, &d_f2, n, s.fc1, s.fc2, Act::Relu, &mut d_f1, gw, gb, ws,
                    );
                }
                let mut d_r2 = ws.take(n * flat);
                {
                    let (gw, gb) =
                        grads[offs[4]..offs[4] + sizes[4] + sizes[5]].split_at_mut(sizes[4]);
                    fused_linear_bwd_into(
                        &r2, p(4), &pre1, &d_f1, n, flat, s.fc1, Act::Relu, &mut d_r2, gw, gb, ws,
                    );
                }
                // relu' then unpool then conv2 backward
                let mut d_p2 = ws.take(d_r2.len());
                for ((d, &g), &v) in d_p2.iter_mut().zip(&d_r2).zip(&p2) {
                    *d = if v > 0.0 { g } else { 0.0 };
                }
                let mut d_c2 = ws.take(c2.len());
                maxpool2_bwd_into(&d_p2, &arg2, &mut d_c2);
                let mut d_r1 = ws.take(r1.len());
                {
                    let (gw, gb) =
                        grads[offs[2]..offs[2] + sizes[2] + sizes[3]].split_at_mut(sizes[2]);
                    conv2d_bwd_into(
                        &col2,
                        p(2),
                        &d_c2,
                        n,
                        h2,
                        w2,
                        s.conv1,
                        s.ks,
                        s.conv2,
                        &mut d_r1,
                        gw,
                        gb,
                        ws,
                    );
                }
                let mut d_p1 = ws.take(d_r1.len());
                for ((d, &g), &v) in d_p1.iter_mut().zip(&d_r1).zip(&p1) {
                    *d = if v > 0.0 { g } else { 0.0 };
                }
                let mut d_c1 = ws.take(c1.len());
                maxpool2_bwd_into(&d_p1, &arg1, &mut d_c1);
                let mut d_x = ws.take(x.len());
                {
                    let (gw, gb) =
                        grads[offs[0]..offs[0] + sizes[0] + sizes[1]].split_at_mut(sizes[0]);
                    conv2d_bwd_into(
                        &col1, p(0), &d_c1, n, h, w, c, s.ks, s.conv1, &mut d_x, gw, gb, ws,
                    );
                }
                // Return every residual to the pool (reverse order of
                // checkout keeps the LIFO take/put sequence stable
                // across iterations).
                ws.put(d_x);
                ws.put(d_c1);
                ws.put(d_p1);
                ws.put(d_r1);
                ws.put(d_c2);
                ws.put(d_p2);
                ws.put(d_r2);
                ws.put(d_f1);
                ws.put(d_f2);
                ws.put(dlogits);
                ws.put(pre3);
                ws.put(logits);
                ws.put(pre2);
                ws.put(f2);
                ws.put(pre1);
                ws.put(f1);
                ws.put(r2);
                ws.put_u32(arg2);
                ws.put(p2);
                ws.put(col2);
                ws.put(c2);
                ws.put(r1);
                ws.put_u32(arg1);
                ws.put(p1);
                ws.put(col1);
                ws.put(c1);
                (grads, loss, correct, n_valid)
            }
        }
    }

    /// Eval on a batch: (loss_mean, correct, n_valid).
    pub fn eval(&self, params: &[f32], x: &[f32], labels: &[i32]) -> (f64, f64, f64) {
        let batch = labels.len();
        let logits = self.forward(params, x, batch);
        let (loss, correct, n, _) = softmax_xent(&logits, labels, self.n_classes());
        (loss, correct, n)
    }

    fn forward_full(&self, params: &[f32], x: &[f32], batch: usize) -> (Vec<f32>, ()) {
        match self {
            NativeModel::Mlp { dims } => {
                let n_layers = dims.len() - 1;
                let mut off = 0usize;
                let mut cur = x.to_vec();
                for i in 0..n_layers {
                    let (k, n) = (dims[i], dims[i + 1]);
                    let w = &params[off..off + k * n];
                    let b = &params[off + k * n..off + k * n + n];
                    off += k * n + n;
                    let act = if i == n_layers - 1 { Act::None } else { Act::Relu };
                    let (y, _) = fused_linear_fwd(&cur, w, b, batch, k, n, act);
                    cur = y;
                }
                (cur, ())
            }
            NativeModel::Cnn { shape: s } => {
                let sizes = self.param_sizes();
                let mut offs = Vec::new();
                let mut off = 0usize;
                for (_, sz) in &sizes {
                    offs.push(off);
                    off += sz;
                }
                let p = |i: usize| &params[offs[i]..offs[i] + sizes[i].1];
                let (n, h, w, c) = (batch, s.h, s.w, s.c);
                let (c1, _) = conv2d_fwd(x, p(0), p(1), n, h, w, c, s.ks, s.conv1);
                let (p1, _) = maxpool2_fwd(&c1, n, h, w, s.conv1);
                let r1: Vec<f32> = p1.iter().map(|&v| v.max(0.0)).collect();
                let (h2, w2) = (h / 2, w / 2);
                let (c2, _) = conv2d_fwd(&r1, p(2), p(3), n, h2, w2, s.conv1, s.ks, s.conv2);
                let (p2, _) = maxpool2_fwd(&c2, n, h2, w2, s.conv2);
                let r2: Vec<f32> = p2.iter().map(|&v| v.max(0.0)).collect();
                let flat = s.flat_after_conv();
                let (f1, _) = fused_linear_fwd(&r2, p(4), p(5), n, flat, s.fc1, Act::Relu);
                let (f2, _) = fused_linear_fwd(&f1, p(6), p(7), n, s.fc1, s.fc2, Act::Relu);
                let (logits, _) =
                    fused_linear_fwd(&f2, p(8), p(9), n, s.fc2, s.classes, Act::None);
                (logits, ())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn param_counts_match_manifest_values() {
        // Values recorded from `make artifacts` output.
        assert_eq!(NativeModel::mlp_default().param_count(), 235_146);
        assert_eq!(NativeModel::cnn_default().param_count(), 300_410);
    }

    #[test]
    fn mlp_grad_matches_finite_difference() {
        let m = NativeModel::Mlp { dims: vec![6, 5, 3] };
        let params = m.init(0);
        let mut r = Rng::seed_from_u64(1);
        let x: Vec<f32> = (0..2 * 6).map(|_| r.normal_f32()).collect();
        let y = vec![1i32, 2];
        let (g, loss, _, n) = m.grad(&params, &x, &y);
        assert_eq!(n, 2.0);
        assert!(loss > 0.0);
        let eps = 1e-3f32;
        for idx in [0usize, 10, g.len() - 1, g.len() / 2] {
            let mut pp = params.clone();
            pp[idx] += eps;
            let (_, lp, _, _) = m.grad(&pp, &x, &y);
            let fd = (lp - loss) / eps as f64;
            assert!(
                (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd={fd} an={}",
                g[idx]
            );
        }
    }

    #[test]
    fn cnn_grad_matches_finite_difference_small() {
        let shape =
            CnnShape { h: 8, w: 8, c: 1, conv1: 2, conv2: 3, ks: 3, fc1: 6, fc2: 4, classes: 3 };
        let m = NativeModel::Cnn { shape };
        let params = m.init(2);
        let mut r = Rng::seed_from_u64(3);
        let x: Vec<f32> = (0..2 * shape.input_dim()).map(|_| r.normal_f32()).collect();
        let y = vec![0i32, 2];
        let (g, loss, _, _) = m.grad(&params, &x, &y);
        let eps = 1e-3f32;
        // One index per tensor family.
        let sizes = m.param_sizes();
        let mut off = 0;
        for (name, sz) in &sizes {
            let idx = off + sz / 2;
            let mut pp = params.clone();
            pp[idx] += eps;
            let (_, lp, _, _) = m.grad(&pp, &x, &y);
            let fd = (lp - loss) / eps as f64;
            assert!(
                (fd - g[idx] as f64).abs() < 3e-2 * (1.0 + fd.abs()),
                "{name}[{idx}]: fd={fd} an={}",
                g[idx]
            );
            off += sz;
        }
    }

    #[test]
    fn mlp_learns_two_constant_classes() {
        let m = NativeModel::Mlp { dims: vec![8, 16, 2] };
        let mut params = m.init(4);
        let x: Vec<f32> = (0..4 * 8)
            .map(|i| if i < 2 * 8 { 0.5 } else { -0.5 })
            .collect();
        let y = vec![0i32, 0, 1, 1];
        let mut last_loss = f64::MAX;
        for _ in 0..50 {
            let (g, loss, _, _) = m.grad(&params, &x, &y);
            for (p, gv) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gv;
            }
            last_loss = loss;
        }
        let (_, correct, n) = m.eval(&params, &x, &y);
        assert_eq!(correct, n);
        assert!(last_loss < 0.1, "{last_loss}");
    }

    #[test]
    fn eval_matches_grad_loss() {
        let m = NativeModel::Mlp { dims: vec![4, 3] };
        let params = m.init(5);
        let x = vec![0.1f32; 2 * 4];
        let y = vec![0i32, -1];
        let (_, loss_g, correct_g, n_g) = m.grad(&params, &x, &y);
        let (loss_e, correct_e, n_e) = m.eval(&params, &x, &y);
        assert!((loss_g - loss_e).abs() < 1e-9);
        assert_eq!(correct_g, correct_e);
        assert_eq!(n_g, n_e);
        assert_eq!(n_e, 1.0);
    }
}
