//! Native (pure-rust) NN substrate — the fast mirror of the L2 JAX graphs.
//!
//! Implements exactly the computations the AOT artifacts perform (MLP and
//! the paper's CNN over a flat f32 parameter vector with the manifest's
//! layout), so the figure harnesses can run hundreds of trainings without
//! queueing on the single PJRT engine. Equivalence against the HLO path is
//! asserted by `rust/tests/hlo_native_equivalence.rs`.

pub mod conv;
pub mod linear;
pub mod loss;
pub mod model;

pub use model::{CnnShape, NativeModel};
