//! CoCoA with a local SCD solver (paper §2.2, Jaggi'14 / Smith'18).
//!
//! Per iteration every task runs one pass of stochastic dual coordinate
//! ascent over *all samples in its local chunks* (H = |local samples|,
//! L = 1) against a snapshot of the shared vector v = w, then ships the
//! accumulated model delta Δv. Per-sample dual state α lives inside the
//! chunks and moves with them (paper §4.4); it is the *only* chunk bytes
//! this algorithm ever writes — the sample payload stays immutable, which
//! is what lets the trainer snapshot chunks for the eval-spanning overlap
//! at O(α bytes) cost (`Chunk::clone` shares the payload).
//!
//! Aggregation follows CoCoA+ with γ = 1 (adding) and σ' = K: local steps
//! are damped by σ' = K and the driver *sums* task deltas. (The paper's
//! eq. 2 describes averaging; combined with σ' = K that would damp twice —
//! see DESIGN.md §Substitutions for the note.) Unequal task loads are
//! handled naturally: each Δv_k already reflects exactly the samples task
//! k visited.

use std::sync::Arc;

use anyhow::Result;

use crate::chunks::Chunk;
use crate::config::CocoaConfig;
use crate::metrics::Metric;
use crate::util::{kernels, Rng};

use super::{Algorithm, Backend, LocalUpdate, ModelVec};

/// CoCoA algorithm instance for one dataset.
pub struct CocoaAlgo {
    cfg: CocoaConfig,
    backend: Arc<Backend>,
    /// Total training samples n (for λn) and feature dimension.
    n_total: usize,
    dim: usize,
}

impl CocoaAlgo {
    pub fn new(cfg: CocoaConfig, backend: Backend, n_total: usize, dim: usize) -> Self {
        CocoaAlgo { cfg, backend: Arc::new(backend), n_total, dim }
    }

    pub fn lambda(&self) -> f64 {
        self.cfg.lambda
    }

    fn lam_n(&self) -> f32 {
        (self.cfg.lambda * self.n_total as f64) as f32
    }
}

impl Algorithm for CocoaAlgo {
    fn model_len(&self) -> usize {
        self.dim
    }

    fn init_model(&self) -> Result<ModelVec> {
        Ok(vec![0.0; self.dim])
    }

    fn task_iterate(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
    ) -> Result<LocalUpdate> {
        self.task_iterate_ws(
            chunks,
            model,
            k_tasks,
            task_seed,
            budget_samples,
            &mut crate::util::Workspace::new(),
        )
    }

    fn task_iterate_ws(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        k_tasks: usize,
        task_seed: u64,
        budget_samples: Option<usize>,
        ws: &mut crate::util::Workspace,
    ) -> Result<LocalUpdate> {
        let mut rng = Rng::seed_from_u64(task_seed);
        let mut v = ws.take_copy(model);
        // The delta is handed off inside LocalUpdate, so it is the one
        // buffer that cannot come from the workspace: exactly one
        // allocation per steady-state iteration.
        let mut delta = vec![0.0f32; self.dim];
        let sigma = k_tasks.max(1) as f32;
        let lam_n = self.lam_n();

        let local_total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        let budget = budget_samples
            .unwrap_or((local_total as f64 * self.cfg.local_passes).round() as usize);
        let mut remaining = budget;
        let mut processed = 0usize;

        // Visit chunks in random order; within each chunk, a random
        // permutation (block-SCD at chunk granularity — the solver still
        // sees every local sample each iteration, matching the paper's
        // "full random access to all task-local data chunks").
        // take_usize_seq + shuffle makes the same RNG draws as the old
        // Rng::permutation, so trajectories are bit-identical.
        let mut chunk_order = ws.take_usize_seq(chunks.len());
        rng.shuffle(&mut chunk_order);
        for &ci in &chunk_order {
            if remaining == 0 {
                break;
            }
            let chunk = &mut chunks[ci];
            let n = chunk.n_samples();
            let take = n.min(remaining);
            let mut order = ws.take_usize_seq(n);
            rng.shuffle(&mut order);
            order.truncate(take);
            let dv = self.backend.scd_chunk_ws(chunk, &order, &mut v, lam_n, sigma, ws)?;
            kernels::acc(&mut delta, &dv);
            ws.put(dv);
            ws.put_usize(order);
            remaining -= take;
            processed += take;
        }
        ws.put_usize(chunk_order);
        ws.put(v);
        Ok(LocalUpdate { delta, samples: processed, loss_sum: 0.0 })
    }

    fn merge_shard(
        &self,
        shard: &mut [f32],
        offset: usize,
        updates: &[LocalUpdate],
        _k_tasks: usize,
    ) {
        // CoCoA+ γ=1: add deltas (σ' = K damping already applied locally).
        // Pure elementwise sum in update order — shard-composable.
        let end = offset + shard.len();
        for u in updates {
            // Lane-per-element accumulate: per-element fold order is this
            // update loop, unchanged — shard-composable and bit-identical
            // to the serial fold.
            kernels::acc(shard, &u.delta[offset..end]);
        }
    }

    fn evaluate(&self, model: &ModelVec, all_chunks: &[&Chunk]) -> Result<Metric> {
        let (mut hinge, mut alpha, mut n) = (0.0f64, 0.0f64, 0usize);
        for chunk in all_chunks {
            let (h, a, _c, cn) = self.backend.gap_contributions(chunk, model)?;
            hinge += h;
            alpha += a;
            n += cn;
        }
        Ok(Metric::DualityGap(super::svm::duality_gap(
            hinge,
            alpha,
            n.max(1),
            model,
            self.cfg.lambda,
        )))
    }

    fn samples_per_iteration(&self, local_samples: usize) -> usize {
        (local_samples as f64 * self.cfg.local_passes).round() as usize
    }

    fn unit_samples(&self, n_total: usize, ref_nodes: usize) -> f64 {
        n_total as f64 / ref_nodes.max(1) as f64
    }

    fn target(&self) -> Option<f64> {
        Some(self.cfg.target_gap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::chunker::make_chunks;
    use crate::data::synth;

    fn setup(n: usize, k: usize) -> (CocoaAlgo, Vec<Vec<Chunk>>) {
        let ds = synth::higgs_like(n, 7);
        let chunks = make_chunks(&ds, 8 * 1024);
        let algo = CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            ds.n_samples(),
            ds.dim(),
        );
        // Round-robin chunks over k tasks.
        let mut parts: Vec<Vec<Chunk>> = (0..k).map(|_| Vec::new()).collect();
        for (i, c) in chunks.into_iter().enumerate() {
            parts[i % k].push(c);
        }
        (algo, parts)
    }

    fn run_iters(algo: &CocoaAlgo, parts: &mut [Vec<Chunk>], iters: usize) -> f64 {
        let k = parts.len();
        let mut model = algo.init_model().unwrap();
        let mut gap = f64::MAX;
        for it in 0..iters {
            let updates: Vec<LocalUpdate> = parts
                .iter_mut()
                .enumerate()
                .map(|(t, chunks)| {
                    algo.task_iterate(chunks, &model, k, (it * 31 + t) as u64, None)
                        .unwrap()
                })
                .collect();
            algo.merge(&mut model, &updates, k);
            let all: Vec<&Chunk> = parts.iter().flat_map(|p| p.iter()).collect();
            gap = match algo.evaluate(&model, &all).unwrap() {
                Metric::DualityGap(g) => g,
                _ => panic!(),
            };
        }
        gap
    }

    #[test]
    fn converges_single_task() {
        let (algo, mut parts) = setup(2000, 1);
        let gap = run_iters(&algo, &mut parts, 10);
        assert!(gap < 0.01, "gap {gap}");
    }

    #[test]
    fn converges_multi_task() {
        let (algo, mut parts) = setup(2000, 4);
        let gap = run_iters(&algo, &mut parts, 15);
        assert!(gap < 0.05, "gap {gap}");
    }

    #[test]
    fn more_tasks_need_more_iterations() {
        // The paper's core premise (Fig 1b): higher K → slower per epoch.
        let (algo1, mut p1) = setup(4000, 2);
        let (algo2, mut p2) = setup(4000, 16);
        let g_small_k = run_iters(&algo1, &mut p1, 6);
        let g_large_k = run_iters(&algo2, &mut p2, 6);
        assert!(
            g_small_k < g_large_k,
            "K=2 gap {g_small_k} should beat K=16 gap {g_large_k}"
        );
    }

    #[test]
    fn update_samples_counts_budget() {
        let (algo, mut parts) = setup(1000, 2);
        let model = algo.init_model().unwrap();
        let u = algo
            .task_iterate(&mut parts[0], &model, 2, 0, Some(100))
            .unwrap();
        assert_eq!(u.samples, 100);
        let u_full = algo.task_iterate(&mut parts[0], &model, 2, 1, None).unwrap();
        let local: usize = parts[0].iter().map(|c| c.n_samples()).sum();
        assert_eq!(u_full.samples, local);
    }
}
