//! Training metrics: per-iteration logs and swimlane recordings.

pub mod swimlane;

pub use swimlane::{SwimlaneRecorder, TaskSpan};

use std::time::Duration;

/// The convergence metric an algorithm reports each iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Metric {
    /// CoCoA: duality gap (lower is better, → 0).
    DualityGap(f64),
    /// lSGD: test accuracy in [0, 1] (higher is better).
    TestAccuracy(f64),
    /// LM: eval loss (lower is better).
    EvalLoss(f64),
}

impl Metric {
    pub fn value(&self) -> f64 {
        match self {
            Metric::DualityGap(v) | Metric::TestAccuracy(v) | Metric::EvalLoss(v) => *v,
        }
    }

    /// Has this metric reached `target`? (direction-aware)
    pub fn reached(&self, target: f64) -> bool {
        match self {
            Metric::DualityGap(v) | Metric::EvalLoss(v) => *v <= target,
            Metric::TestAccuracy(v) => *v >= target,
        }
    }
}

/// One trainer iteration as recorded by the coordinator.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub iter: usize,
    /// Cumulative fraction of the dataset processed so far, in epochs.
    pub epochs: f64,
    /// Convergence metric after this iteration (None if not evaluated).
    pub metric: Option<Metric>,
    /// Virtual time at the *end* of this iteration (projected, paper §5.3).
    pub vtime: Duration,
    /// Wallclock compute time actually spent in this iteration.
    pub wall: Duration,
    /// Wallclock of the merge phase (serial fold or sharded pool reduce;
    /// for a pipelined iteration, the reduce-in-flight window).
    pub merge_wall: Duration,
    /// Shards claimed outside their home worker's block during the
    /// work-stealing pool reduction (0 = serial fold or no stealing).
    pub steal_count: usize,
    /// How long the *next* iteration was in flight on the workers while
    /// the coordinator finished collecting this iteration's reduce —
    /// and, at an overlapped eval point, ran the evaluation against the
    /// snapshot. Zero on barriered iterations.
    pub overlap_wall: Duration,
    /// Shards-per-worker granularity the pool reduction used this
    /// iteration (0 = serial fold, no reduction dispatched). Driven by
    /// the adaptive controller when `SessionConfig::adaptive_spw` is on,
    /// so spikes in `steal_count` show up as a widening `spw` a few
    /// iterations later.
    pub spw: usize,
    /// *Measured* sequential transport rounds of this iteration's merge
    /// collective (`2(k−1)` ring, `2·⌊log2 k⌋` tree; 0 under the
    /// coordinator-side reduce, which never touches the transport).
    /// Recorded next to the *simulated* exchange charge folded into
    /// `vtime` so the two can be compared; never fed into virtual time.
    pub transport_rounds: usize,
    /// Payload bytes the merge collective put on the wire, summed over
    /// all ranks (0 under the coordinator-side reduce).
    pub transport_bytes: usize,
    /// Non-payload framing bytes the transport backend added on top of
    /// the payload (length prefixes, tags, handshakes), summed over all
    /// ranks. Zero for the in-process channel backend, whose messages
    /// never cross a wire format; over TCP this is the measured framing
    /// overhead next to `transport_bytes`.
    pub transport_frame_bytes: usize,
    /// Number of logical tasks active during this iteration (the
    /// algorithmic parallelism K; equals the node count under the legacy
    /// one-task-per-thread coupling).
    pub n_tasks: usize,
    /// Number of worker threads hosting those tasks. Equals `n_tasks`
    /// under the legacy coupling and micro-task emulation; at most
    /// `n_tasks` under the decoupled schedule
    /// (`SessionConfig::logical_tasks`), where `n_tasks / n_threads` is
    /// the per-thread occupancy.
    pub n_threads: usize,
    /// Samples processed across all tasks this iteration.
    pub samples: usize,
    /// Training loss if the algorithm reports one.
    pub train_loss: Option<f64>,
}

/// Full per-run log; everything the figure harnesses consume.
#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    pub records: Vec<IterationRecord>,
}

impl MetricsLog {
    pub fn new() -> Self {
        MetricsLog { records: Vec::new() }
    }

    pub fn push(&mut self, rec: IterationRecord) {
        self.records.push(rec);
    }

    pub fn last_gap(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| match r.metric {
            Some(Metric::DualityGap(g)) => Some(g),
            _ => None,
        })
    }

    pub fn last_accuracy(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| match r.metric {
            Some(Metric::TestAccuracy(a)) => Some(a),
            _ => None,
        })
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| match r.metric {
                Some(Metric::TestAccuracy(a)) => Some(a),
                _ => None,
            })
            .fold(None, |acc, a| Some(acc.map_or(a, |b: f64| b.max(a))))
    }

    /// Epochs needed until the metric first reaches `target` (paper Fig 1 /
    /// Fig 9/10). None if never reached.
    pub fn epochs_to_target(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.metric.map_or(false, |m| m.reached(target)))
            .map(|r| r.epochs)
    }

    /// Projected time until the metric first reaches `target` (Fig 4/5).
    pub fn time_to_target(&self, target: f64) -> Option<Duration> {
        self.records
            .iter()
            .find(|r| r.metric.map_or(false, |m| m.reached(target)))
            .map(|r| r.vtime)
    }

    pub fn total_epochs(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.epochs)
    }

    pub fn total_vtime(&self) -> Duration {
        self.records.last().map_or(Duration::ZERO, |r| r.vtime)
    }

    pub fn total_wall(&self) -> Duration {
        self.records.iter().map(|r| r.wall).sum()
    }

    /// (vtime_secs, metric) convergence-over-time series.
    pub fn time_series(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.metric.map(|m| (r.vtime.as_secs_f64(), m.value())))
            .collect()
    }

    /// (epochs, metric) convergence-per-epoch series.
    pub fn epoch_series(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.metric.map(|m| (r.epochs, m.value())))
            .collect()
    }

    /// Tab-separated dump for the figure harnesses / plotting.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from(
            "iter\tepochs\tvtime_s\twall_s\tmerge_wall_s\tsteal_count\toverlap_wall_s\tspw\t\
             transport_rounds\ttransport_bytes\ttransport_frame_bytes\tn_tasks\tn_threads\t\
             occupancy\tsamples\tmetric\ttrain_loss\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{}\t{:.4}\t{:.4}\t{:.4}\t{:.6}\t{}\t{:.6}\t{}\t{}\t{}\t{}\t{}\t{}\t{:.2}\t{}\t{}\t{}\n",
                r.iter,
                r.epochs,
                r.vtime.as_secs_f64(),
                r.wall.as_secs_f64(),
                r.merge_wall.as_secs_f64(),
                r.steal_count,
                r.overlap_wall.as_secs_f64(),
                r.spw,
                r.transport_rounds,
                r.transport_bytes,
                r.transport_frame_bytes,
                r.n_tasks,
                r.n_threads,
                r.n_tasks as f64 / r.n_threads.max(1) as f64,
                r.samples,
                r.metric.map_or("".into(), |m| format!("{:.6}", m.value())),
                r.train_loss.map_or("".into(), |l| format!("{:.6}", l)),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, epochs: f64, gap: f64, vt: f64) -> IterationRecord {
        IterationRecord {
            iter,
            epochs,
            metric: Some(Metric::DualityGap(gap)),
            vtime: Duration::from_secs_f64(vt),
            wall: Duration::from_millis(5),
            merge_wall: Duration::from_micros(50),
            steal_count: 0,
            overlap_wall: Duration::ZERO,
            spw: 0,
            transport_rounds: 0,
            transport_bytes: 0,
            transport_frame_bytes: 0,
            n_tasks: 4,
            n_threads: 4,
            samples: 100,
            train_loss: None,
        }
    }

    #[test]
    fn targets_are_direction_aware() {
        assert!(Metric::DualityGap(0.01).reached(0.1));
        assert!(!Metric::DualityGap(0.2).reached(0.1));
        assert!(Metric::TestAccuracy(0.8).reached(0.6));
        assert!(!Metric::TestAccuracy(0.5).reached(0.6));
    }

    #[test]
    fn epochs_and_time_to_target() {
        let mut log = MetricsLog::new();
        log.push(rec(0, 1.0, 0.5, 1.0));
        log.push(rec(1, 2.0, 0.05, 2.0));
        log.push(rec(2, 3.0, 0.01, 3.0));
        assert_eq!(log.epochs_to_target(0.1), Some(2.0));
        assert_eq!(log.time_to_target(0.1), Some(Duration::from_secs(2)));
        assert_eq!(log.epochs_to_target(0.001), None);
        assert_eq!(log.last_gap(), Some(0.01));
        assert_eq!(log.total_epochs(), 3.0);
    }

    #[test]
    fn tsv_has_header_and_rows() {
        let mut log = MetricsLog::new();
        log.push(rec(0, 1.0, 0.5, 1.0));
        let tsv = log.to_tsv();
        assert!(tsv.starts_with("iter\t"));
        assert_eq!(tsv.lines().count(), 2);
        let header = tsv.lines().next().unwrap();
        assert!(header.contains("steal_count") && header.contains("overlap_wall_s"));
        assert!(header.contains("\tspw\t"), "adaptive-spw column present");
        assert!(
            header.contains("\ttransport_rounds\ttransport_bytes\ttransport_frame_bytes\t"),
            "measured-transport columns present"
        );
        assert!(
            header.contains("\tn_tasks\tn_threads\toccupancy\t"),
            "decoupled-schedule occupancy columns present"
        );
        // Every row has exactly as many cells as the header.
        let cols = header.split('\t').count();
        assert!(tsv.lines().all(|l| l.split('\t').count() == cols));
    }
}
