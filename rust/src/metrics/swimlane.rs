//! Swimlane recording: per-task, per-iteration execution spans
//! (paper Fig 6 / Fig 11).
//!
//! The recorder collects one span per (task, iteration) with the task's
//! busy window and workload, and renders the paper's three diagrams:
//! task runtimes without/with load balancing and relative workload bars.

use std::fmt::Write as _;
use std::time::Duration;

use crate::cluster::NodeId;

/// One task's execution within one iteration.
#[derive(Clone, Debug)]
pub struct TaskSpan {
    pub node: NodeId,
    pub iter: usize,
    /// Virtual time when the task started computing this iteration.
    pub start: Duration,
    /// Virtual time when the task finished its local work.
    pub end: Duration,
    pub n_chunks: usize,
    pub n_samples: usize,
}

impl TaskSpan {
    pub fn busy(&self) -> Duration {
        self.end.saturating_sub(self.start)
    }
}

/// Collects spans across a run and renders swimlane diagrams.
#[derive(Clone, Debug, Default)]
pub struct SwimlaneRecorder {
    pub spans: Vec<TaskSpan>,
}

impl SwimlaneRecorder {
    pub fn new() -> Self {
        SwimlaneRecorder { spans: Vec::new() }
    }

    pub fn record(&mut self, span: TaskSpan) {
        self.spans.push(span);
    }

    pub fn n_iterations(&self) -> usize {
        self.spans.iter().map(|s| s.iter + 1).max().unwrap_or(0)
    }

    pub fn nodes(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.spans.iter().map(|s| s.node).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Iteration duration = latest task end − earliest task start.
    pub fn iteration_duration(&self, iter: usize) -> Option<Duration> {
        let spans: Vec<&TaskSpan> = self.spans.iter().filter(|s| s.iter == iter).collect();
        if spans.is_empty() {
            return None;
        }
        let start = spans.iter().map(|s| s.start).min().unwrap();
        let end = spans.iter().map(|s| s.end).max().unwrap();
        Some(end - start)
    }

    /// Max/min busy-time ratio within an iteration (1.0 = perfectly
    /// balanced); the rebalance policy drives this toward 1.
    pub fn imbalance(&self, iter: usize) -> Option<f64> {
        let busys: Vec<f64> = self
            .spans
            .iter()
            .filter(|s| s.iter == iter)
            .map(|s| s.busy().as_secs_f64())
            .collect();
        if busys.is_empty() {
            return None;
        }
        let max = busys.iter().cloned().fold(f64::MIN, f64::max);
        let min = busys.iter().cloned().fold(f64::MAX, f64::min);
        if min <= 0.0 {
            return None;
        }
        Some(max / min)
    }

    /// ASCII rendering of task busy-bars per node over iterations
    /// (one row per node, `width` chars across the full time range).
    pub fn render_ascii(&self, width: usize) -> String {
        let mut out = String::new();
        let t_end = self
            .spans
            .iter()
            .map(|s| s.end)
            .max()
            .unwrap_or(Duration::ZERO)
            .as_secs_f64();
        if t_end <= 0.0 {
            return out;
        }
        let scale = width as f64 / t_end;
        for node in self.nodes() {
            let mut row = vec![' '; width];
            for s in self.spans.iter().filter(|s| s.node == node) {
                let a = (s.start.as_secs_f64() * scale) as usize;
                let b = ((s.end.as_secs_f64() * scale) as usize).min(width);
                for c in row.iter_mut().take(b).skip(a.min(width)) {
                    *c = '█';
                }
            }
            let _ = writeln!(out, "node {:>2} |{}|", node, row.iter().collect::<String>());
        }
        out
    }

    /// Relative per-task workload bars (Fig 6 bottom): for the final
    /// iteration, each node's chunk count relative to the busiest node.
    pub fn render_workload(&self) -> String {
        let mut out = String::new();
        let last = match self.n_iterations().checked_sub(1) {
            Some(i) => i,
            None => return out,
        };
        let max_chunks = self
            .spans
            .iter()
            .filter(|s| s.iter == last)
            .map(|s| s.n_chunks)
            .max()
            .unwrap_or(0)
            .max(1);
        for node in self.nodes() {
            if let Some(s) = self
                .spans
                .iter()
                .find(|s| s.node == node && s.iter == last)
            {
                let bar = "▇".repeat(s.n_chunks * 40 / max_chunks);
                let _ = writeln!(out, "node {:>2} |{:<40}| {} chunks", node, bar, s.n_chunks);
            }
        }
        out
    }

    /// TSV dump: node, iter, start_s, end_s, busy_s, chunks, samples.
    pub fn to_tsv(&self) -> String {
        let mut out = String::from("node\titer\tstart_s\tend_s\tbusy_s\tchunks\tsamples\n");
        for s in &self.spans {
            let _ = writeln!(
                out,
                "{}\t{}\t{:.6}\t{:.6}\t{:.6}\t{}\t{}",
                s.node,
                s.iter,
                s.start.as_secs_f64(),
                s.end.as_secs_f64(),
                s.busy().as_secs_f64(),
                s.n_chunks,
                s.n_samples
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(node: NodeId, iter: usize, start: f64, end: f64, chunks: usize) -> TaskSpan {
        TaskSpan {
            node,
            iter,
            start: Duration::from_secs_f64(start),
            end: Duration::from_secs_f64(end),
            n_chunks: chunks,
            n_samples: chunks * 100,
        }
    }

    #[test]
    fn durations_and_imbalance() {
        let mut r = SwimlaneRecorder::new();
        r.record(span(0, 0, 0.0, 1.0, 4));
        r.record(span(1, 0, 0.0, 2.0, 4));
        assert_eq!(r.iteration_duration(0), Some(Duration::from_secs(2)));
        assert_eq!(r.imbalance(0), Some(2.0));
        assert_eq!(r.n_iterations(), 1);
        assert_eq!(r.nodes(), vec![0, 1]);
        assert!(r.iteration_duration(5).is_none());
    }

    #[test]
    fn ascii_renders_rows() {
        let mut r = SwimlaneRecorder::new();
        r.record(span(0, 0, 0.0, 1.0, 1));
        r.record(span(1, 0, 0.0, 0.5, 1));
        let art = r.render_ascii(20);
        assert_eq!(art.lines().count(), 2);
        assert!(art.contains("node  0"));
    }

    #[test]
    fn tsv_roundtrip_columns() {
        let mut r = SwimlaneRecorder::new();
        r.record(span(3, 1, 1.0, 2.5, 7));
        let tsv = r.to_tsv();
        let row = tsv.lines().nth(1).unwrap();
        let cols: Vec<&str> = row.split('\t').collect();
        assert_eq!(cols[0], "3");
        assert_eq!(cols[5], "7");
    }
}
