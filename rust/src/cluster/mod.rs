//! Simulated cluster: nodes with speed factors and a trace-driven
//! resource manager (the YARN substitute — DESIGN.md §Substitutions).

pub mod node;
pub mod rm;

pub use node::{NodeId, NodeSpec};
pub use rm::{ResourceEvent, ResourceManager, TraceResourceManager};
