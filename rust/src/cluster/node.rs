//! Cluster nodes.
//!
//! The paper's testbed mixes Xeon E5-2630/40/50 v2/v3 generations and, for
//! some experiments, down-clocks four nodes from 2.6 to 1.2 GHz (§5.1,
//! §5.4). Heterogeneity is expressed here as a per-node `speed` factor
//! relative to the fastest node: a task processing S samples on node n
//! takes `S * per_sample_cost / speed(n)` virtual time.

pub type NodeId = u32;

/// Static description of one cluster node.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSpec {
    pub id: NodeId,
    /// Relative speed: 1.0 = fast baseline; the paper's down-clocked nodes
    /// run at 1.2/2.6 ≈ 0.46, the "1.5× slower" scenario at 1/1.5 ≈ 0.67.
    pub speed: f64,
}

impl NodeSpec {
    pub fn new(id: NodeId, speed: f64) -> Self {
        assert!(speed > 0.0, "node speed must be positive");
        NodeSpec { id, speed }
    }

    /// A homogeneous cluster of `n` unit-speed nodes.
    pub fn homogeneous(n: usize) -> Vec<NodeSpec> {
        (0..n as u32).map(|id| NodeSpec::new(id, 1.0)).collect()
    }

    /// The paper's §5.4 scenario-1 cluster: `n_fast` unit-speed nodes and
    /// `n_slow` nodes slower by `factor` (factor = 1.5 → speed 0.667).
    pub fn heterogeneous(n_fast: usize, n_slow: usize, factor: f64) -> Vec<NodeSpec> {
        let mut v = Vec::with_capacity(n_fast + n_slow);
        for id in 0..n_fast as u32 {
            v.push(NodeSpec::new(id, 1.0));
        }
        for id in 0..n_slow as u32 {
            v.push(NodeSpec::new(n_fast as u32 + id, 1.0 / factor));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_all_unit_speed() {
        let nodes = NodeSpec::homogeneous(4);
        assert_eq!(nodes.len(), 4);
        assert!(nodes.iter().all(|n| n.speed == 1.0));
        assert_eq!(nodes[3].id, 3);
    }

    #[test]
    fn heterogeneous_speeds() {
        let nodes = NodeSpec::heterogeneous(8, 8, 1.5);
        assert_eq!(nodes.len(), 16);
        assert_eq!(nodes[0].speed, 1.0);
        assert!((nodes[8].speed - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_speed_rejected() {
        NodeSpec::new(0, 0.0);
    }
}
