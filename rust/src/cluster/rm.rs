//! Resource manager simulation (the YARN substitute).
//!
//! Chicle's elastic scaling policy "interfaces with the resource manager
//! ... to make resource requests and get resource assignment and revocation
//! notices", with advance notice before revocation (paper §4.5). Here the
//! RM is driven by a timestamped trace of node-availability events, which
//! lets the harness replay the paper's scenarios (±2 nodes every 20 s)
//! deterministically.

use std::time::Duration;

use super::node::{NodeId, NodeSpec};

/// An availability change the RM reports to the elastic policy.
#[derive(Clone, Debug, PartialEq)]
pub enum ResourceEvent {
    /// New nodes were assigned to the application.
    Assigned(Vec<NodeSpec>),
    /// These nodes will be revoked; the application must drain them now
    /// (the paper's advance-notice contract, §4.5).
    RevokeNotice(Vec<NodeId>),
}

/// Interface the elastic policy programs against.
pub trait ResourceManager: Send {
    /// Poll for events up to virtual time `now`.
    fn poll(&mut self, now: Duration) -> Vec<ResourceEvent>;
    /// Nodes currently assigned (after all events up to the last poll).
    fn assigned(&self) -> &[NodeSpec];
}

/// One trace entry: at `at`, the application's allocation becomes `nodes`.
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub at: Duration,
    pub nodes: Vec<NodeSpec>,
}

/// Trace-driven RM: replays a list of target allocations.
pub struct TraceResourceManager {
    trace: Vec<TracePoint>,
    next: usize,
    current: Vec<NodeSpec>,
}

impl TraceResourceManager {
    pub fn new(mut trace: Vec<TracePoint>) -> Self {
        trace.sort_by_key(|p| p.at);
        assert!(!trace.is_empty(), "trace must have an initial allocation");
        assert_eq!(trace[0].at, Duration::ZERO, "trace must start at t=0");
        let current = trace[0].nodes.clone();
        TraceResourceManager { trace, next: 1, current }
    }

    /// Fixed allocation of `nodes` for the whole run (rigid mode).
    pub fn rigid(nodes: Vec<NodeSpec>) -> Self {
        TraceResourceManager::new(vec![TracePoint { at: Duration::ZERO, nodes }])
    }

    /// The paper's elastic scenarios (§5.3): start at `from` nodes and step
    /// by ±2 every `interval` until `to` nodes, from a homogeneous pool.
    pub fn gradual(from: usize, to: usize, interval: Duration) -> Self {
        let pool = NodeSpec::homogeneous(from.max(to));
        let mut trace = vec![TracePoint { at: Duration::ZERO, nodes: pool[..from].to_vec() }];
        let mut cur = from as i64;
        let step: i64 = if to >= from { 2 } else { -2 };
        let mut t = Duration::ZERO;
        while cur != to as i64 {
            cur = (cur + step).clamp(to.min(from) as i64, to.max(from) as i64);
            t += interval;
            trace.push(TracePoint { at: t, nodes: pool[..cur as usize].to_vec() });
        }
        TraceResourceManager::new(trace)
    }

    /// The full trace (for harness introspection / projections).
    pub fn trace(&self) -> &[TracePoint] {
        &self.trace
    }

    /// Target allocation at time `t` (ignoring poll state).
    pub fn allocation_at(&self, t: Duration) -> &[NodeSpec] {
        let mut cur = &self.trace[0].nodes;
        for p in &self.trace {
            if p.at <= t {
                cur = &p.nodes;
            } else {
                break;
            }
        }
        cur
    }
}

impl ResourceManager for TraceResourceManager {
    fn poll(&mut self, now: Duration) -> Vec<ResourceEvent> {
        let mut events = Vec::new();
        while self.next < self.trace.len() && self.trace[self.next].at <= now {
            let target = self.trace[self.next].nodes.clone();
            let added: Vec<NodeSpec> = target
                .iter()
                .filter(|n| !self.current.iter().any(|c| c.id == n.id))
                .cloned()
                .collect();
            let removed: Vec<NodeId> = self
                .current
                .iter()
                .filter(|c| !target.iter().any(|n| n.id == c.id))
                .map(|c| c.id)
                .collect();
            if !removed.is_empty() {
                events.push(ResourceEvent::RevokeNotice(removed));
            }
            if !added.is_empty() {
                events.push(ResourceEvent::Assigned(added));
            }
            self.current = target;
            self.next += 1;
        }
        events
    }

    fn assigned(&self) -> &[NodeSpec] {
        &self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(s: u64) -> Duration {
        Duration::from_secs(s)
    }

    #[test]
    fn rigid_never_changes() {
        let mut rm = TraceResourceManager::rigid(NodeSpec::homogeneous(4));
        assert!(rm.poll(secs(100)).is_empty());
        assert_eq!(rm.assigned().len(), 4);
    }

    #[test]
    fn gradual_scale_out_2_to_16() {
        let rm = TraceResourceManager::gradual(2, 16, secs(20));
        // 7 steps of +2 after the initial point.
        assert_eq!(rm.trace().len(), 8);
        assert_eq!(rm.allocation_at(secs(0)).len(), 2);
        assert_eq!(rm.allocation_at(secs(20)).len(), 4);
        assert_eq!(rm.allocation_at(secs(139)).len(), 14);
        assert_eq!(rm.allocation_at(secs(140)).len(), 16);
        assert_eq!(rm.allocation_at(secs(10_000)).len(), 16);
    }

    #[test]
    fn gradual_scale_in_16_to_2() {
        let mut rm = TraceResourceManager::gradual(16, 2, secs(20));
        assert_eq!(rm.assigned().len(), 16);
        let ev = rm.poll(secs(20));
        assert_eq!(ev.len(), 1);
        match &ev[0] {
            ResourceEvent::RevokeNotice(ids) => assert_eq!(ids.len(), 2),
            _ => panic!("expected revoke"),
        }
        assert_eq!(rm.assigned().len(), 14);
        // Polling far ahead drains the rest of the trace.
        rm.poll(secs(10_000));
        assert_eq!(rm.assigned().len(), 2);
    }

    #[test]
    fn poll_emits_assign_and_revoke_together_on_swap() {
        let a = NodeSpec::homogeneous(2);
        let b = vec![NodeSpec::new(5, 1.0), NodeSpec::new(6, 1.0)];
        let mut rm = TraceResourceManager::new(vec![
            TracePoint { at: secs(0), nodes: a },
            TracePoint { at: secs(10), nodes: b },
        ]);
        let ev = rm.poll(secs(10));
        assert_eq!(ev.len(), 2);
        assert!(matches!(ev[0], ResourceEvent::RevokeNotice(_)));
        assert!(matches!(ev[1], ResourceEvent::Assigned(_)));
    }
}
