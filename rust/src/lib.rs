//! # Chicle — elastic distributed ML training with uni-tasks
//!
//! A reproduction of *"Addressing Algorithmic Bottlenecks in Elastic Machine
//! Learning with Chicle"* (Kaufmann et al., MLSys 2019) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a
//!   driver/worker training runtime built on *uni-tasks* (exactly one
//!   multi-threaded task per node) and *mobile data chunks*, with an
//!   event-driven policy framework for elastic scaling, load rebalancing,
//!   straggler mitigation and background shuffling
//!   ([`coordinator`], [`chunks`], [`cluster`]). Task execution runs on a
//!   persistent worker runtime ([`exec`]): one long-lived thread per
//!   uni-task, driven by channel commands and surviving across iterations,
//!   so elasticity moves only data and roles — never compute state.
//! * **L2/L1 (build time)** — the compute graphs (CoCoA/SCD, the paper's CNN,
//!   an MLP, a transformer LM) written in JAX calling Pallas kernels, lowered
//!   once to HLO text by `python/compile/aot.py` and executed from the rust
//!   hot path via PJRT ([`runtime`]). Python is never on the training path.
//!
//! The crate also ships the substrates the paper depends on: synthetic
//! dataset generators standing in for HIGGS/Criteo/CIFAR-10/Fashion-MNIST
//! ([`data`]), a native (pure-rust) compute backend mirroring the HLO math
//! for fast figure regeneration ([`algos::nn`]), the paper's time-projection
//! methodology ([`sim`]), and the evaluation harness behind every figure and
//! table (`examples/`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use chicle::prelude::*;
//!
//! let dataset = chicle::data::synth::higgs_like(20_000, 42);
//! let cfg = SessionConfig::cocoa("quickstart", 4 /* nodes */);
//! let mut session = TrainingSession::new(cfg, dataset).unwrap();
//! let log = session.run().unwrap();
//! println!("final duality gap: {:.4}", log.last_gap().unwrap());
//! ```

pub mod algos;
pub mod chunks;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod harness;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::config::{AlgoConfig, SessionConfig, TimeModel};
    pub use crate::coordinator::session::TrainingSession;
    pub use crate::data::Dataset;
    pub use crate::metrics::MetricsLog;
}

/// Crate-wide result type (wraps `anyhow`).
pub type Result<T> = anyhow::Result<T>;
