//! The chunk format: samples + per-sample state in flat, serialization-free
//! arrays (paper §4.4).

use crate::data::SparseVec;

/// Globally unique chunk identifier (assigned once at chunking time).
pub type ChunkId = u32;

/// Sample payload of a chunk. Variants mirror [`crate::data::FeatureMatrix`]
/// plus the label storage, so a chunk is self-contained and movable.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Dense features + binary (±1) labels — the GLM/SVM workloads.
    DenseBinary { x: Vec<f32>, dim: usize, y: Vec<f32> },
    /// Dense features + class labels — the NN workloads.
    DenseClass { x: Vec<f32>, dim: usize, y: Vec<i32> },
    /// Sparse features + binary labels — the Criteo-like workload.
    SparseBinary { rows: Vec<SparseVec>, dim: usize, y: Vec<f32> },
    /// Token sequences (one sample = one sequence) — the LM workload.
    Tokens { data: Vec<i32>, seq_len: usize },
}

/// A mobile data chunk: fixed-capacity set of samples, their labels and
/// their per-sample optimizer state. Chunks are the scheduling granularity;
/// tasks are not (paper §3 "Core concepts").
#[derive(Clone, Debug)]
pub struct Chunk {
    pub id: ChunkId,
    pub payload: Payload,
    /// Per-sample state co-located with the data (CoCoA's α). Empty when the
    /// algorithm keeps no per-sample state (lSGD).
    pub state: Vec<f32>,
    /// Original dataset indices of the samples (diagnostics / shuffling).
    pub global_ids: Vec<u32>,
}

impl Chunk {
    pub fn n_samples(&self) -> usize {
        match &self.payload {
            Payload::DenseBinary { y, .. } => y.len(),
            Payload::DenseClass { y, .. } => y.len(),
            Payload::SparseBinary { y, .. } => y.len(),
            Payload::Tokens { data, seq_len } => data.len() / seq_len.max(&1),
        }
    }

    /// In-memory footprint in bytes — what the transfer cost model charges
    /// when the scheduler moves this chunk (§4.3).
    pub fn size_bytes(&self) -> usize {
        let payload = match &self.payload {
            Payload::DenseBinary { x, y, .. } => x.len() * 4 + y.len() * 4,
            Payload::DenseClass { x, y, .. } => x.len() * 4 + y.len() * 4,
            Payload::SparseBinary { rows, y, .. } => {
                rows.iter().map(|r| r.size_bytes()).sum::<usize>() + y.len() * 4
            }
            Payload::Tokens { data, .. } => data.len() * 4,
        };
        payload + self.state.len() * 4 + self.global_ids.len() * 4
    }

    /// Feature dimension (or sequence length for token chunks).
    pub fn dim(&self) -> usize {
        match &self.payload {
            Payload::DenseBinary { dim, .. } => *dim,
            Payload::DenseClass { dim, .. } => *dim,
            Payload::SparseBinary { dim, .. } => *dim,
            Payload::Tokens { seq_len, .. } => *seq_len,
        }
    }

    /// Reset per-sample state to zeros (length = n_samples).
    pub fn init_state(&mut self) {
        self.state = vec![0.0; self.n_samples()];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_chunk(n: usize, dim: usize) -> Chunk {
        Chunk {
            id: 1,
            payload: Payload::DenseBinary {
                x: vec![0.5; n * dim],
                dim,
                y: vec![1.0; n],
            },
            state: vec![],
            global_ids: (0..n as u32).collect(),
        }
    }

    #[test]
    fn sizes_and_counts() {
        let mut c = dense_chunk(10, 4);
        assert_eq!(c.n_samples(), 10);
        assert_eq!(c.dim(), 4);
        let base = 10 * 4 * 4 + 10 * 4 + 10 * 4;
        assert_eq!(c.size_bytes(), base);
        c.init_state();
        assert_eq!(c.state.len(), 10);
        assert_eq!(c.size_bytes(), base + 40);
    }

    #[test]
    fn token_chunk_counts_sequences() {
        let c = Chunk {
            id: 2,
            payload: Payload::Tokens { data: vec![0; 64 * 3], seq_len: 64 },
            state: vec![],
            global_ids: vec![0, 1, 2],
        };
        assert_eq!(c.n_samples(), 3);
        assert_eq!(c.dim(), 64);
    }
}
