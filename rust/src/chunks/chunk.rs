//! The chunk format: samples + per-sample state in flat, serialization-free
//! arrays (paper §4.4), split into an immutable reference-counted payload
//! and small mutable per-chunk state.
//!
//! # The payload/state split (zero-copy data plane)
//!
//! A [`Chunk`] is two very different kinds of bytes:
//!
//! * [`Payload`] — the sample data (features, labels) plus the samples'
//!   original dataset indices. Written exactly once, at chunking time,
//!   and **never mutated afterwards**; held behind an `Arc` and private
//!   to this module, so the only way to touch it post-chunking is the
//!   read-only accessors ([`Chunk::samples`], [`Chunk::global_ids`]).
//! * `state` — the per-sample optimizer state (CoCoA's dual variables α),
//!   a small `Vec<f32>` the solver mutates every iteration. It stays a
//!   plain owned field.
//!
//! `Chunk::clone` therefore bumps the payload's refcount and deep-copies
//! only the state: cloning a chunk costs O(per-sample state), not
//! O(sample bytes). This is what makes the trainer's eval-spanning
//! snapshot, elastic revoke/install and any copy-retaining migration
//! protocol pointer-bump cheap — the observation behind Elastic CoCoA's
//! "resizes are nearly free" argument. Use [`Chunk::deep_clone`] when a
//! genuinely private payload copy is required (benchmark reference
//! variants; a real cross-address-space transfer).

use std::sync::Arc;

use crate::data::SparseVec;

/// Globally unique chunk identifier (assigned once at chunking time).
pub type ChunkId = u32;

/// Sample data of a chunk. Variants mirror [`crate::data::FeatureMatrix`]
/// plus the label storage, so a chunk is self-contained and movable.
#[derive(Clone, Debug)]
pub enum Samples {
    /// Dense features + binary (±1) labels — the GLM/SVM workloads.
    DenseBinary { x: Vec<f32>, dim: usize, y: Vec<f32> },
    /// Dense features + class labels — the NN workloads.
    DenseClass { x: Vec<f32>, dim: usize, y: Vec<i32> },
    /// Sparse features + binary labels — the Criteo-like workload.
    SparseBinary { rows: Vec<SparseVec>, dim: usize, y: Vec<f32> },
    /// Token sequences (one sample = one sequence) — the LM workload.
    Tokens { data: Vec<i32>, seq_len: usize },
}

/// The immutable half of a chunk: sample data + the samples' original
/// dataset indices. Built once by the chunker, then shared by `Arc` —
/// every consumer reads it through [`Chunk`]'s accessors and nothing may
/// mutate it post-chunking.
#[derive(Clone, Debug)]
pub struct Payload {
    pub samples: Samples,
    /// Original dataset indices of the samples (diagnostics / shuffling).
    pub global_ids: Vec<u32>,
    /// Cached byte total of `samples` + `global_ids`. Immutable like the
    /// rest of the payload (id remapping preserves length), computed once
    /// at construction so size accounting — which the trainer's eval gate
    /// and the policies' transfer charges read on hot paths — never
    /// re-walks sparse rows.
    bytes: usize,
}

fn samples_bytes(samples: &Samples) -> usize {
    match samples {
        Samples::DenseBinary { x, y, .. } => x.len() * 4 + y.len() * 4,
        Samples::DenseClass { x, y, .. } => x.len() * 4 + y.len() * 4,
        Samples::SparseBinary { rows, y, .. } => {
            rows.iter().map(|r| r.size_bytes()).sum::<usize>() + y.len() * 4
        }
        Samples::Tokens { data, .. } => data.len() * 4,
    }
}

/// A mobile data chunk: fixed-capacity set of samples, their labels and
/// their per-sample optimizer state. Chunks are the scheduling granularity;
/// tasks are not (paper §3 "Core concepts").
///
/// Cloning shares the immutable payload (refcount bump) and deep-copies
/// only `state` — see the module docs for the ownership rules.
#[derive(Clone, Debug)]
pub struct Chunk {
    pub id: ChunkId,
    /// Immutable sample data, shared by reference. Private: post-chunking
    /// access is read-only through [`Chunk::samples`] /
    /// [`Chunk::global_ids`] / [`Chunk::samples_and_state_mut`].
    payload: Arc<Payload>,
    /// Per-sample state co-located with the data (CoCoA's α). Empty when
    /// the algorithm keeps no per-sample state (lSGD).
    pub state: Vec<f32>,
}

impl Chunk {
    /// Build a chunk from freshly produced sample data (chunking time).
    /// The per-sample state starts empty; call [`Chunk::init_state`] to
    /// zero-fill it.
    pub fn new(id: ChunkId, samples: Samples, global_ids: Vec<u32>) -> Self {
        let bytes = samples_bytes(&samples) + global_ids.len() * 4;
        Chunk {
            id,
            payload: Arc::new(Payload { samples, global_ids, bytes }),
            state: Vec::new(),
        }
    }

    /// Read-only view of the sample data.
    pub fn samples(&self) -> &Samples {
        &self.payload.samples
    }

    /// Original dataset indices of the samples.
    pub fn global_ids(&self) -> &[u32] {
        &self.payload.global_ids
    }

    /// The shared payload handle (pointer identity — lets tests and the
    /// migration benches verify a path really is zero-copy).
    pub fn payload(&self) -> &Arc<Payload> {
        &self.payload
    }

    /// Do two chunks share one payload allocation?
    pub fn shares_payload(&self, other: &Chunk) -> bool {
        Arc::ptr_eq(&self.payload, &other.payload)
    }

    /// Borrow the immutable sample data and the mutable per-sample state
    /// together — the solver hot-path accessor (the payload borrow proves
    /// the sample data cannot be written while the state is).
    pub fn samples_and_state_mut(&mut self) -> (&Samples, &mut [f32]) {
        (&self.payload.samples, &mut self.state)
    }

    /// A copy with its own private payload allocation (O(sample bytes)).
    /// The reference variant for the migration/snapshot benches; real
    /// data-plane paths use `clone()`, which shares the payload.
    pub fn deep_clone(&self) -> Chunk {
        Chunk {
            id: self.id,
            payload: Arc::new((*self.payload).clone()),
            state: self.state.clone(),
        }
    }

    /// Rewrite the global ids (chunking time only — copy-on-write, so it
    /// is free while the payload is still uniquely owned and never
    /// corrupts a shared payload afterwards).
    pub(crate) fn remap_global_ids(&mut self, mut f: impl FnMut(u32) -> u32) {
        let payload = Arc::make_mut(&mut self.payload);
        for g in payload.global_ids.iter_mut() {
            *g = f(*g);
        }
    }

    pub fn n_samples(&self) -> usize {
        match self.samples() {
            Samples::DenseBinary { y, .. } => y.len(),
            Samples::DenseClass { y, .. } => y.len(),
            Samples::SparseBinary { y, .. } => y.len(),
            Samples::Tokens { data, seq_len } => data.len() / (*seq_len).max(1),
        }
    }

    /// Bytes of the immutable payload (features + labels + global ids) —
    /// what a *cold* transfer must move, and what `clone()` never copies.
    /// O(1): cached at construction, valid forever because the payload is.
    pub fn payload_bytes(&self) -> usize {
        self.payload.bytes
    }

    /// Bytes of the mutable per-sample state — what a *warm* transfer
    /// (payload already resident at the destination) moves, and the whole
    /// cost of `clone()`.
    pub fn state_bytes(&self) -> usize {
        self.state.len() * 4
    }

    /// Total in-memory footprint in bytes — what the transfer cost model
    /// charges when the scheduler moves this chunk cold (§4.3). See
    /// [`crate::chunks::NetworkModel::chunk_cost`] for the warm/cold
    /// split.
    pub fn size_bytes(&self) -> usize {
        self.payload_bytes() + self.state_bytes()
    }

    /// Feature dimension (or sequence length for token chunks).
    pub fn dim(&self) -> usize {
        match self.samples() {
            Samples::DenseBinary { dim, .. } => *dim,
            Samples::DenseClass { dim, .. } => *dim,
            Samples::SparseBinary { dim, .. } => *dim,
            Samples::Tokens { seq_len, .. } => *seq_len,
        }
    }

    /// Reset per-sample state to zeros (length = n_samples).
    pub fn init_state(&mut self) {
        self.state = vec![0.0; self.n_samples()];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_chunk(n: usize, dim: usize) -> Chunk {
        Chunk::new(
            1,
            Samples::DenseBinary {
                x: vec![0.5; n * dim],
                dim,
                y: vec![1.0; n],
            },
            (0..n as u32).collect(),
        )
    }

    #[test]
    fn sizes_and_counts() {
        let mut c = dense_chunk(10, 4);
        assert_eq!(c.n_samples(), 10);
        assert_eq!(c.dim(), 4);
        let base = 10 * 4 * 4 + 10 * 4 + 10 * 4;
        assert_eq!(c.size_bytes(), base);
        assert_eq!(c.payload_bytes(), base);
        assert_eq!(c.state_bytes(), 0);
        c.init_state();
        assert_eq!(c.state.len(), 10);
        assert_eq!(c.size_bytes(), base + 40);
        assert_eq!(c.state_bytes(), 40);
        assert_eq!(c.payload_bytes(), base);
    }

    #[test]
    fn token_chunk_counts_sequences() {
        let c = Chunk::new(
            2,
            Samples::Tokens { data: vec![0; 64 * 3], seq_len: 64 },
            vec![0, 1, 2],
        );
        assert_eq!(c.n_samples(), 3);
        assert_eq!(c.dim(), 64);
    }

    #[test]
    fn clone_shares_payload_and_copies_state() {
        let mut a = dense_chunk(10, 4);
        a.init_state();
        let mut b = a.clone();
        assert!(a.shares_payload(&b), "clone must bump the Arc, not copy");
        // State is private per clone: mutating one never leaks into the
        // other (the eval-snapshot correctness condition).
        b.state[0] = 7.0;
        assert_eq!(a.state[0], 0.0);
        // deep_clone severs payload sharing.
        let d = a.deep_clone();
        assert!(!a.shares_payload(&d));
        assert_eq!(d.n_samples(), a.n_samples());
        assert_eq!(d.global_ids(), a.global_ids());
    }

    #[test]
    fn payload_bytes_cache_survives_remap_and_deep_clone() {
        let mut a = dense_chunk(10, 4);
        let expect = 10 * 4 * 4 + 10 * 4 + 10 * 4;
        assert_eq!(a.payload_bytes(), expect);
        a.remap_global_ids(|g| g + 1);
        assert_eq!(a.payload_bytes(), expect, "remap preserves payload size");
        assert_eq!(a.deep_clone().payload_bytes(), expect);
        assert_eq!(a.clone().payload_bytes(), expect);
    }

    #[test]
    fn remap_is_copy_on_write() {
        let a = dense_chunk(4, 2);
        let mut b = a.clone();
        b.remap_global_ids(|g| g + 100);
        assert_eq!(b.global_ids(), &[100, 101, 102, 103]);
        // The shared original is untouched: remap cloned before writing.
        assert_eq!(a.global_ids(), &[0, 1, 2, 3]);
        assert!(!a.shares_payload(&b));
    }
}
