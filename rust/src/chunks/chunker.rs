//! Dataset → chunks: split training data into fixed-size mobile chunks.
//!
//! The paper uses 1 MiB chunks for CoCoA and 200 KiB for lSGD (§5.1); the
//! chunk size is a tunable (§4.4, "e.g. to the CPU cache size").

use crate::data::{Dataset, FeatureMatrix, Labels};
use crate::util::Rng;

use super::{Chunk, Samples};

/// Split `ds` into chunks of at most `chunk_bytes` bytes each, preserving
/// sample order (contiguous chunking; pair with the trainer's
/// `Partitioning::RandomChunks` placement for the Chicle behaviour, or
/// `Partitioning::Contiguous` for the Snap-ML-style baseline).
pub fn make_chunks(ds: &Dataset, chunk_bytes: usize) -> Vec<Chunk> {
    let n = ds.n_samples();
    let mut chunks = Vec::new();
    let mut start = 0usize;
    let mut next_id: u32 = 0;
    while start < n {
        let take = samples_for_budget(ds, start, chunk_bytes).max(1).min(n - start);
        let end = start + take;
        let samples = slice_samples(ds, start, end);
        let mut chunk = Chunk::new(next_id, samples, (start as u32..end as u32).collect());
        chunk.init_state();
        chunks.push(chunk);
        next_id += 1;
        start = end;
    }
    chunks
}

/// Like [`make_chunks`] but with samples globally shuffled first (seeded).
/// Random sample-to-chunk placement is what gives Chicle its partitioning
/// advantage on session-correlated data (paper §A.1, Criteo).
pub fn make_chunks_shuffled(ds: &Dataset, chunk_bytes: usize, seed: u64) -> Vec<Chunk> {
    let mut order: Vec<usize> = (0..ds.n_samples()).collect();
    Rng::seed_from_u64(seed).shuffle(&mut order);
    let permuted = permute(ds, &order);
    let mut chunks = make_chunks(&permuted, chunk_bytes);
    // Rewrite global ids to the original dataset indices. Copy-on-write,
    // which is free here: the payloads are still uniquely owned.
    for c in &mut chunks {
        c.remap_global_ids(|g| order[g as usize] as u32);
    }
    chunks
}

fn per_sample_bytes(ds: &Dataset, i: usize) -> usize {
    let feat = match &ds.features {
        FeatureMatrix::Dense { dim, .. } => dim * 4,
        FeatureMatrix::Sparse { rows, .. } => rows[i].size_bytes(),
        FeatureMatrix::Tokens { seq_len, .. } => seq_len * 4,
    };
    feat + 4 /* label */ + 4 /* state */ + 4 /* global id */
}

fn samples_for_budget(ds: &Dataset, start: usize, budget: usize) -> usize {
    let n = ds.n_samples();
    let mut used = 0usize;
    let mut count = 0usize;
    while start + count < n {
        let s = per_sample_bytes(ds, start + count);
        if used + s > budget && count > 0 {
            break;
        }
        used += s;
        count += 1;
        if used >= budget {
            break;
        }
    }
    count
}

fn slice_samples(ds: &Dataset, start: usize, end: usize) -> Samples {
    match (&ds.features, &ds.labels) {
        (FeatureMatrix::Dense { data, dim }, Labels::Binary(y)) => Samples::DenseBinary {
            x: data[start * dim..end * dim].to_vec(),
            dim: *dim,
            y: y[start..end].to_vec(),
        },
        (FeatureMatrix::Dense { data, dim }, Labels::Class(y)) => Samples::DenseClass {
            x: data[start * dim..end * dim].to_vec(),
            dim: *dim,
            y: y[start..end].to_vec(),
        },
        (FeatureMatrix::Sparse { rows, dim }, Labels::Binary(y)) => Samples::SparseBinary {
            rows: rows[start..end].to_vec(),
            dim: *dim,
            y: y[start..end].to_vec(),
        },
        (FeatureMatrix::Tokens { data, seq_len }, _) => Samples::Tokens {
            data: data[start * seq_len..end * seq_len].to_vec(),
            seq_len: *seq_len,
        },
        _ => panic!("unsupported dataset/label combination for chunking"),
    }
}

fn permute(ds: &Dataset, order: &[usize]) -> Dataset {
    let features = match &ds.features {
        FeatureMatrix::Dense { data, dim } => {
            let mut out = Vec::with_capacity(data.len());
            for &i in order {
                out.extend_from_slice(&data[i * dim..(i + 1) * dim]);
            }
            FeatureMatrix::Dense { data: out, dim: *dim }
        }
        FeatureMatrix::Sparse { rows, dim } => FeatureMatrix::Sparse {
            rows: order.iter().map(|&i| rows[i].clone()).collect(),
            dim: *dim,
        },
        FeatureMatrix::Tokens { data, seq_len } => {
            let mut out = Vec::with_capacity(data.len());
            for &i in order {
                out.extend_from_slice(&data[i * seq_len..(i + 1) * seq_len]);
            }
            FeatureMatrix::Tokens { data: out, seq_len: *seq_len }
        }
    };
    let labels = match &ds.labels {
        Labels::Binary(y) => Labels::Binary(order.iter().map(|&i| y[i]).collect()),
        Labels::Class(y) => Labels::Class(order.iter().map(|&i| y[i]).collect()),
        Labels::None => Labels::None,
    };
    Dataset { name: ds.name.clone(), features, labels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn chunks_cover_all_samples_exactly_once() {
        let ds = synth::higgs_like(1000, 1);
        let chunks = make_chunks(&ds, 8 * 1024);
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        assert_eq!(total, 1000);
        let mut ids: Vec<u32> = chunks.iter().flat_map(|c| c.global_ids().to_vec()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..1000).collect::<Vec<u32>>());
    }

    #[test]
    fn chunks_respect_size_budget() {
        let ds = synth::higgs_like(1000, 2);
        let budget = 4 * 1024;
        let chunks = make_chunks(&ds, budget);
        assert!(chunks.len() > 1);
        for c in &chunks {
            // +1 sample of slack: budget is a target, samples are atomic.
            assert!(c.size_bytes() <= budget + 28 * 4 + 12, "{}", c.size_bytes());
        }
    }

    #[test]
    fn sparse_chunking_uses_actual_row_sizes() {
        let ds = synth::criteo_like_with(500, 10_000, 20, 16, 3);
        let chunks = make_chunks(&ds, 2 * 1024);
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        assert_eq!(total, 500);
        assert!(chunks.len() > 5);
    }

    #[test]
    fn shuffled_chunks_break_session_locality() {
        let ds = synth::criteo_like_with(512, 10_000, 20, 16, 4);
        let chunks = make_chunks_shuffled(&ds, 4 * 1024, 7);
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        assert_eq!(total, 512);
        // global ids within a chunk should NOT be contiguous
        let ids = chunks[0].global_ids();
        let contiguous = ids.windows(2).filter(|w| w[1] == w[0] + 1).count();
        assert!(contiguous < ids.len() / 2, "still contiguous: {contiguous}");
        // all ids still covered exactly once
        let mut all: Vec<u32> = chunks.iter().flat_map(|c| c.global_ids().to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..512).collect::<Vec<u32>>());
    }

    #[test]
    fn token_dataset_chunks_by_sequence() {
        let ds = synth::token_corpus(64, 32, 128, 5);
        let chunks = make_chunks(&ds, 1024);
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        assert_eq!(total, 64);
        for c in &chunks {
            assert_eq!(c.dim(), 32);
        }
    }
}
