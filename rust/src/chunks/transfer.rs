//! Chunk-transfer cost model — the RDMA substitute.
//!
//! The paper moves chunks with one-sided RDMA reads over 56 Gbit/s
//! Infiniband (§4.3). In this reproduction chunks move between in-process
//! stores by pointer, and this model charges the *virtual* time a real
//! transfer would take, so scheduler decisions (e.g. rebalancing
//! granularity, scale-in drain cost) see the same trade-offs.

use std::time::Duration;

use super::Chunk;

/// Byte breakdown of one chunk move, mirroring the payload/state split of
/// [`Chunk`]: the immutable payload only has to cross the wire when the
/// destination does not already hold it (a *cold* transfer), while the
/// mutable per-sample state moves every time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkBytes {
    /// Immutable sample data + global ids (Arc-shared in process).
    pub payload: usize,
    /// Mutable per-sample optimizer state.
    pub state: usize,
}

impl ChunkBytes {
    /// The split for one chunk.
    pub fn of(chunk: &Chunk) -> ChunkBytes {
        ChunkBytes { payload: chunk.payload_bytes(), state: chunk.state_bytes() }
    }

    /// Bytes a transfer must move: payload + state when cold, state only
    /// when the payload is already resident at the destination.
    pub fn wire_bytes(&self, warm: bool) -> usize {
        if warm {
            self.state
        } else {
            self.payload + self.state
        }
    }
}

/// Bandwidth/latency model of the cluster interconnect.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Link bandwidth in bytes/second (default: 56 Gbit/s IB ≈ 7e9 B/s).
    pub bandwidth_bps: f64,
    /// Per-operation latency (RDMA read setup + completion).
    pub latency: Duration,
    /// Effective utilization factor (protocol overheads, 0 < f <= 1).
    pub efficiency: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            bandwidth_bps: 56.0e9 / 8.0,
            latency: Duration::from_micros(3),
            efficiency: 0.9,
        }
    }
}

impl NetworkModel {
    /// Cost of moving `bytes` in one RDMA-style transfer.
    pub fn transfer_cost(&self, bytes: usize) -> Duration {
        let secs = bytes as f64 / (self.bandwidth_bps * self.efficiency);
        self.latency + Duration::from_secs_f64(secs)
    }

    /// Cost of moving a set of chunks sequentially over one link.
    pub fn bulk_cost(&self, chunk_bytes: &[usize]) -> Duration {
        chunk_bytes
            .iter()
            .map(|&b| self.transfer_cost(b))
            .sum()
    }

    /// Cost of migrating one chunk given its payload/state byte split.
    ///
    /// `warm` means the destination already holds the chunk's immutable
    /// payload (it hosted the chunk before, while a group member), so
    /// only the per-sample state crosses the wire — pricing a
    /// scale-in/scale-out round-trip at O(state) instead of O(dataset),
    /// matching what the in-process data plane actually does (payloads
    /// move by `Arc` clone). A cold transfer charges payload + state.
    /// The scheduler reads `warm` from transport-membership residency
    /// ([`crate::transport::Residency`], consulted per move by
    /// `PolicyCtx::move_chunk`); residency is a pure function of the
    /// movement + membership history, so priced vtime stays
    /// deterministic.
    pub fn chunk_cost(&self, bytes: ChunkBytes, warm: bool) -> Duration {
        self.transfer_cost(bytes.wire_bytes(warm))
    }

    /// Rounds of a binary tree reduce-then-broadcast over `k` participants:
    /// `2·⌈log2 k⌉` (0 for a single participant).
    pub fn reduce_rounds(k: usize) -> u32 {
        if k <= 1 {
            return 0;
        }
        2 * (usize::BITS - (k - 1).leading_zeros())
    }

    /// Cost of an allreduce-style model exchange: each of `k` tasks sends
    /// and receives `bytes` (the paper's ≈16 MiB/task Criteo example, §4.3).
    ///
    /// Modeled as a binary tree reduce followed by a broadcast — the shape
    /// of the sharded parallel merge in [`crate::exec`] — so the cost grows
    /// with `2·⌈log2 k⌉` rounds, each moving the model once per link. (The
    /// previous serialized-at-driver model charged `2k` full transfers,
    /// which overcharges heavily at large `k` and no longer matches how the
    /// reduction actually runs.)
    pub fn model_exchange_cost(&self, bytes: usize, k: usize) -> Duration {
        self.transfer_cost(bytes) * Self::reduce_rounds(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_scales_with_bytes() {
        let m = NetworkModel::default();
        let small = m.transfer_cost(1024);
        let big = m.transfer_cost(1024 * 1024);
        assert!(big > small);
        // 1 MiB over ~6.3 GB/s effective ≈ 166 µs + 3 µs latency.
        assert!(big < Duration::from_millis(1));
        assert!(big > Duration::from_micros(100));
    }

    #[test]
    fn latency_dominates_tiny_transfers() {
        let m = NetworkModel::default();
        let c = m.transfer_cost(1);
        assert!(c >= m.latency);
        assert!(c < m.latency * 2);
    }

    #[test]
    fn bulk_and_exchange() {
        let m = NetworkModel::default();
        let bulk = m.bulk_cost(&[1024, 1024, 1024]);
        assert_eq!(bulk, m.transfer_cost(1024) * 3);
        assert_eq!(m.model_exchange_cost(16 << 20, 1), Duration::ZERO);
        // Tree reduce + broadcast: 2·⌈log2 k⌉ full-model rounds.
        let one = m.transfer_cost(16 << 20);
        assert_eq!(m.model_exchange_cost(16 << 20, 2), one * 2);
        assert_eq!(m.model_exchange_cost(16 << 20, 16), one * 8);
        assert_eq!(m.model_exchange_cost(16 << 20, 17), one * 10);
        // Logarithmic, not linear: far below the serialized-driver 2k.
        assert!(m.model_exchange_cost(16 << 20, 64) < one * 16);
    }

    #[test]
    fn warm_transfers_charge_state_only() {
        let m = NetworkModel::default();
        let bytes = ChunkBytes { payload: 1 << 20, state: 4 << 10 };
        assert_eq!(bytes.wire_bytes(false), (1 << 20) + (4 << 10));
        assert_eq!(bytes.wire_bytes(true), 4 << 10);
        let cold = m.chunk_cost(bytes, false);
        let warm = m.chunk_cost(bytes, true);
        assert!(warm < cold, "{warm:?} !< {cold:?}");
        assert_eq!(cold, m.transfer_cost((1 << 20) + (4 << 10)));
        assert_eq!(warm, m.transfer_cost(4 << 10));
    }

    #[test]
    fn chunk_bytes_split_matches_chunk_accounting() {
        use crate::chunks::{Chunk, Samples};
        let mut c = Chunk::new(
            1,
            Samples::DenseBinary { x: vec![0.0; 40], dim: 4, y: vec![1.0; 10] },
            (0..10).collect(),
        );
        c.init_state();
        let b = ChunkBytes::of(&c);
        assert_eq!(b.payload, c.payload_bytes());
        assert_eq!(b.state, c.state_bytes());
        assert_eq!(b.wire_bytes(false), c.size_bytes());
    }

    #[test]
    fn reduce_rounds_are_ceil_log2() {
        for (k, rounds) in [(0, 0), (1, 0), (2, 2), (3, 4), (4, 4), (5, 6), (8, 6), (9, 8)] {
            assert_eq!(NetworkModel::reduce_rounds(k), rounds, "k={k}");
        }
        // Monotone non-decreasing in k.
        let mut prev = 0;
        for k in 1..200 {
            let r = NetworkModel::reduce_rounds(k);
            assert!(r >= prev, "k={k}");
            prev = r;
        }
    }
}
