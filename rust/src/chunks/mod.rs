//! Mobile data chunks — the scheduling currency of uni-tasks (paper §3, §4.4).
//!
//! All training samples live in small fixed-size *stateful* chunks that the
//! scheduler moves between tasks in-between iterations. A chunk bundles its
//! samples with their per-sample optimizer state (CoCoA's dual variables α),
//! "ensuring that state and the data it correlates to are always moved
//! together" (§4.4). The in-memory layout is flat arrays — nothing needs
//! serialization, mirroring the paper's one-sided-RDMA constraint.
//!
//! Since the zero-copy data-plane refactor, a chunk is split into an
//! immutable, `Arc`-shared [`Payload`] (samples + global ids, written once
//! at chunking time) and small mutable per-sample `state`; `Chunk::clone`
//! is a pointer bump plus a state copy, which is what makes eval
//! snapshots and elastic migrations O(per-sample state) instead of
//! O(dataset) — see [`chunk`]'s module docs for the ownership rules.

pub mod chunk;
pub mod chunker;
pub mod store;
pub mod transfer;

pub use chunk::{Chunk, ChunkId, Payload, Samples};
pub use chunker::make_chunks;
pub use store::{ChunkStore, SharedStore};
pub use transfer::{ChunkBytes, NetworkModel};
