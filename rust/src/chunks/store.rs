//! Per-task chunk store.
//!
//! Each uni-task owns the set of chunks currently assigned to it and has
//! full random access to every sample across all local chunks (paper §3,
//! core concept 2). The ownership contract is enforced by the coordinator:
//! chunks are only added/removed between iterations.

use std::sync::{Arc, Mutex, MutexGuard};

use super::{Chunk, ChunkId};

/// The set of chunks local to one uni-task.
#[derive(Debug, Default)]
pub struct ChunkStore {
    chunks: Vec<Chunk>,
}

impl ChunkStore {
    pub fn new() -> Self {
        ChunkStore { chunks: Vec::new() }
    }

    pub fn from_chunks(chunks: Vec<Chunk>) -> Self {
        ChunkStore { chunks }
    }

    pub fn add(&mut self, chunk: Chunk) {
        debug_assert!(
            !self.chunks.iter().any(|c| c.id == chunk.id),
            "duplicate chunk {}",
            chunk.id
        );
        self.chunks.push(chunk);
    }

    /// Remove and return a chunk by id (None if not local).
    pub fn remove(&mut self, id: ChunkId) -> Option<Chunk> {
        let pos = self.chunks.iter().position(|c| c.id == id)?;
        Some(self.chunks.swap_remove(pos))
    }

    /// Drain all chunks (task termination on scale-in).
    pub fn drain(&mut self) -> Vec<Chunk> {
        std::mem::take(&mut self.chunks)
    }

    pub fn chunk_ids(&self) -> Vec<ChunkId> {
        self.chunks.iter().map(|c| c.id).collect()
    }

    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    pub fn n_samples(&self) -> usize {
        self.chunks.iter().map(|c| c.n_samples()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.size_bytes()).sum()
    }

    /// Bytes of mutable per-sample state across all local chunks — the
    /// cost of a state-only snapshot (`Chunk::clone` of every chunk).
    pub fn state_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.state_bytes()).sum()
    }

    /// Bytes of immutable (Arc-shared) payload across all local chunks.
    pub fn payload_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.payload_bytes()).sum()
    }

    /// `(payload_bytes, state_bytes)` — the pair the trainer's
    /// eval-overlap gate reads every evaluation point.
    pub fn byte_split(&self) -> (usize, usize) {
        (self.payload_bytes(), self.state_bytes())
    }

    pub fn iter(&self) -> impl Iterator<Item = &Chunk> {
        self.chunks.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Chunk> {
        self.chunks.iter_mut()
    }

    pub fn get(&self, id: ChunkId) -> Option<&Chunk> {
        self.chunks.iter().find(|c| c.id == id)
    }

    pub fn get_mut(&mut self, id: ChunkId) -> Option<&mut Chunk> {
        self.chunks.iter_mut().find(|c| c.id == id)
    }

    /// Locate sample `k` (in local flat order) as (chunk index, row in chunk).
    pub fn locate(&self, k: usize) -> Option<(usize, usize)> {
        let mut rem = k;
        for (ci, c) in self.chunks.iter().enumerate() {
            let n = c.n_samples();
            if rem < n {
                return Some((ci, rem));
            }
            rem -= n;
        }
        None
    }

    pub fn chunks(&self) -> &[Chunk] {
        &self.chunks
    }

    pub fn chunks_mut(&mut self) -> &mut [Chunk] {
        &mut self.chunks
    }
}

/// Shared handle to one uni-task's chunk store.
///
/// The coordinator-side [`crate::coordinator::TaskState`] and that task's
/// persistent [`crate::exec`] worker hold clones of the same store. The
/// uni-task ownership contract keeps the lock uncontended: the worker
/// touches the store only while executing a `RunIteration` command, the
/// scheduler/policies only between iterations.
#[derive(Clone, Debug, Default)]
pub struct SharedStore {
    inner: Arc<Mutex<ChunkStore>>,
}

impl SharedStore {
    pub fn new() -> Self {
        SharedStore::default()
    }

    pub fn from_chunks(chunks: Vec<Chunk>) -> Self {
        SharedStore { inner: Arc::new(Mutex::new(ChunkStore::from_chunks(chunks))) }
    }

    /// Lock the underlying store for direct access (e.g. iterating chunks
    /// for evaluation, or the worker's in-iteration mutation).
    pub fn lock(&self) -> MutexGuard<'_, ChunkStore> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    pub fn add(&self, chunk: Chunk) {
        self.lock().add(chunk);
    }

    /// Remove and return a chunk by id (None if not local).
    pub fn remove(&self, id: ChunkId) -> Option<Chunk> {
        self.lock().remove(id)
    }

    /// Drain all chunks (task termination on scale-in).
    pub fn drain(&self) -> Vec<Chunk> {
        self.lock().drain()
    }

    pub fn chunk_ids(&self) -> Vec<ChunkId> {
        self.lock().chunk_ids()
    }

    pub fn n_chunks(&self) -> usize {
        self.lock().n_chunks()
    }

    pub fn n_samples(&self) -> usize {
        self.lock().n_samples()
    }

    pub fn size_bytes(&self) -> usize {
        self.lock().size_bytes()
    }

    /// Bytes of mutable per-sample state across all local chunks.
    pub fn state_bytes(&self) -> usize {
        self.lock().state_bytes()
    }

    /// Bytes of immutable (Arc-shared) payload across all local chunks.
    pub fn payload_bytes(&self) -> usize {
        self.lock().payload_bytes()
    }

    /// `(payload_bytes, state_bytes)` under a single lock acquisition.
    pub fn byte_split(&self) -> (usize, usize) {
        self.lock().byte_split()
    }

    /// Sample count of a local chunk (None if not local).
    pub fn chunk_samples(&self, id: ChunkId) -> Option<usize> {
        self.lock().get(id).map(|c| c.n_samples())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::Samples;

    fn chunk(id: ChunkId, n: usize) -> Chunk {
        let mut c = Chunk::new(
            id,
            Samples::DenseBinary { x: vec![0.0; n * 2], dim: 2, y: vec![1.0; n] },
            (0..n as u32).collect(),
        );
        c.init_state();
        c
    }

    #[test]
    fn add_remove_roundtrip() {
        let mut s = ChunkStore::new();
        s.add(chunk(1, 3));
        s.add(chunk(2, 5));
        assert_eq!(s.n_chunks(), 2);
        assert_eq!(s.n_samples(), 8);
        let c = s.remove(1).unwrap();
        assert_eq!(c.n_samples(), 3);
        assert_eq!(s.n_samples(), 5);
        assert!(s.remove(1).is_none());
    }

    #[test]
    fn locate_flat_sample_index() {
        let mut s = ChunkStore::new();
        s.add(chunk(1, 3));
        s.add(chunk(2, 5));
        assert_eq!(s.locate(0), Some((0, 0)));
        assert_eq!(s.locate(2), Some((0, 2)));
        assert_eq!(s.locate(3), Some((1, 0)));
        assert_eq!(s.locate(7), Some((1, 4)));
        assert_eq!(s.locate(8), None);
    }

    #[test]
    fn drain_empties_store() {
        let mut s = ChunkStore::from_chunks(vec![chunk(1, 2), chunk(2, 2)]);
        let all = s.drain();
        assert_eq!(all.len(), 2);
        assert_eq!(s.n_chunks(), 0);
    }

    #[test]
    fn byte_split_sums_payload_and_state() {
        let mut s = ChunkStore::new();
        s.add(chunk(1, 3));
        s.add(chunk(2, 5));
        // Per chunk: payload = n·(2·4 features + 4 label + 4 global id),
        // state = n·4.
        assert_eq!(s.state_bytes(), (3 + 5) * 4);
        assert_eq!(s.payload_bytes(), (3 + 5) * 16);
        assert_eq!(s.size_bytes(), s.payload_bytes() + s.state_bytes());
        assert_eq!(s.byte_split(), (s.payload_bytes(), s.state_bytes()));
    }

    #[test]
    fn shared_store_clones_alias_one_store() {
        let a = SharedStore::new();
        let b = a.clone();
        a.add(chunk(1, 3));
        b.add(chunk(2, 5));
        assert_eq!(a.n_chunks(), 2);
        assert_eq!(b.n_samples(), 8);
        assert_eq!(a.chunk_samples(2), Some(5));
        assert_eq!(a.chunk_samples(9), None);
        let removed = b.remove(1).unwrap();
        assert_eq!(removed.n_samples(), 3);
        assert_eq!(a.n_chunks(), 1);
        assert_eq!(b.drain().len(), 1);
        assert_eq!(a.n_chunks(), 0);
    }
}
