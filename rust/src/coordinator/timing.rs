//! Iteration time accounting — the paper's projection methodology (§5.3)
//! factored out of the trainer's step loop.
//!
//! Two time models:
//!
//! * **Projected** — per-task time is `samples / unit / speed`, where one
//!   unit is the algorithm's normalization (CoCoA: 1/16th of the dataset
//!   on a unit-speed node). Uni-task iterations take the slowest task's
//!   time; micro-task iterations are projected with the wave model over
//!   the current node allocation. Transfer overheads are excluded, as in
//!   the paper ("this favors micro-tasks").
//! * **Measured** — wallclock compute scaled by node speed, plus the
//!   network model's cost for chunks moved this boundary, plus the
//!   merge phase charged as a tree reduce of the model over the active
//!   tasks ([`crate::chunks::NetworkModel::model_exchange_cost`]).
//!
//! Accounting also feeds each task's learned per-sample runtime history,
//! which the rebalance policy consumes (§4.5).
//!
//! # Why the overlap never enters virtual time
//!
//! Accounting is deliberately independent of the trainer's reduce/dispatch
//! overlap: virtual time charges the same tree-reduce exchange cost whether
//! the merge ran barriered or pipelined behind the next iteration's
//! dispatch — and, since the eval-spanning extension, whether the
//! evaluation ran against a live barriered snapshot or against the
//! completed reduce buffer while the next iteration was already computing.
//! Wallclock savings from the overlap show up in the measured
//! `merge_wall`/`overlap_wall` TSV columns instead; the adaptive
//! shards-per-worker controller likewise only ever appears as the `spw`
//! column, and a `ring`/`tree` merge collective's *measured* transport
//! reality only as `transport_rounds`/`transport_bytes` — logged next to
//! the simulated `exchange_time` (this module's `2·⌈log2 k⌉` tree-reduce
//! charge) precisely so the cost model can be audited against what the
//! wire actually did, never silently replaced by it. Folding any of them
//! into virtual time would make the projected
//! trajectory depend on host scheduling (steal counts and overlap windows
//! vary run to run) and break the determinism of scheduler projections —
//! two runs with the same seed must report the same vtime series, which is
//! what makes the paper's elasticity comparisons reproducible.

use std::time::Duration;

use crate::algos::{Algorithm, LocalUpdate};
use crate::chunks::NetworkModel;
use crate::cluster::NodeSpec;
use crate::config::{SessionConfig, TaskModel, TimeModel};
use crate::sim::microtask_iteration_time;

use super::task::TaskState;

/// Aggregate times of one iteration.
#[derive(Clone, Debug)]
pub struct IterationTiming {
    /// Per-task (virtual) compute time, aligned with the task list.
    pub task_times: Vec<f64>,
    /// Barrier-to-barrier iteration time under the configured task model.
    pub iteration_time: f64,
    /// Chunk-transfer time charged this boundary (measured mode only).
    pub transfer_time: f64,
    /// Model-exchange (merge-phase) time charged under the network model's
    /// tree reduce (measured mode only; projections exclude it, §5.3).
    pub exchange_time: f64,
}

/// Stateless time accountant configured from the session.
#[derive(Clone, Copy, Debug)]
pub struct TimeAccountant {
    time_model: TimeModel,
    task_model: TaskModel,
    ref_nodes: usize,
}

impl TimeAccountant {
    pub fn new(cfg: &SessionConfig) -> Self {
        TimeAccountant {
            time_model: cfg.time_model,
            task_model: cfg.task_model,
            ref_nodes: cfg.ref_nodes,
        }
    }

    /// Charge one iteration: compute per-task and aggregate times and
    /// record per-sample runtimes into the tasks' learning windows.
    #[allow(clippy::too_many_arguments)]
    pub fn account(
        &self,
        algo: &dyn Algorithm,
        tasks: &mut [TaskState],
        updates: &[LocalUpdate],
        walls: &[Duration],
        nodes: &[NodeSpec],
        net: &NetworkModel,
        moved_bytes: usize,
        model_bytes: usize,
        n_total: usize,
    ) -> IterationTiming {
        let unit = algo.unit_samples(n_total, self.ref_nodes);
        let mut task_times = Vec::with_capacity(updates.len());
        for ((task, upd), wall) in tasks.iter_mut().zip(updates).zip(walls) {
            let t = match self.time_model {
                TimeModel::Projected => (upd.samples as f64 / unit) / task.node.speed,
                TimeModel::Measured => wall.as_secs_f64() / task.node.speed,
            };
            task_times.push(t);
            if upd.samples > 0 {
                task.record_time(t / upd.samples as f64);
            }
        }
        let iteration_time = match self.task_model {
            TaskModel::UniTasks => task_times.iter().cloned().fold(0.0, f64::max),
            TaskModel::MicroTasks { k } => {
                // Wave model over the *current* node allocation: each task
                // is one unit of work of the largest observed size.
                let task_units = task_times.iter().cloned().fold(0.0, f64::max);
                microtask_iteration_time(k, task_units * k as f64, nodes)
            }
        };
        let (transfer_time, exchange_time) = match self.time_model {
            // The paper's projections exclude transfer overheads
            // (§5.3: "this favors micro-tasks").
            TimeModel::Projected => (0.0, 0.0),
            TimeModel::Measured => (
                net.transfer_cost(moved_bytes).as_secs_f64(),
                net.model_exchange_cost(model_bytes, updates.len()).as_secs_f64(),
            ),
        };
        IterationTiming { task_times, iteration_time, transfer_time, exchange_time }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Backend, CocoaAlgo};
    use crate::config::CocoaConfig;

    fn upd(samples: usize) -> LocalUpdate {
        LocalUpdate { delta: vec![], samples, loss_sum: 0.0 }
    }

    #[test]
    fn projected_uni_time_is_slowest_task() {
        let cfg = SessionConfig::cocoa("t", 2);
        let acct = TimeAccountant::new(&cfg);
        let algo = CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 1600, 4);
        let mut tasks = vec![
            TaskState::new(NodeSpec::new(0, 1.0), 3),
            TaskState::new(NodeSpec::new(1, 0.5), 3),
        ];
        let nodes: Vec<NodeSpec> = tasks.iter().map(|t| t.node.clone()).collect();
        let updates = vec![upd(100), upd(100)];
        let walls = vec![Duration::from_millis(1); 2];
        let timing = acct.account(
            &algo,
            &mut tasks,
            &updates,
            &walls,
            &nodes,
            &NetworkModel::default(),
            0,
            16,
            1600,
        );
        // unit = 1600/16 = 100 samples → 1.0 on the fast node, 2.0 on the
        // half-speed node; the iteration is pinned to the slow task.
        assert!((timing.task_times[0] - 1.0).abs() < 1e-12);
        assert!((timing.task_times[1] - 2.0).abs() < 1e-12);
        assert!((timing.iteration_time - 2.0).abs() < 1e-12);
        assert_eq!(timing.transfer_time, 0.0);
        // Projections exclude the model exchange too.
        assert_eq!(timing.exchange_time, 0.0);
        // History recorded for both tasks.
        assert!(tasks.iter().all(|t| t.est_per_sample().is_some()));
    }

    #[test]
    fn measured_mode_charges_transfers() {
        let mut cfg = SessionConfig::cocoa("t", 2);
        cfg.time_model = TimeModel::Measured;
        let acct = TimeAccountant::new(&cfg);
        let algo = CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 1600, 4);
        let mut tasks = vec![TaskState::new(NodeSpec::new(0, 1.0), 3)];
        let nodes: Vec<NodeSpec> = tasks.iter().map(|t| t.node.clone()).collect();
        let updates = vec![upd(50)];
        let walls = vec![Duration::from_millis(50)];
        let net = NetworkModel::default();
        let timing = acct.account(
            &algo, &mut tasks, &updates, &walls, &nodes, &net, 1 << 20, 1 << 20, 1600,
        );
        assert!((timing.transfer_time - net.transfer_cost(1 << 20).as_secs_f64()).abs() < 1e-12);
        assert!(timing.iteration_time > 0.0);
        // A single task has nothing to exchange with.
        assert_eq!(timing.exchange_time, 0.0);
    }

    #[test]
    fn measured_mode_charges_model_exchange_tree() {
        let mut cfg = SessionConfig::cocoa("t", 2);
        cfg.time_model = TimeModel::Measured;
        let acct = TimeAccountant::new(&cfg);
        let algo = CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 1600, 4);
        let mut tasks = vec![
            TaskState::new(NodeSpec::new(0, 1.0), 3),
            TaskState::new(NodeSpec::new(1, 1.0), 3),
        ];
        let nodes: Vec<NodeSpec> = tasks.iter().map(|t| t.node.clone()).collect();
        let updates = vec![upd(50), upd(50)];
        let walls = vec![Duration::from_millis(10); 2];
        let net = NetworkModel::default();
        let model_bytes = 16 << 20;
        let timing = acct.account(
            &algo, &mut tasks, &updates, &walls, &nodes, &net, 0, model_bytes, 1600,
        );
        let expect = net.model_exchange_cost(model_bytes, 2).as_secs_f64();
        assert!(expect > 0.0);
        assert!((timing.exchange_time - expect).abs() < 1e-12);
    }
}
