//! Per-task state owned by the trainer.

use std::collections::VecDeque;

use crate::chunks::SharedStore;
use crate::cluster::NodeSpec;

/// One uni-task: the node it runs on, its local chunks, and the runtime
/// history the rebalance policy learns from (paper §4.5: "observes
/// iteration runtimes over multiple iterations to learn the per-sample
/// runtime of each task").
///
/// The chunk store is a [`SharedStore`]: the task's persistent
/// [`crate::exec`] worker holds a clone of the same handle, so chunks the
/// scheduler moves between iterations are immediately visible to the
/// worker without tearing down its thread.
#[derive(Debug)]
pub struct TaskState {
    pub node: NodeSpec,
    pub store: SharedStore,
    /// Recent per-sample task times in seconds (virtual or measured).
    history: VecDeque<f64>,
    history_cap: usize,
}

impl TaskState {
    pub fn new(node: NodeSpec, history_cap: usize) -> Self {
        TaskState {
            node,
            store: SharedStore::new(),
            history: VecDeque::new(),
            history_cap: history_cap.max(1),
        }
    }

    /// Record one iteration's per-sample time.
    pub fn record_time(&mut self, secs_per_sample: f64) {
        if self.history.len() == self.history_cap {
            self.history.pop_front();
        }
        self.history.push_back(secs_per_sample);
    }

    /// Median per-sample time over the window (None until one iteration
    /// has run). The median gives robustness to runtime fluctuations —
    /// the paper's tunable `I`.
    pub fn est_per_sample(&self) -> Option<f64> {
        if self.history.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = self.history.iter().copied().collect();
        v.sort_by(|a, b| a.total_cmp(b));
        Some(v[v.len() / 2])
    }

    /// Forget learned timings (e.g. after this task's load changed a lot).
    pub fn clear_history(&mut self) {
        self.history.clear();
    }

    pub fn n_samples(&self) -> usize {
        self.store.n_samples()
    }

    pub fn n_chunks(&self) -> usize {
        self.store.n_chunks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_is_windowed_median() {
        let mut t = TaskState::new(NodeSpec::new(0, 1.0), 3);
        assert_eq!(t.est_per_sample(), None);
        t.record_time(1.0);
        t.record_time(100.0);
        t.record_time(2.0);
        assert_eq!(t.est_per_sample(), Some(2.0)); // median of {1,100,2}
        t.record_time(3.0); // evicts 1.0 → {100,2,3}
        assert_eq!(t.est_per_sample(), Some(3.0));
        t.clear_history();
        assert_eq!(t.est_per_sample(), None);
    }
}
