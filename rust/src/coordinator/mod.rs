//! The Chicle coordinator — the paper's system contribution (§4).
//!
//! A driver ("trainer") orchestrates K uni-tasks over mobile data chunks:
//!
//! * [`trainer`] — the iteration loop: barrier-synchronous task execution,
//!   weighted model merge, virtual-time accounting (projected per §5.3 or
//!   measured), metric evaluation, swimlane recording.
//! * [`task`] — per-task state: the chunk store (ownership contract: the
//!   scheduler only touches it between iterations) and the learned runtime
//!   history the rebalancer uses.
//! * [`policy`] — the event-driven policy framework (§4.5): elastic
//!   scaling against the resource-manager trace, load rebalancing,
//!   background shuffling, straggler mitigation.
//! * [`session`] — the user-facing entry point: build a full session from
//!   a [`crate::config::SessionConfig`] + dataset, run it, get metrics.

pub mod policy;
pub mod session;
pub mod task;
pub mod trainer;

pub use session::TrainingSession;
pub use task::TaskState;
pub use trainer::Trainer;
