//! The Chicle coordinator — the paper's system contribution (§4).
//!
//! A driver ("trainer") orchestrates K uni-tasks over mobile data chunks,
//! executing on the persistent worker runtime in [`crate::exec`]:
//!
//! * [`trainer`] — the iteration loop as an explicit phase pipeline:
//!   `elasticity → policies → execute → merge → account → evaluate`.
//!   Execution dispatches to long-lived uni-task workers (no per-iteration
//!   thread churn); elastic scale-in/out maps to executor spawn and
//!   drain-then-shutdown commands.
//! * [`task`] — per-task state: the shared chunk store (ownership
//!   contract: the scheduler only touches it between iterations, the
//!   resident worker only during one) and the learned runtime history the
//!   rebalancer uses.
//! * [`timing`] — iteration time accounting: the paper's projection model
//!   (§5.3) or measured wallclock, factored out of the step loop.
//! * [`policy`] — the event-driven policy framework (§4.5): elastic
//!   scaling against the resource-manager trace, load rebalancing,
//!   background shuffling, straggler mitigation.
//! * [`session`] — the user-facing entry point: build a full session from
//!   a [`crate::config::SessionConfig`] + dataset, run it, get metrics.

pub mod policy;
pub mod session;
pub mod task;
pub mod timing;
pub mod trainer;

pub use session::TrainingSession;
pub use task::TaskState;
pub use trainer::Trainer;
