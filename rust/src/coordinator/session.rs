//! Training sessions: the user-facing assembly of config + dataset →
//! algorithm + backend + trainer.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::algos::lsgd::LsgdAlgo;
use crate::algos::nn::NativeModel;
use crate::algos::{Algorithm, Backend, CocoaAlgo};
use crate::chunks::chunker::make_chunks;
use crate::config::{AlgoConfig, ComputeBackend, ModelKind, SessionConfig};
use crate::data::{Dataset, FeatureMatrix, Labels};
use crate::metrics::{MetricsLog, SwimlaneRecorder};
use crate::runtime::{HloService, Manifest};

use super::trainer::Trainer;

/// A fully-assembled training session.
pub struct TrainingSession {
    trainer: Trainer,
    pub name: String,
}

impl TrainingSession {
    /// Build a session. For lSGD workloads a held-out test split is taken
    /// from the dataset per `cfg.test_frac`.
    pub fn new(cfg: SessionConfig, dataset: Dataset) -> Result<Self> {
        let name = cfg.name.clone();

        // HLO plumbing if requested (one engine service per session).
        let hlo: Option<(HloService, Manifest)> = if cfg.backend == ComputeBackend::Hlo {
            let service = HloService::spawn(&cfg.artifacts_dir)?;
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            Some((service, manifest))
        } else {
            None
        };

        let (algo, train): (Arc<dyn Algorithm>, Dataset) = match &cfg.algo {
            AlgoConfig::Cocoa(ccfg) => {
                if !matches!(dataset.labels, Labels::Binary(_)) {
                    bail!("CoCoA requires binary (±1) labels");
                }
                let backend = match &hlo {
                    None => Backend::native_cocoa(),
                    Some((service, manifest)) => Backend::hlo_cocoa(
                        service.clone(),
                        manifest,
                        256,
                        dataset.dim(),
                    )
                    .context("HLO CoCoA backend (is the feature width lowered?)")?,
                };
                let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
                    ccfg.clone(),
                    backend,
                    dataset.n_samples(),
                    dataset.dim(),
                ));
                (algo, dataset)
            }
            AlgoConfig::Lsgd(lcfg) => {
                match (&dataset.features, &dataset.labels) {
                    (_, Labels::Class(_)) => {
                        let (train, test) = dataset.split_test(cfg.test_frac);
                        let (tx, ty) = match (&test.features, &test.labels) {
                            (FeatureMatrix::Dense { data, .. }, Labels::Class(y)) => {
                                (data.clone(), y.clone())
                            }
                            _ => bail!("lSGD classif requires dense features"),
                        };
                        let backend = match &hlo {
                            None => {
                                let model = match lcfg.model {
                                    ModelKind::Mlp => NativeModel::mlp_default(),
                                    ModelKind::Cnn => NativeModel::cnn_default(),
                                    other => bail!(
                                        "{other:?} has no native backend; use backend=hlo"
                                    ),
                                };
                                if model.input_dim() != train.dim() {
                                    bail!(
                                        "model expects input dim {}, dataset has {}",
                                        model.input_dim(),
                                        train.dim()
                                    );
                                }
                                Backend::native_nn(model)
                            }
                            Some((service, manifest)) => Backend::hlo_nn(
                                service.clone(),
                                manifest,
                                lcfg.model.artifact_prefix(),
                            )?,
                        };
                        let algo: Arc<dyn Algorithm> = Arc::new(LsgdAlgo::new_classif(
                            lcfg.clone(),
                            backend,
                            train.dim(),
                            tx,
                            ty,
                            cfg.seed,
                        )?);
                        (algo, train)
                    }
                    (FeatureMatrix::Tokens { seq_len, .. }, Labels::None) => {
                        let seq_len = *seq_len;
                        let (train, test) = dataset.split_test(cfg.test_frac.max(0.05));
                        let test_tokens = match &test.features {
                            FeatureMatrix::Tokens { data, .. } => data.clone(),
                            _ => unreachable!(),
                        };
                        let (service, manifest) = hlo
                            .as_ref()
                            .context("LM workloads require backend=hlo")?;
                        let backend = Backend::hlo_nn(
                            service.clone(),
                            manifest,
                            lcfg.model.artifact_prefix(),
                        )?;
                        let algo: Arc<dyn Algorithm> = Arc::new(LsgdAlgo::new_lm(
                            lcfg.clone(),
                            backend,
                            seq_len,
                            test_tokens,
                            cfg.seed,
                        )?);
                        (algo, train)
                    }
                    _ => bail!("lSGD requires class labels or token sequences"),
                }
            }
        };

        let chunks = make_chunks(&train, cfg.chunk_bytes);
        // Enough chunks to give every task at least one: tasks are nodes
        // under the legacy coupling, but a fixed K logical tasks under
        // the decoupled schedule.
        let min_tasks = cfg.elastic.max_nodes().max(cfg.decoupled_tasks().unwrap_or(0));
        anyhow::ensure!(
            chunks.len() >= min_tasks,
            "only {} chunks for up to {} tasks — reduce chunk_bytes",
            chunks.len(),
            min_tasks
        );
        let trainer = Trainer::new(cfg, algo, chunks)?;
        Ok(TrainingSession { trainer, name })
    }

    /// Run to completion and return the metrics log.
    pub fn run(&mut self) -> Result<MetricsLog> {
        self.trainer.run()?;
        Ok(self.trainer.metrics.clone())
    }

    /// Execute a single iteration (benchmarks / custom loops), barriered:
    /// no work is left in flight, so callers may stop after any step and
    /// observe a consistent model/chunk state. The overlap pipeline —
    /// which since the eval-spanning extension covers evaluation
    /// iterations too — is exercised by `run`/`run_iters`, which know
    /// whether a next iteration is coming.
    pub fn step(&mut self, iter: usize) -> Result<Option<crate::metrics::Metric>> {
        self.trainer.step_barriered(iter)
    }

    /// Run exactly `iters` iterations (ignores targets). The last
    /// iteration is barriered so the overlap pipeline never dispatches an
    /// iteration beyond the requested count; every earlier iteration —
    /// eval points included — may pipeline.
    pub fn run_iters(&mut self, iters: usize) -> Result<MetricsLog> {
        for i in 0..iters {
            if i + 1 == iters {
                self.trainer.step_barriered(i)?;
            } else {
                self.trainer.step(i)?;
            }
        }
        Ok(self.trainer.metrics.clone())
    }

    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    pub fn swimlanes(&self) -> &SwimlaneRecorder {
        &self.trainer.swimlanes
    }

    pub fn metrics(&self) -> &MetricsLog {
        &self.trainer.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ElasticSpec;
    use crate::data::synth;

    #[test]
    fn quickstart_cocoa_session() {
        let ds = synth::higgs_like(2000, 42);
        let mut cfg = SessionConfig::cocoa("quickstart", 4);
        cfg.chunk_bytes = 8 * 1024;
        cfg.max_iters = 60;
        let mut s = TrainingSession::new(cfg, ds).unwrap();
        let log = s.run().unwrap();
        assert!(log.last_gap().unwrap() < 0.01, "gap {:?}", log.last_gap());
    }

    #[test]
    fn lsgd_mlp_session_improves_accuracy() {
        let ds = synth::fmnist_like(1500, 7);
        let mut cfg = SessionConfig::lsgd("mlp", ModelKind::Mlp, 2);
        cfg.chunk_bytes = 32 * 1024;
        cfg.max_iters = 40;
        if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
            l.lr = 5e-3;
            l.eval_every = 10;
            l.target_acc = 2.0; // unreachable: run all iters
        }
        let mut s = TrainingSession::new(cfg, ds).unwrap();
        let log = s.run().unwrap();
        let acc = log.last_accuracy().unwrap();
        assert!(acc > 0.5, "accuracy {acc}");
    }

    #[test]
    fn session_rejects_label_mismatch() {
        let ds = synth::fmnist_like(100, 1);
        let cfg = SessionConfig::cocoa("bad", 2);
        assert!(TrainingSession::new(cfg, ds).is_err());
    }

    #[test]
    fn session_requires_enough_chunks() {
        let ds = synth::higgs_like(100, 1);
        let mut cfg = SessionConfig::cocoa("tiny", 2);
        cfg.chunk_bytes = usize::MAX; // 1 chunk
        cfg.elastic = ElasticSpec::Rigid { nodes: 4 };
        assert!(TrainingSession::new(cfg, ds).is_err());
    }
}
