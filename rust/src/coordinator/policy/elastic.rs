//! Elastic scaling support (paper §4.5, "Elastic scaling policy").
//!
//! The trainer handles the resource-manager events directly (it owns the
//! task list); this module provides the chunk-redistribution primitives:
//!
//! * on **scale-out**, chunks move from old tasks to newly spawned ones,
//!   picked randomly from each donor — the random pick is what shuffles
//!   samples and lets CoCoA's local solver find new correlations (§5.3
//!   "Results", scale-out discussion);
//! * on **scale-in** (revocation), the departing tasks' chunks are dealt
//!   round-robin to the survivors.
//!
//! Both target a speed-proportional sample share per task, which is also
//! what the rebalance policy maintains steady-state.

use crate::chunks::Chunk;
use crate::coordinator::task::TaskState;
use crate::util::Rng;

/// Deal `chunks` (from revoked tasks) round-robin onto the remaining
/// tasks (paper: "redistributes data chunks from to-be freed workers to
/// remaining ones in a round robin fashion"). Returns bytes moved.
pub fn deal_round_robin(tasks: &mut [TaskState], chunks: Vec<Chunk>) -> usize {
    if tasks.is_empty() {
        return 0;
    }
    let mut bytes = 0usize;
    for (i, chunk) in chunks.into_iter().enumerate() {
        bytes += chunk.size_bytes();
        tasks[i % tasks.len()].store.add(chunk);
    }
    bytes
}

/// After scale-out: move randomly-picked chunks from donor tasks to the
/// new (empty or light) tasks until every task holds approximately a
/// speed-proportional share of samples. Returns bytes moved.
pub fn redistribute_for_new_tasks(tasks: &mut [TaskState], rng: &mut Rng) -> usize {
    if tasks.len() < 2 {
        return 0;
    }
    let total_samples: usize = tasks.iter().map(|t| t.n_samples()).sum();
    let total_speed: f64 = tasks.iter().map(|t| t.node.speed).sum();
    if total_samples == 0 || total_speed <= 0.0 {
        return 0;
    }
    let target: Vec<f64> = tasks
        .iter()
        .map(|t| total_samples as f64 * t.node.speed / total_speed)
        .collect();
    let mut bytes = 0usize;
    // Repeatedly move one random chunk from the most-over-target donor to
    // the most-under-target receiver while it reduces total imbalance.
    loop {
        let over: Vec<f64> = tasks
            .iter()
            .zip(&target)
            .map(|(t, &tg)| t.n_samples() as f64 - tg)
            .collect();
        let donor = over
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        let recv = over
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        if donor == recv || tasks[donor].store.n_chunks() <= 1 {
            break;
        }
        let ids = tasks[donor].store.chunk_ids();
        let cid = ids[rng.below(ids.len())];
        let chunk_samples = tasks[donor].store.chunk_samples(cid).unwrap_or(0) as f64;
        // Only move if it strictly reduces the donor's overshoot without
        // overshooting the receiver by more.
        if over[donor] < chunk_samples / 2.0 || -over[recv] < chunk_samples / 2.0 {
            break;
        }
        let chunk = tasks[donor].store.remove(cid).unwrap();
        bytes += chunk.size_bytes();
        tasks[recv].store.add(chunk);
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::Samples;
    use crate::cluster::NodeSpec;

    fn chunk(id: u32, n: usize) -> Chunk {
        let mut c = Chunk::new(
            id,
            Samples::DenseBinary { x: vec![0.0; n * 2], dim: 2, y: vec![1.0; n] },
            vec![0; n],
        );
        c.init_state();
        c
    }

    fn task_with(node: NodeSpec, ids: std::ops::Range<u32>, n: usize) -> TaskState {
        let mut t = TaskState::new(node, 3);
        for id in ids {
            t.store.add(chunk(id, n));
        }
        t
    }

    #[test]
    fn round_robin_deal_covers_all() {
        let mut tasks = vec![
            task_with(NodeSpec::new(0, 1.0), 0..2, 10),
            task_with(NodeSpec::new(1, 1.0), 2..4, 10),
        ];
        let orphans: Vec<Chunk> = (10..15).map(|i| chunk(i, 10)).collect();
        let bytes = deal_round_robin(&mut tasks, orphans);
        assert!(bytes > 0);
        assert_eq!(tasks[0].n_chunks() + tasks[1].n_chunks(), 9);
        // Round-robin: first task gets 3, second 2.
        assert_eq!(tasks[0].n_chunks(), 5);
        assert_eq!(tasks[1].n_chunks(), 4);
    }

    #[test]
    fn redistribute_fills_empty_new_task() {
        let mut tasks = vec![
            task_with(NodeSpec::new(0, 1.0), 0..16, 10),
            TaskState::new(NodeSpec::new(1, 1.0), 3),
        ];
        let mut rng = Rng::seed_from_u64(1);
        let bytes = redistribute_for_new_tasks(&mut tasks, &mut rng);
        assert!(bytes > 0);
        let (a, b) = (tasks[0].n_samples(), tasks[1].n_samples());
        assert!((a as i64 - b as i64).abs() <= 10, "{a} vs {b}");
    }

    #[test]
    fn redistribute_respects_speed_proportional_share() {
        let mut tasks = vec![
            task_with(NodeSpec::new(0, 1.0), 0..30, 10),
            TaskState::new(NodeSpec::new(1, 0.5), 3),
        ];
        let mut rng = Rng::seed_from_u64(2);
        redistribute_for_new_tasks(&mut tasks, &mut rng);
        let (a, b) = (tasks[0].n_samples() as f64, tasks[1].n_samples() as f64);
        // fast node should hold ~2× the slow node's samples
        assert!(a / b > 1.5 && a / b < 3.0, "ratio {}", a / b);
    }

    #[test]
    fn chunk_conservation() {
        let mut tasks = vec![
            task_with(NodeSpec::new(0, 1.0), 0..9, 7),
            TaskState::new(NodeSpec::new(1, 1.0), 3),
            TaskState::new(NodeSpec::new(2, 1.0), 3),
        ];
        let before: usize = tasks.iter().map(|t| t.n_samples()).sum();
        let mut rng = Rng::seed_from_u64(3);
        redistribute_for_new_tasks(&mut tasks, &mut rng);
        let after: usize = tasks.iter().map(|t| t.n_samples()).sum();
        assert_eq!(before, after);
        let mut all_ids: Vec<u32> = tasks
            .iter()
            .flat_map(|t| t.store.chunk_ids())
            .collect();
        all_ids.sort_unstable();
        assert_eq!(all_ids, (0..9).collect::<Vec<u32>>());
    }
}
