//! Straggler mitigation policy (paper §4.5 "Other policies").
//!
//! Distinct from rebalancing (which tracks *persistent* speed differences
//! via the median), this policy reacts to *acute* stragglers: a task whose
//! latest iteration ran slower than `factor` × the median task time sheds
//! one chunk immediately to the currently fastest task. Transient blips
//! are tolerated by requiring the condition to hold `patience` times in a
//! row.

use anyhow::Result;

use super::{Policy, PolicyCtx};

pub struct StragglerPolicy {
    factor: f64,
    patience: usize,
    /// Consecutive straggler observations per task index.
    strikes: Vec<usize>,
    /// Total mitigations applied (diagnostics / tests).
    pub mitigations: usize,
}

impl StragglerPolicy {
    pub fn new(factor: f64, patience: usize) -> Self {
        StragglerPolicy {
            factor: factor.max(1.0),
            patience: patience.max(1),
            strikes: Vec::new(),
            mitigations: 0,
        }
    }
}

impl Policy for StragglerPolicy {
    fn name(&self) -> &'static str {
        "straggler"
    }

    fn apply(&mut self, ctx: &mut PolicyCtx) -> Result<()> {
        let n = ctx.tasks.len();
        self.strikes.resize(n, 0);
        if n < 2 {
            return Ok(());
        }
        // Latest projected per-task time.
        let times: Vec<Option<f64>> = ctx
            .tasks
            .iter()
            .map(|t| t.est_per_sample().map(|ps| ps * t.n_samples() as f64))
            .collect();
        if times.iter().any(|t| t.is_none()) {
            return Ok(());
        }
        let mut sorted: Vec<f64> = times.iter().map(|t| t.unwrap()).collect();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = sorted[n / 2];
        if median <= 0.0 {
            return Ok(());
        }
        let fastest = times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.unwrap().total_cmp(&b.1.unwrap()))
            .map(|(i, _)| i)
            .unwrap();
        for i in 0..n {
            if times[i].unwrap() > self.factor * median {
                self.strikes[i] += 1;
                if self.strikes[i] >= self.patience && i != fastest {
                    let ids = ctx.tasks[i].store.chunk_ids();
                    if ids.len() > 1 {
                        let cid = ids[ctx.rng.below(ids.len())];
                        ctx.move_chunk(i, fastest, cid)?;
                        self.mitigations += 1;
                    }
                    self.strikes[i] = 0;
                }
            } else {
                self.strikes[i] = 0;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::{Chunk, NetworkModel, Samples};
    use crate::cluster::NodeSpec;
    use crate::coordinator::task::TaskState;
    use crate::util::Rng;

    fn task(id: u32, n_chunks: usize, per_sample: f64) -> TaskState {
        let mut t = TaskState::new(NodeSpec::new(id, 1.0), 3);
        for c in 0..n_chunks {
            let mut chunk = Chunk::new(
                id * 100 + c as u32,
                Samples::DenseBinary { x: vec![0.0; 20], dim: 2, y: vec![1.0; 10] },
                vec![0; 10],
            );
            chunk.init_state();
            t.store.add(chunk);
        }
        t.record_time(per_sample);
        t
    }

    fn apply_n(tasks: &mut Vec<TaskState>, p: &mut StragglerPolicy, iters: usize) {
        let net = NetworkModel::default();
        let mut rng = Rng::seed_from_u64(0);
        for iter in 0..iters {
            let mut ctx = PolicyCtx {
                tasks,
                iter,
                net: &net,
                moved_bytes: 0,
                moved_chunks: 0,
                residency: crate::transport::Residency::default(),
                rng: &mut rng,
            };
            p.apply(&mut ctx).unwrap();
        }
    }

    #[test]
    fn persistent_straggler_sheds_chunks() {
        let mut tasks = vec![task(0, 4, 0.001), task(1, 4, 0.001), task(2, 4, 0.010)];
        let mut p = StragglerPolicy::new(2.0, 2);
        apply_n(&mut tasks, &mut p, 5);
        assert!(p.mitigations >= 1);
        assert!(tasks[2].n_chunks() < 4);
    }

    #[test]
    fn uniform_cluster_untouched() {
        let mut tasks = vec![task(0, 4, 0.002), task(1, 4, 0.002), task(2, 4, 0.002)];
        let mut p = StragglerPolicy::new(2.0, 1);
        apply_n(&mut tasks, &mut p, 5);
        assert_eq!(p.mitigations, 0);
    }

    #[test]
    fn patience_filters_transients() {
        // Straggler condition must persist `patience` consecutive rounds;
        // with patience 3 and only 2 rounds, nothing moves.
        let mut tasks = vec![task(0, 4, 0.001), task(1, 4, 0.001), task(2, 4, 0.010)];
        let mut p = StragglerPolicy::new(2.0, 3);
        apply_n(&mut tasks, &mut p, 2);
        assert_eq!(p.mitigations, 0);
    }
}
