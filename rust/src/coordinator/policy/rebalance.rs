//! Rebalancing policy (paper §4.5): learn per-sample runtimes, move
//! chunks gradually from slower to faster tasks until iteration runtimes
//! align.
//!
//! Tasks are ranked by their median per-sample time over the last `I`
//! iterations; each boundary at most `step` chunks move from the slowest
//! to the fastest task, stopping when the projected runtime difference is
//! smaller than the estimated processing time of a single chunk.

use anyhow::Result;

use super::{Policy, PolicyCtx};

pub struct RebalancePolicy {
    /// Max chunks moved per boundary ("gradually, across multiple
    /// iterations").
    step: usize,
}

impl RebalancePolicy {
    pub fn new(step: usize) -> Self {
        RebalancePolicy { step: step.max(1) }
    }
}

impl Policy for RebalancePolicy {
    fn name(&self) -> &'static str {
        "rebalance"
    }

    fn apply(&mut self, ctx: &mut PolicyCtx) -> Result<()> {
        if ctx.tasks.len() < 2 {
            return Ok(());
        }
        for _ in 0..self.step {
            // Projected runtime of each task = local samples × per-sample.
            let mut projections: Vec<(usize, f64, f64)> = Vec::new(); // (idx, time, per_sample)
            for (i, t) in ctx.tasks.iter().enumerate() {
                let Some(ps) = t.est_per_sample() else {
                    return Ok(()); // not enough history yet
                };
                projections.push((i, ps * t.n_samples() as f64, ps));
            }
            let (slow_idx, slow_time, slow_ps) = *projections
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap();
            let (fast_idx, fast_time, fast_ps) = *projections
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .unwrap();
            if slow_idx == fast_idx {
                return Ok(());
            }
            // Candidate: a random chunk on the slow task.
            let ids = ctx.tasks[slow_idx].store.chunk_ids();
            if ids.len() <= 1 {
                return Ok(()); // never strip a task bare
            }
            let cid = ids[ctx.rng.below(ids.len())];
            let chunk_samples =
                ctx.tasks[slow_idx].store.chunk_samples(cid).unwrap_or(0) as f64;
            // Stop when the gap is already smaller than one chunk's cost
            // on the slow task (paper: "until performance differences are
            // smaller than the estimated processing time of a single
            // chunk").
            let chunk_cost = chunk_samples * slow_ps;
            if slow_time - fast_time <= chunk_cost {
                return Ok(());
            }
            // Don't overshoot: moving must not make the fast task the new
            // bottleneck worse than the current gap.
            if fast_time + chunk_samples * fast_ps >= slow_time {
                return Ok(());
            }
            ctx.move_chunk(slow_idx, fast_idx, cid)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::{Chunk, NetworkModel, Samples};
    use crate::cluster::NodeSpec;
    use crate::coordinator::task::TaskState;
    use crate::util::Rng;

    fn chunk(id: u32, n: usize) -> Chunk {
        let mut c = Chunk::new(
            id,
            Samples::DenseBinary { x: vec![0.0; n * 4], dim: 4, y: vec![1.0; n] },
            vec![0; n],
        );
        c.init_state();
        c
    }

    fn setup(chunks_a: usize, chunks_b: usize, speed_b: f64) -> Vec<TaskState> {
        let mut a = TaskState::new(NodeSpec::new(0, 1.0), 3);
        let mut b = TaskState::new(NodeSpec::new(1, speed_b), 3);
        let mut id = 0;
        for _ in 0..chunks_a {
            a.store.add(chunk(id, 100));
            id += 1;
        }
        for _ in 0..chunks_b {
            b.store.add(chunk(id, 100));
            id += 1;
        }
        // Histories reflecting node speeds: per-sample time = 1/speed ms.
        for _ in 0..3 {
            a.record_time(0.001);
            b.record_time(0.001 / speed_b);
        }
        vec![a, b]
    }

    fn run_policy(tasks: &mut Vec<TaskState>, iters: usize, step: usize) -> usize {
        let net = NetworkModel::default();
        let mut rng = Rng::seed_from_u64(0);
        let mut policy = RebalancePolicy::new(step);
        let mut moved = 0;
        for iter in 0..iters {
            let mut ctx = PolicyCtx {
                tasks,
                iter,
                net: &net,
                moved_bytes: 0,
                moved_chunks: 0,
                residency: crate::transport::Residency::default(),
                rng: &mut rng,
            };
            policy.apply(&mut ctx).unwrap();
            moved += ctx.moved_chunks;
        }
        moved
    }

    #[test]
    fn moves_load_from_slow_to_fast() {
        // Equal chunks, but task 1 runs at half speed → chunks should flow
        // toward task 0 until runtimes align (≈ 2:1 chunk split).
        let mut tasks = setup(8, 8, 0.5);
        run_policy(&mut tasks, 20, 2);
        let (a, b) = (tasks[0].n_samples() as f64, tasks[1].n_samples() as f64);
        // projected times: a*0.001 vs b*0.002 — should be within one chunk.
        let ta = a * 0.001;
        let tb = b * 0.002;
        assert!((ta - tb).abs() <= 100.0 * 0.002 + 1e-9, "ta={ta} tb={tb}");
        assert!(a > b, "fast node should hold more samples: {a} vs {b}");
    }

    #[test]
    fn balanced_tasks_stay_put() {
        let mut tasks = setup(8, 8, 1.0);
        let moved = run_policy(&mut tasks, 10, 2);
        assert_eq!(moved, 0);
        assert_eq!(tasks[0].n_chunks(), 8);
    }

    #[test]
    fn never_strips_a_task_bare() {
        let mut tasks = setup(1, 1, 0.01);
        run_policy(&mut tasks, 50, 4);
        assert!(tasks[1].n_chunks() >= 1);
    }

    #[test]
    fn no_history_no_moves() {
        let mut a = TaskState::new(NodeSpec::new(0, 1.0), 3);
        a.store.add(chunk(0, 100));
        a.store.add(chunk(1, 100));
        let b = TaskState::new(NodeSpec::new(1, 0.5), 3);
        let mut tasks = vec![a, b];
        let moved = run_policy(&mut tasks, 5, 2);
        assert_eq!(moved, 0);
    }
}
