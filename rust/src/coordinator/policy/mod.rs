//! The policy framework (paper §4.5).
//!
//! Policies run *between* iterations — the window in which the scheduler
//! owns the data chunks (the uni-tasks ownership contract, §3) — and may
//! move chunks between tasks through [`PolicyCtx`]. Each enabled policy is
//! consulted every iteration in registration order.

pub mod elastic;
pub mod rebalance;
pub mod shuffle;
pub mod straggler;

pub use elastic::{deal_round_robin, redistribute_for_new_tasks};
pub use rebalance::RebalancePolicy;
pub use shuffle::ShufflePolicy;
pub use straggler::StragglerPolicy;

use crate::chunks::{ChunkBytes, NetworkModel};
use crate::coordinator::task::TaskState;
use crate::transport::Residency;
use crate::Result;

/// What policies see and mutate between iterations.
pub struct PolicyCtx<'a> {
    pub tasks: &'a mut Vec<TaskState>,
    pub iter: usize,
    pub net: &'a NetworkModel,
    /// Bytes moved between tasks this boundary (the trainer charges the
    /// transfer model for them in measured-time mode).
    pub moved_bytes: usize,
    /// Chunks moved this boundary (diagnostics).
    pub moved_chunks: usize,
    /// Which chunk payloads each live transport member already hosts
    /// (shared with the session's [`crate::transport::ChannelGroup`]):
    /// [`PolicyCtx::move_chunk`] prices moves to a warm destination as
    /// state-only transfers.
    pub residency: Residency,
    /// Deterministic per-boundary randomness.
    pub rng: &'a mut crate::util::Rng,
}

impl<'a> PolicyCtx<'a> {
    /// Move one chunk `cid` from task `from` to task `to`, charging the
    /// transfer accounting.
    ///
    /// The in-process move is zero-copy (the `Chunk` value moves between
    /// stores; its payload stays one `Arc` allocation). The *virtual*
    /// charge reads payload residency from transport membership: a
    /// destination node that already hosts this chunk's immutable payload
    /// (it held the chunk before and never left the group) pays the warm
    /// [`ChunkBytes`] state-only cost, anyone else pays the cold payload
    /// + state cost — the same split [`NetworkModel::chunk_cost`] prices.
    /// Residency is a pure function of the movement and membership
    /// history, so the priced vtime trajectory stays deterministic.
    pub fn move_chunk(&mut self, from: usize, to: usize, cid: crate::chunks::ChunkId) -> Result<()> {
        let chunk = self.tasks[from]
            .store
            .remove(cid)
            .ok_or_else(|| anyhow::anyhow!("chunk {cid} not on task {from}"))?;
        let dest = self.tasks[to].node.id;
        let warm = self.residency.resident(dest, cid);
        self.moved_bytes += ChunkBytes::of(&chunk).wire_bytes(warm);
        self.moved_chunks += 1;
        self.residency.record(dest, cid);
        self.tasks[to].store.add(chunk);
        Ok(())
    }
}

/// A between-iterations scheduling policy.
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    fn apply(&mut self, ctx: &mut PolicyCtx) -> Result<()>;
}
