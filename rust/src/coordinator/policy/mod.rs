//! The policy framework (paper §4.5).
//!
//! Policies run *between* iterations — the window in which the scheduler
//! owns the data chunks (the uni-tasks ownership contract, §3) — and may
//! move chunks between tasks through [`PolicyCtx`]. Each enabled policy is
//! consulted every iteration in registration order.

pub mod elastic;
pub mod rebalance;
pub mod shuffle;
pub mod straggler;

pub use elastic::{deal_round_robin, redistribute_for_new_tasks};
pub use rebalance::RebalancePolicy;
pub use shuffle::ShufflePolicy;
pub use straggler::StragglerPolicy;

use crate::chunks::NetworkModel;
use crate::coordinator::task::TaskState;
use crate::Result;

/// What policies see and mutate between iterations.
pub struct PolicyCtx<'a> {
    pub tasks: &'a mut Vec<TaskState>,
    pub iter: usize,
    pub net: &'a NetworkModel,
    /// Bytes moved between tasks this boundary (the trainer charges the
    /// transfer model for them in measured-time mode).
    pub moved_bytes: usize,
    /// Chunks moved this boundary (diagnostics).
    pub moved_chunks: usize,
    /// Deterministic per-boundary randomness.
    pub rng: &'a mut crate::util::Rng,
}

impl<'a> PolicyCtx<'a> {
    /// Move one chunk `cid` from task `from` to task `to`, charging the
    /// transfer accounting.
    ///
    /// The in-process move is zero-copy (the `Chunk` value moves between
    /// stores; its payload stays one `Arc` allocation), but the *virtual*
    /// accounting deliberately charges a cold transfer (`size_bytes`, not
    /// the warm [`crate::chunks::ChunkBytes`] state-only cost): in the
    /// modeled cluster the destination node has never seen this chunk's
    /// payload, and keeping the charge deterministic keeps vtime
    /// trajectories reproducible. Schedulers that track payload residency
    /// can price warm moves with [`NetworkModel::chunk_cost`].
    pub fn move_chunk(&mut self, from: usize, to: usize, cid: crate::chunks::ChunkId) -> Result<()> {
        let chunk = self.tasks[from]
            .store
            .remove(cid)
            .ok_or_else(|| anyhow::anyhow!("chunk {cid} not on task {from}"))?;
        self.moved_bytes += chunk.size_bytes();
        self.moved_chunks += 1;
        self.tasks[to].store.add(chunk);
        Ok(())
    }
}

/// A between-iterations scheduling policy.
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    fn apply(&mut self, ctx: &mut PolicyCtx) -> Result<()>;
}
