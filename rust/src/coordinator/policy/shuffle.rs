//! Background global shuffling policy (paper §4.5 "Other policies").
//!
//! Every `every` iterations, each task donates a few randomly-picked
//! chunks to randomly-picked peers. This continuously re-mixes sample
//! placement, which helps local solvers (CoCoA) discover correlations
//! beyond their initial partition without a global reshuffle barrier.

use anyhow::Result;

use super::{Policy, PolicyCtx};

pub struct ShufflePolicy {
    every: usize,
    /// Chunks each task donates per shuffle round.
    per_task: usize,
}

impl ShufflePolicy {
    pub fn new(every: usize, per_task: usize) -> Self {
        ShufflePolicy { every: every.max(1), per_task: per_task.max(1) }
    }
}

impl Policy for ShufflePolicy {
    fn name(&self) -> &'static str {
        "shuffle"
    }

    fn apply(&mut self, ctx: &mut PolicyCtx) -> Result<()> {
        if ctx.tasks.len() < 2 || ctx.iter == 0 || ctx.iter % self.every != 0 {
            return Ok(());
        }
        let n = ctx.tasks.len();
        // Collect (from, chunk) donations first to avoid borrow juggling.
        let mut moves = Vec::new();
        for from in 0..n {
            for _ in 0..self.per_task {
                let ids = ctx.tasks[from].store.chunk_ids();
                if ids.len() <= 1 {
                    break;
                }
                let cid = ids[ctx.rng.below(ids.len())];
                let mut to = ctx.rng.below(n - 1);
                if to >= from {
                    to += 1;
                }
                moves.push((from, to, cid));
                // Mark as moved by actually moving now (ids refresh above).
                ctx.move_chunk(from, to, cid)?;
            }
        }
        let _ = moves;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunks::{Chunk, NetworkModel, Samples};
    use crate::cluster::NodeSpec;
    use crate::coordinator::task::TaskState;
    use crate::util::Rng;

    fn tasks(n_tasks: usize, chunks_each: usize) -> Vec<TaskState> {
        let mut id = 0u32;
        (0..n_tasks)
            .map(|i| {
                let mut t = TaskState::new(NodeSpec::new(i as u32, 1.0), 3);
                for _ in 0..chunks_each {
                    let mut c = Chunk::new(
                        id,
                        Samples::DenseBinary {
                            x: vec![0.0; 8],
                            dim: 2,
                            y: vec![1.0; 4],
                        },
                        vec![0; 4],
                    );
                    c.init_state();
                    t.store.add(c);
                    id += 1;
                }
                t
            })
            .collect()
    }

    #[test]
    fn shuffles_on_schedule_and_conserves_chunks() {
        let mut ts = tasks(4, 5);
        let net = NetworkModel::default();
        let mut rng = Rng::seed_from_u64(0);
        let mut p = ShufflePolicy::new(3, 1);
        let mut total_moved = 0;
        for iter in 0..7 {
            let mut ctx = PolicyCtx {
                tasks: &mut ts,
                iter,
                net: &net,
                moved_bytes: 0,
                moved_chunks: 0,
                residency: crate::transport::Residency::default(),
                rng: &mut rng,
            };
            p.apply(&mut ctx).unwrap();
            if iter % 3 == 0 && iter > 0 {
                assert!(ctx.moved_chunks > 0, "iter {iter} should shuffle");
            } else {
                assert_eq!(ctx.moved_chunks, 0, "iter {iter} should not shuffle");
            }
            total_moved += ctx.moved_chunks;
        }
        assert!(total_moved >= 8, "{total_moved}");
        let total: usize = ts.iter().map(|t| t.n_chunks()).sum();
        assert_eq!(total, 20);
        let mut ids: Vec<u32> = ts.iter().flat_map(|t| t.store.chunk_ids()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..20).collect::<Vec<u32>>());
    }
}
