//! The trainer: Chicle's central driver (paper §4.1–§4.2).
//!
//! Each iteration is barrier-synchronous:
//!
//! 1. Poll the resource manager at the current virtual time and apply
//!    elastic events (uni-tasks mode): spawn tasks on newly assigned
//!    nodes, drain and redistribute chunks from revoked ones.
//! 2. Run the between-iteration policies (rebalance / shuffle /
//!    straggler) — the window where the scheduler owns the chunks.
//! 3. Execute one solver iteration on every task concurrently.
//! 4. Merge task updates into the shared model (weighted per eq. 2).
//! 5. Account time: the paper's projection model (§5.3) or measured
//!    wallclock scaled by node speed; record swimlane spans.
//! 6. Evaluate the convergence metric on schedule and log the iteration.
//!
//! Micro-task emulation (§5.1 "Micro-tasks") keeps K fixed task states
//! regardless of node count and projects iteration time with the wave
//! model; convergence per epoch then only depends on K, exactly as the
//! paper argues.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, NetworkModel};
use crate::cluster::{NodeSpec, ResourceEvent, ResourceManager, TraceResourceManager};
use crate::config::{SessionConfig, TaskModel, TimeModel};
use crate::metrics::{IterationRecord, Metric, MetricsLog, SwimlaneRecorder, TaskSpan};
use crate::sim::{microtask_iteration_time, VirtualClock};
use crate::util::Rng;

use super::policy::{
    deal_round_robin, redistribute_for_new_tasks, Policy, PolicyCtx, RebalancePolicy,
    ShufflePolicy, StragglerPolicy,
};
use super::task::TaskState;

/// The central driver.
pub struct Trainer {
    cfg: SessionConfig,
    algo: Arc<dyn Algorithm>,
    tasks: Vec<TaskState>,
    rm: TraceResourceManager,
    clock: VirtualClock,
    net: NetworkModel,
    policies: Vec<Box<dyn Policy>>,
    rng: Rng,
    n_total: usize,
    cum_samples: usize,
    eval_every: usize,
    pub metrics: MetricsLog,
    pub swimlanes: SwimlaneRecorder,
    model: ModelVec,
}

impl Trainer {
    /// Build a trainer from config + algorithm + the dataset's chunks.
    pub fn new(
        cfg: SessionConfig,
        algo: Arc<dyn Algorithm>,
        mut chunks: Vec<Chunk>,
    ) -> Result<Self> {
        let rm = cfg.elastic.build_rm();
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let n_total: usize = chunks.iter().map(|c| c.n_samples()).sum();

        // Initial task set.
        let window = cfg.policies.rebalance_window;
        let mut tasks: Vec<TaskState> = match cfg.task_model {
            TaskModel::UniTasks => rm
                .assigned()
                .iter()
                .map(|n| TaskState::new(n.clone(), window))
                .collect(),
            TaskModel::MicroTasks { k } => (0..k)
                .map(|i| TaskState::new(NodeSpec::new(i as u32, 1.0), window))
                .collect(),
        };
        anyhow::ensure!(!tasks.is_empty(), "no tasks at t=0");

        // Initial chunk placement. RandomChunks = Chicle's random
        // assignment; Contiguous = the Snap-ML-style split (paper §A.1).
        match cfg.partitioning {
            crate::config::Partitioning::RandomChunks => rng.shuffle(&mut chunks),
            crate::config::Partitioning::Contiguous => {
                chunks.sort_by_key(|c| c.id);
            }
        }
        let k = tasks.len();
        match cfg.partitioning {
            crate::config::Partitioning::RandomChunks => {
                // Random chunk→task placement, balanced by sample count:
                // each (shuffled) chunk goes to the task currently holding
                // the fewest samples. Deliberately speed-agnostic — node
                // speeds are unknown a priori; the rebalance policy learns
                // them from iteration timings (paper §4.5).
                for chunk in chunks {
                    let t = tasks
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, task)| task.n_samples())
                        .map(|(i, _)| i)
                        .unwrap();
                    tasks[t].store.add(chunk);
                }
            }
            crate::config::Partitioning::Contiguous => {
                let n = chunks.len();
                for (i, chunk) in chunks.into_iter().enumerate() {
                    // Contiguous blocks of ceil(n/k) chunks per task.
                    let per = n.div_ceil(k);
                    tasks[(i / per).min(k - 1)].store.add(chunk);
                }
            }
        }

        let mut policies: Vec<Box<dyn Policy>> = Vec::new();
        if matches!(cfg.task_model, TaskModel::UniTasks) {
            if cfg.policies.rebalance {
                policies.push(Box::new(RebalancePolicy::new(cfg.policies.rebalance_step)));
            }
            if cfg.policies.shuffle {
                policies.push(Box::new(ShufflePolicy::new(cfg.policies.shuffle_every, 1)));
            }
            if cfg.policies.straggler {
                policies.push(Box::new(StragglerPolicy::new(cfg.policies.straggler_factor, 2)));
            }
        }

        let eval_every = match &cfg.algo {
            crate::config::AlgoConfig::Cocoa(_) => 1,
            crate::config::AlgoConfig::Lsgd(l) => l.eval_every.max(1),
        };

        let model = algo.init_model()?;
        Ok(Trainer {
            cfg,
            algo,
            tasks,
            rm,
            clock: VirtualClock::new(),
            net: NetworkModel::default(),
            policies,
            rng,
            n_total,
            cum_samples: 0,
            eval_every,
            metrics: MetricsLog::new(),
            swimlanes: SwimlaneRecorder::new(),
            model,
        })
    }

    pub fn model(&self) -> &ModelVec {
        &self.model
    }

    pub fn tasks(&self) -> &[TaskState] {
        &self.tasks
    }

    pub fn epochs(&self) -> f64 {
        self.cum_samples as f64 / self.n_total.max(1) as f64
    }

    /// Current active node set (for projections): assigned nodes in
    /// uni-tasks mode, the RM's current allocation in micro-task mode.
    fn current_nodes(&self) -> Vec<NodeSpec> {
        match self.cfg.task_model {
            TaskModel::UniTasks => self.tasks.iter().map(|t| t.node.clone()).collect(),
            TaskModel::MicroTasks { .. } => self.rm.assigned().to_vec(),
        }
    }

    /// Apply pending resource-manager events (uni-tasks only). Returns
    /// bytes moved for transfer accounting.
    fn handle_elasticity(&mut self) -> Result<usize> {
        if !matches!(self.cfg.task_model, TaskModel::UniTasks) {
            return Ok(0);
        }
        let events = self.rm.poll(self.clock.now());
        if events.is_empty() {
            return Ok(0);
        }
        let mut moved = 0usize;
        for ev in events {
            match ev {
                ResourceEvent::RevokeNotice(ids) => {
                    let mut orphans: Vec<Chunk> = Vec::new();
                    self.tasks.retain_mut(|t| {
                        if ids.contains(&t.node.id) {
                            orphans.extend(t.store.drain());
                            false
                        } else {
                            true
                        }
                    });
                    anyhow::ensure!(!self.tasks.is_empty(), "all nodes revoked");
                    moved += deal_round_robin(&mut self.tasks, orphans);
                }
                ResourceEvent::Assigned(nodes) => {
                    let window = self.cfg.policies.rebalance_window;
                    for n in nodes {
                        self.tasks.push(TaskState::new(n, window));
                    }
                    moved += redistribute_for_new_tasks(&mut self.tasks, &mut self.rng);
                }
            }
        }
        // Loads changed; learned runtimes are stale.
        for t in &mut self.tasks {
            t.clear_history();
        }
        Ok(moved)
    }

    /// Execute one full training iteration. Returns the evaluated metric
    /// if this iteration was an evaluation point.
    pub fn step(&mut self, iter: usize) -> Result<Option<Metric>> {
        // 1. Elasticity.
        let mut moved_bytes = self.handle_elasticity()?;

        // 2. Policies (scheduler owns chunks between iterations).
        for p in &mut self.policies {
            let mut ctx = PolicyCtx {
                tasks: &mut self.tasks,
                iter,
                net: &self.net,
                moved_bytes: 0,
                moved_chunks: 0,
                rng: &mut self.rng,
            };
            p.apply(&mut ctx)?;
            moved_bytes += ctx.moved_bytes;
        }

        // 3. Execute all tasks concurrently (barrier at scope end).
        let k = self.tasks.len();
        let algo = Arc::clone(&self.algo);
        let model_ref = &self.model;
        let base_seed = self
            .cfg
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(iter as u64);
        let results: Vec<Result<(LocalUpdate, Duration)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .tasks
                .iter_mut()
                .enumerate()
                .map(|(t, task)| {
                    let algo = Arc::clone(&algo);
                    scope.spawn(move || -> Result<(LocalUpdate, Duration)> {
                        if task.store.n_samples() == 0 {
                            return Ok((
                                LocalUpdate {
                                    delta: vec![0.0; algo.model_len()],
                                    samples: 0,
                                    loss_sum: 0.0,
                                },
                                Duration::ZERO,
                            ));
                        }
                        let t0 = Instant::now();
                        let upd = algo.task_iterate(
                            task.store.chunks_mut(),
                            model_ref,
                            k,
                            base_seed.wrapping_add((t as u64) << 32),
                            None,
                        )?;
                        Ok((upd, t0.elapsed()))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("task thread panicked"))
                .collect()
        });
        let mut updates = Vec::with_capacity(k);
        let mut walls = Vec::with_capacity(k);
        for r in results {
            let (u, w) = r?;
            walls.push(w);
            updates.push(u);
        }

        // 4. Merge.
        self.algo.merge(&mut self.model, &updates, k);

        // 5. Time accounting.
        let unit = self.algo.unit_samples(self.n_total, self.cfg.ref_nodes);
        let nodes = self.current_nodes();
        let start = self.clock.now();
        let mut task_times: Vec<f64> = Vec::with_capacity(k);
        for ((task, upd), wall) in self.tasks.iter_mut().zip(&updates).zip(&walls) {
            let t = match self.cfg.time_model {
                TimeModel::Projected => (upd.samples as f64 / unit) / task.node.speed,
                TimeModel::Measured => wall.as_secs_f64() / task.node.speed,
            };
            task_times.push(t);
            if upd.samples > 0 {
                task.record_time(t / upd.samples as f64);
            }
        }
        let iteration_time = match self.cfg.task_model {
            TaskModel::UniTasks => task_times.iter().cloned().fold(0.0, f64::max),
            TaskModel::MicroTasks { k } => {
                // Wave model over the *current* node allocation: each task
                // is one unit of work of the largest observed size.
                let task_units = task_times.iter().cloned().fold(0.0, f64::max);
                microtask_iteration_time(k, task_units * k as f64, &nodes)
            }
        };
        let transfer_time = match self.cfg.time_model {
            // The paper's projections exclude transfer overheads
            // (§5.3: "this favors micro-tasks").
            TimeModel::Projected => 0.0,
            TimeModel::Measured => self.net.transfer_cost(moved_bytes).as_secs_f64(),
        };

        // 6. Swimlanes (uni-tasks; micro-task waves aren't per-node).
        if matches!(self.cfg.task_model, TaskModel::UniTasks) {
            for (task, (t, upd)) in self
                .tasks
                .iter()
                .zip(task_times.iter().zip(&updates))
            {
                self.swimlanes.record(TaskSpan {
                    node: task.node.id,
                    iter,
                    start,
                    end: start + Duration::from_secs_f64(*t),
                    n_chunks: task.n_chunks(),
                    n_samples: upd.samples,
                });
            }
        }

        self.clock
            .advance(Duration::from_secs_f64(iteration_time + transfer_time));
        let iter_samples: usize = updates.iter().map(|u| u.samples).sum();
        self.cum_samples += iter_samples;

        // 7. Evaluate + record.
        let metric = if iter % self.eval_every == 0 {
            let all: Vec<&Chunk> = self
                .tasks
                .iter()
                .flat_map(|t| t.store.iter())
                .collect();
            Some(self.algo.evaluate(&self.model, &all)?)
        } else {
            None
        };
        let loss_sum: f64 = updates.iter().map(|u| u.loss_sum).sum();
        let steps: usize = updates.iter().filter(|u| u.samples > 0).count();
        self.metrics.push(IterationRecord {
            iter,
            epochs: self.epochs(),
            metric,
            vtime: self.clock.now(),
            wall: walls.iter().copied().max().unwrap_or(Duration::ZERO),
            n_tasks: k,
            samples: iter_samples,
            train_loss: if steps > 0 { Some(loss_sum / steps as f64) } else { None },
        });
        Ok(metric)
    }

    /// Run to completion: stops at `max_iters`, `max_epochs`, or when the
    /// algorithm's convergence target is reached.
    pub fn run(&mut self) -> Result<&MetricsLog> {
        let target = self.algo.target();
        for iter in 0..self.cfg.max_iters {
            let metric = self.step(iter)?;
            if self.epochs() >= self.cfg.max_epochs {
                break;
            }
            if let (Some(m), Some(t)) = (metric, target) {
                if m.reached(t) {
                    break;
                }
            }
        }
        Ok(&self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Backend, CocoaAlgo};
    use crate::chunks::chunker::make_chunks;
    use crate::config::{CocoaConfig, ElasticSpec, SessionConfig};
    use crate::data::synth;

    fn cocoa_trainer(cfg: SessionConfig, n: usize) -> Trainer {
        let ds = synth::higgs_like(n, 5);
        let chunks = make_chunks(&ds, 8 * 1024);
        let algo = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            ds.n_samples(),
            ds.dim(),
        ));
        Trainer::new(cfg, algo, chunks).unwrap()
    }

    #[test]
    fn rigid_cocoa_converges() {
        let mut cfg = SessionConfig::cocoa("t", 4);
        cfg.max_iters = 30;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        let gap = tr.metrics.last_gap().unwrap();
        assert!(gap < 0.01, "gap {gap}");
        // One full local pass per task per iteration → 1 epoch/iteration.
        assert!((tr.metrics.records[0].epochs - 1.0).abs() < 1e-9);
    }

    #[test]
    fn projected_time_matches_wave_model_rigid() {
        let mut cfg = SessionConfig::cocoa("t", 4);
        cfg.ref_nodes = 16;
        cfg.max_iters = 3;
        let mut tr = cocoa_trainer(cfg, 1600);
        tr.run().unwrap();
        // 4 nodes, 16-node normalization → 16/4 = 4 units/iteration,
        // up to one chunk (~66 samples / 100 units) of placement slack.
        let t0 = tr.metrics.records[0].vtime.as_secs_f64();
        assert!(t0 >= 4.0 - 1e-9 && t0 < 4.8, "{t0}");
    }

    #[test]
    fn elastic_scale_out_adds_tasks() {
        let mut cfg = SessionConfig::cocoa("t", 2).with_elastic(ElasticSpec::Gradual {
            from: 2,
            to: 8,
            interval_s: 10.0,
        });
        cfg.max_iters = 20;
        cfg.policies.rebalance = true;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 8);
        // Chunks conserved across all the redistribution.
        let total: usize = tr.tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, 2000);
        // n_tasks in the log should be non-decreasing 2 → 8.
        let firsts = tr.metrics.records.first().unwrap().n_tasks;
        let lasts = tr.metrics.records.last().unwrap().n_tasks;
        assert_eq!(firsts, 2);
        assert_eq!(lasts, 8);
    }

    #[test]
    fn elastic_scale_in_removes_tasks_conserving_chunks() {
        let mut cfg = SessionConfig::cocoa("t", 8).with_elastic(ElasticSpec::Gradual {
            from: 8,
            to: 2,
            interval_s: 5.0,
        });
        cfg.max_iters = 25;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 2);
        let total: usize = tr.tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, 2000);
    }

    #[test]
    fn microtask_mode_keeps_k_constant() {
        let mut cfg = SessionConfig::cocoa("t", 4)
            .with_microtasks(16)
            .with_elastic(ElasticSpec::Gradual { from: 8, to: 2, interval_s: 5.0 });
        cfg.max_iters = 10;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 16);
        assert!(tr.metrics.records.iter().all(|r| r.n_tasks == 16));
    }

    #[test]
    fn heterogeneous_rebalance_aligns_runtimes() {
        let mut cfg = SessionConfig::cocoa("t", 4).with_elastic(ElasticSpec::Heterogeneous {
            fast: 2,
            slow: 2,
            factor: 2.0,
        });
        cfg.max_iters = 25;
        cfg.policies.rebalance = true;
        cfg.policies.rebalance_step = 8;
        let mut tr = cocoa_trainer(cfg, 4000);
        tr.run().unwrap();
        // After rebalancing, the last iteration should be far better
        // balanced than the first.
        let first = tr.swimlanes.imbalance(0).unwrap();
        let last_iter = tr.swimlanes.n_iterations() - 1;
        let last = tr.swimlanes.imbalance(last_iter).unwrap();
        assert!(first > 1.8, "first iteration imbalance {first}");
        assert!(last < first, "imbalance {first} -> {last}");
        assert!(last < 1.4, "final imbalance {last}");
    }
}
