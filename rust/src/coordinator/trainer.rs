//! The trainer: Chicle's central driver (paper §4.1–§4.2).
//!
//! Each iteration is barrier-synchronous and runs as an explicit phase
//! pipeline over the persistent [`crate::exec`] worker runtime:
//!
//! 1. **elasticity** — poll the resource manager at the current virtual
//!    time and apply elastic events (uni-tasks mode): spawn a persistent
//!    worker on each newly assigned node, drain-then-shutdown revoked
//!    workers through the executor's command protocol and redistribute
//!    their chunks.
//! 2. **policies** — run the between-iteration policies (rebalance /
//!    shuffle / straggler) — the window where the scheduler owns the
//!    chunks.
//! 3. **execute** — dispatch one `RunIteration` command to every resident
//!    worker and collect the `LocalUpdate`s in task order.
//! 4. **merge** — fold task updates into the shared model (weighted per
//!    eq. 2). Small models are folded serially in place via
//!    `Arc::make_mut`; large models are reduced *in parallel* by a
//!    work-stealing sharded fan-out over the same worker pool
//!    (`WorkerPool::begin_reduce`) — bit-identical to the serial fold by
//!    the `Algorithm::merge_shard` elementwise contract, however the
//!    shards interleave.
//! 5. **account** — the paper's projection model (§5.3) or measured
//!    wallclock scaled by node speed ([`super::timing`]); the merge phase
//!    is charged as a tree reduce under the network model; record swimlane
//!    spans.
//! 6. **evaluate** — compute the convergence metric on schedule and log
//!    the iteration.
//!
//! ## Reduce/dispatch overlap — spanning eval points
//!
//! The trainer *pipelines* the merge with the next iteration: after
//! accounting for iteration `i` it runs iteration `i+1`'s boundary phases
//! (elasticity + policies — the workers are idle, so the scheduler owns
//! the chunks), then enqueues the work-stealing reduction of `i`'s
//! updates and, right behind it, iteration `i+1`'s `RunIteration` against
//! the *pending* merge buffer ([`crate::exec::ModelRef::Pending`]). Each
//! worker finishes its share of the merge and starts computing the
//! instant the last shard lands — no coordinator round-trip on the
//! critical path — while the coordinator logs iteration `i` in the shadow
//! of the pipeline.
//!
//! Evaluation points do **not** break the pipeline. The metric needs a
//! consistent `(model, chunk-state)` snapshot, and the overlapped
//! schedule provides one without a flush: the chunk state the evaluator
//! reads is snapshotted *before* the next boundary phases run (if the
//! algorithm's `evaluate` reads chunks at all — see
//! [`Algorithm::eval_reads_chunks`]), the merged model is read straight
//! out of the completed [`ReduceBuf`] the moment its shard countdown
//! reaches zero, and the evaluation runs on the coordinator while the
//! workers are already computing iteration `i+1` on the live buffer.
//! The snapshot is *state-only* — `Chunk::clone` shares the immutable
//! payload by `Arc` and copies just the per-sample state — so even
//! chunk-reading evaluators on large datasets (CoCoA) pay O(per-sample
//! state), not O(dataset), and take the overlapped path. One economic
//! exception remains: an algorithm whose per-sample *state* dwarfs both
//! its model and its sample data falls back to the barriered,
//! snapshot-free schedule — see `eval_overlap_affordable`.
//!
//! The iterate trajectory is *identical* to the barriered schedule: the
//! boundary phases run at the same virtual time, consume the RNG in the
//! same order, the merged model is bit-identical, and the eval snapshot
//! preserves both the content and the accumulation order the barriered
//! evaluation would see (see `tests/overlap_pipeline.rs`, which pins
//! trajectory equality through eval points and elastic resizes). The one
//! stop condition the pipeline cannot see coming — the metric reaching
//! its convergence target — is settled by [`Trainer::run`] draining the
//! speculative in-flight iteration.
//!
//! Micro-task emulation (§5.1 "Micro-tasks") keeps K fixed task states
//! (each with its own resident worker) regardless of node count and
//! projects iteration time with the wave model; convergence per epoch then
//! only depends on K, exactly as the paper argues.
//!
//! The *decoupled schedule* (`SessionConfig::logical_tasks` = K > 0,
//! uni-tasks mode) goes further: K logical uni-tasks are a session
//! constant, worker threads are interchangeable hosts, and elasticity
//! resizes the thread count W — rebinding task→thread assignments
//! round-robin — while the iterate trajectory stays bit-identical at
//! fixed K for any 1 ≤ W ≤ K, mid-run resizes included (see
//! `docs/ARCHITECTURE.md`, "Logical-task multiplexing", and
//! `tests/logical_tasks.rs`, which pins the W-sweep).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::chunks::{Chunk, NetworkModel};
use crate::cluster::{NodeId, NodeSpec, ResourceEvent, ResourceManager, TraceResourceManager};
use crate::config::{MergeStrategy, Partitioning, SessionConfig, TaskModel};
use crate::exec::{
    ModelRef, PendingIteration, ReduceBuf, ReduceOptions, TaskRun, TaskSlot, WorkerPool,
};
use crate::metrics::{IterationRecord, Metric, MetricsLog, SwimlaneRecorder, TaskSpan};
use crate::sim::VirtualClock;
use crate::transport::AllreduceKind;
use crate::util::Rng;

use super::policy::{
    deal_round_robin, redistribute_for_new_tasks, Policy, PolicyCtx, RebalancePolicy,
    ShufflePolicy, StragglerPolicy,
};
use super::task::TaskState;
use super::timing::{IterationTiming, TimeAccountant};

/// Minimum model length for fanning the merge out over the worker pool.
/// Below this the serial fold wins: one `ReduceShards` round-trip costs
/// tens of microseconds of dispatch, which only pays for itself once the
/// per-shard arithmetic dominates (NN-scale models; CoCoA's GLM vectors
/// stay serial).
const PARALLEL_MERGE_MIN_LEN: usize = 1 << 15;

/// Largest eval snapshot the eval-spanning overlap will pay for, as a
/// multiple of the bytes the evaluation streams anyway (model + chunk
/// payloads). The snapshot is *state-only* — `Chunk::clone` shares the
/// immutable payload by `Arc` and copies just the per-sample state — so
/// for CoCoA-style evaluators (state ≪ payload) it is always affordable
/// and large-dataset sessions now take the overlapped eval path. The
/// gate survives as a guard for pathological algorithms whose per-sample
/// state dwarfs both their model and their sample data: there the serial
/// state memcpy on the dispatch path can exceed the flush the overlap
/// avoids, and the barriered, snapshot-free schedule wins. Algorithms
/// whose evaluate ignores chunks (lSGD) never pay a snapshot and are
/// unaffected.
const EVAL_SNAPSHOT_MAX_RATIO: usize = 4;

/// What one merge phase reports back to `step` for the metrics log,
/// whichever strategy ran it.
struct MergeReport {
    /// Wall of the merge (serial fold, sharded reduce, or collective; for
    /// a pipelined iteration, the reduce-in-flight window).
    merge_wall: Duration,
    /// Shards claimed outside their home block during a coordinator
    /// reduction (0 for collectives and serial folds).
    steal_count: usize,
    /// Shard granularity a coordinator reduction used (0 otherwise).
    spw: usize,
    /// *Measured* sequential transport rounds of a merge collective —
    /// recorded next to the *simulated* `exchange_time` vtime charge,
    /// never folded into it (0 under the coordinator strategy).
    transport_rounds: usize,
    /// Payload bytes the collective put on the wire, all ranks summed.
    transport_bytes: usize,
    /// Non-payload framing bytes the transport backend added (length
    /// prefixes, tags, handshakes), all ranks summed. Zero for the
    /// in-process channel backend and for the coordinator strategy.
    transport_frame_bytes: usize,
}

/// What one engagement of the overlap pipeline reports back to `step`.
struct PipelineOutcome {
    report: MergeReport,
    /// How long the next iteration was in flight while the coordinator
    /// collected the reduce and (at eval points) ran the evaluation.
    overlap_wall: Duration,
    /// The metric, when this was an overlapped evaluation point.
    metric: Option<Metric>,
}

/// A pipelined iteration in flight: iteration `iter`'s `RunIteration`
/// commands are queued behind the previous iteration's reduction.
struct PendingStep {
    iter: usize,
    iteration: PendingIteration,
    /// The merge output buffer iteration `iter` is running against.
    buf: Arc<ReduceBuf>,
    /// Boundary bytes (elasticity + policies) already moved for `iter`.
    moved_bytes: usize,
}

/// The central driver.
pub struct Trainer {
    cfg: SessionConfig,
    algo: Arc<dyn Algorithm>,
    tasks: Vec<TaskState>,
    /// The persistent uni-task executor: one resident worker per task
    /// under the legacy coupling, one per *thread* (hosting a set of
    /// logical tasks) under the decoupled schedule.
    pool: WorkerPool,
    /// Decoupled schedule only (`cfg.decoupled_tasks()`): the live worker
    /// threads, in resource-manager assignment order. Empty under the
    /// legacy coupling and micro-task emulation.
    threads: Vec<NodeId>,
    /// Decoupled schedule only: `assignment[i]` is the thread currently
    /// hosting logical task `i`. Rebound round-robin over `threads` after
    /// every elastic event; rebinds move *bindings*, never chunks — the
    /// stores are shared `Arc`s between the trainer and the workers.
    assignment: Vec<NodeId>,
    rm: TraceResourceManager,
    clock: VirtualClock,
    net: NetworkModel,
    policies: Vec<Box<dyn Policy>>,
    timing: TimeAccountant,
    rng: Rng,
    n_total: usize,
    cum_samples: usize,
    eval_every: usize,
    /// Overlapped next iteration, if the pipeline is engaged.
    pending: Option<PendingStep>,
    pub metrics: MetricsLog,
    pub swimlanes: SwimlaneRecorder,
    /// Shared model, published to workers as a snapshot each iteration.
    model: Arc<ModelVec>,
}

impl Trainer {
    /// Build a trainer from config + algorithm + the dataset's chunks.
    pub fn new(
        cfg: SessionConfig,
        algo: Arc<dyn Algorithm>,
        mut chunks: Vec<Chunk>,
    ) -> Result<Self> {
        let rm = cfg.elastic.build_rm();
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let n_total: usize = chunks.iter().map(|c| c.n_samples()).sum();

        // Initial task set. Under the decoupled schedule the K logical
        // tasks get *synthetic* unit-speed specs: virtual time projects
        // per logical task (`super::timing`), so unit speeds make the
        // vtime trajectory a function of K alone — bit-identical at any
        // worker-thread count W, which is the whole point.
        let window = cfg.policies.rebalance_window;
        let tasks: Vec<TaskState> = if let Some(k) = cfg.decoupled_tasks() {
            (0..k)
                .map(|i| TaskState::new(NodeSpec::new(i as u32, 1.0), window))
                .collect()
        } else {
            match cfg.task_model {
                TaskModel::UniTasks => rm
                    .assigned()
                    .iter()
                    .map(|n| TaskState::new(n.clone(), window))
                    .collect(),
                TaskModel::MicroTasks { k } => (0..k)
                    .map(|i| TaskState::new(NodeSpec::new(i as u32, 1.0), window))
                    .collect(),
            }
        };
        anyhow::ensure!(!tasks.is_empty(), "no tasks at t=0");

        // Initial chunk placement. RandomChunks = Chicle's random
        // assignment; Contiguous = the Snap-ML-style split (paper §A.1).
        let k = tasks.len();
        match cfg.partitioning {
            Partitioning::RandomChunks => {
                rng.shuffle(&mut chunks);
                // Random chunk→task placement, balanced by sample count:
                // each (shuffled) chunk goes to the task currently holding
                // the fewest samples. Deliberately speed-agnostic — node
                // speeds are unknown a priori; the rebalance policy learns
                // them from iteration timings (paper §4.5).
                for chunk in chunks {
                    let t = tasks
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, task)| task.n_samples())
                        .map(|(i, _)| i)
                        .unwrap();
                    tasks[t].store.add(chunk);
                }
            }
            Partitioning::Contiguous => {
                chunks.sort_by_key(|c| c.id);
                // Contiguous blocks of ceil(n/k) chunks per task.
                let per = chunks.len().div_ceil(k);
                for (i, chunk) in chunks.into_iter().enumerate() {
                    tasks[(i / per).min(k - 1)].store.add(chunk);
                }
            }
        }

        let mut policies: Vec<Box<dyn Policy>> = Vec::new();
        if matches!(cfg.task_model, TaskModel::UniTasks) {
            if cfg.policies.rebalance {
                policies.push(Box::new(RebalancePolicy::new(cfg.policies.rebalance_step)));
            }
            if cfg.policies.shuffle {
                policies.push(Box::new(ShufflePolicy::new(cfg.policies.shuffle_every, 1)));
            }
            if cfg.policies.straggler {
                policies.push(Box::new(StragglerPolicy::new(cfg.policies.straggler_factor, 2)));
            }
        }

        let eval_every = match &cfg.algo {
            crate::config::AlgoConfig::Cocoa(_) => 1,
            crate::config::AlgoConfig::Lsgd(l) => l.eval_every.max(1),
        };

        // Task → thread multiplexing (decoupled schedule): the RM's
        // assigned nodes are worker *threads*, and logical task `i` is
        // dealt to thread `i mod W`. Legacy coupling keeps both empty.
        let (threads, assignment): (Vec<NodeId>, Vec<NodeId>) =
            if cfg.decoupled_tasks().is_some() {
                let threads: Vec<NodeId> = rm.assigned().iter().map(|n| n.id).collect();
                anyhow::ensure!(!threads.is_empty(), "no worker threads at t=0");
                let assignment =
                    (0..tasks.len()).map(|i| threads[i % threads.len()]).collect();
                (threads, assignment)
            } else {
                (Vec::new(), Vec::new())
            };

        // Bring up the persistent executor — one resident worker per task
        // (legacy), or one per thread hosting its dealt set of logical-
        // task contexts (decoupled) — sharing the tasks' chunk stores.
        let mut pool = WorkerPool::new_with_transport(Arc::clone(&algo), cfg.transport);
        if cfg.adaptive_spw {
            pool.enable_adaptive_spw(cfg.shards_per_worker.max(1));
        }
        if cfg.decoupled_tasks().is_some() {
            for &th in &threads {
                let hosted: Vec<_> = assignment
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| **a == th)
                    .map(|(i, _)| (i, tasks[i].store.clone()))
                    .collect();
                pool.spawn_worker_with_tasks(th, hosted);
            }
        } else {
            for task in &tasks {
                pool.spawn_worker(task.node.id, task.store.clone());
            }
        }
        // Seed the transport group's payload-residency map with the
        // initial placement: a chunk later moving back to its first home
        // is priced warm (state-only) by `PolicyCtx::move_chunk`. In the
        // decoupled schedule the group members are threads, so a hosted
        // chunk is resident on its task's current host.
        let residency = pool.residency();
        for (i, task) in tasks.iter().enumerate() {
            let home = assignment.get(i).copied().unwrap_or(task.node.id);
            for chunk in task.store.lock().iter() {
                residency.record(home, chunk.id);
            }
        }

        let model = Arc::new(algo.init_model()?);
        let timing = TimeAccountant::new(&cfg);
        Ok(Trainer {
            cfg,
            algo,
            tasks,
            pool,
            threads,
            assignment,
            rm,
            clock: VirtualClock::new(),
            net: NetworkModel::default(),
            policies,
            timing,
            rng,
            n_total,
            cum_samples: 0,
            eval_every,
            pending: None,
            metrics: MetricsLog::new(),
            swimlanes: SwimlaneRecorder::new(),
            model,
        })
    }

    pub fn model(&self) -> &ModelVec {
        &*self.model
    }

    pub fn tasks(&self) -> &[TaskState] {
        &self.tasks
    }

    pub fn epochs(&self) -> f64 {
        self.cum_samples as f64 / self.n_total.max(1) as f64
    }

    /// Current active node set (for projections): assigned nodes in
    /// uni-tasks mode, the RM's current allocation in micro-task mode.
    fn current_nodes(&self) -> Vec<NodeSpec> {
        match self.cfg.task_model {
            TaskModel::UniTasks => self.tasks.iter().map(|t| t.node.clone()).collect(),
            TaskModel::MicroTasks { .. } => self.rm.assigned().to_vec(),
        }
    }

    /// Phase 1 — apply pending resource-manager events (uni-tasks only):
    /// spawn a worker per assigned node, drain-then-shutdown revoked ones
    /// through the executor. Returns bytes moved for transfer accounting.
    fn phase_elasticity(&mut self) -> Result<usize> {
        if self.cfg.decoupled_tasks().is_some() {
            return self.phase_elasticity_decoupled();
        }
        if !matches!(self.cfg.task_model, TaskModel::UniTasks) {
            // Micro-task emulation keeps K fixed, but the RM must still
            // advance so the wave model projects over the *current* node
            // allocation rather than the t=0 snapshot.
            let _ = self.rm.poll(self.clock.now());
            return Ok(0);
        }
        let events = self.rm.poll(self.clock.now());
        if events.is_empty() {
            return Ok(0);
        }
        // Snapshot loads so only tasks whose load actually changes lose
        // their learned runtimes (tasks untouched by the event keep them,
        // letting the rebalance policy re-converge faster).
        let before: Vec<(NodeId, usize)> =
            self.tasks.iter().map(|t| (t.node.id, t.n_samples())).collect();
        let mut moved = 0usize;
        for ev in events {
            match ev {
                ResourceEvent::RevokeNotice(ids) => {
                    // Shut down every revoked worker before surfacing any
                    // failure: aborting halfway would drop the chunks
                    // already drained into `orphans`.
                    let mut orphans: Vec<Chunk> = Vec::new();
                    let mut shutdown_err = None;
                    for id in &ids {
                        if self.pool.has_worker(*id) {
                            match self.pool.shutdown_worker(*id) {
                                Ok(chunks) => orphans.extend(chunks),
                                Err(e) => shutdown_err = shutdown_err.or(Some(e)),
                            }
                        }
                    }
                    self.tasks.retain(|t| {
                        if ids.contains(&t.node.id) {
                            // The worker drain above already emptied the
                            // store; draining again conserves chunks even
                            // when the worker was gone or its drain failed.
                            orphans.extend(t.store.drain());
                            false
                        } else {
                            true
                        }
                    });
                    anyhow::ensure!(!self.tasks.is_empty(), "all nodes revoked");
                    moved += deal_round_robin(&mut self.tasks, orphans);
                    if let Some(e) = shutdown_err {
                        return Err(e);
                    }
                }
                ResourceEvent::Assigned(nodes) => {
                    let window = self.cfg.policies.rebalance_window;
                    for n in nodes {
                        let task = TaskState::new(n, window);
                        self.pool.spawn_worker(task.node.id, task.store.clone());
                        self.tasks.push(task);
                    }
                    moved += redistribute_for_new_tasks(&mut self.tasks, &mut self.rng);
                }
            }
        }
        // Refresh payload residency after the elastic moves: revoked
        // members were already forgotten when their endpoints left the
        // group (`shutdown_worker` joins the thread), and every orphan or
        // redistributed chunk now resides wherever the deal landed it.
        let residency = self.pool.residency();
        for t in &self.tasks {
            for chunk in t.store.lock().iter() {
                residency.record(t.node.id, chunk.id);
            }
        }
        // Loads changed on these tasks; their learned runtimes are stale.
        // (A task whose chunks net out to the same sample count keeps its
        // history — the per-sample estimate is still valid.)
        for t in &mut self.tasks {
            let prev = before
                .iter()
                .find(|(id, _)| *id == t.node.id)
                .map(|(_, n)| *n);
            if prev != Some(t.n_samples()) {
                t.clear_history();
            }
        }
        Ok(moved)
    }

    /// Phase 1, decoupled schedule: elastic events change the worker
    /// *thread* set W, never the logical task set K. A revoked thread is
    /// released without draining (its tasks' stores are shared with the
    /// trainer, so the chunks never move); a newly assigned thread starts
    /// empty; then every logical task is rebound round-robin over the
    /// surviving thread list. The whole phase moves zero bytes, consumes
    /// no RNG and touches no task state or history — which is exactly why
    /// the iterate trajectory at fixed K is bit-identical across any
    /// resize schedule of W.
    fn phase_elasticity_decoupled(&mut self) -> Result<usize> {
        let events = self.rm.poll(self.clock.now());
        if events.is_empty() {
            return Ok(0);
        }
        for ev in events {
            match ev {
                ResourceEvent::RevokeNotice(ids) => {
                    for id in &ids {
                        if self.pool.has_worker(*id) {
                            self.pool.release_worker(*id)?;
                        }
                        self.threads.retain(|t| t != id);
                    }
                    anyhow::ensure!(!self.threads.is_empty(), "all worker threads revoked");
                }
                ResourceEvent::Assigned(nodes) => {
                    // Thread speeds are irrelevant to vtime here (the
                    // projection runs over the synthetic unit-speed task
                    // specs); the id is all the pool needs.
                    for n in nodes {
                        self.pool.spawn_worker_with_tasks(n.id, Vec::new());
                        self.threads.push(n.id);
                    }
                }
            }
        }
        // Rebind task → thread, round-robin over the new thread list.
        // FIFO command ordering makes this race-free: the install lands
        // before any iteration dispatched after this phase.
        let w = self.threads.len();
        for i in 0..self.tasks.len() {
            let want = self.threads[i % w];
            if self.assignment[i] != want {
                let old = self.assignment[i];
                if self.pool.has_worker(old) {
                    self.pool.revoke_task(old, i)?;
                }
                self.pool.install_task(want, i, self.tasks[i].store.clone())?;
                // The task's payloads now reside on the new host (warm-
                // transfer pricing for any later policy move).
                let residency = self.pool.residency();
                for chunk in self.tasks[i].store.lock().iter() {
                    residency.record(want, chunk.id);
                }
                self.assignment[i] = want;
            }
        }
        Ok(0)
    }

    /// Phase 2 — between-iteration policies (scheduler owns the chunks).
    /// Returns bytes moved.
    fn phase_policies(&mut self, iter: usize) -> Result<usize> {
        let mut moved_bytes = 0usize;
        for p in &mut self.policies {
            let mut ctx = PolicyCtx {
                tasks: &mut self.tasks,
                iter,
                net: &self.net,
                moved_bytes: 0,
                moved_chunks: 0,
                residency: self.pool.residency(),
                rng: &mut self.rng,
            };
            p.apply(&mut ctx)?;
            moved_bytes += ctx.moved_bytes;
        }
        Ok(moved_bytes)
    }

    /// The dispatch plan for one iteration: each entry is a worker node
    /// plus the logical-task slots it hosts. Seeds are keyed by
    /// `(session seed, iteration, logical task index)` — never by thread
    /// — so the trajectory depends on neither worker scheduling,
    /// pipelining, nor (decoupled schedule) the thread count W or where a
    /// rebind happens to place a task.
    fn iteration_plan(&self, iter: usize) -> Vec<(NodeId, Vec<TaskSlot>)> {
        let base_seed = self
            .cfg
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(iter as u64);
        let seed_for = |t: usize| base_seed.wrapping_add((t as u64) << 32);
        if self.cfg.decoupled_tasks().is_some() {
            // One entry per thread hosting at least one task, hosted
            // tasks in ascending task order.
            self.threads
                .iter()
                .filter_map(|&th| {
                    let slots: Vec<TaskSlot> = self
                        .assignment
                        .iter()
                        .enumerate()
                        .filter(|(_, a)| **a == th)
                        .map(|(i, _)| TaskSlot { task: i, seed: seed_for(i) })
                        .collect();
                    (!slots.is_empty()).then_some((th, slots))
                })
                .collect()
        } else {
            // Legacy coupling: one slot per worker, and the logical task
            // index is the node id (the key `spawn_worker` registered).
            // The *seed* stays keyed by position in task order, exactly
            // as before the decoupling.
            self.tasks
                .iter()
                .enumerate()
                .map(|(t, task)| {
                    (
                        task.node.id,
                        vec![TaskSlot { task: task.node.id as usize, seed: seed_for(t) }],
                    )
                })
                .collect()
        }
    }

    /// Collect a dispatched iteration's runs in *logical task order*. The
    /// pool returns them in dispatch order (flattened per worker), which
    /// under the decoupled schedule interleaves by hosting thread — so
    /// they are sorted back by task index, and the cover is checked:
    /// exactly one run per logical task, or the merge fold would be
    /// silently wrong.
    fn collect_runs(&self, pending: PendingIteration) -> Result<Vec<TaskRun>> {
        let mut runs = self.pool.collect_iteration(pending)?;
        if self.cfg.decoupled_tasks().is_some() {
            runs.sort_by_key(|r| r.task);
            anyhow::ensure!(
                runs.len() == self.tasks.len()
                    && runs.iter().enumerate().all(|(i, r)| r.task == i),
                "iteration covered {} of {} logical tasks",
                runs.len(),
                self.tasks.len()
            );
        }
        Ok(runs)
    }

    /// Phase 3 — dispatch the iteration to every resident worker and
    /// collect updates in task order (the barrier).
    fn phase_execute(&mut self, iter: usize) -> Result<Vec<TaskRun>> {
        let k = self.tasks.len();
        let plan = self.iteration_plan(iter);
        let pending =
            self.pool
                .dispatch_tasks(&plan, ModelRef::Ready(Arc::clone(&self.model)), k, None)?;
        self.collect_runs(pending)
    }

    /// Phase 4 — merge task updates into the shared model, barriered,
    /// by whichever [`MergeStrategy`] the session configured.
    ///
    /// **Coordinator** (default): models below [`PARALLEL_MERGE_MIN_LEN`]
    /// take the serial fold — workers dropped their snapshots before
    /// completing, so `Arc::make_mut` merges in place, not on a copy.
    /// Larger models are reduced by the work-stealing sharded fan-out
    /// across the resident workers; fixed shard offsets make the result
    /// bit-identical to the serial fold at any worker count, elastic
    /// resizes included.
    ///
    /// **Ring / Tree**: the updates move peer-to-peer over the transport
    /// layer and the workers run the collective among themselves
    /// ([`WorkerPool::allreduce_model`]) — the coordinator only dispatches
    /// and collects. Same bits again (`tests/merge_strategies.rs` pins
    /// it); what changes is the wire pattern, reported back as *measured*
    /// transport rounds/bytes next to the simulated exchange charge.
    fn phase_merge(&mut self, iter: usize, updates: &Arc<Vec<LocalUpdate>>) -> Result<MergeReport> {
        let t0 = Instant::now();
        let k = updates.len();
        let kind = match self.cfg.merge_strategy {
            MergeStrategy::Ring => Some(AllreduceKind::Ring),
            MergeStrategy::Tree => Some(AllreduceKind::Tree),
            MergeStrategy::Coordinator => None,
        };
        if let Some(kind) = kind {
            let out = if self.cfg.decoupled_tasks().is_some() {
                // Decoupled schedule: ranks are *threads* (those hosting
                // at least one task), and each rank carries one
                // `(task_idx, update)` part per hosted task — k parts
                // across the collective in total. Owners sort all parts
                // into task order before the single fold, so the bits
                // match the serial fold at any thread count W.
                let order: Vec<NodeId> = self
                    .threads
                    .iter()
                    .copied()
                    .filter(|th| self.assignment.contains(th))
                    .collect();
                let parts: Vec<Vec<(usize, LocalUpdate)>> = order
                    .iter()
                    .map(|th| {
                        self.assignment
                            .iter()
                            .enumerate()
                            .filter(|(_, a)| *a == th)
                            .map(|(i, _)| (i, updates[i].clone()))
                            .collect()
                    })
                    .collect();
                self.pool
                    .allreduce_model_parts(&order, &self.model, parts, k, kind, iter as u64)?
            } else {
                // Legacy coupling: rank order = task order — `updates[i]`
                // belongs to `tasks[i]`, and the collective folds in
                // exactly this order.
                let order: Vec<NodeId> = self.tasks.iter().map(|t| t.node.id).collect();
                self.pool.allreduce_model(
                    &order,
                    &self.model,
                    updates.as_ref().clone(),
                    k,
                    kind,
                    iter as u64,
                )?
            };
            self.model = Arc::new(out.model);
            return Ok(MergeReport {
                merge_wall: t0.elapsed(),
                steal_count: 0,
                spw: 0,
                transport_rounds: out.rounds,
                transport_bytes: out.bytes,
                transport_frame_bytes: out.frame_bytes,
            });
        }
        let (steals, spw) = if self.pool.len() >= 2 && self.model.len() >= PARALLEL_MERGE_MIN_LEN {
            let opts = self.reduce_opts();
            let (merged, stats) =
                self.pool
                    .reduce_model(&self.model, Arc::clone(updates), k, opts)?;
            self.model = Arc::new(merged);
            (stats.steals, opts.shards_per_worker)
        } else {
            let model = Arc::make_mut(&mut self.model);
            self.algo.merge(model, updates, k);
            (0, 0)
        };
        Ok(MergeReport {
            merge_wall: t0.elapsed(),
            steal_count: steals,
            spw,
            transport_rounds: 0,
            transport_bytes: 0,
            transport_frame_bytes: 0,
        })
    }

    /// Phase 5 — time accounting over the configured model.
    fn phase_account(
        &mut self,
        updates: &[LocalUpdate],
        walls: &[Duration],
        moved_bytes: usize,
    ) -> IterationTiming {
        let nodes = self.current_nodes();
        let model_bytes = self.model.len() * std::mem::size_of::<f32>();
        self.timing.account(
            self.algo.as_ref(),
            &mut self.tasks,
            updates,
            walls,
            &nodes,
            &self.net,
            moved_bytes,
            model_bytes,
            self.n_total,
        )
    }

    /// Phase 6a — swimlane spans, virtual-clock advance and epoch
    /// bookkeeping for one accounted iteration.
    fn phase_timeline(&mut self, iter: usize, updates: &[LocalUpdate], timing: &IterationTiming) {
        let start = self.clock.now();
        // Swimlanes (uni-tasks; micro-task waves aren't per-node).
        if matches!(self.cfg.task_model, TaskModel::UniTasks) {
            for (task, (t, upd)) in self
                .tasks
                .iter()
                .zip(timing.task_times.iter().zip(updates))
            {
                self.swimlanes.record(TaskSpan {
                    node: task.node.id,
                    iter,
                    start,
                    end: start + Duration::from_secs_f64(*t),
                    n_chunks: task.n_chunks(),
                    n_samples: upd.samples,
                });
            }
        }
        self.clock.advance(Duration::from_secs_f64(
            timing.iteration_time + timing.transfer_time + timing.exchange_time,
        ));
        self.cum_samples += updates.iter().map(|u| u.samples).sum::<usize>();
    }

    /// Phase 6b — the convergence metric over the current model and every
    /// task's chunks, read live (barriered iterations only: the stores are
    /// quiescent and `self.model` is the fresh merge). Overlapped eval
    /// points instead evaluate inside [`Trainer::pipeline_next`], against
    /// the completed reduce buffer and a pre-dispatch chunk snapshot.
    fn evaluate_now(&self) -> Result<Metric> {
        let guards: Vec<_> = self.tasks.iter().map(|t| t.store.lock()).collect();
        let all: Vec<&Chunk> = guards.iter().flat_map(|g| g.iter()).collect();
        self.algo.evaluate(&self.model, &all)
    }

    /// Phase 6c — append the iteration to the metrics log.
    fn push_record(
        &mut self,
        iter: usize,
        updates: &[LocalUpdate],
        walls: &[Duration],
        report: &MergeReport,
        overlap_wall: Duration,
        metric: Option<Metric>,
    ) {
        let iter_samples: usize = updates.iter().map(|u| u.samples).sum();
        let loss_sum: f64 = updates.iter().map(|u| u.loss_sum).sum();
        let steps: usize = updates.iter().filter(|u| u.samples > 0).count();
        self.metrics.push(IterationRecord {
            iter,
            epochs: self.epochs(),
            metric,
            vtime: self.clock.now(),
            wall: walls.iter().copied().max().unwrap_or(Duration::ZERO),
            merge_wall: report.merge_wall,
            steal_count: report.steal_count,
            overlap_wall,
            spw: report.spw,
            transport_rounds: report.transport_rounds,
            transport_bytes: report.transport_bytes,
            transport_frame_bytes: report.transport_frame_bytes,
            n_tasks: updates.len(),
            n_threads: self.pool.len(),
            samples: iter_samples,
            train_loss: if steps > 0 { Some(loss_sum / steps as f64) } else { None },
        });
    }

    /// Reduction options for this iteration. With `cfg.adaptive_spw` the
    /// granularity comes from the pool's steal-count feedback controller;
    /// otherwise it is the fixed configured value.
    fn reduce_opts(&self) -> ReduceOptions {
        ReduceOptions {
            shards_per_worker: self
                .pool
                .adaptive_spw()
                .unwrap_or(self.cfg.shards_per_worker)
                .max(1),
            stealing: true,
        }
    }

    /// May iteration `iter`'s merge be overlapped with iteration
    /// `iter + 1`'s dispatch? Requires: the pipeline enabled, another
    /// iteration actually coming (run() stops on max_iters / max_epochs —
    /// the epoch check matches run()'s, since `phase_timeline` has
    /// already folded this iteration's samples in), and a model large
    /// enough for the pool reduce. Eval points *do* overlap (the metric
    /// is computed from a snapshot in the pipeline's shadow) provided the
    /// snapshot is affordable ([`Trainer::eval_overlap_affordable`] —
    /// checked by the caller, since only it knows the eval schedule); the
    /// one stop the pipeline cannot predict — the metric reaching its
    /// target — is settled by `run()` draining the speculative iteration.
    fn should_overlap(&self, iter: usize) -> bool {
        // Collectives are barriered — every rank both sends and receives —
        // so only the coordinator-side reduce can hide the next dispatch.
        self.cfg.merge_strategy == MergeStrategy::Coordinator
            && self.cfg.overlap
            && iter + 1 < self.cfg.max_iters
            && self.epochs() < self.cfg.max_epochs
            && self.pool.len() >= 2
            && self.model.len() >= PARALLEL_MERGE_MIN_LEN
    }

    /// At an eval point, is the overlapped (snapshot-based) evaluation
    /// worth it? Free for algorithms whose evaluate ignores chunks.
    /// Otherwise the snapshot costs only the *state* bytes (payloads are
    /// `Arc`-shared, never copied), while a chunk-reading evaluation
    /// streams every payload byte plus the model regardless of schedule —
    /// so the snapshot pays whenever its state memcpy stays within
    /// [`EVAL_SNAPSHOT_MAX_RATIO`]× those streamed bytes. For CoCoA (4
    /// state bytes per sample vs a full feature row) this always holds:
    /// large-dataset CoCoA takes the overlapped eval path. Only an
    /// algorithm whose per-sample state dwarfs both its model and its
    /// sample data falls back to the barriered, snapshot-free schedule.
    /// Either schedule yields bit-identical metrics, so this gate is a
    /// pure wallclock decision.
    fn eval_overlap_affordable(&self) -> bool {
        if !self.algo.eval_reads_chunks() {
            return true;
        }
        let mut state_bytes = 0usize;
        let mut payload_bytes = 0usize;
        for t in &self.tasks {
            // One lock per store; per-chunk payload sizes are cached at
            // construction, so this is O(chunks), not O(dataset).
            let (p, s) = t.store.byte_split();
            payload_bytes += p;
            state_bytes += s;
        }
        let model_bytes = self.model.len() * std::mem::size_of::<f32>();
        let streamed = model_bytes.saturating_add(payload_bytes);
        state_bytes <= streamed.saturating_mul(EVAL_SNAPSHOT_MAX_RATIO)
    }

    /// Clone every task's chunks, in the exact order
    /// [`Trainer::evaluate_now`] would visit them. This is the eval
    /// snapshot for an overlapped evaluation point, taken *before* the
    /// next boundary phases run: chunk moves never change chunk
    /// *contents*, but they do change which store a chunk sits in, and
    /// the metric's floating-point accumulation follows store order — so
    /// both content and order must be captured here for the overlapped
    /// metric to be bit-identical to the barriered one.
    ///
    /// Cost: *state-only* — `Chunk::clone` shares the immutable payload
    /// by `Arc` and copies just the per-sample state, so the snapshot
    /// allocates O(per-sample state bytes), never O(dataset). The next
    /// iteration's workers mutate their own chunks' state `Vec`s, which
    /// the snapshot no longer aliases; the shared payloads are immutable
    /// post-chunking by construction (`chunks::chunk` privacy), so the
    /// snapshot stays exactly the bytes the barriered evaluation would
    /// have read. Only paid when the algorithm's evaluate reads chunks at
    /// all (lSGD skips it entirely).
    fn snapshot_eval_chunks(&self) -> Vec<Chunk> {
        let mut all = Vec::new();
        for task in &self.tasks {
            let guard = task.store.lock();
            all.extend(guard.iter().cloned());
        }
        all
    }

    /// The overlapped merge: run iteration `iter + 1`'s boundary phases
    /// now (workers are idle — the scheduler owns the chunks), then queue
    /// the work-stealing reduction of `iter`'s updates and iteration
    /// `iter + 1` right behind it against the pending merge buffer. At an
    /// eval point, the metric is additionally computed on the coordinator
    /// — against the completed reduce buffer and a pre-dispatch chunk
    /// snapshot — while the workers compute `iter + 1`. The dispatched
    /// iteration stays in flight and is collected by the next `step`
    /// call.
    fn pipeline_next(
        &mut self,
        iter: usize,
        updates: &Arc<Vec<LocalUpdate>>,
        eval_point: bool,
    ) -> Result<PipelineOutcome> {
        // Eval snapshot of the chunk state, before the boundary moves
        // chunks between stores and long before iteration `iter + 1`'s
        // workers start mutating per-sample state. Skipped entirely when
        // the algorithm's evaluate ignores chunks (lSGD's held-out set).
        let eval_chunks: Option<Vec<Chunk>> = (eval_point && self.algo.eval_reads_chunks())
            .then(|| self.snapshot_eval_chunks());

        // Boundary of iteration `iter + 1`, at the virtual time the
        // barriered schedule would run it (the clock already advanced) and
        // in the same RNG order.
        let mut moved = self.phase_elasticity()?;
        moved += self.phase_policies(iter + 1)?;

        let k = updates.len();
        let opts = self.reduce_opts();
        let t0 = Instant::now();
        let reduce = self
            .pool
            .begin_reduce(&self.model, Arc::clone(updates), k, opts)?;
        let buf = reduce.buf();
        let plan = self.iteration_plan(iter + 1);
        let k_next = self.tasks.len();
        let t_dispatch = Instant::now();
        let iteration = match self.pool.dispatch_tasks(
            &plan,
            ModelRef::Pending(Arc::clone(&buf)),
            k_next,
            None,
        ) {
            Ok(p) => p,
            Err(e) => {
                // Nothing overlapped after all — settle the reduce so the
                // reply protocol stays in sync, then surface the error.
                let _ = self.pool.collect_reduce(reduce);
                return Err(e);
            }
        };
        let stats = match self.pool.collect_reduce(reduce) {
            Ok(s) => s,
            Err(e) => {
                // collect_reduce poisoned the buffer: the overlapped
                // iteration unblocks with per-worker errors — drain them.
                let _ = self.pool.collect_iteration(iteration);
                return Err(e);
            }
        };
        let merge_wall = t0.elapsed();
        // Eval-spanning overlap: the reduction is complete (collected
        // above), so the merged model can be read straight out of the
        // shared buffer — zero-copy — and evaluated on the coordinator
        // while the workers are already computing `iter + 1` against the
        // very same buffer. The snapshot taken up top supplies the chunk
        // state as the barriered evaluation would have seen it.
        let metric = if eval_point {
            let model = buf.wait().expect("collected reduction must be complete");
            let refs: Vec<&Chunk> = eval_chunks.iter().flatten().collect();
            match self.algo.evaluate(model, &refs) {
                Ok(m) => Some(m),
                Err(e) => {
                    // Keep the reply protocol in sync: the overlapped
                    // iteration is in flight and must be collected before
                    // this step can surface the evaluation error. The
                    // merge itself *succeeded* — install it, so a caller
                    // that survives the error is not left training from
                    // the stale pre-merge model.
                    let _ = self.pool.collect_iteration(iteration);
                    self.model = Arc::new(buf.into_model());
                    return Err(e);
                }
            }
        } else {
            None
        };
        let overlap_wall = t_dispatch.elapsed();
        self.pending = Some(PendingStep {
            iter: iter + 1,
            iteration,
            buf,
            moved_bytes: moved,
        });
        Ok(PipelineOutcome {
            report: MergeReport {
                merge_wall,
                steal_count: stats.steals,
                spw: opts.shards_per_worker,
                // The pipeline only engages under the coordinator
                // strategy (`should_overlap`), which never touches the
                // transport.
                transport_rounds: 0,
                transport_bytes: 0,
                transport_frame_bytes: 0,
            },
            overlap_wall,
            metric,
        })
    }

    /// Execute one full training iteration. Returns the evaluated metric
    /// if this iteration was an evaluation point.
    ///
    /// With the overlap pipeline enabled (`cfg.overlap`), a step may leave
    /// the *next* iteration's compute in flight; the following `step` call
    /// collects it. Use [`Trainer::step_barriered`] for a final iteration
    /// outside `run()`'s stop conditions (e.g. fixed-count loops).
    pub fn step(&mut self, iter: usize) -> Result<Option<Metric>> {
        self.step_inner(iter, true)
    }

    /// Like [`Trainer::step`], but never leaves work in flight.
    pub fn step_barriered(&mut self, iter: usize) -> Result<Option<Metric>> {
        self.step_inner(iter, false)
    }

    fn step_inner(&mut self, iter: usize, allow_overlap: bool) -> Result<Option<Metric>> {
        // Phases 1–3: results for `iter` — either collected from the
        // pipeline (boundary phases already ran last step) or computed
        // barriered right now.
        let (runs, moved_bytes) = match self.pending.take() {
            Some(p) => {
                anyhow::ensure!(
                    p.iter == iter,
                    "pipelined iteration {} pending, step({iter}) requested",
                    p.iter
                );
                let runs = self.collect_runs(p.iteration)?;
                // Workers dropped their buffer handles before replying, so
                // this is the zero-copy hand-over of the merged model.
                self.model = Arc::new(p.buf.into_model());
                (runs, p.moved_bytes)
            }
            None => {
                let mut moved = self.phase_elasticity()?;
                moved += self.phase_policies(iter)?;
                (self.phase_execute(iter)?, moved)
            }
        };
        let (updates, walls): (Vec<LocalUpdate>, Vec<Duration>) =
            runs.into_iter().map(|r| (r.update, r.wall)).unzip();
        // Shared with the worker pool during the (possibly parallel) merge.
        let updates = Arc::new(updates);

        // Phases 5–6a: pure bookkeeping — independent of the merge, so it
        // runs first and the merge can be overlapped behind it.
        let timing = self.phase_account(&updates, &walls, moved_bytes);
        self.phase_timeline(iter, &updates, &timing);

        let eval_point = iter % self.eval_every == 0;
        let overlap_now = allow_overlap
            && self.should_overlap(iter)
            && (!eval_point || self.eval_overlap_affordable());
        let (metric, report, overlap_wall) = if overlap_now {
            let out = self.pipeline_next(iter, &updates, eval_point)?;
            (out.metric, out.report, out.overlap_wall)
        } else {
            let report = self.phase_merge(iter, &updates)?;
            let metric = if eval_point { Some(self.evaluate_now()?) } else { None };
            (metric, report, Duration::ZERO)
        };
        self.push_record(iter, &updates, &walls, &report, overlap_wall, metric);
        Ok(metric)
    }

    /// Collect and discard a speculative pipelined iteration after an
    /// early stop: the merged model it was running against becomes the
    /// final model (bit-identical to what the barriered schedule would
    /// have stopped on); its updates are dropped — the barriered schedule
    /// would never have run it.
    ///
    /// Scope of the guarantee: the final *model*, the metrics log and the
    /// virtual-time trajectory match the barriered schedule exactly. The
    /// speculative iteration's side effects are not rolled back — its
    /// boundary phases already moved chunks/consumed RNG and its compute
    /// already advanced per-sample chunk state — so a trainer reused
    /// *after* an early-stopped `run()` (further `step` calls, or a
    /// chunk-reading re-evaluation) observes chunk state one iteration
    /// ahead of the barriered schedule. Rolling that back would require
    /// retaining a state snapshot of every store at every overlapped eval
    /// point (cheap since snapshots went state-only, but still
    /// bookkeeping); training has stopped, so the model/metrics guarantee
    /// is the one that matters.
    fn drain_pending(&mut self) -> Result<()> {
        if let Some(p) = self.pending.take() {
            self.pool.collect_iteration(p.iteration)?;
            self.model = Arc::new(p.buf.into_model());
        }
        Ok(())
    }

    /// Run to completion: stops at `max_iters`, `max_epochs`, or when the
    /// algorithm's convergence target is reached. The overlap pipeline
    /// never outruns the first two conditions (`should_overlap` checks
    /// them before engaging); a metric-triggered stop at an overlapped
    /// eval point leaves one speculative iteration in flight, which is
    /// drained here — no work is left pending on return.
    pub fn run(&mut self) -> Result<&MetricsLog> {
        let target = self.algo.target();
        for iter in 0..self.cfg.max_iters {
            let metric = self.step(iter)?;
            if self.epochs() >= self.cfg.max_epochs {
                break;
            }
            if let (Some(m), Some(t)) = (metric, target) {
                if m.reached(t) {
                    self.drain_pending()?;
                    break;
                }
            }
        }
        debug_assert!(self.pending.is_none(), "pipeline outran run()'s stop conditions");
        Ok(&self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Backend, CocoaAlgo};
    use crate::chunks::chunker::make_chunks;
    use crate::config::{CocoaConfig, ElasticSpec, SessionConfig};
    use crate::data::synth;

    fn cocoa_trainer(cfg: SessionConfig, n: usize) -> Trainer {
        let ds = synth::higgs_like(n, 5);
        let chunks = make_chunks(&ds, 8 * 1024);
        let algo = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            ds.n_samples(),
            ds.dim(),
        ));
        Trainer::new(cfg, algo, chunks).unwrap()
    }

    #[test]
    fn rigid_cocoa_converges() {
        let mut cfg = SessionConfig::cocoa("t", 4);
        cfg.max_iters = 30;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        let gap = tr.metrics.last_gap().unwrap();
        assert!(gap < 0.01, "gap {gap}");
        // One full local pass per task per iteration → 1 epoch/iteration.
        assert!((tr.metrics.records[0].epochs - 1.0).abs() < 1e-9);
    }

    #[test]
    fn projected_time_matches_wave_model_rigid() {
        let mut cfg = SessionConfig::cocoa("t", 4);
        cfg.ref_nodes = 16;
        cfg.max_iters = 3;
        let mut tr = cocoa_trainer(cfg, 1600);
        tr.run().unwrap();
        // 4 nodes, 16-node normalization → 16/4 = 4 units/iteration,
        // up to one chunk (~66 samples / 100 units) of placement slack.
        let t0 = tr.metrics.records[0].vtime.as_secs_f64();
        assert!(t0 >= 4.0 - 1e-9 && t0 < 4.8, "{t0}");
    }

    #[test]
    fn elastic_scale_out_adds_tasks() {
        let mut cfg = SessionConfig::cocoa("t", 2).with_elastic(ElasticSpec::Gradual {
            from: 2,
            to: 8,
            interval_s: 10.0,
        });
        cfg.max_iters = 20;
        cfg.policies.rebalance = true;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 8);
        // Chunks conserved across all the redistribution.
        let total: usize = tr.tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, 2000);
        // n_tasks in the log should be non-decreasing 2 → 8.
        let firsts = tr.metrics.records.first().unwrap().n_tasks;
        let lasts = tr.metrics.records.last().unwrap().n_tasks;
        assert_eq!(firsts, 2);
        assert_eq!(lasts, 8);
    }

    #[test]
    fn elastic_scale_in_removes_tasks_conserving_chunks() {
        let mut cfg = SessionConfig::cocoa("t", 8).with_elastic(ElasticSpec::Gradual {
            from: 8,
            to: 2,
            interval_s: 5.0,
        });
        cfg.max_iters = 25;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 2);
        let total: usize = tr.tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, 2000);
    }

    #[test]
    fn microtask_mode_keeps_k_constant() {
        let mut cfg = SessionConfig::cocoa("t", 4)
            .with_microtasks(16)
            .with_elastic(ElasticSpec::Gradual { from: 8, to: 2, interval_s: 5.0 });
        cfg.max_iters = 10;
        let mut tr = cocoa_trainer(cfg, 2000);
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 16);
        assert!(tr.metrics.records.iter().all(|r| r.n_tasks == 16));
    }

    #[test]
    fn decoupled_mode_keeps_k_tasks_across_thread_scale_in() {
        // 8 logical tasks on 4 threads scaling in to 2: K (and the
        // per-iteration task count in the log) must never budge, the
        // thread column must shrink, and no chunk may be lost — the
        // stores are shared, rebinds move bindings only.
        let ds = synth::higgs_like(2000, 5);
        let chunks = make_chunks(&ds, 2 * 1024);
        let algo = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            ds.n_samples(),
            ds.dim(),
        ));
        let mut cfg = SessionConfig::cocoa("t", 4)
            .with_logical_tasks(8)
            .with_elastic(ElasticSpec::Gradual { from: 4, to: 2, interval_s: 5.0 });
        cfg.max_iters = 25;
        let mut tr = Trainer::new(cfg, algo, chunks).unwrap();
        tr.run().unwrap();
        assert_eq!(tr.tasks().len(), 8, "K is a session constant");
        assert!(tr.metrics.records.iter().all(|r| r.n_tasks == 8));
        let first = tr.metrics.records.first().unwrap();
        let last = tr.metrics.records.last().unwrap();
        assert_eq!(first.n_threads, 4);
        assert_eq!(last.n_threads, 2, "scale-in should have fired");
        let total: usize = tr.tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, 2000, "rebinds must conserve chunks");
    }

    #[test]
    fn heterogeneous_rebalance_aligns_runtimes() {
        let mut cfg = SessionConfig::cocoa("t", 4).with_elastic(ElasticSpec::Heterogeneous {
            fast: 2,
            slow: 2,
            factor: 2.0,
        });
        cfg.max_iters = 25;
        cfg.policies.rebalance = true;
        cfg.policies.rebalance_step = 8;
        let mut tr = cocoa_trainer(cfg, 4000);
        tr.run().unwrap();
        // After rebalancing, the last iteration should be far better
        // balanced than the first.
        let first = tr.swimlanes.imbalance(0).unwrap();
        let last_iter = tr.swimlanes.n_iterations() - 1;
        let last = tr.swimlanes.imbalance(last_iter).unwrap();
        assert!(first > 1.8, "first iteration imbalance {first}");
        assert!(last < first, "imbalance {first} -> {last}");
        assert!(last < 1.4, "final imbalance {last}");
    }

    #[test]
    fn selective_history_clear_after_scale_event() {
        // 8 tasks holding ~1 chunk each; revoking 2 nodes deals 2 orphan
        // chunks round-robin, so most survivors' loads are untouched —
        // they must keep their learned runtimes (no blanket clear).
        let ds = synth::higgs_like(2000, 5);
        let chunks = make_chunks(&ds, 32 * 1024);
        let algo = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            ds.n_samples(),
            ds.dim(),
        ));
        let mut cfg = SessionConfig::cocoa("t", 8).with_elastic(ElasticSpec::Gradual {
            from: 8,
            to: 6,
            interval_s: 6.0,
        });
        cfg.policies.rebalance = false;
        cfg.max_iters = 10;
        let mut tr = Trainer::new(cfg, algo, chunks).unwrap();
        // Build runtime history before the t=6 event (2 units/iteration).
        for iter in 0..3 {
            tr.step(iter).unwrap();
        }
        assert!(tr.tasks().iter().all(|t| t.est_per_sample().is_some()));
        tr.phase_elasticity().unwrap();
        assert_eq!(tr.tasks().len(), 6, "scale-in should have fired");
        let kept = tr
            .tasks()
            .iter()
            .filter(|t| t.est_per_sample().is_some())
            .count();
        assert!(kept >= 1, "survivors untouched by the deal must keep history");
        assert!(kept < 6, "tasks that gained chunks must lose history");
    }
}
