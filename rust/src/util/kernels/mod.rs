//! Explicit-SIMD, cache-blocked compute primitives for the training hot
//! paths: `dot`, `axpy`, `scale_add`, elementwise accumulates, a blocked
//! `matmul` family, and the fused linear forward/backward (matmul + bias
//! + activation in one pass) — the native port of the Pallas
//! `fused_linear` kernel sketched in `python/compile/kernels/`.
//!
//! # Dispatch strategy
//!
//! Every public kernel has exactly two implementations with *identical
//! arithmetic structure*:
//!
//! * [`scalar`] — the portable reference. Reductions are written as a
//!   fixed [`LANES`]-wide accumulator split with a fixed pairwise
//!   horizontal-sum tree; elementwise kernels are plain per-element
//!   loops. This path compiles everywhere and auto-vectorizes to
//!   whatever the baseline target offers (SSE2 on x86-64).
//! * `x86` (private) — hand-written AVX2 intrinsics, compiled only under
//!   `--features simd` on x86-64 and selected at runtime via
//!   `is_x86_feature_detected!("avx2")` (cached). No FMA contraction is
//!   used anywhere: every lane performs the same IEEE-754 single-rounded
//!   `mul` and `add` as the scalar path.
//!
//! The top-level functions dispatch between the two; [`simd_active`]
//! reports which path is live (benches gate their speedup assertions on
//! it, and skip them when the fallback is running).
//!
//! # Determinism: why bit-identity survives vectorization
//!
//! Two rules, matching the ROADMAP merge invariant:
//!
//! * **Merge-path (elementwise) kernels vectorize across output
//!   elements** — lane-per-element. Element `i` of the output depends
//!   only on element `i` of the inputs, and the fold order *per element*
//!   is exactly the caller's loop order, so `merge_shard` built on
//!   [`acc`]/[`axpy`] stays elementwise and bit-identical to the serial
//!   fold at any shard geometry, worker count, or claim interleaving.
//! * **Reduction kernels use a fixed lane split** — [`dot`] accumulates
//!   element `i` into accumulator lane `i % LANES` and combines lanes in
//!   a fixed pairwise tree ([`scalar::hsum`]), with the tail (`len %
//!   LANES`) summed serially. The split depends only on the input
//!   length, never on worker count or timing, so results are identical
//!   run-to-run — and, because AVX2 `mul`/`add` round exactly like their
//!   scalar counterparts, identical between the scalar and SIMD paths
//!   too (asserted bit-for-bit by `tests/kernel_parity.rs`).
//!
//! Inputs are expected to be finite: `NaN` propagation in [`vmax`]
//! differs between `f32::max` and the AVX2 `maxps` semantics, which is
//! the one place the two paths could disagree.

pub mod scalar;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86;

use crate::util::workspace::Workspace;

/// Accumulator lanes used by the fixed-split reduction kernels (two
/// 8-wide AVX2 registers; four 4-wide SSE2 registers after autovec).
pub const LANES: usize = 16;

/// K-dimension block for the cache-blocked matmul family: the B-panel
/// (`BLOCK_K × BLOCK_N` f32) stays L2-resident and is reused across all
/// M rows.
const BLOCK_K: usize = 128;
/// N-dimension block: one `BLOCK_N` f32 strip of C/B fits L1 comfortably.
const BLOCK_N: usize = 512;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn avx2() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| std::is_x86_feature_detected!("avx2"))
}

/// Is the explicit-SIMD path live (feature compiled in *and* the CPU
/// supports AVX2)? Benches consult this before asserting speedup ratios;
/// when `false`, every kernel below is the scalar reference.
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        avx2()
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

// ------------------------------------------------------------ activation

/// Activation of a fused linear layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    None,
    Relu,
    Gelu,
}

impl Act {
    #[inline]
    pub fn apply(&self, v: f32) -> f32 {
        match self {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Gelu => gelu(v),
        }
    }
}

/// jax's default tanh-approximate GELU: 0.5·x·(1 + tanh(√(2/π)·(x +
/// 0.044715·x³))). Mirrored here so native and HLO paths agree.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d gelu(x) / dx for the tanh approximation.
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let x3 = x * x * x;
    let t = (C * (x + 0.044715 * x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

// ----------------------------------------------------- level-1 kernels

/// Fixed-lane-split dot product: deterministic run-to-run and bit-equal
/// between the scalar and SIMD paths (see module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            return unsafe { x86::dot(a, b) };
        }
    }
    scalar::dot(a, b)
}

/// Horizontal max with a fixed lane split. Inputs must be finite (NaN
/// semantics differ between the paths).
#[inline]
pub fn vmax(x: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            return unsafe { x86::vmax(x) };
        }
    }
    scalar::vmax(x)
}

/// Elementwise accumulate: `y[i] += x[i]`. Lane-per-element.
#[inline]
pub fn acc(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            unsafe { x86::acc(y, x) };
            return;
        }
    }
    scalar::acc(y, x)
}

/// `y[i] += a · x[i]`. Lane-per-element.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            unsafe { x86::axpy(y, a, x) };
            return;
        }
    }
    scalar::axpy(y, a, x)
}

/// `y[i] = beta · y[i] + x[i]` (momentum-style update). Lane-per-element.
#[inline]
pub fn scale_add(y: &mut [f32], beta: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            unsafe { x86::scale_add(y, beta, x) };
            return;
        }
    }
    scalar::scale_add(y, beta, x)
}

/// The SCD/CoCoA dual-update fused axpy: with `u = scale · x[i]`, do
/// `v[i] += sigma · u` and `dv[i] += u` in one pass. Lane-per-element.
#[inline]
pub fn fused_axpy2(v: &mut [f32], dv: &mut [f32], sigma: f32, scale: f32, x: &[f32]) {
    debug_assert_eq!(v.len(), x.len());
    debug_assert_eq!(dv.len(), x.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            unsafe { x86::fused_axpy2(v, dv, sigma, scale, x) };
            return;
        }
    }
    scalar::fused_axpy2(v, dv, sigma, scale, x)
}

/// Sparse·dense dot product `Σ vals[i] · dense[idx[i]]` with the same
/// fixed lane split as [`dot`] (AVX2 path: `vgatherdps`). Every
/// `idx[i]` must be `< dense.len()`.
#[inline]
pub fn sparse_dot(idx: &[u32], vals: &[f32], dense: &[f32]) -> f32 {
    debug_assert!(idx.iter().all(|&j| (j as usize) < dense.len()));
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2; the index
            // bound is the caller's contract (debug-asserted above).
            return unsafe { x86::sparse_dot(idx, vals, dense) };
        }
    }
    scalar::sparse_dot(idx, vals, dense)
}

/// Sparse scatter form of [`fused_axpy2`]: with `u = scale · vals[i]`,
/// `v[idx[i]] += sigma · u` and `dv[idx[i]] += u`, entries in input
/// order. Every `idx[i]` must be `< v.len().min(dv.len())`.
#[inline]
pub fn sparse_fused_axpy2(
    v: &mut [f32],
    dv: &mut [f32],
    sigma: f32,
    scale: f32,
    idx: &[u32],
    vals: &[f32],
) {
    debug_assert!(idx.iter().all(|&j| (j as usize) < v.len() && (j as usize) < dv.len()));
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            unsafe { x86::sparse_fused_axpy2(v, dv, sigma, scale, idx, vals) };
            return;
        }
    }
    scalar::sparse_fused_axpy2(v, dv, sigma, scale, idx, vals)
}

/// One 2×2 max-pool window across the channel dimension: candidates
/// `c0..c3` in `(dy, dx)` order, `base[q]` the flat index of candidate
/// `q`'s channel 0; writes `y[ch] = max` and `arg[ch] = base[q*] + ch`
/// with strict-`>` first-max-wins tie-breaking. Lane-per-channel.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn maxpool4(
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
    base: [u32; 4],
    y: &mut [f32],
    arg: &mut [u32],
) {
    debug_assert_eq!(y.len(), arg.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            // SAFETY: avx2() confirmed the CPU supports AVX2.
            unsafe { x86::maxpool4(c0, c1, c2, c3, base, y, arg) };
            return;
        }
    }
    scalar::maxpool4(c0, c1, c2, c3, base, y, arg)
}

// ----------------------------------------------------- blocked matmul

#[inline]
fn pick_axpy() -> fn(&mut [f32], f32, &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            return x86::axpy_dispatched;
        }
    }
    scalar::axpy
}

#[inline]
fn pick_dot() -> fn(&[f32], &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2() {
            return x86::dot_dispatched;
        }
    }
    scalar::dot
}

/// Shared cache-blocked accumulate loop: `c(m,n) += a(m,k) · b(k,n)`,
/// parameterized over the axpy kernel so the scalar and SIMD entry
/// points run the *same* blocking (and therefore the same per-element
/// accumulation order: `p` ascending for every `c[i][j]`, independent of
/// block boundaries).
pub(crate) fn matmul_acc_with(
    axpy_fn: fn(&mut [f32], f32, &[f32]),
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    for p0 in (0..k).step_by(BLOCK_K) {
        let p1 = (p0 + BLOCK_K).min(k);
        for j0 in (0..n).step_by(BLOCK_N) {
            let j1 = (j0 + BLOCK_N).min(n);
            for i in 0..m {
                let crow = &mut c[i * n + j0..i * n + j1];
                for p in p0..p1 {
                    axpy_fn(crow, a[i * k + p], &b[p * n + j0..p * n + j1]);
                }
            }
        }
    }
}

fn matmul_checked(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
}

/// Dense `C(m,n) = A(m,k) · B(k,n)`, cache-blocked, `c` overwritten.
///
/// Unconditionally dense: no per-element zero test in the hot loop (the
/// old scalar path's `av == 0.0` skip pessimized dense inputs with a
/// branch per A element). For genuinely sparse A use [`matmul_zero_skip`].
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_checked(a, b, c, m, k, n);
    c.fill(0.0);
    matmul_acc_with(pick_axpy(), a, b, c, m, k, n);
}

/// The explicit sparse-A variant: identical accumulation order to
/// [`matmul`], but rows of B whose A coefficient is exactly `0.0` are
/// skipped. Worth it only when a substantial fraction of A is exact
/// zeros (e.g. post-ReLU activations); bit-identical to [`matmul`]
/// either way, since skipping `+= 0.0 · b` only ever adds exact zeros.
///
/// (Not quite: `0.0 · b` can be `-0.0` or NaN for infinite `b`; with
/// finite inputs and `+0.0`-preserving accumulation the results match —
/// `x + 0.0 == x` for every finite non-`-0.0` x accumulated here. The
/// parity test pins the agreement on finite data.)
pub fn matmul_zero_skip(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_checked(a, b, c, m, k, n);
    c.fill(0.0);
    let axpy_fn = pick_axpy();
    for p0 in (0..k).step_by(BLOCK_K) {
        let p1 = (p0 + BLOCK_K).min(k);
        for j0 in (0..n).step_by(BLOCK_N) {
            let j1 = (j0 + BLOCK_N).min(n);
            for i in 0..m {
                let crow = &mut c[i * n + j0..i * n + j1];
                for p in p0..p1 {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    axpy_fn(crow, av, &b[p * n + j0..p * n + j1]);
                }
            }
        }
    }
}

/// `C(m,n) = Aᵀ · B` where A is stored `(k,m)` — i.e. `AᵀB`. Used for
/// `dW = Xᵀ·dY`. Implemented as an explicit transpose of A followed by
/// the blocked [`matmul`] accumulation (the transpose is O(km), dwarfed
/// by the O(mkn) product, and buys the dense contiguous inner loop).
pub fn matmul_at_b(a: &[f32], b: &[f32], c: &mut [f32], k: usize, m: usize, n: usize) {
    matmul_at_b_ws(a, b, c, k, m, n, &mut Workspace::new())
}

/// [`matmul_at_b`] with the transpose scratch checked out of `ws`
/// instead of freshly allocated — the form the allocation-free backward
/// pass uses. The scratch is fully overwritten before use, so a dirty
/// workspace gives bit-identical results to [`matmul_at_b`].
#[allow(clippy::too_many_arguments)]
pub fn matmul_at_b_ws(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    ws: &mut Workspace,
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let mut at = ws.take(m * k);
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        for (i, &av) in arow.iter().enumerate() {
            at[i * k + p] = av;
        }
    }
    c.fill(0.0);
    matmul_acc_with(pick_axpy(), &at, b, c, m, k, n);
    ws.put(at);
}

/// `C(m,k) = A(m,n) · Bᵀ` where B is stored `(k,n)`. Used for
/// `dX = dY·Wᵀ`. Row-against-row [`dot`] products: both operands are
/// contiguous, and the fixed lane split keeps every output element
/// deterministic.
pub fn matmul_a_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * k);
    let dot_fn = pick_dot();
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = dot_fn(arow, &b[j * n..(j + 1) * n]);
        }
    }
}

// ------------------------------------------------------ packed-B matmul

/// Length of the packed-B buffer for a `(k, n)` B matrix: packing is a
/// permutation of B, so the panel buffer is exactly `k · n` floats.
pub fn packed_b_len(k: usize, n: usize) -> usize {
    k * n
}

/// Pack `B(k, n)` into `(BLOCK_K × BLOCK_N)`-panel order: panels laid
/// out in the exact `(p0, j0)` order the blocked accumulate loop visits
/// them, each panel row-major (`p` rows of `j1−j0` contiguous floats).
/// For `n > BLOCK_N` this turns the strided `B[p·n + j0 ..]` row
/// segments the inner axpy streams into contiguous memory, packed once
/// and reused across all `m` rows — the pack is O(kn) copies, dwarfed
/// by the O(mkn) product. For `n ≤ BLOCK_N` there is a single column
/// block and packing degenerates to a plain copy of B.
pub fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    assert_eq!(b.len(), k * n);
    assert_eq!(packed.len(), packed_b_len(k, n));
    let mut off = 0;
    for p0 in (0..k).step_by(BLOCK_K) {
        let p1 = (p0 + BLOCK_K).min(k);
        for j0 in (0..n).step_by(BLOCK_N) {
            let j1 = (j0 + BLOCK_N).min(n);
            let bw = j1 - j0;
            for p in p0..p1 {
                packed[off..off + bw].copy_from_slice(&b[p * n + j0..p * n + j1]);
                off += bw;
            }
        }
    }
}

/// The packed-B accumulate: identical `(p0, j0, i, p)` iteration order
/// and per-element arithmetic as [`matmul_acc_with`] — only the B panel
/// addressing changes — so the packed product is bit-identical to the
/// unpacked one (pinned by `tests/kernel_parity.rs` across
/// block-straddling N).
pub(crate) fn matmul_packed_acc_with(
    axpy_fn: fn(&mut [f32], f32, &[f32]),
    a: &[f32],
    packed: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let mut off = 0;
    for p0 in (0..k).step_by(BLOCK_K) {
        let p1 = (p0 + BLOCK_K).min(k);
        for j0 in (0..n).step_by(BLOCK_N) {
            let j1 = (j0 + BLOCK_N).min(n);
            let bw = j1 - j0;
            for i in 0..m {
                let crow = &mut c[i * n + j0..i * n + j1];
                let mut po = off;
                for p in p0..p1 {
                    axpy_fn(crow, a[i * k + p], &packed[po..po + bw]);
                    po += bw;
                }
            }
            off += (p1 - p0) * bw;
        }
    }
}

/// Packed-B `C(m,n) = A(m,k) · B(k,n)`: packs B into `packed` (caller
/// scratch of [`packed_b_len`] floats, workspace-checked-out on the hot
/// path), then runs the blocked accumulate against the contiguous
/// panels. Bit-identical to [`matmul`]; worth it when `n > BLOCK_N`,
/// where it is used by the fused linear forward.
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    packed: &mut [f32],
) {
    matmul_checked(a, b, c, m, k, n);
    pack_b(b, k, n, packed);
    c.fill(0.0);
    matmul_packed_acc_with(pick_axpy(), a, packed, c, m, k, n);
}

/// Scalar-reference twin of [`matmul_packed`] for bench pairing: same
/// packing and accumulate order, forced onto the scalar axpy.
#[allow(clippy::too_many_arguments)]
pub fn matmul_packed_scalar(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    packed: &mut [f32],
) {
    matmul_checked(a, b, c, m, k, n);
    pack_b(b, k, n, packed);
    c.fill(0.0);
    matmul_packed_acc_with(scalar::axpy, a, packed, c, m, k, n);
}

// ------------------------------------------------------- fused linear

#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_linear_fwd_into_with(
    axpy_fn: fn(&mut [f32], f32, &[f32]),
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    y: &mut [f32],
    pre: &mut [f32],
    ws: &mut Workspace,
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(bias.len(), n);
    assert_eq!(y.len(), m * n);
    assert_eq!(pre.len(), m * n);
    // Fused pass: seed each output row with the bias (so pre = bias + Σ,
    // accumulated p-ascending), run the blocked matmul accumulate, then
    // apply the activation while the rows are still hot.
    for row in pre.chunks_exact_mut(n) {
        row.copy_from_slice(bias);
    }
    if n > BLOCK_N {
        // Wide layer: W's row segments are strided across column blocks
        // — pack once into workspace panels, reuse across all m rows.
        // Identical accumulation order, so identical bits.
        let mut packed = ws.take(packed_b_len(k, n));
        pack_b(w, k, n, &mut packed);
        matmul_packed_acc_with(axpy_fn, x, &packed, pre, m, k, n);
        ws.put(packed);
    } else {
        matmul_acc_with(axpy_fn, x, w, pre, m, k, n);
    }
    for (yv, &pv) in y.iter_mut().zip(pre.iter()) {
        *yv = act.apply(pv);
    }
}

/// Forward fused linear writing into caller buffers (`y` and `pre` are
/// fully overwritten; internal scratch comes from `ws`) — the
/// allocation-free form the training hot path uses.
#[allow(clippy::too_many_arguments)]
pub fn fused_linear_fwd_into(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    y: &mut [f32],
    pre: &mut [f32],
    ws: &mut Workspace,
) {
    fused_linear_fwd_into_with(pick_axpy(), x, w, bias, m, k, n, act, y, pre, ws);
}

/// Forward fused linear: `y(m,n) = act(x(m,k)·w(k,n) + bias)`. Returns
/// the pre-activation too (the gelu backward needs it). Allocating
/// convenience wrapper over [`fused_linear_fwd_into`].
pub fn fused_linear_fwd(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; m * n];
    let mut pre = vec![0.0f32; m * n];
    fused_linear_fwd_into_with(
        pick_axpy(),
        x,
        w,
        bias,
        m,
        k,
        n,
        act,
        &mut y,
        &mut pre,
        &mut Workspace::new(),
    );
    (y, pre)
}

/// Scalar-reference forward for bench pairing and parity tests:
/// identical blocking, packing, and per-element accumulation order to
/// [`fused_linear_fwd`], forced onto the scalar axpy kernel (so its
/// output is bit-equal to the dispatched version — the pair measures
/// pure kernel speedup, not algorithmic drift).
pub fn fused_linear_fwd_scalar(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; m * n];
    let mut pre = vec![0.0f32; m * n];
    fused_linear_fwd_into_with(
        scalar::axpy,
        x,
        w,
        bias,
        m,
        k,
        n,
        act,
        &mut y,
        &mut pre,
        &mut Workspace::new(),
    );
    (y, pre)
}

/// Backward fused linear writing into caller buffers: `dx`, `dw`, `db`
/// are fully overwritten (`dw`/`db` may alias disjoint slices of a flat
/// gradient vector — the in-place form is bit-identical to computing
/// into fresh buffers and copying, because both are zero-seeded
/// overwrites). Internal `d(pre)` and transpose scratch come from `ws`.
#[allow(clippy::too_many_arguments)]
pub fn fused_linear_bwd_into(
    x: &[f32],
    w: &[f32],
    pre: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
    ws: &mut Workspace,
) {
    assert_eq!(pre.len(), m * n);
    assert_eq!(dy.len(), m * n);
    assert_eq!(dx.len(), m * k);
    assert_eq!(dw.len(), k * n);
    assert_eq!(db.len(), n);
    // d(pre) = dy ⊙ act'(pre) — elementwise, lane-per-element safe.
    let mut dpre = ws.take(m * n);
    match act {
        Act::None => dpre.copy_from_slice(dy),
        Act::Relu => {
            for ((d, &g), &p) in dpre.iter_mut().zip(dy).zip(pre) {
                *d = if p > 0.0 { g } else { 0.0 };
            }
        }
        Act::Gelu => {
            for ((d, &g), &p) in dpre.iter_mut().zip(dy).zip(pre) {
                *d = g * gelu_grad(p);
            }
        }
    }
    matmul_a_bt(&dpre, w, dx, m, n, k);
    matmul_at_b_ws(x, &dpre, dw, m, k, n, ws);
    db.fill(0.0);
    for row in 0..m {
        acc(db, &dpre[row * n..(row + 1) * n]);
    }
    ws.put(dpre);
}

/// Backward fused linear given upstream grad `dy`: returns
/// `(dx, dw, db)`. `pre` is the forward pre-activation. Allocating
/// convenience wrapper over [`fused_linear_bwd_into`].
#[allow(clippy::too_many_arguments)]
pub fn fused_linear_bwd(
    x: &[f32],
    w: &[f32],
    pre: &[f32],
    dy: &[f32],
    m: usize,
    k: usize,
    n: usize,
    act: Act,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; m * k];
    let mut dw = vec![0.0f32; k * n];
    let mut db = vec![0.0f32; n];
    fused_linear_bwd_into(
        x,
        w,
        pre,
        dy,
        m,
        k,
        n,
        act,
        &mut dx,
        &mut dw,
        &mut db,
        &mut Workspace::new(),
    );
    (dx, dw, db)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    #[test]
    fn dot_matches_naive_within_ulp_bound() {
        for n in [0usize, 1, 7, 15, 16, 17, 100, 1023] {
            let a = seq(n, |i| (i as f32 * 0.37).sin());
            let b = seq(n, |i| ((i + 3) as f32 * 0.11).cos());
            let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
            let got = dot(&a, &b) as f64;
            assert!(
                (got - naive).abs() < 1e-4 * (1.0 + naive.abs()),
                "n={n}: {got} vs {naive}"
            );
        }
    }

    #[test]
    fn dispatched_kernels_bit_equal_scalar_reference() {
        let n = 203; // odd tail on purpose
        let x = seq(n, |i| (i as f32 * 0.7).sin());
        let mut y1 = seq(n, |i| (i as f32 * 0.3).cos());
        let mut y2 = y1.clone();
        axpy(&mut y1, 1.25, &x);
        scalar::axpy(&mut y2, 1.25, &x);
        assert_eq!(y1, y2);
        scale_add(&mut y1, 0.9, &x);
        scalar::scale_add(&mut y2, 0.9, &x);
        assert_eq!(y1, y2);
        acc(&mut y1, &x);
        scalar::acc(&mut y2, &x);
        assert_eq!(y1, y2);
        assert_eq!(dot(&x, &y1).to_bits(), scalar::dot(&x, &y2).to_bits());
        assert_eq!(vmax(&x).to_bits(), scalar::vmax(&x).to_bits());
        let (mut v1, mut dv1) = (y1.clone(), vec![0.0f32; n]);
        let (mut v2, mut dv2) = (y2.clone(), vec![0.0f32; n]);
        fused_axpy2(&mut v1, &mut dv1, 4.0, 0.5, &x);
        scalar::fused_axpy2(&mut v2, &mut dv2, 4.0, 0.5, &x);
        assert_eq!(v1, v2);
        assert_eq!(dv1, dv2);
    }

    #[test]
    fn matmul_small_known() {
        // [[1,2],[3,4]] @ I = same
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, a);
    }

    #[test]
    fn zero_skip_matches_dense_on_sparse_input() {
        let (m, k, n) = (5, 37, 19);
        let a = seq(m * k, |i| if i % 3 == 0 { 0.0 } else { (i as f32 * 0.1).sin() });
        let b = seq(k * n, |i| (i as f32 * 0.05).cos());
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        matmul(&a, &b, &mut c1, m, k, n);
        matmul_zero_skip(&a, &b, &mut c2, m, k, n);
        assert_eq!(c1, c2);
    }

    #[test]
    fn blocked_matmul_crosses_block_boundaries_correctly() {
        // k and n straddle BLOCK_K/BLOCK_N so the block loops matter.
        let (m, k, n) = (3usize, 130usize, 515usize);
        let a = seq(m * k, |i| ((i % 23) as f32 - 11.0) * 0.09);
        let b = seq(k * n, |i| ((i % 17) as f32 - 8.0) * 0.07);
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        // Naive f64 reference.
        for i in 0..m {
            for j in [0usize, 511, 512, 514] {
                let want: f64 = (0..k)
                    .map(|p| (a[i * k + p] as f64) * (b[p * n + j] as f64))
                    .sum();
                let got = c[i * n + j] as f64;
                assert!(
                    (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "c[{i}][{j}] = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn vmax_matches_fold() {
        for n in [1usize, 7, 16, 33] {
            let x = seq(n, |i| ((i * 7919) % 97) as f32 - 48.0);
            let want = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(vmax(&x), want);
        }
        assert_eq!(vmax(&[]), f32::NEG_INFINITY);
    }

    #[test]
    fn packed_matmul_bit_equal_unpacked_across_block_geometries() {
        // N below, at, and straddling BLOCK_N; K straddling BLOCK_K.
        for (m, k, n) in [(3usize, 130usize, 300usize), (2, 64, 512), (3, 200, 515), (2, 300, 1030)]
        {
            let a = seq(m * k, |i| ((i % 23) as f32 - 11.0) * 0.09);
            let b = seq(k * n, |i| ((i % 17) as f32 - 8.0) * 0.07);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            let mut packed = vec![0.0; packed_b_len(k, n)];
            matmul(&a, &b, &mut c1, m, k, n);
            matmul_packed(&a, &b, &mut c2, m, k, n, &mut packed);
            assert_eq!(c1, c2, "packed vs unpacked at (m={m}, k={k}, n={n})");
        }
    }

    #[test]
    fn sparse_dot_matches_dense_dot_on_scattered_data() {
        let dim = 211usize;
        let idx: Vec<u32> = (0..50).map(|i| (i * 4 + 1) as u32).collect();
        let vals = seq(idx.len(), |i| (i as f32 * 0.31).sin());
        let dense = seq(dim, |i| (i as f32 * 0.17).cos());
        let densified: Vec<f32> = {
            let mut d = vec![0.0f32; dim];
            for (&j, &v) in idx.iter().zip(&vals) {
                d[j as usize] = v;
            }
            d
        };
        let got = sparse_dot(&idx, &vals, &dense) as f64;
        let want = densified.iter().zip(&dense).map(|(&a, &b)| a as f64 * b as f64).sum::<f64>();
        assert!((got - want).abs() < 1e-4 * (1.0 + want.abs()), "{got} vs {want}");
        // Dispatched and scalar twins bit-equal.
        assert_eq!(got as f32, scalar::sparse_dot(&idx, &vals, &dense));
    }

    #[test]
    fn maxpool4_first_max_wins_ties() {
        // Candidates 0 and 2 tie at channel 0; strict > keeps candidate 0.
        let c0 = [5.0f32, 1.0];
        let c1 = [2.0f32, 4.0];
        let c2 = [5.0f32, 3.0];
        let c3 = [0.0f32, 2.0];
        let mut y = [0.0f32; 2];
        let mut arg = [0u32; 2];
        maxpool4(&c0, &c1, &c2, &c3, [100, 200, 300, 400], &mut y, &mut arg);
        assert_eq!(y, [5.0, 4.0]);
        assert_eq!(arg, [100, 201]);
    }

    #[test]
    fn gelu_matches_reference_points() {
        // jax.nn.gelu(1.0) ≈ 0.841192, gelu(-1.0) ≈ -0.158808 (tanh approx)
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert_eq!(gelu(0.0), 0.0);
    }
}
