//! AVX2 kernels, mirroring `scalar.rs` operation-for-operation.
//!
//! Compiled only under `--features simd` on x86-64; callers must gate on
//! `is_x86_feature_detected!("avx2")` (the parent module's `avx2()`
//! cache) before entering. Every kernel performs the same IEEE-754
//! single-rounded `mul`/`add` sequence as its scalar twin — in
//! particular **no FMA** (`_mm256_mul_ps` + `_mm256_add_ps`, never
//! `_mm256_fmadd_ps`) — so results are bit-identical to the scalar path.

#![allow(clippy::missing_safety_doc)] // safety contract documented once above

use std::arch::x86_64::*;

use super::LANES;

/// Horizontal sum of one ymm register with the fixed tree from
/// `scalar::hsum`: `(i, i+4)` via extractf128, `(i, i+2)` via movehl,
/// `(0, 1)` via shuffle.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum8(s: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(s);
    let hi = _mm256_extractf128_ps(s, 1);
    let t = _mm_add_ps(lo, hi);
    let u = _mm_add_ps(t, _mm_movehl_ps(t, t));
    _mm_cvtss_f32(_mm_add_ss(u, _mm_shuffle_ps(u, u, 0b01)))
}

/// Horizontal max of one ymm register with the fixed tree from
/// `scalar::hmax`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hmax8(s: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(s);
    let hi = _mm256_extractf128_ps(s, 1);
    let t = _mm_max_ps(lo, hi);
    let u = _mm_max_ps(t, _mm_movehl_ps(t, t));
    _mm_cvtss_f32(_mm_max_ss(u, _mm_shuffle_ps(u, u, 0b01)))
}

/// Two-accumulator dot (two ymm chains hide the 4-cycle add latency;
/// a single chain would be no faster than the SSE2 autovec fallback).
/// Lane `i % LANES` accumulates element `i`, exactly as in
/// `scalar::dot`; `acc0 + acc1` is the `l + l+8` fold of `scalar::hsum`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    for c in 0..chunks {
        let base = c * LANES;
        let p0 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(base)), _mm256_loadu_ps(bp.add(base)));
        acc0 = _mm256_add_ps(acc0, p0);
        let p1 =
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(base + 8)), _mm256_loadu_ps(bp.add(base + 8)));
        acc1 = _mm256_add_ps(acc1, p1);
    }
    let mut s = hsum8(_mm256_add_ps(acc0, acc1));
    for i in chunks * LANES..n {
        s += a[i] * b[i];
    }
    s
}

/// 8-lane running max + `hmax8` tree, matching `scalar::vmax` on finite
/// inputs.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn vmax(x: &[f32]) -> f32 {
    const ML: usize = 8;
    let chunks = x.len() / ML;
    let mut m = _mm256_set1_ps(f32::NEG_INFINITY);
    let xp = x.as_ptr();
    for c in 0..chunks {
        m = _mm256_max_ps(m, _mm256_loadu_ps(xp.add(c * ML)));
    }
    let mut r = hmax8(m);
    for &v in &x[chunks * ML..] {
        r = r.max(v);
    }
    r
}

/// `y[i] += x[i]`, 8 elements per iteration, scalar tail.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn acc(y: &mut [f32], x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    let yp = y.as_mut_ptr();
    let xp = x.as_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let v = _mm256_add_ps(_mm256_loadu_ps(yp.add(o)), _mm256_loadu_ps(xp.add(o)));
        _mm256_storeu_ps(yp.add(o), v);
    }
    for i in chunks * 8..n {
        y[i] += x[i];
    }
}

/// `y[i] += a · x[i]` — mul then add, no FMA.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    let va = _mm256_set1_ps(a);
    let yp = y.as_mut_ptr();
    let xp = x.as_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let prod = _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(o)));
        _mm256_storeu_ps(yp.add(o), _mm256_add_ps(_mm256_loadu_ps(yp.add(o)), prod));
    }
    for i in chunks * 8..n {
        y[i] += a * x[i];
    }
}

/// `y[i] = beta · y[i] + x[i]`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn scale_add(y: &mut [f32], beta: f32, x: &[f32]) {
    let n = y.len().min(x.len());
    let chunks = n / 8;
    let vb = _mm256_set1_ps(beta);
    let yp = y.as_mut_ptr();
    let xp = x.as_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let scaled = _mm256_mul_ps(vb, _mm256_loadu_ps(yp.add(o)));
        _mm256_storeu_ps(yp.add(o), _mm256_add_ps(scaled, _mm256_loadu_ps(xp.add(o))));
    }
    for i in chunks * 8..n {
        y[i] = beta * y[i] + x[i];
    }
}

/// `u = scale · x[i]; v[i] += sigma · u; dv[i] += u`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn fused_axpy2(v: &mut [f32], dv: &mut [f32], sigma: f32, scale: f32, x: &[f32]) {
    let n = v.len().min(dv.len()).min(x.len());
    let chunks = n / 8;
    let vs = _mm256_set1_ps(sigma);
    let vc = _mm256_set1_ps(scale);
    let vp = v.as_mut_ptr();
    let dp = dv.as_mut_ptr();
    let xp = x.as_ptr();
    for c in 0..chunks {
        let o = c * 8;
        let u = _mm256_mul_ps(vc, _mm256_loadu_ps(xp.add(o)));
        let su = _mm256_mul_ps(vs, u);
        _mm256_storeu_ps(vp.add(o), _mm256_add_ps(_mm256_loadu_ps(vp.add(o)), su));
        _mm256_storeu_ps(dp.add(o), _mm256_add_ps(_mm256_loadu_ps(dp.add(o)), u));
    }
    for i in chunks * 8..n {
        let u = scale * x[i];
        v[i] += sigma * u;
        dv[i] += u;
    }
}

/// Sparse·dense dot via `vgatherdps`, mirroring `scalar::sparse_dot`:
/// two 8-wide accumulator chains (lane `i % LANES`), `hsum8` tree,
/// serial tail. Caller guarantees every `idx[i] < dense.len()` (the
/// gather reads `dense + idx[i]` unchecked).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sparse_dot(idx: &[u32], vals: &[f32], dense: &[f32]) -> f32 {
    let n = idx.len().min(vals.len());
    let chunks = n / LANES;
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let ip = idx.as_ptr();
    let vp = vals.as_ptr();
    let dp = dense.as_ptr();
    for c in 0..chunks {
        let base = c * LANES;
        let i0 = _mm256_loadu_si256(ip.add(base) as *const __m256i);
        let g0 = _mm256_i32gather_ps::<4>(dp, i0);
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(vp.add(base)), g0));
        let i1 = _mm256_loadu_si256(ip.add(base + 8) as *const __m256i);
        let g1 = _mm256_i32gather_ps::<4>(dp, i1);
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(vp.add(base + 8)), g1));
    }
    let mut s = hsum8(_mm256_add_ps(acc0, acc1));
    for i in chunks * LANES..n {
        s += vals[i] * dense[idx[i] as usize];
    }
    s
}

/// Sparse scatter fused-axpy2: `u = scale · vals` and `sigma · u` are
/// computed 8-wide (same mul/mul rounding as the scalar twin), then
/// scattered with scalar adds in entry order — AVX2 has no scatter, and
/// the scalar adds keep the per-element sequence identical to
/// `scalar::sparse_fused_axpy2`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sparse_fused_axpy2(
    v: &mut [f32],
    dv: &mut [f32],
    sigma: f32,
    scale: f32,
    idx: &[u32],
    vals: &[f32],
) {
    let n = idx.len().min(vals.len());
    let chunks = n / 8;
    let vs = _mm256_set1_ps(sigma);
    let vc = _mm256_set1_ps(scale);
    let mut ua = [0.0f32; 8];
    let mut sa = [0.0f32; 8];
    for c in 0..chunks {
        let o = c * 8;
        let u = _mm256_mul_ps(vc, _mm256_loadu_ps(vals.as_ptr().add(o)));
        let su = _mm256_mul_ps(vs, u);
        _mm256_storeu_ps(ua.as_mut_ptr(), u);
        _mm256_storeu_ps(sa.as_mut_ptr(), su);
        for l in 0..8 {
            let j = idx[o + l] as usize;
            v[j] += sa[l];
            dv[j] += ua[l];
        }
    }
    for i in chunks * 8..n {
        let u = scale * vals[i];
        let j = idx[i] as usize;
        v[j] += sigma * u;
        dv[j] += u;
    }
}

/// Channel-vectorized 2×2 max-pool window, mirroring `scalar::maxpool4`:
/// candidates in `(dy, dx)` order, strict-greater compare
/// (`_CMP_GT_OQ`) so the first maximum wins ties, value and index lanes
/// blended on the same mask. Pure copies/compares — bit-identical to
/// the scalar twin on finite inputs.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn maxpool4(
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
    base: [u32; 4],
    y: &mut [f32],
    arg: &mut [u32],
) {
    let n = y.len();
    let chunks = n / 8;
    let iota = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
    for cix in 0..chunks {
        let o = cix * 8;
        let vo = _mm256_add_epi32(iota, _mm256_set1_epi32(o as i32));
        let mut best = _mm256_loadu_ps(c0.as_ptr().add(o));
        let mut bidx = _mm256_add_epi32(_mm256_set1_epi32(base[0] as i32), vo);
        for (cand, b) in [(c1, base[1]), (c2, base[2]), (c3, base[3])] {
            let vc = _mm256_loadu_ps(cand.as_ptr().add(o));
            let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(vc, best);
            best = _mm256_blendv_ps(best, vc, gt);
            let vi = _mm256_add_epi32(_mm256_set1_epi32(b as i32), vo);
            bidx = _mm256_castps_si256(_mm256_blendv_ps(
                _mm256_castsi256_ps(bidx),
                _mm256_castsi256_ps(vi),
                gt,
            ));
        }
        _mm256_storeu_ps(y.as_mut_ptr().add(o), best);
        _mm256_storeu_si256(arg.as_mut_ptr().add(o) as *mut __m256i, bidx);
    }
    for ch in chunks * 8..n {
        let mut bv = c0[ch];
        let mut bi = base[0];
        if c1[ch] > bv {
            bv = c1[ch];
            bi = base[1];
        }
        if c2[ch] > bv {
            bv = c2[ch];
            bi = base[2];
        }
        if c3[ch] > bv {
            bv = c3[ch];
            bi = base[3];
        }
        y[ch] = bv;
        arg[ch] = bi + ch as u32;
    }
}

// Safe fn-pointer shims for the blocked matmul dispatch table. Only
// installed after `avx2()` has returned true, which upholds the
// target-feature contract of the unsafe fns they wrap.

pub(super) fn axpy_dispatched(y: &mut [f32], a: f32, x: &[f32]) {
    // SAFETY: parent module installs this pointer only when AVX2 is present.
    unsafe { axpy(y, a, x) }
}

pub(super) fn dot_dispatched(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: parent module installs this pointer only when AVX2 is present.
    unsafe { dot(a, b) }
}
