//! Portable scalar reference kernels.
//!
//! These are not "naive" loops: the reduction kernels are written with
//! the *same arithmetic structure* as the AVX2 implementations in
//! `x86.rs` — a fixed [`LANES`]-wide accumulator split and a fixed
//! pairwise horizontal-combine tree — so the two paths perform an
//! identical sequence of IEEE-754 single-rounded operations and produce
//! bit-identical results. The elementwise kernels are plain
//! lane-per-element loops, which are order-identical by construction.
//!
//! The structure also happens to be what the baseline x86-64 target
//! auto-vectorizes well (four SSE2 chains for [`dot`]), so the fallback
//! is respectable, not a strawman.

use super::LANES;

/// Fixed pairwise horizontal-sum tree over the [`LANES`] accumulators:
/// fold the upper half onto the lower (`l + l+8`), then `(i, i+4)`,
/// `(i, i+2)`, `(0, 1)` — the exact add order of the AVX2
/// `extractf128`/`movehl`/`shuffle` reduction in `x86.rs`.
#[inline]
pub fn hsum(acc: &[f32; LANES]) -> f32 {
    let mut s = [0.0f32; 8];
    for l in 0..8 {
        s[l] = acc[l] + acc[l + 8];
    }
    let t = [s[0] + s[4], s[1] + s[5], s[2] + s[6], s[3] + s[7]];
    let u = [t[0] + t[2], t[1] + t[3]];
    u[0] + u[1]
}

/// Fixed pairwise horizontal-max tree over 8 lanes (same shape as
/// [`hsum`]'s lower half). Finite inputs only.
#[inline]
pub fn hmax(m: &[f32; 8]) -> f32 {
    let t = [m[0].max(m[4]), m[1].max(m[5]), m[2].max(m[6]), m[3].max(m[7])];
    let u = [t[0].max(t[2]), t[1].max(t[3])];
    u[0].max(u[1])
}

/// Fixed-lane-split dot product: element `i` accumulates into lane
/// `i % LANES`; lanes combine via [`hsum`]; the `len % LANES` tail is
/// added serially. The split depends only on `len`, so the result is
/// identical run-to-run and bit-equal to the AVX2 path.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for (l, av) in acc.iter_mut().enumerate() {
            *av += a[base + l] * b[base + l];
        }
    }
    let mut s = hsum(&acc);
    for i in chunks * LANES..n {
        s += a[i] * b[i];
    }
    s
}

/// Fixed-lane-split horizontal max (8 lanes). Finite inputs only — NaN
/// handling differs between `f32::max` and the AVX2 `maxps`.
pub fn vmax(x: &[f32]) -> f32 {
    const ML: usize = 8;
    let chunks = x.len() / ML;
    let mut m = [f32::NEG_INFINITY; ML];
    for c in 0..chunks {
        let base = c * ML;
        for (l, mv) in m.iter_mut().enumerate() {
            *mv = mv.max(x[base + l]);
        }
    }
    let mut r = hmax(&m);
    for &v in &x[chunks * ML..] {
        r = r.max(v);
    }
    r
}

/// `y[i] += x[i]`.
pub fn acc(y: &mut [f32], x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += xv;
    }
}

/// `y[i] += a · x[i]` (one mul, one add per element — no FMA).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// `y[i] = beta · y[i] + x[i]`.
pub fn scale_add(y: &mut [f32], beta: f32, x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv = beta * *yv + xv;
    }
}

/// With `u = scale · x[i]`: `v[i] += sigma · u`, `dv[i] += u`.
pub fn fused_axpy2(v: &mut [f32], dv: &mut [f32], sigma: f32, scale: f32, x: &[f32]) {
    for ((vv, dvv), &xv) in v.iter_mut().zip(dv.iter_mut()).zip(x) {
        let u = scale * xv;
        *vv += sigma * u;
        *dvv += u;
    }
}

/// Sparse·dense dot product `Σ vals[i] · dense[idx[i]]` with the same
/// fixed [`LANES`]-wide split and [`hsum`] tree as [`dot`]: entry `i`
/// accumulates into lane `i % LANES`, tail summed serially. The AVX2
/// twin replaces the indexed loads with `vgatherdps`; the arithmetic
/// sequence is identical. Requires every `idx[i] < dense.len()`.
pub fn sparse_dot(idx: &[u32], vals: &[f32], dense: &[f32]) -> f32 {
    let n = idx.len().min(vals.len());
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for (l, av) in acc.iter_mut().enumerate() {
            *av += vals[base + l] * dense[idx[base + l] as usize];
        }
    }
    let mut s = hsum(&acc);
    for i in chunks * LANES..n {
        s += vals[i] * dense[idx[i] as usize];
    }
    s
}

/// Sparse scatter form of [`fused_axpy2`]: with `u = scale · vals[i]`,
/// do `v[idx[i]] += sigma · u` and `dv[idx[i]] += u`, entries in input
/// order. The AVX2 twin vectorizes the two multiplies 8-wide and keeps
/// the scatter scalar (AVX2 has gathers but no scatters), touching every
/// element with the identical rounded values in the identical order.
pub fn sparse_fused_axpy2(
    v: &mut [f32],
    dv: &mut [f32],
    sigma: f32,
    scale: f32,
    idx: &[u32],
    vals: &[f32],
) {
    for (&j, &xv) in idx.iter().zip(vals) {
        let u = scale * xv;
        let j = j as usize;
        v[j] += sigma * u;
        dv[j] += u;
    }
}

/// One 2×2 max-pool window across `c` channels: candidates `c0..c3` are
/// the four window cells in `(dy, dx)` row-major order, `base[q]` the
/// flat index of candidate `q`'s channel 0. Strict `>` comparisons in
/// candidate order, so the **first** maximum wins ties — the argmax
/// contract `maxpool2_bwd` routes gradients by. Lane-per-channel: pure
/// copies and compares, so the AVX2 twin (blendv on the compare mask)
/// is trivially bit-identical. Finite inputs only.
pub fn maxpool4(
    c0: &[f32],
    c1: &[f32],
    c2: &[f32],
    c3: &[f32],
    base: [u32; 4],
    y: &mut [f32],
    arg: &mut [u32],
) {
    for ch in 0..y.len() {
        let mut best = c0[ch];
        let mut bidx = base[0];
        if c1[ch] > best {
            best = c1[ch];
            bidx = base[1];
        }
        if c2[ch] > best {
            best = c2[ch];
            bidx = base[2];
        }
        if c3[ch] > best {
            best = c3[ch];
            bidx = base[3];
        }
        y[ch] = best;
        arg[ch] = bidx + ch as u32;
    }
}
