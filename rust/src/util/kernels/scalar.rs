//! Portable scalar reference kernels.
//!
//! These are not "naive" loops: the reduction kernels are written with
//! the *same arithmetic structure* as the AVX2 implementations in
//! `x86.rs` — a fixed [`LANES`]-wide accumulator split and a fixed
//! pairwise horizontal-combine tree — so the two paths perform an
//! identical sequence of IEEE-754 single-rounded operations and produce
//! bit-identical results. The elementwise kernels are plain
//! lane-per-element loops, which are order-identical by construction.
//!
//! The structure also happens to be what the baseline x86-64 target
//! auto-vectorizes well (four SSE2 chains for [`dot`]), so the fallback
//! is respectable, not a strawman.

use super::LANES;

/// Fixed pairwise horizontal-sum tree over the [`LANES`] accumulators:
/// fold the upper half onto the lower (`l + l+8`), then `(i, i+4)`,
/// `(i, i+2)`, `(0, 1)` — the exact add order of the AVX2
/// `extractf128`/`movehl`/`shuffle` reduction in `x86.rs`.
#[inline]
pub fn hsum(acc: &[f32; LANES]) -> f32 {
    let mut s = [0.0f32; 8];
    for l in 0..8 {
        s[l] = acc[l] + acc[l + 8];
    }
    let t = [s[0] + s[4], s[1] + s[5], s[2] + s[6], s[3] + s[7]];
    let u = [t[0] + t[2], t[1] + t[3]];
    u[0] + u[1]
}

/// Fixed pairwise horizontal-max tree over 8 lanes (same shape as
/// [`hsum`]'s lower half). Finite inputs only.
#[inline]
pub fn hmax(m: &[f32; 8]) -> f32 {
    let t = [m[0].max(m[4]), m[1].max(m[5]), m[2].max(m[6]), m[3].max(m[7])];
    let u = [t[0].max(t[2]), t[1].max(t[3])];
    u[0].max(u[1])
}

/// Fixed-lane-split dot product: element `i` accumulates into lane
/// `i % LANES`; lanes combine via [`hsum`]; the `len % LANES` tail is
/// added serially. The split depends only on `len`, so the result is
/// identical run-to-run and bit-equal to the AVX2 path.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for (l, av) in acc.iter_mut().enumerate() {
            *av += a[base + l] * b[base + l];
        }
    }
    let mut s = hsum(&acc);
    for i in chunks * LANES..n {
        s += a[i] * b[i];
    }
    s
}

/// Fixed-lane-split horizontal max (8 lanes). Finite inputs only — NaN
/// handling differs between `f32::max` and the AVX2 `maxps`.
pub fn vmax(x: &[f32]) -> f32 {
    const ML: usize = 8;
    let chunks = x.len() / ML;
    let mut m = [f32::NEG_INFINITY; ML];
    for c in 0..chunks {
        let base = c * ML;
        for (l, mv) in m.iter_mut().enumerate() {
            *mv = mv.max(x[base + l]);
        }
    }
    let mut r = hmax(&m);
    for &v in &x[chunks * ML..] {
        r = r.max(v);
    }
    r
}

/// `y[i] += x[i]`.
pub fn acc(y: &mut [f32], x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += xv;
    }
}

/// `y[i] += a · x[i]` (one mul, one add per element — no FMA).
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// `y[i] = beta · y[i] + x[i]`.
pub fn scale_add(y: &mut [f32], beta: f32, x: &[f32]) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv = beta * *yv + xv;
    }
}

/// With `u = scale · x[i]`: `v[i] += sigma · u`, `dv[i] += u`.
pub fn fused_axpy2(v: &mut [f32], dv: &mut [f32], sigma: f32, scale: f32, x: &[f32]) {
    for ((vv, dvv), &xv) in v.iter_mut().zip(dv.iter_mut()).zip(x) {
        let u = scale * xv;
        *vv += sigma * u;
        *dvv += u;
    }
}
