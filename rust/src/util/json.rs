//! Minimal JSON parser + writer (offline substitute for serde_json).
//!
//! Covers the full JSON grammar; used to read `artifacts/manifest.json`
//! (written by python's `json.dump`) and to read/write session configs
//! and experiment results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ------------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object"),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // -------------------------------------------------------------- builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    // --------------------------------------------------------------- writing

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut vec = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(vec));
        }
        loop {
            vec.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(vec));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1..self.pos + 3) == Some(b"\\u") {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let low =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    0xFFFD
                                }
                            } else {
                                code
                            };
                            out.push(char::from_u32(ch).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Json::Num(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null},"s":"a\"b\\c"}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"artifacts": {"f": {"file": "f.hlo.txt",
            "inputs": [{"shape": [256, 28], "dtype": "float32"}],
            "outputs": [], "meta": {"batch": 8}}}, "models": {}}"#;
        let v = Json::parse(src).unwrap();
        let f = v.get("artifacts").unwrap().get("f").unwrap();
        assert_eq!(
            f.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
        assert_eq!(f.get("meta").unwrap().get("batch").unwrap().as_usize().unwrap(), 8);
    }
}
