//! Grow-only scratch-buffer pool for the per-iteration training hot path.
//!
//! A [`Workspace`] owns typed free-lists of buffers. `take*` pops the
//! most-recently-returned buffer (LIFO) and resizes it in place; `put*`
//! returns a buffer to the pool without shrinking it. The training loops
//! take and put in a fixed order every iteration, so after the first
//! pass through a workspace the buffer-to-role assignment is stable and
//! every `take` is satisfied from the pool with sufficient capacity —
//! the steady-state iteration performs **zero heap allocations** in the
//! compute loop (pinned by `tests/alloc_free_hot_path.rs`).
//!
//! # Ownership
//!
//! One `Workspace` per *logical task* (not per worker thread): the
//! executor's task contexts in `exec/worker.rs` each carry their own,
//! so PR-8 oversubscription (K tasks round-robin on W ≤ K threads)
//! reuses a task's scratch across its slots, and migrating a task to
//! another thread just moves (or lazily recreates) its workspace.
//!
//! # Why reuse can never change bits
//!
//! The contract is purely about *capacity*, never *contents*:
//! [`Workspace::take`] returns a buffer with **unspecified contents**
//! and the caller must fully overwrite it before reading; callers that
//! need defined contents use [`Workspace::take_zeroed`] /
//! [`Workspace::take_copy`] / the `*_cleared` variants, which
//! re-establish the exact state a fresh allocation would have. Since no
//! value ever read from a workspace buffer can depend on what a
//! previous iteration (or a previous task binding) left behind, a dirty
//! workspace produces bit-identical results to fresh allocation — the
//! W-sweep / task-rebinding determinism contract holds by construction,
//! and `tests/kernel_parity.rs` pins it.

/// Typed grow-only scratch pools. See the module docs for the reuse
/// contract.
#[derive(Debug, Default)]
pub struct Workspace {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
    i32s: Vec<Vec<i32>>,
    usizes: Vec<Vec<usize>>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out an `f32` buffer of length `len` with **unspecified
    /// contents** — the caller must fully overwrite it before reading.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        if v.len() < len {
            v.resize(len, 0.0);
        } else {
            v.truncate(len);
        }
        v
    }

    /// Check out an `f32` buffer of length `len`, zero-filled (the state
    /// `vec![0.0; len]` would have).
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take(len);
        v.fill(0.0);
        v
    }

    /// Check out an `f32` buffer initialized to a copy of `src` (the
    /// state `src.to_vec()` would have).
    pub fn take_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// Check out an empty `f32` buffer (length 0, capacity retained from
    /// previous use) for `push`/`extend_from_slice`-style filling.
    pub fn take_cleared(&mut self) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return an `f32` buffer to the pool.
    pub fn put(&mut self, v: Vec<f32>) {
        self.f32s.push(v);
    }

    /// Check out a `u32` buffer of length `len` with unspecified
    /// contents (e.g. maxpool argmax indices, fully overwritten).
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        let mut v = self.u32s.pop().unwrap_or_default();
        if v.len() < len {
            v.resize(len, 0);
        } else {
            v.truncate(len);
        }
        v
    }

    /// Return a `u32` buffer to the pool.
    pub fn put_u32(&mut self, v: Vec<u32>) {
        self.u32s.push(v);
    }

    /// Check out an empty `i32` buffer (e.g. a label batch built with
    /// `push`).
    pub fn take_i32_cleared(&mut self) -> Vec<i32> {
        let mut v = self.i32s.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return an `i32` buffer to the pool.
    pub fn put_i32(&mut self, v: Vec<i32>) {
        self.i32s.push(v);
    }

    /// Check out a `usize` buffer filled with `0..n` — the state
    /// `(0..n).collect()` would have (e.g. a permutation about to be
    /// shuffled; the RNG draw sequence is identical either way).
    pub fn take_usize_seq(&mut self, n: usize) -> Vec<usize> {
        let mut v = self.usizes.pop().unwrap_or_default();
        v.clear();
        v.extend(0..n);
        v
    }

    /// Check out an empty `usize` buffer for `push`-style filling.
    pub fn take_usize_cleared(&mut self) -> Vec<usize> {
        let mut v = self.usizes.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a `usize` buffer to the pool.
    pub fn put_usize(&mut self, v: Vec<usize>) {
        self.usizes.push(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroed_and_copy_match_fresh_allocation_state() {
        let mut ws = Workspace::new();
        // Dirty a buffer, return it, and check every typed take
        // re-establishes fresh-allocation state.
        let mut b = ws.take(8);
        b.fill(7.5);
        ws.put(b);
        assert_eq!(ws.take_zeroed(5), vec![0.0; 5]);

        let mut b = ws.take(8);
        b.fill(-1.0);
        ws.put(b);
        assert_eq!(ws.take_copy(&[1.0, 2.0]), vec![1.0, 2.0]);

        let mut s = ws.take_usize_seq(4);
        assert_eq!(s, vec![0, 1, 2, 3]);
        s.reverse();
        ws.put_usize(s);
        assert_eq!(ws.take_usize_seq(6), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn lifo_reuse_retains_capacity() {
        let mut ws = Workspace::new();
        let b = ws.take(1024);
        let cap = b.capacity();
        ws.put(b);
        // A smaller take reuses the same buffer (and its capacity).
        let b2 = ws.take(16);
        assert_eq!(b2.len(), 16);
        assert!(b2.capacity() >= cap);
    }

    #[test]
    fn take_shrinks_and_grows_length() {
        let mut ws = Workspace::new();
        ws.put(vec![1.0; 10]);
        assert_eq!(ws.take(3).len(), 3);
        ws.put(vec![1.0; 2]);
        assert_eq!(ws.take(9).len(), 9);
    }
}
