//! Self-contained utility substrates.
//!
//! This reproduction builds fully offline against a minimal vendored crate
//! set (xla + anyhow), so the usual ecosystem crates are reimplemented here
//! as small, tested substrates: a seeded RNG ([`rng`]), a JSON
//! parser/writer ([`json`]), a micro-benchmark harness ([`bench`]), and
//! the grow-only scratch pool behind the allocation-free training hot
//! path ([`workspace`]).

pub mod bench;
pub mod json;
pub mod kernels;
pub mod rng;
pub mod workspace;

pub use json::Json;
pub use rng::Rng;
pub use workspace::Workspace;
