//! Deterministic, seedable RNG (xoshiro256++) with the distributions the
//! synthetic data generators need: uniform, normal (Box–Muller), Zipf
//! (rejection-inversion), Fisher–Yates shuffle.

/// xoshiro256++ — fast, high-quality, reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box–Muller.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as usize;
            }
            // Rejection zone for exact uniformity.
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = std::f64::consts::TAU * v;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Zipf-distributed integer in [1, n] with exponent `a > 1`, by
    /// inversion of the (approximate) CDF with rejection. Good enough for
    /// synthetic power-law feature popularity.
    pub fn zipf(&mut self, n: u64, a: f64) -> u64 {
        debug_assert!(a > 1.0);
        // Rejection-inversion (Hörmann & Derflinger).
        let am1 = a - 1.0;
        let b = 2f64.powf(am1);
        loop {
            let u = 1.0 - self.f64(); // (0, 1]
            let v = self.f64();
            let x = u.powf(-1.0 / am1);
            if x > n as f64 {
                continue;
            }
            let k = x.floor().max(1.0);
            let t = (1.0 + 1.0 / k).powf(am1);
            if v * k * (t - 1.0) / (b - 1.0) <= t / b {
                return k as u64;
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Fork an independent stream (for per-worker determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_500..11_500).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::seed_from_u64(4);
        let n = 50_000;
        let mut ones = 0;
        for _ in 0..n {
            let v = r.zipf(1000, 1.5);
            assert!((1..=1000).contains(&v));
            if v == 1 {
                ones += 1;
            }
        }
        // P(1) for zeta(1.5) over 1..1000 ≈ 0.38.
        assert!(ones as f64 / n as f64 > 0.25, "{ones}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::seed_from_u64(6);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
