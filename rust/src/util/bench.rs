//! Micro-benchmark harness (offline substitute for criterion).
//!
//! Warms up, runs timed batches until a time budget or iteration cap,
//! reports mean / p50 / p95 and throughput. Used by `rust/benches/*.rs`
//! (declared `harness = false` in Cargo.toml).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p95 {:>12}   min {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            fmt_dur(self.min),
        );
    }

    /// Mean-based ops/sec.
    pub fn ops_per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Benchmark runner with a total time budget per benchmark.
pub struct Bencher {
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(Duration::from_secs(2))
    }
}

impl Bencher {
    pub fn new(budget: Duration) -> Self {
        // CHICLE_BENCH_BUDGET_MS overrides (used by `make bench` in CI).
        let budget = std::env::var("CHICLE_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(budget);
        Bencher { budget, min_iters: 10, max_iters: 100_000, results: Vec::new() }
    }

    pub fn with_iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Run `f` repeatedly; `f` returns a value to keep it un-optimized.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warm-up: one call (compilation, caches).
        std::hint::black_box(f());
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let iters = samples.len();
        let total: Duration = samples.iter().sum();
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean: total / iters as u32,
            p50: samples[iters / 2],
            p95: samples[(iters * 95 / 100).min(iters - 1)],
            min: samples[0],
        };
        result.report();
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write a TSV summary next to the bench binary (results/bench_*.tsv).
    pub fn write_tsv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::from("name\titers\tmean_ns\tp50_ns\tp95_ns\tmin_ns\n");
        for r in &self.results {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{}\t{}\n",
                r.name,
                r.iters,
                r.mean.as_nanos(),
                r.p50.as_nanos(),
                r.p95.as_nanos(),
                r.min.as_nanos()
            ));
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::new(Duration::from_millis(50)).with_iters(5, 1000);
        let r = b.bench("noop", || 1 + 1);
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert!(b.results().len() == 1);
    }

    #[test]
    fn fmt_covers_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains("s"));
    }
}
