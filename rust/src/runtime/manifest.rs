//! artifacts/manifest.json — the contract between the AOT compiler
//! (python/compile/aot.py) and the rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Tensor signature of one artifact input/output.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorMeta {
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub meta: HashMap<String, Json>,
}

/// Layout of one tensor inside a model's flat parameter vector.
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// A model family: flat-parameter layout + free-form config.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub params: Vec<ParamMeta>,
    pub param_count: usize,
    pub extra: HashMap<String, Json>,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub models: HashMap<String, ModelMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} (run `make artifacts` first)",
                path.display()
            )
        })?;
        let mut m = Self::from_json(&Json::parse(&text)?)?;
        m.dir = dir.to_path_buf();
        Ok(m)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mut artifacts = HashMap::new();
        for (name, a) in v.get("artifacts")?.as_obj()? {
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<_>>()?;
            let outputs = a
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<_>>()?;
            let meta = match a.opt("meta") {
                Some(m) => m.as_obj()?.clone().into_iter().collect(),
                None => HashMap::new(),
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                    meta,
                },
            );
        }
        let mut models = HashMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            let params = mv
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamMeta {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                        offset: p.get("offset")?.as_usize()?,
                        size: p.get("size")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let extra = mv
                .as_obj()?
                .iter()
                .filter(|(k, _)| k.as_str() != "params" && k.as_str() != "param_count")
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            models.insert(
                name.clone(),
                ModelMeta {
                    params,
                    param_count: mv.get("param_count")?.as_usize()?,
                    extra,
                },
            );
        }
        Ok(Manifest { artifacts, models, dir: PathBuf::new() })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Find the grad artifact for a model prefix, e.g. "cnn" →
    /// ("cnn_grad_l8", batch 8).
    pub fn grad_artifact(&self, model_prefix: &str) -> Result<(String, usize)> {
        self.find_kind(model_prefix, "grad")
    }

    /// Find the eval artifact for a model prefix.
    pub fn eval_artifact(&self, model_prefix: &str) -> Result<(String, usize)> {
        self.find_kind(model_prefix, "eval")
    }

    /// Find the init artifact for a model prefix.
    pub fn init_artifact(&self, model_prefix: &str) -> Result<String> {
        self.find_kind(model_prefix, "init").map(|(n, _)| n)
    }

    fn find_kind(&self, model_prefix: &str, kind: &str) -> Result<(String, usize)> {
        for (name, a) in &self.artifacts {
            let model = a.meta.get("model").and_then(|v| v.as_str().ok());
            let k = a.meta.get("kind").and_then(|v| v.as_str().ok());
            if model == Some(model_prefix) && k == Some(kind) {
                let batch = a
                    .meta
                    .get("batch")
                    .and_then(|v| v.as_usize().ok())
                    .unwrap_or(0);
                return Ok((name.clone(), batch));
            }
        }
        bail!("no {kind} artifact for model {model_prefix:?}")
    }
}

impl ModelMeta {
    /// Look up a tensor's slice bounds in the flat parameter vector.
    pub fn param_range(&self, name: &str) -> Option<std::ops::Range<usize>> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .map(|p| p.offset..p.offset + p.size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_if_present() {
        // Integration check against the artifacts built by `make artifacts`;
        // skipped when artifacts are absent (pure-unit CI).
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        let scd = m.artifact("scd_chunk_s256_f28").unwrap();
        assert_eq!(scd.inputs.len(), 7);
        assert_eq!(scd.outputs.len(), 2);
        assert_eq!(scd.inputs[0].shape, vec![256, 28]);
        let mlp = m.model("mlp").unwrap();
        assert_eq!(
            mlp.param_count,
            mlp.params.iter().map(|p| p.size).sum::<usize>()
        );
        let r = mlp.param_range("fc0.w").unwrap();
        assert_eq!(r.start, 0);
        assert_eq!(r.len(), 784 * 256);
        let (g, b) = m.grad_artifact("mlp").unwrap();
        assert_eq!(g, "mlp_grad_l8");
        assert_eq!(b, 8);
        assert_eq!(m.init_artifact("mlp").unwrap(), "mlp_init");
    }

    #[test]
    fn manifest_from_inline_json() {
        let json = r#"{
            "artifacts": {
                "f": {"file": "f.hlo.txt",
                       "inputs": [{"shape": [2, 2], "dtype": "float32"}],
                       "outputs": [{"shape": [], "dtype": "float32"}],
                       "meta": {"kind": "grad", "model": "m", "batch": 4}}
            },
            "models": {
                "m": {"params": [{"name": "w", "shape": [2, 2], "offset": 0, "size": 4}],
                       "param_count": 4}
            }
        }"#;
        let m = Manifest::from_json(&Json::parse(json).unwrap()).unwrap();
        assert_eq!(m.artifact("f").unwrap().inputs[0].element_count(), 4);
        assert_eq!(m.grad_artifact("m").unwrap(), ("f".into(), 4));
        assert!(m.artifact("missing").is_err());
        assert!(m.eval_artifact("m").is_err());
        assert_eq!(m.model("m").unwrap().param_range("w").unwrap(), 0..4);
    }
}
