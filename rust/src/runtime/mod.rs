//! The PJRT runtime: load AOT HLO artifacts and execute them from rust.
//!
//! This is the only place the `xla` crate is touched. The interchange is
//! HLO *text* (see `python/compile/aot.py` for why), compiled once per
//! artifact by [`engine::Engine`] on the PJRT CPU client. Because the
//! crate's client types are `Rc`-based (not `Send`), the engine lives on a
//! dedicated service thread ([`service::HloService`]) and worker tasks
//! talk to it with plain-data [`tensor::HostTensor`] messages — analogous
//! to host↔device transfers on a real accelerator node.

pub mod engine;
pub mod manifest;
pub mod service;
pub mod tensor;
mod xla_stub;

pub use engine::Engine;
pub use manifest::Manifest;
pub use service::HloService;
pub use tensor::HostTensor;
