//! HLO execution service: a dedicated thread owning the [`Engine`],
//! reachable from any worker via a cloneable handle.
//!
//! PJRT's CPU executor parallelizes *within* one execution (intra-op
//! thread pool), so serializing executions at the service is analogous to
//! each node owning a single device queue. For multi-node scaling studies
//! the solvers' native backend avoids this shared queue entirely.

use std::path::Path;
use std::sync::mpsc::{sync_channel, Sender, SyncSender};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::tensor::HostTensor;

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: SyncSender<Result<Vec<HostTensor>>>,
    },
    Prepare {
        artifact: String,
        reply: SyncSender<Result<()>>,
    },
}

/// Cloneable, thread-safe handle to the engine thread.
///
/// `std::sync::mpsc::Sender` is `Send` but not `Sync`; a tiny mutex around
/// it gives a shareable handle (send is effectively instant — the engine
/// queue is unbounded).
pub struct HloService {
    tx: Mutex<Sender<Request>>,
}

impl Clone for HloService {
    fn clone(&self) -> Self {
        HloService { tx: Mutex::new(self.tx.lock().unwrap().clone()) }
    }
}

impl HloService {
    /// Spawn the engine thread over `artifacts_dir`. The thread exits when
    /// every `HloService` clone has been dropped.
    pub fn spawn(artifacts_dir: &Path) -> Result<HloService> {
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        let dir = artifacts_dir.to_path_buf();
        // Engine creation happens on the service thread (the client is not
        // Send); surface init errors through a one-shot channel.
        let (init_tx, init_rx) = sync_channel::<Result<()>>(1);
        std::thread::Builder::new()
            .name("hlo-engine".into())
            .spawn(move || {
                let mut engine = match Engine::new(&dir) {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute { artifact, inputs, reply } => {
                            let _ = reply.send(engine.execute(&artifact, &inputs));
                        }
                        Request::Prepare { artifact, reply } => {
                            let _ = reply.send(engine.prepare(&artifact));
                        }
                    }
                }
            })?;
        init_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during init"))??;
        Ok(HloService { tx: Mutex::new(tx) })
    }

    /// Blocking execute on the engine thread.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine thread dropped request"))?
    }

    /// Pre-compile an artifact (warm-up before timed runs).
    pub fn prepare(&self, artifact: &str) -> Result<()> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .lock()
            .unwrap()
            .send(Request::Prepare { artifact: artifact.to_string(), reply: reply_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine thread dropped request"))?
    }
}
