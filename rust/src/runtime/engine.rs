//! The PJRT engine: compile HLO-text artifacts once, execute many times.
//!
//! Single-threaded by construction (the `xla` crate's client is `Rc`-based);
//! wrap in [`super::HloService`] for multi-worker access.

use std::collections::HashMap;
use std::path::Path;

use crate::Result;

use super::manifest::{Manifest, TensorMeta};
use super::tensor::HostTensor;
// The real `xla` crate (PJRT bindings) is not in the offline crate set;
// the stub mirrors the API surface used below and errors at client
// construction. Point this import at the real crate to enable PJRT.
use super::xla_stub as xla;

/// Owns the PJRT client, the manifest and the compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifact directory. Compilation is
    /// lazy: each artifact is compiled on first execution.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT init: {e}"))?;
        Ok(Engine { client, manifest, executables: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Eagerly compile one artifact (idempotent).
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host tensors, returning host tensors.
    ///
    /// Inputs are validated against the manifest signature. Outputs are
    /// decoded using the manifest's output dtypes (the lowered modules
    /// return one flat tuple — `return_tuple=True` in aot.py).
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let meta = self.manifest.artifact(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            anyhow::bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, sig)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.element_count() != sig.element_count() {
                anyhow::bail!(
                    "{name}: input {i} has {} elements, signature wants {:?}",
                    t.element_count(),
                    sig.shape
                );
            }
            literals.push(to_literal(t, sig)?);
        }
        let exe = self.executables.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))?;
        if parts.len() != meta.outputs.len() {
            anyhow::bail!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, sig)| from_literal(lit, sig))
            .collect()
    }

    /// Names of all loadable artifacts.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }
}

fn to_literal(t: &HostTensor, sig: &TensorMeta) -> Result<xla::Literal> {
    let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
        HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
    };
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e}"))
}

fn from_literal(lit: xla::Literal, sig: &TensorMeta) -> Result<HostTensor> {
    let shape = sig.shape.clone();
    match sig.dtype.as_str() {
        "float32" => Ok(HostTensor::F32 {
            data: lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))?,
            shape,
        }),
        "int32" => Ok(HostTensor::I32 {
            data: lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec i32: {e}"))?,
            shape,
        }),
        other => anyhow::bail!("unsupported output dtype {other}"),
    }
}
