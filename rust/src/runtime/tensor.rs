//! Plain-data tensors that cross the worker↔engine thread boundary.
//!
//! The `xla` crate's `Literal` wraps raw C pointers (not `Send`), so
//! workers exchange `HostTensor`s with the engine service instead — the
//! in-process analogue of a host→device transfer.

/// A host-side tensor: flat data + shape.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], shape: vec![] }
    }

    pub fn vec_f32(data: Vec<f32>) -> Self {
        let n = data.len();
        HostTensor::F32 { data, shape: vec![n] }
    }

    pub fn vec_i32(data: Vec<i32>) -> Self {
        let n = data.len();
        HostTensor::I32 { data, shape: vec![n] }
    }

    pub fn mat_f32(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        HostTensor::F32 { data, shape: vec![rows, cols] }
    }

    pub fn mat_i32(data: Vec<i32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        HostTensor::I32 { data, shape: vec![rows, cols] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// Borrow f32 contents (error for i32 tensors).
    pub fn as_f32(&self) -> crate::Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> crate::Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => anyhow::bail!("expected i32 tensor, got f32"),
        }
    }

    /// Consume into f32 data (error for i32 tensors).
    pub fn into_f32(self) -> crate::Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("expected f32 tensor, got i32"),
        }
    }

    /// First element as f64 (scalar outputs like loss/counters).
    pub fn scalar_value(&self) -> crate::Result<f64> {
        match self {
            HostTensor::F32 { data, .. } => data
                .first()
                .map(|v| *v as f64)
                .ok_or_else(|| anyhow::anyhow!("empty tensor")),
            HostTensor::I32 { data, .. } => data
                .first()
                .map(|v| *v as f64)
                .ok_or_else(|| anyhow::anyhow!("empty tensor")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let t = HostTensor::mat_f32(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.element_count(), 4);
        assert_eq!(t.as_f32().unwrap()[3], 4.0);
        assert!(t.as_i32().is_err());
        assert_eq!(t.scalar_value().unwrap(), 1.0);

        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.scalar_value().unwrap(), 7.0);
    }

    #[test]
    #[should_panic]
    fn mat_shape_mismatch_panics() {
        HostTensor::mat_f32(vec![1.0; 3], 2, 2);
    }
}
