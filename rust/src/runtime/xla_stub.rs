//! Minimal stand-in for the `xla` crate's PJRT API surface.
//!
//! The offline build ships without the PJRT bindings, so every entry
//! point here fails at construction time with a clear error while keeping
//! [`super::engine`] compiling against the real crate's signatures
//! (swap the `use super::xla_stub as xla;` import in `engine.rs` for the
//! real crate to enable the PJRT path). The HLO backend is only reachable
//! when `artifacts/manifest.json` exists, which hermetic test runs never
//! have — the native backend covers them.

use std::fmt;

/// Error type mirroring the real crate's: only needs `Display`.
#[derive(Debug)]
pub struct Error(&'static str);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} unavailable: built without the xla/PJRT bindings", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &'static str) -> Result<T, Error> {
    Err(Error(what))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}
