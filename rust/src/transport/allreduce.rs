//! Ring- and tree-allreduce for the merge phase, over any [`Transport`].
//!
//! Both collectives produce the *exact bits* of the serial fold
//! (`Algorithm::merge`), for any rank count — the property the whole
//! trainer is built on. The trick is that neither collective ever folds
//! in arrival order:
//!
//! * **Ring** (`2(k−1)` rounds, segment-sized messages): phase 1
//!   *scatters* — in round `t`, rank `r` sends, for each logical task it
//!   hosts, that update's slice of segment `(r+t) mod k` straight to the
//!   segment's owner (a thread multiplexing `m` uni-tasks contributes
//!   `m` slices per round), so after `k−1` rounds the owner of segment
//!   `s` holds all `k_tasks` update slices
//!   for its fixed-offset range. It sorts them by `task_idx` and folds
//!   **once**, in task order, with `merge_shard` — not pairwise along the
//!   ring, which would fold in rotation order and (f32 addition being
//!   non-associative) break bit-identity. Slices carry their update's
//!   `samples` so sample-weighted merges (lSGD's `Σ samples` normalizer)
//!   see every weight exactly as the serial fold does. Phase 2 is a
//!   standard ring all-gather of the merged segments.
//! * **Tree** (`2·⌊log2 k⌋` rounds, full-model messages): updates gather
//!   up a binary tree (children `2r+1`, `2r+2`) to rank 0, which performs
//!   the *literal* serial fold in task order and broadcasts the merged
//!   model back down. The simulated cost model
//!   (`NetworkModel::reduce_rounds`, `2·⌈log2 k⌉`) can now be compared
//!   against this measured round count per iteration.
//!
//! Both lean on the elementwise `merge_shard` invariant
//! ([`crate::algos::Algorithm::merge_shard`]): element `i` of the merged
//! model depends only on element `i` of the inputs plus shard-independent
//! scalars, with `offset` used solely to select the sub-range. That is
//! what licenses handing `merge_shard` a *pre-sliced* delta at offset 0 —
//! the ring owner's fold — and still getting the serial fold's bits.
//!
//! # Robustness rules (shared by both collectives)
//!
//! * **Staleness** — incoming collective traffic is dropped (counted in
//!   [`CollectiveStats::stale_dropped`]) when stamped with an epoch older
//!   than the membership snapshot this collective was launched with, or
//!   when sent by a node outside the rank order. Iteration tags guard the
//!   payload level the same way.
//! * **Rejoin service** — [`Payload::StateRequest`] is exempt from both
//!   checks (a rejoining node is cross-epoch by design): every
//!   participant answers requests inline — queued ones at collective
//!   entry, new ones whenever it is blocked in a receive — with its
//!   latest complete (pre-merge) model, so a rejoining peer can
//!   [`fetch_state`] from *any* member without a coordinator round-trip.
//! * **Mid-collective revoke** — revocation is queued *behind* the
//!   collective command (FIFO per worker), so a revoked rank always
//!   completes the in-flight collective first; its peers depend on its
//!   slices, and its endpoint leaves the group only when the worker
//!   thread exits. The pool stashes its completion for the eventual
//!   collect (`WorkerPool::collect_allreduce`).

use std::time::{Duration, Instant};

use crate::algos::{Algorithm, LocalUpdate, ModelVec};
use crate::cluster::NodeId;

use super::{segment_range, Message, Payload, Transport, TransportError, UpdatePart};

/// How long a collective waits on any single receive before declaring the
/// group wedged. Generous: the only way to hit it is a peer that died
/// without the pool noticing (a protocol bug, not a slow node).
pub const COLLECTIVE_RECV_TIMEOUT: Duration = Duration::from_secs(10);

/// Which collective runs the merge phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllreduceKind {
    Ring,
    Tree,
}

/// What one rank measured while participating in a collective.
#[derive(Clone, Copy, Debug, Default)]
pub struct CollectiveStats {
    /// Ranks in the collective.
    pub peers: usize,
    /// This participant's rank in the fold order.
    pub rank: usize,
    /// Sequential protocol rounds: `2(k−1)` for ring, `2·⌊log2 k⌋` for
    /// tree, `0` for the single-rank degenerate case. Measured transport
    /// reality, to be compared against the *simulated*
    /// `NetworkModel::reduce_rounds` — never fed into virtual time.
    pub rounds: usize,
    /// Payload bytes this rank put on the wire (collective traffic only;
    /// rejoin-service replies are excluded so the figure is comparable
    /// across iterations).
    pub bytes_sent: usize,
    /// Messages dropped by the staleness rule.
    pub stale_dropped: usize,
    /// Rejoin state requests served inline.
    pub state_served: usize,
    /// Non-payload framing bytes the transport backend charged this rank
    /// for the collective (length prefixes, tags, handshakes). Zero on
    /// the in-process channel backend; filled in by the worker loop from
    /// `Transport::frame_bytes` deltas, not by the collective itself.
    pub frame_bytes: usize,
}

/// One rank's completed collective: the merged model (every rank ends
/// with the full result — that is what "allreduce" means) plus its stats.
#[derive(Clone, Debug)]
pub struct AllreduceRun {
    pub model: ModelVec,
    pub stats: CollectiveStats,
}

/// Everything a rank needs to participate in one merge collective.
pub struct CollectiveCtx<'a> {
    pub algo: &'a dyn Algorithm,
    /// The pre-merge model (every rank holds the same bits — the model is
    /// replicated; this is also what rejoin state requests are served
    /// from).
    pub model: &'a ModelVec,
    /// The logical-task updates this rank carries into the fold, as
    /// `(task_idx, update)` pairs. Under the legacy one-task-per-thread
    /// coupling this is a single entry whose index equals the rank; a
    /// thread hosting `m` logical tasks contributes `m` entries (and the
    /// ring sends `m` slices per scatter round). The fold itself is
    /// keyed purely by `task_idx`, so where a task happens to be hosted
    /// never changes the merged bits.
    pub parts: &'a [(usize, LocalUpdate)],
    /// Total logical tasks across *all* ranks — the serial fold's K.
    /// Equals the rank count only under the legacy coupling.
    pub k_tasks: usize,
    /// Rank order of the collective. `order[s]` owns ring segment `s`.
    /// With one task per rank this is also the task order; with
    /// multiplexed tasks the ordering burden moves entirely to
    /// `into_fold_order`'s sort by `task_idx`.
    pub order: &'a [NodeId],
    /// Membership epoch snapshotted at launch (the staleness floor).
    pub epoch: u64,
    /// Iteration tag carried by every collective payload.
    pub iter: u64,
}

/// Ring-allreduce: reduce-scatter (slices to segment owners, task-order
/// fold at the owner) + ring all-gather. `2(k−1)` rounds of segment-sized
/// messages; bit-identical to the serial fold. See the module docs for
/// why the fold happens once at the owner instead of pairwise.
pub fn ring_allreduce(
    tp: &mut dyn Transport,
    ctx: &CollectiveCtx,
) -> Result<AllreduceRun, TransportError> {
    let (k, rank, mut stats, mut stash) = enter(tp, ctx)?;
    if k == 1 {
        return Ok(AllreduceRun { model: local_fold(ctx)?, stats });
    }
    let len = ctx.model.len();

    // Phase 1 — scatter: round t sends my slices of segment (rank+t) mod
    // k straight to its owner — one `UpdateSlice` per logical task this
    // rank hosts (a round is a protocol step, not a message count). All
    // sends are independent, so they go out before any receive (channels
    // are unbounded; a real backend windows).
    for t in 1..k {
        let seg = (rank + t) % k;
        let (off, l) = segment_range(len, k, seg);
        for (task_idx, update) in ctx.parts {
            let payload = Payload::UpdateSlice {
                iter: ctx.iter,
                seg,
                part: UpdatePart {
                    task_idx: *task_idx,
                    samples: update.samples,
                    delta: update.delta[off..off + l].to_vec(),
                },
            };
            stats.bytes_sent += payload.wire_bytes();
            tp.send(ctx.order[seg], payload)?;
        }
    }

    // Collect the remaining slices of my own segment — k_tasks in total,
    // counting my own — then fold all of them in task order: one
    // merge_shard call, exactly like the serial fold restricted to this
    // fixed-offset range.
    let (my_off, my_len) = segment_range(len, k, rank);
    let mut parts = Vec::with_capacity(ctx.k_tasks);
    for (task_idx, update) in ctx.parts {
        parts.push(UpdatePart {
            task_idx: *task_idx,
            samples: update.samples,
            delta: update.delta[my_off..my_off + my_len].to_vec(),
        });
    }
    while parts.len() < ctx.k_tasks {
        let msg = recv_matching(tp, ctx, &mut stash, &mut stats, |p| {
            matches!(p, Payload::UpdateSlice { iter, seg, .. }
                     if *iter == ctx.iter && *seg == rank)
        })?;
        let Payload::UpdateSlice { part, .. } = msg.payload else { unreachable!() };
        if part.delta.len() != my_len {
            return Err(TransportError::Protocol("update slice length mismatch"));
        }
        parts.push(part);
    }
    let slices = into_fold_order(parts)?;
    let mut seg = ctx.model[my_off..my_off + my_len].to_vec();
    ctx.algo.merge_shard(&mut seg, 0, &slices, ctx.k_tasks);

    // Phase 2 — ring all-gather: each round, forward the segment received
    // last round to the right neighbor; after k−1 rounds every rank holds
    // every merged segment.
    let right = ctx.order[(rank + 1) % k];
    let mut segments: Vec<Option<Vec<f32>>> = (0..k).map(|_| None).collect();
    let mut travel = (rank, seg.clone());
    segments[rank] = Some(seg);
    for t in 1..k {
        let payload = Payload::Segment { iter: ctx.iter, seg: travel.0, data: travel.1 };
        stats.bytes_sent += payload.wire_bytes();
        tp.send(right, payload)?;
        let expect = (rank + k - t) % k;
        let msg = recv_matching(tp, ctx, &mut stash, &mut stats, |p| {
            matches!(p, Payload::Segment { iter, seg, .. }
                     if *iter == ctx.iter && *seg == expect)
        })?;
        let Payload::Segment { data, .. } = msg.payload else { unreachable!() };
        segments[expect] = Some(data.clone());
        travel = (expect, data);
    }

    // Assemble at the fixed offsets.
    let mut out = ctx.model.clone();
    for (s, data) in segments.into_iter().enumerate() {
        let (off, l) = segment_range(len, k, s);
        let data = data.expect("every segment received by construction");
        if data.len() != l {
            return Err(TransportError::Protocol("merged segment length mismatch"));
        }
        out[off..off + l].copy_from_slice(&data);
    }
    stats.rounds = 2 * (k - 1);
    Ok(AllreduceRun { model: out, stats })
}

/// Tree-allreduce: gather every update up a binary tree to rank 0, fold
/// serially in task order at the root, broadcast the merged model back
/// down. `2·⌊log2 k⌋` rounds of full-model messages — trivially
/// bit-identical (the root runs the literal serial fold), at the price of
/// root-bound bandwidth; the ring trades that for `2(k−1)` segment-sized
/// rounds.
pub fn tree_allreduce(
    tp: &mut dyn Transport,
    ctx: &CollectiveCtx,
) -> Result<AllreduceRun, TransportError> {
    let (k, rank, mut stats, mut stash) = enter(tp, ctx)?;
    if k == 1 {
        return Ok(AllreduceRun { model: local_fold(ctx)?, stats });
    }
    let children: Vec<usize> =
        [2 * rank + 1, 2 * rank + 2].into_iter().filter(|&c| c < k).collect();

    // Gather: my own hosted updates plus both children's subtrees.
    let mut parts: Vec<UpdatePart> = ctx
        .parts
        .iter()
        .map(|(task_idx, update)| UpdatePart {
            task_idx: *task_idx,
            samples: update.samples,
            delta: update.delta.clone(),
        })
        .collect();
    for _ in &children {
        let msg = recv_matching(tp, ctx, &mut stash, &mut stats, |p| {
            matches!(p, Payload::Updates { iter, .. } if *iter == ctx.iter)
        })?;
        let Payload::Updates { parts: got, .. } = msg.payload else { unreachable!() };
        parts.extend(got);
    }

    let model = if rank == 0 {
        if parts.len() != ctx.k_tasks {
            return Err(TransportError::Protocol("tree gather missed updates"));
        }
        if parts.iter().any(|p| p.delta.len() != ctx.model.len()) {
            return Err(TransportError::Protocol("tree update length mismatch"));
        }
        let updates = into_fold_order(parts)?;
        // The literal serial fold, in task order.
        let mut out = ctx.model.clone();
        ctx.algo.merge_shard(&mut out, 0, &updates, ctx.k_tasks);
        out
    } else {
        let parent = ctx.order[(rank - 1) / 2];
        let payload = Payload::Updates { iter: ctx.iter, parts };
        stats.bytes_sent += payload.wire_bytes();
        tp.send(parent, payload)?;
        let msg = recv_matching(tp, ctx, &mut stash, &mut stats, |p| {
            matches!(p, Payload::Model { iter, .. } if *iter == ctx.iter)
        })?;
        let Payload::Model { data, .. } = msg.payload else { unreachable!() };
        data
    };

    // Broadcast down.
    for &c in &children {
        let payload = Payload::Model { iter: ctx.iter, data: model.clone() };
        stats.bytes_sent += payload.wire_bytes();
        tp.send(ctx.order[c], payload)?;
    }
    // Height of a k-node binary heap — the sequential depth of both the
    // gather and the broadcast wave.
    stats.rounds = 2 * k.ilog2() as usize;
    Ok(AllreduceRun { model, stats })
}

/// The rejoin protocol, requester side: ask `from` for its latest
/// complete model. Any live peer can answer (requests are served inline
/// while peers sit in a collective — see the module docs), so a rejoining
/// node never needs the coordinator.
pub fn fetch_state(
    tp: &mut dyn Transport,
    from: NodeId,
    timeout: Duration,
) -> Result<ModelVec, TransportError> {
    tp.send(from, Payload::StateRequest)?;
    let deadline = Instant::now() + timeout;
    loop {
        let left = deadline
            .checked_duration_since(Instant::now())
            .ok_or(TransportError::Timeout)?;
        if let Payload::Model { data, .. } = tp.recv(left)?.payload {
            return Ok(data);
        }
        // Anything else predates this endpoint's (re)join — skip it.
    }
}

/// Common collective entry: resolve the caller's rank, then drain the
/// receive queue — queued rejoin requests get served even on ranks that
/// will never block in a receive (the single-rank degenerate case).
fn enter(
    tp: &mut dyn Transport,
    ctx: &CollectiveCtx,
) -> Result<(usize, usize, CollectiveStats, Vec<Message>), TransportError> {
    let k = ctx.order.len();
    let me = tp.node();
    let rank = ctx
        .order
        .iter()
        .position(|&n| n == me)
        .ok_or(TransportError::Protocol("caller not in the collective order"))?;
    let mut stats = CollectiveStats { peers: k, rank, ..Default::default() };
    let mut stash = Vec::new();
    while let Some(msg) = tp.try_recv() {
        if let Some(m) = sieve(msg, tp, ctx, &mut stats) {
            stash.push(m);
        }
    }
    Ok((k, rank, stats, stash))
}

/// The single-rank degenerate collective: the local serial fold (0
/// rounds, 0 bytes — a ring of one is a no-op transport-wise). The lone
/// rank may still host many logical tasks, so the fold sorts them into
/// task order first, exactly like the distributed paths do.
fn local_fold(ctx: &CollectiveCtx) -> Result<ModelVec, TransportError> {
    let mut own: Vec<&(usize, LocalUpdate)> = ctx.parts.iter().collect();
    own.sort_by_key(|(task_idx, _)| *task_idx);
    if own.windows(2).any(|w| w[0].0 == w[1].0) {
        return Err(TransportError::Protocol("duplicate task index in fold"));
    }
    let updates: Vec<LocalUpdate> = own.into_iter().map(|(_, u)| u.clone()).collect();
    let mut out = ctx.model.clone();
    ctx.algo.merge_shard(&mut out, 0, &updates, ctx.k_tasks);
    Ok(out)
}

/// Sort gathered parts into task order and convert them to the
/// `LocalUpdate` slice `merge_shard` folds. Duplicate task indices mean
/// cross-regime traffic leaked past the staleness rule — refuse to fold.
fn into_fold_order(mut parts: Vec<UpdatePart>) -> Result<Vec<LocalUpdate>, TransportError> {
    parts.sort_by_key(|p| p.task_idx);
    if parts.windows(2).any(|w| w[0].task_idx == w[1].task_idx) {
        return Err(TransportError::Protocol("duplicate task index in fold"));
    }
    Ok(parts
        .into_iter()
        .map(|p| LocalUpdate { delta: p.delta, samples: p.samples, loss_sum: 0.0 })
        .collect())
}

/// Triage one incoming message: serve rejoin requests inline, drop stale
/// or foreign traffic, pass current collective traffic through.
fn sieve(
    msg: Message,
    tp: &mut dyn Transport,
    ctx: &CollectiveCtx,
    stats: &mut CollectiveStats,
) -> Option<Message> {
    if matches!(msg.payload, Payload::StateRequest) {
        // Reply with the latest *complete* model — the pre-merge snapshot
        // every rank holds. A failed reply is the requester's problem
        // (it may have timed out and left); the collective must not fail.
        let _ = tp.send(msg.from, Payload::Model { iter: ctx.iter, data: ctx.model.clone() });
        stats.state_served += 1;
        return None;
    }
    if msg.epoch < ctx.epoch || !ctx.order.contains(&msg.from) {
        stats.stale_dropped += 1;
        return None;
    }
    Some(msg)
}

/// Receive until a message matching `want` arrives, stashing current
/// collective traffic that belongs to a later step (out-of-order arrival
/// across *senders* is expected — per-pair FIFO is all the transport
/// guarantees).
fn recv_matching(
    tp: &mut dyn Transport,
    ctx: &CollectiveCtx,
    stash: &mut Vec<Message>,
    stats: &mut CollectiveStats,
    want: impl Fn(&Payload) -> bool,
) -> Result<Message, TransportError> {
    if let Some(i) = stash.iter().position(|m| want(&m.payload)) {
        return Ok(stash.swap_remove(i));
    }
    loop {
        let msg = tp.recv(COLLECTIVE_RECV_TIMEOUT)?;
        match sieve(msg, tp, ctx, stats) {
            Some(m) if want(&m.payload) => return Ok(m),
            Some(m) => stash.push(m),
            None => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Backend, CocoaAlgo};
    use crate::config::CocoaConfig;
    use crate::transport::ChannelGroup;
    use std::sync::Arc;

    fn algo(len: usize) -> Arc<dyn Algorithm> {
        Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, len))
    }

    #[test]
    fn single_rank_ring_degenerates_to_local_fold() {
        let len = 17;
        let algo = algo(len);
        let model: ModelVec = (0..len).map(|i| i as f32 * 0.5).collect();
        let update = LocalUpdate { delta: vec![0.25; len], samples: 9, loss_sum: 0.0 };
        let mut serial = model.clone();
        algo.merge(&mut serial, std::slice::from_ref(&update), 1);

        let g = ChannelGroup::new();
        let mut ep = g.join(5);
        let parts = vec![(0usize, update.clone())];
        let ctx = CollectiveCtx {
            algo: algo.as_ref(),
            model: &model,
            parts: &parts,
            k_tasks: 1,
            order: &[5],
            epoch: g.membership().epoch,
            iter: 0,
        };
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            let run = match kind {
                AllreduceKind::Ring => ring_allreduce(&mut ep, &ctx).unwrap(),
                AllreduceKind::Tree => tree_allreduce(&mut ep, &ctx).unwrap(),
            };
            assert_eq!(run.model, serial, "{kind:?}");
            assert_eq!(run.stats.rounds, 0, "a ring of one never touches the wire");
            assert_eq!(run.stats.bytes_sent, 0);
        }
    }

    #[test]
    fn single_rank_hosting_many_tasks_folds_in_task_order() {
        // One thread multiplexing all K logical tasks must still produce
        // the serial fold's bits — including when its hosted parts arrive
        // out of task order (rebinds don't promise sorted hosting).
        let len = 23;
        let algo = algo(len);
        let model: ModelVec = (0..len).map(|i| (i as f32).sin()).collect();
        let updates: Vec<LocalUpdate> = (0..3)
            .map(|t| LocalUpdate {
                delta: (0..len).map(|i| (t * len + i) as f32 * 0.1).collect(),
                samples: 5 + t,
                loss_sum: 0.0,
            })
            .collect();
        let mut serial = model.clone();
        algo.merge(&mut serial, &updates, 3);

        let g = ChannelGroup::new();
        let mut ep = g.join(7);
        let parts =
            vec![(2usize, updates[2].clone()), (0, updates[0].clone()), (1, updates[1].clone())];
        let ctx = CollectiveCtx {
            algo: algo.as_ref(),
            model: &model,
            parts: &parts,
            k_tasks: 3,
            order: &[7],
            epoch: g.membership().epoch,
            iter: 0,
        };
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            let run = match kind {
                AllreduceKind::Ring => ring_allreduce(&mut ep, &ctx).unwrap(),
                AllreduceKind::Tree => tree_allreduce(&mut ep, &ctx).unwrap(),
            };
            assert_eq!(run.model, serial, "{kind:?}");
            assert_eq!(run.stats.rounds, 0);
        }
    }

    #[test]
    fn single_rank_collective_serves_queued_state_requests() {
        // The entry drain is what guarantees a rejoiner is answered even
        // by a rank that never blocks in a receive.
        let len = 8;
        let algo = algo(len);
        let model = vec![1.0f32; len];
        let update = LocalUpdate { delta: vec![0.5; len], samples: 4, loss_sum: 0.0 };
        let g = ChannelGroup::new();
        let mut worker = g.join(1);
        let mut rejoiner = g.join(2);
        rejoiner.send(1, Payload::StateRequest).unwrap();
        let parts = vec![(0usize, update.clone())];
        let ctx = CollectiveCtx {
            algo: algo.as_ref(),
            model: &model,
            parts: &parts,
            k_tasks: 1,
            order: &[1],
            epoch: g.membership().epoch,
            iter: 3,
        };
        let run = ring_allreduce(&mut worker, &ctx).unwrap();
        assert_eq!(run.stats.state_served, 1);
        // The rejoiner gets the latest *complete* (pre-merge) model.
        // (fetch_state sends its own second request — unserved, the
        // collective already finished — but the first reply is queued.)
        let state = fetch_state(&mut rejoiner, 1, Duration::from_millis(50))
            .expect("reply was already queued");
        assert_eq!(state, model);
    }

    #[test]
    fn caller_outside_the_order_is_a_protocol_error() {
        let len = 4;
        let algo = algo(len);
        let model = vec![0.0f32; len];
        let update = LocalUpdate { delta: vec![0.0; len], samples: 1, loss_sum: 0.0 };
        let g = ChannelGroup::new();
        let mut ep = g.join(9);
        let parts = vec![(0usize, update.clone())];
        let ctx = CollectiveCtx {
            algo: algo.as_ref(),
            model: &model,
            parts: &parts,
            k_tasks: 2,
            order: &[1, 2],
            epoch: 0,
            iter: 0,
        };
        assert!(matches!(
            ring_allreduce(&mut ep, &ctx),
            Err(TransportError::Protocol(_))
        ));
    }

    #[test]
    fn duplicate_task_indices_refuse_to_fold() {
        let parts = vec![
            UpdatePart { task_idx: 1, samples: 1, delta: vec![0.0] },
            UpdatePart { task_idx: 1, samples: 2, delta: vec![1.0] },
        ];
        assert!(matches!(
            into_fold_order(parts),
            Err(TransportError::Protocol(_))
        ));
        let parts = vec![
            UpdatePart { task_idx: 1, samples: 1, delta: vec![0.0] },
            UpdatePart { task_idx: 0, samples: 2, delta: vec![1.0] },
        ];
        let updates = into_fold_order(parts).unwrap();
        assert_eq!(updates[0].samples, 2, "sorted into task order");
        assert_eq!(updates[1].samples, 1);
    }
}
