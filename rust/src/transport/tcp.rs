//! The real-socket TCP backend of the [`Transport`] contract.
//!
//! Same group semantics as [`crate::transport::channel`] — a shared
//! registry guarded by one mutex models the control plane (who is a
//! member, at which epoch, listening where), and dropping an endpoint
//! *is* leaving — but the data plane is real `std::net::TcpStream`
//! sockets on loopback, so every byte a collective moves is framed,
//! written to a kernel socket buffer, read back, and decoded. The framing
//! format is specified in `docs/TRANSPORT.md` § "The TCP backend".
//!
//! How each contract obligation is met:
//!
//! * **FIFO per ordered pair** — one connection per ordered pair, one
//!   writer thread per connection fed by an in-order queue, and TCP's own
//!   byte-stream ordering. A receiver's reader threads push into a single
//!   queue, so per-sender order survives the last hop too.
//! * **Deliver or error** — `send` resolves the peer in the registry
//!   (`NoSuchPeer` if it left), connects lazily (`Closed` if the listener
//!   is gone), and enqueues the encoded frame to the writer thread; a
//!   broken connection marks the writer poisoned so the *next* send
//!   errors instead of silently dropping.
//! * **Membership epochs** — the registry holds the epoch; `send` reads
//!   `(addr, epoch)` under one lock acquisition and stamps the frame, so
//!   the stamp is the epoch the peer was observed at. On connect the two
//!   sides exchange a `Hello` frame carrying the dialer's node id and
//!   epoch — the membership handshake that lets a receiver attribute the
//!   connection before any payload arrives.
//! * **Payload integrity** — f32 data travels as little-endian bit
//!   patterns (`to_bits`/`from_bits`), so NaN payloads and negative
//!   zeros survive the trip bit-for-bit.
//!
//! Framing overhead (length prefixes, tags, handshakes — every wire byte
//! that is not f32 payload) is tallied per endpoint and surfaced through
//! [`Transport::frame_bytes`], which is how the metrics log reports a
//! *measured* framing-overhead column next to the backend-independent
//! `transport_bytes`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::NodeId;

use super::{Membership, Message, Payload, Residency, Transport, TransportError, UpdatePart};

/// How long a blocked reader waits per `read` before re-checking the
/// shutdown flag. Bounds how long `drop` can take, not message latency.
const READER_POLL: Duration = Duration::from_millis(25);

struct TcpInner {
    epoch: u64,
    /// Where each member's acceptor listens. The control plane: a real
    /// multi-process deployment would replace this map with a discovery
    /// service, and nothing else in the file would change.
    members: HashMap<NodeId, SocketAddr>,
}

/// The shared registry of the TCP backend: membership + epoch + listen
/// addresses, plus the group's payload [`Residency`]. All mutation goes
/// through [`TcpGroup::join`] and endpoint drop; both bump the epoch.
pub struct TcpGroup {
    inner: Mutex<TcpInner>,
    residency: Residency,
}

impl TcpGroup {
    pub fn new() -> Arc<Self> {
        Arc::new(TcpGroup {
            inner: Mutex::new(TcpInner { epoch: 0, members: HashMap::new() }),
            residency: Residency::default(),
        })
    }

    /// Bind a loopback listener for `node`, add it to the group, and hand
    /// back its endpoint. Bumps the epoch. Panics if the node is already
    /// a member — a rejoining worker must have dropped its previous
    /// endpoint first (the worker thread's exit guarantees this on the
    /// revoke path).
    pub fn join(self: &Arc<Self>, node: NodeId) -> TcpEndpoint {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind tcp transport listener");
        let addr = listener.local_addr().expect("tcp listener local addr");
        {
            let mut inner = self.inner.lock().expect("transport group lock");
            assert!(
                inner.members.insert(node, addr).is_none(),
                "node {node} already in the transport group"
            );
            inner.epoch += 1;
        }
        let (in_tx, in_rx) = channel();
        let shutdown = Arc::new(AtomicBool::new(false));
        let readers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::default();
        let acceptor = {
            let (tx, flag, readers) = (in_tx, Arc::clone(&shutdown), Arc::clone(&readers));
            std::thread::Builder::new()
                .name(format!("tcp-accept-{node}"))
                .spawn(move || accept_loop(listener, tx, flag, readers))
                .expect("spawn tcp acceptor thread")
        };
        TcpEndpoint {
            group: Arc::clone(self),
            node,
            addr,
            rx: in_rx,
            writers: HashMap::new(),
            frame_overhead: 0,
            shutdown,
            acceptor: Some(acceptor),
            readers,
        }
    }

    /// Current membership snapshot (epoch + sorted members).
    pub fn membership(&self) -> Membership {
        let inner = self.inner.lock().expect("transport group lock");
        let mut members: Vec<NodeId> = inner.members.keys().copied().collect();
        members.sort_unstable();
        Membership { epoch: inner.epoch, members }
    }

    /// The group's payload-residency map (shared with the scheduler).
    pub fn residency(&self) -> &Residency {
        &self.residency
    }

    fn leave(&self, node: NodeId) {
        let mut inner = self.inner.lock().expect("transport group lock");
        if inner.members.remove(&node).is_some() {
            inner.epoch += 1;
        }
        drop(inner);
        self.residency.forget(node);
    }

    /// `(listen addr, epoch)` for a live member, under one lock
    /// acquisition so the stamped epoch is the one the member was
    /// observed at.
    fn addr_of(&self, to: NodeId) -> Result<(SocketAddr, u64), TransportError> {
        let inner = self.inner.lock().expect("transport group lock");
        match inner.members.get(&to) {
            Some(addr) => Ok((*addr, inner.epoch)),
            None => Err(TransportError::NoSuchPeer(to)),
        }
    }
}

/// One member's handle on a [`TcpGroup`]: its listener/acceptor, reader
/// threads, per-peer writer threads, and receive queue. Owned by exactly
/// one worker thread; dropping it leaves the group (epoch bump, residency
/// forgotten, listener and connections torn down — connection drop *is*
/// leave, exactly like a departed node in a real cluster).
pub struct TcpEndpoint {
    group: Arc<TcpGroup>,
    node: NodeId,
    addr: SocketAddr,
    rx: Receiver<Message>,
    writers: HashMap<NodeId, PeerWriter>,
    frame_overhead: usize,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// One outbound connection: an in-order frame queue draining into a
/// dedicated writer thread that owns the socket.
struct PeerWriter {
    /// The listen address the connection was dialed to; if the peer
    /// rejoined on a new listener the cached connection is stale and the
    /// next send re-dials.
    addr: SocketAddr,
    tx: Sender<Vec<u8>>,
    /// Set by the writer thread on a failed write: the connection is
    /// dead, and the next send must error instead of enqueueing into a
    /// black hole (deliver-or-error).
    broken: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for PeerWriter {
    fn drop(&mut self) {
        // Dropping the queue sender lets the writer drain what is already
        // enqueued, then exit — in-flight frames are flushed, not lost.
        // (The sender must go before the join, or the writer never wakes.)
        drop(std::mem::replace(&mut self.tx, channel().0));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl TcpEndpoint {
    /// Get (or lazily dial) the writer for `to`'s current listen address.
    fn writer_to(&mut self, to: NodeId, addr: SocketAddr, epoch: u64) -> Result<(), TransportError> {
        let stale = self
            .writers
            .get(&to)
            .is_some_and(|w| w.addr != addr || w.broken.load(Ordering::Acquire));
        if stale {
            self.writers.remove(&to);
        }
        if self.writers.contains_key(&to) {
            return Ok(());
        }
        let stream = TcpStream::connect(addr).map_err(|_| TransportError::Closed(to))?;
        stream.set_nodelay(true).ok();
        let (tx, rx) = channel::<Vec<u8>>();
        let broken = Arc::new(AtomicBool::new(false));
        let handle = {
            let broken = Arc::clone(&broken);
            std::thread::Builder::new()
                .name(format!("tcp-write-{}-{}", self.node, to))
                .spawn(move || write_loop(stream, rx, broken))
                .expect("spawn tcp writer thread")
        };
        // Membership handshake: the first frame on every connection names
        // the dialer and stamps its epoch, so the accepting side can
        // attribute the stream before any payload arrives.
        let hello = encode_hello(self.node, epoch);
        self.frame_overhead += hello.len();
        tx.send(hello).map_err(|_| TransportError::Closed(to))?;
        self.writers.insert(to, PeerWriter { addr, tx, broken, handle: Some(handle) });
        Ok(())
    }
}

impl Transport for TcpEndpoint {
    fn node(&self) -> NodeId {
        self.node
    }

    fn membership(&self) -> Membership {
        self.group.membership()
    }

    fn send(&mut self, to: NodeId, payload: Payload) -> Result<(), TransportError> {
        let (addr, epoch) = self.group.addr_of(to)?;
        self.writer_to(to, addr, epoch)?;
        let msg = Message { from: self.node, epoch, payload };
        let frame = encode_message(&msg);
        self.frame_overhead += frame.len() - msg.payload.wire_bytes();
        let w = self.writers.get(&to).expect("writer just ensured");
        if w.broken.load(Ordering::Acquire) || w.tx.send(frame).is_err() {
            self.writers.remove(&to);
            return Err(TransportError::Closed(to));
        }
        Ok(())
    }

    fn recv(&mut self, timeout: Duration) -> Result<Message, TransportError> {
        use std::sync::mpsc::RecvTimeoutError;
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => TransportError::Timeout,
            // Only possible once the acceptor has shut down, i.e. this
            // endpoint has already left the group.
            RecvTimeoutError::Disconnected => TransportError::Closed(self.node),
        })
    }

    fn try_recv(&mut self) -> Option<Message> {
        self.rx.try_recv().ok()
    }

    fn frame_bytes(&self) -> usize {
        self.frame_overhead
    }
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        // Leave first so peers' registry lookups fail fast (NoSuchPeer)
        // while the sockets are still draining.
        self.group.leave(self.node);
        // Flush + close outbound connections (PeerWriter::drop joins each
        // writer after it drains its queue).
        self.writers.clear();
        // Stop the acceptor: set the flag, then dial the listener once so
        // a blocked `accept` wakes up and observes it.
        self.shutdown.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let readers = std::mem::take(&mut *self.readers.lock().expect("tcp reader registry"));
        for h in readers {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    tx: Sender<Message>,
    shutdown: Arc<AtomicBool>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let Ok((stream, _)) = listener.accept() else { return };
        if shutdown.load(Ordering::Acquire) {
            return;
        }
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(READER_POLL)).ok();
        let handle = {
            let (tx, flag) = (tx.clone(), Arc::clone(&shutdown));
            std::thread::Builder::new()
                .name("tcp-read".into())
                .spawn(move || read_loop(stream, tx, flag))
                .expect("spawn tcp reader thread")
        };
        readers.lock().expect("tcp reader registry").push(handle);
    }
}

fn write_loop(mut stream: TcpStream, rx: Receiver<Vec<u8>>, broken: Arc<AtomicBool>) {
    while let Ok(frame) = rx.recv() {
        if stream.write_all(&frame).is_err() {
            broken.store(true, Ordering::Release);
            return;
        }
    }
    let _ = stream.flush();
}

fn read_loop(mut stream: TcpStream, tx: Sender<Message>, shutdown: Arc<AtomicBool>) {
    // First frame must be the membership handshake.
    let Some(hello) = read_frame(&mut stream, &shutdown) else { return };
    if decode_hello(&hello).is_none() {
        return; // not a handshake: protocol violation, drop the stream
    }
    while let Some(frame) = read_frame(&mut stream, &shutdown) {
        let Some(msg) = decode_message(&frame) else { return };
        if tx.send(msg).is_err() {
            return; // endpoint gone — nobody left to deliver to
        }
    }
}

/// Read one length-prefixed frame, polling the shutdown flag between
/// timed-out reads. `None` on EOF, shutdown, or a malformed prefix.
fn read_frame(stream: &mut TcpStream, shutdown: &AtomicBool) -> Option<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    read_exact_polling(stream, &mut len_buf, shutdown)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        return None;
    }
    let mut body = vec![0u8; len];
    read_exact_polling(stream, &mut body, shutdown)?;
    Some(body)
}

/// Upper bound on a sane frame (a full model of ~256M f32s); anything
/// larger is a corrupt length prefix, not a payload.
const MAX_FRAME_BYTES: usize = 1 << 30;

fn read_exact_polling(stream: &mut TcpStream, buf: &mut [u8], shutdown: &AtomicBool) -> Option<()> {
    let mut filled = 0;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return None, // EOF
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::Acquire) {
                    return None;
                }
            }
            Err(_) => return None,
        }
    }
    Some(())
}

// ---------------------------------------------------------------------
// Frame codec: `[u32 len][u8 tag][fields…]`, all little-endian, f32 as
// raw bit patterns. Hand-rolled — the offline crate set has no serde —
// and round-trip-tested below. The format is documented for other
// implementations in docs/TRANSPORT.md § "The TCP backend".
// ---------------------------------------------------------------------

const TAG_HELLO: u8 = 0;
const TAG_MESSAGE: u8 = 1;

const PTAG_UPDATE_SLICE: u8 = 0;
const PTAG_SEGMENT: u8 = 1;
const PTAG_UPDATES: u8 = 2;
const PTAG_MODEL: u8 = 3;
const PTAG_STATE_REQUEST: u8 = 4;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, data: &[f32]) {
    put_u64(buf, data.len() as u64);
    for v in data {
        buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

fn put_part(buf: &mut Vec<u8>, part: &UpdatePart) {
    put_u64(buf, part.task_idx as u64);
    put_u64(buf, part.samples as u64);
    put_f32s(buf, &part.delta);
}

/// Wrap an encoded body in the `[u32 len]` prefix.
fn frame(body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

fn encode_hello(node: NodeId, epoch: u64) -> Vec<u8> {
    let mut body = vec![TAG_HELLO];
    put_u32(&mut body, node);
    put_u64(&mut body, epoch);
    frame(body)
}

fn encode_message(msg: &Message) -> Vec<u8> {
    let mut body = vec![TAG_MESSAGE];
    body.reserve(21 + msg.payload.wire_bytes());
    put_u32(&mut body, msg.from);
    put_u64(&mut body, msg.epoch);
    match &msg.payload {
        Payload::UpdateSlice { iter, seg, part } => {
            body.push(PTAG_UPDATE_SLICE);
            put_u64(&mut body, *iter);
            put_u64(&mut body, *seg as u64);
            put_part(&mut body, part);
        }
        Payload::Segment { iter, seg, data } => {
            body.push(PTAG_SEGMENT);
            put_u64(&mut body, *iter);
            put_u64(&mut body, *seg as u64);
            put_f32s(&mut body, data);
        }
        Payload::Updates { iter, parts } => {
            body.push(PTAG_UPDATES);
            put_u64(&mut body, *iter);
            put_u64(&mut body, parts.len() as u64);
            for p in parts {
                put_part(&mut body, p);
            }
        }
        Payload::Model { iter, data } => {
            body.push(PTAG_MODEL);
            put_u64(&mut body, *iter);
            put_f32s(&mut body, data);
        }
        Payload::StateRequest => body.push(PTAG_STATE_REQUEST),
    }
    frame(body)
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let slice = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(slice)
    }

    fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    fn f32s(&mut self) -> Option<Vec<f32>> {
        let n = self.u64()? as usize;
        // A length that cannot fit in the remaining bytes is corruption.
        if n > (self.buf.len() - self.pos) / 4 {
            return None;
        }
        let raw = self.take(n * 4)?;
        Some(
            raw.chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                .collect(),
        )
    }

    fn part(&mut self) -> Option<UpdatePart> {
        let task_idx = self.u64()? as usize;
        let samples = self.u64()? as usize;
        let delta = self.f32s()?;
        Some(UpdatePart { task_idx, samples, delta })
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn decode_hello(body: &[u8]) -> Option<(NodeId, u64)> {
    let mut c = Cursor::new(body);
    if c.u8()? != TAG_HELLO {
        return None;
    }
    let node = c.u32()?;
    let epoch = c.u64()?;
    c.done().then_some((node, epoch))
}

fn decode_message(body: &[u8]) -> Option<Message> {
    let mut c = Cursor::new(body);
    if c.u8()? != TAG_MESSAGE {
        return None;
    }
    let from = c.u32()?;
    let epoch = c.u64()?;
    let payload = match c.u8()? {
        PTAG_UPDATE_SLICE => {
            let iter = c.u64()?;
            let seg = c.u64()? as usize;
            let part = c.part()?;
            Payload::UpdateSlice { iter, seg, part }
        }
        PTAG_SEGMENT => {
            let iter = c.u64()?;
            let seg = c.u64()? as usize;
            let data = c.f32s()?;
            Payload::Segment { iter, seg, data }
        }
        PTAG_UPDATES => {
            let iter = c.u64()?;
            let n = c.u64()? as usize;
            let mut parts = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                parts.push(c.part()?);
            }
            Payload::Updates { iter, parts }
        }
        PTAG_MODEL => {
            let iter = c.u64()?;
            let data = c.f32s()?;
            Payload::Model { iter, data }
        }
        PTAG_STATE_REQUEST => Payload::StateRequest,
        _ => return None,
    };
    c.done().then_some(Message { from, epoch, payload })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(payload: Payload) -> Message {
        let msg = Message { from: 7, epoch: 3, payload };
        let frame = encode_message(&msg);
        let (prefix, body) = frame.split_at(4);
        assert_eq!(u32::from_le_bytes(prefix.try_into().unwrap()) as usize, body.len());
        decode_message(body).expect("frame must decode")
    }

    #[test]
    fn codec_roundtrips_every_payload_bit_for_bit() {
        // Deliberately nasty f32s: NaN with a payload, -0.0, subnormals.
        let nasty = vec![f32::from_bits(0x7fc0_dead), -0.0, 1.0e-42, f32::MAX, -3.5];
        let part = UpdatePart { task_idx: 5, samples: 1999, delta: nasty.clone() };
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();

        let m = roundtrip(Payload::UpdateSlice { iter: 42, seg: 3, part: part.clone() });
        assert_eq!((m.from, m.epoch), (7, 3));
        match m.payload {
            Payload::UpdateSlice { iter: 42, seg: 3, part: p } => {
                assert_eq!((p.task_idx, p.samples), (5, 1999));
                assert_eq!(bits(&p.delta), bits(&nasty));
            }
            p => panic!("wrong payload {p:?}"),
        }

        match roundtrip(Payload::Segment { iter: 1, seg: 0, data: nasty.clone() }).payload {
            Payload::Segment { iter: 1, seg: 0, data } => assert_eq!(bits(&data), bits(&nasty)),
            p => panic!("wrong payload {p:?}"),
        }

        let empty = UpdatePart { task_idx: 0, samples: 1, delta: vec![] };
        match roundtrip(Payload::Updates { iter: 9, parts: vec![part, empty] }).payload {
            Payload::Updates { iter: 9, parts } => {
                assert_eq!(parts.len(), 2);
                assert_eq!(bits(&parts[0].delta), bits(&nasty));
                assert!(parts[1].delta.is_empty());
            }
            p => panic!("wrong payload {p:?}"),
        }

        match roundtrip(Payload::Model { iter: 2, data: vec![0.5; 3] }).payload {
            Payload::Model { iter: 2, data } => assert_eq!(data, vec![0.5; 3]),
            p => panic!("wrong payload {p:?}"),
        }

        assert!(matches!(roundtrip(Payload::StateRequest).payload, Payload::StateRequest));
    }

    #[test]
    fn codec_rejects_truncated_and_oversized_frames() {
        let msg = Message {
            from: 1,
            epoch: 0,
            payload: Payload::Segment { iter: 0, seg: 0, data: vec![1.0, 2.0] },
        };
        let full = encode_message(&msg);
        let body = &full[4..];
        for cut in 0..body.len() {
            assert!(decode_message(&body[..cut]).is_none(), "truncation at {cut} decoded");
        }
        // Trailing garbage is corruption, not padding.
        let mut long = body.to_vec();
        long.push(0);
        assert!(decode_message(&long).is_none());
        // An f32 count pointing past the end of the frame must not allocate.
        let mut lying = vec![TAG_MESSAGE];
        put_u32(&mut lying, 1);
        put_u64(&mut lying, 0);
        lying.push(PTAG_MODEL);
        put_u64(&mut lying, 0);
        put_u64(&mut lying, u64::MAX);
        assert!(decode_message(&lying).is_none());
    }

    #[test]
    fn join_leave_bump_epoch_and_sort_members() {
        let g = TcpGroup::new();
        assert_eq!(g.membership().epoch, 0);
        assert!(g.membership().is_empty());
        let a = g.join(3);
        let b = g.join(1);
        let m = g.membership();
        assert_eq!(m.epoch, 2);
        assert_eq!(m.members, vec![1, 3]);
        drop(a);
        let m = g.membership();
        assert_eq!(m.epoch, 3);
        assert_eq!(m.members, vec![1]);
        drop(b);
        assert_eq!(g.membership().epoch, 4);
        assert!(g.membership().is_empty());
    }

    #[test]
    fn send_recv_roundtrip_over_a_real_socket() {
        let g = TcpGroup::new();
        let mut a = g.join(10);
        let mut b = g.join(20);
        a.send(20, Payload::Segment { iter: 7, seg: 1, data: vec![1.0, 2.0] }).unwrap();
        let msg = b.recv(Duration::from_secs(5)).unwrap();
        assert_eq!(msg.from, 10);
        assert_eq!(msg.epoch, 2, "stamped with the epoch at send time");
        match msg.payload {
            Payload::Segment { iter: 7, seg: 1, ref data } => assert_eq!(data, &[1.0, 2.0]),
            ref p => panic!("unexpected payload {p:?}"),
        }
        assert!(matches!(b.recv(Duration::from_millis(5)), Err(TransportError::Timeout)));
    }

    #[test]
    fn per_pair_fifo_is_preserved() {
        let g = TcpGroup::new();
        let mut a = g.join(1);
        let mut b = g.join(2);
        for seg in 0..32usize {
            a.send(2, Payload::Segment { iter: 0, seg, data: vec![] }).unwrap();
        }
        for seg in 0..32usize {
            match b.recv(Duration::from_secs(5)).unwrap().payload {
                Payload::Segment { seg: s, .. } => assert_eq!(s, seg, "FIFO violated"),
                ref p => panic!("unexpected payload {p:?}"),
            }
        }
    }

    #[test]
    fn send_to_departed_peer_errors() {
        let g = TcpGroup::new();
        let mut a = g.join(1);
        let b = g.join(2);
        drop(b);
        assert!(matches!(a.send(2, Payload::StateRequest), Err(TransportError::NoSuchPeer(2))));
    }

    #[test]
    fn leaving_forgets_residency() {
        let g = TcpGroup::new();
        let a = g.join(1);
        g.residency().record(1, 42);
        assert!(g.residency().resident(1, 42));
        drop(a);
        assert!(!g.residency().resident(1, 42));
    }

    #[test]
    fn frame_overhead_counts_every_non_payload_byte() {
        let g = TcpGroup::new();
        let mut a = g.join(1);
        let mut b = g.join(2);
        assert_eq!(a.frame_bytes(), 0);
        let payload = Payload::Segment { iter: 0, seg: 0, data: vec![1.0; 8] };
        let wire = payload.wire_bytes();
        a.send(2, payload.clone()).unwrap();
        // Hello frame + (message frame − f32 payload), both pure overhead.
        let hello = encode_hello(1, 2).len();
        let per_msg = encode_message(&Message { from: 1, epoch: 2, payload }).len() - wire;
        assert_eq!(a.frame_bytes(), hello + per_msg);
        a.send(2, Payload::StateRequest).unwrap();
        let req = Message { from: 1, epoch: 2, payload: Payload::StateRequest };
        assert_eq!(a.frame_bytes(), hello + per_msg + encode_message(&req).len());
        // The receiver counted nothing: overhead is tallied where it is
        // written, so summing over endpoints never double-counts.
        let _ = b.recv(Duration::from_secs(5)).unwrap();
        assert_eq!(b.frame_bytes(), 0);
    }

    #[test]
    fn messages_enqueued_before_drop_are_flushed_not_lost() {
        let g = TcpGroup::new();
        let mut a = g.join(1);
        let mut b = g.join(2);
        for seg in 0..8usize {
            a.send(2, Payload::Segment { iter: 3, seg, data: vec![0.25; 4] }).unwrap();
        }
        drop(a); // writer drains its queue before the connection closes
        for seg in 0..8usize {
            match b.recv(Duration::from_secs(5)).unwrap().payload {
                Payload::Segment { seg: s, .. } => assert_eq!(s, seg),
                ref p => panic!("unexpected payload {p:?}"),
            }
        }
    }
}
