//! Deterministic fault injection for any [`Transport`] backend.
//!
//! [`FaultTransport`] wraps an endpoint and perturbs its *send* side
//! according to an explicit [`FaultPlan`]: per-edge drop, duplicate,
//! reorder, and delay, plus whole-endpoint death after N emissions
//! (kill-peer-at-round-N). Everything is a pure function of the plan and
//! the operation sequence — no clocks, no randomness at injection time —
//! so a failing schedule replays exactly. The chaos property suite
//! (`rust/tests/transport_chaos.rs`) uses this to assert the collectives'
//! central robustness claim: under any injected fault the merge is either
//! **bit-identical** to the serial fold or **fails loudly** — there is no
//! silent-corruption outcome. `docs/TRANSPORT.md` § "Fault-injection
//! matrix" maps each fault class to the rule that absorbs it.
//!
//! Faults are keyed by *emission* index per destination edge, not by
//! send-call index: a duplicated message's copy is itself emission
//! `n + 1` and can be targeted by further rules. That is what makes the
//! canonical absorbed-drop schedule expressible — `Duplicate{nth: i}`
//! followed by `Drop{nth: i + 1}` kills exactly the redundant copy, so
//! the wire carries precisely the original traffic.
//!
//! Time is modeled as *operation ticks* (every `send`/`recv`/`try_recv`
//! advances the clock by one), so a `Delay` releases after a fixed number
//! of the wrapped endpoint's own operations — deterministic where a
//! wall-clock delay would race.

use std::collections::HashMap;
use std::time::Duration;

use crate::cluster::NodeId;
use crate::util::Rng;

use super::{Membership, Message, Payload, Transport, TransportError};

/// One injected fault. `nth` counts emissions on the edge to `to`,
/// starting at 0.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Silently swallow the edge's `nth` emission — the one fault class
    /// that deliberately violates deliver-or-error, to prove a lost
    /// essential message surfaces as a loud timeout (never wrong bits).
    Drop { to: NodeId, nth: usize },
    /// Emit the edge's `nth` emission twice; the copy becomes emission
    /// `nth + 1` and is itself subject to the plan.
    Duplicate { to: NodeId, nth: usize },
    /// Hold the edge's `nth` emission and release it *after* the edge's
    /// next wire emission — adjacent messages on one pair swap places,
    /// the minimal FIFO violation.
    Reorder { to: NodeId, nth: usize },
    /// Hold the edge's `nth` emission for `ops` operation ticks, then
    /// release it at the start of a later operation (or at drop).
    Delay { to: NodeId, nth: usize, ops: usize },
    /// The endpoint dies once it has emitted `after` messages in total:
    /// every later operation returns `Closed(self)` and held messages
    /// are discarded — a crashed peer mid-collective.
    KillAfterSends { after: usize },
}

/// A deterministic fault schedule plus an optional receive-timeout cap.
///
/// The cap exists because the collectives' `recv` backstop is generous
/// (10 s): a chaos schedule that starves a rank *should* fail loudly, and
/// the cap makes it fail in milliseconds so sweeping many seeds stays
/// cheap. It never changes the outcome, only how long a doomed wait lasts.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    pub recv_cap: Option<Duration>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> Self {
        FaultPlan { faults, recv_cap: None }
    }

    pub fn with_recv_cap(mut self, cap: Duration) -> Self {
        self.recv_cap = Some(cap);
        self
    }

    /// An empty plan: the decorator becomes a transparent wrapper (used
    /// for ranks that carry no faults in a seeded schedule).
    pub fn clean() -> Self {
        FaultPlan::default()
    }
}

/// One seeded fault schedule over a rank order: for each rank, a plan.
///
/// Derived deterministically from `seed` with the crate's own
/// [`Rng`] — the same seed always yields the same schedule, which is what
/// lets CI upload a failing seed and a developer replay it locally
/// (`CHICLE_CHAOS_SEED=n cargo test --test transport_chaos`). Each
/// schedule injects one to three faults of random class on random edges;
/// every class is reachable.
pub fn seeded_schedule(seed: u64, order: &[NodeId]) -> Vec<FaultPlan> {
    let k = order.len();
    let mut rng = Rng::seed_from_u64(seed ^ 0x5eed_fa17u64.wrapping_mul(k as u64));
    let mut plans = vec![FaultPlan::clean(); k];
    if k < 2 {
        return plans;
    }
    let n_faults = 1 + rng.below(3);
    for _ in 0..n_faults {
        let rank = rng.below(k);
        let to = order[(rank + 1 + rng.below(k - 1)) % k];
        let nth = rng.below(4);
        let fault = match rng.below(5) {
            0 => Fault::Drop { to, nth },
            1 => Fault::Duplicate { to, nth },
            2 => Fault::Reorder { to, nth },
            3 => Fault::Delay { to, nth, ops: 1 + rng.below(4) },
            _ => Fault::KillAfterSends { after: 1 + rng.below(2 * k) },
        };
        plans[rank].faults.push(fault);
    }
    plans
}

/// A [`Transport`] decorator that applies a [`FaultPlan`] to the wrapped
/// endpoint. See the module docs for the fault semantics.
pub struct FaultTransport {
    inner: Option<Box<dyn Transport>>,
    plan: FaultPlan,
    /// Emission counter per destination edge.
    emitted: HashMap<NodeId, usize>,
    total_emitted: usize,
    /// Messages held by a `Reorder`, keyed by edge, released after the
    /// edge's next wire emission.
    reorder_held: HashMap<NodeId, Vec<Payload>>,
    /// Messages held by a `Delay`, released once `ticks` passes the due
    /// tick (or at drop).
    delay_held: Vec<(NodeId, Payload, usize)>,
    ticks: usize,
    dead: bool,
}

impl FaultTransport {
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> Self {
        FaultTransport {
            inner: Some(inner),
            plan,
            emitted: HashMap::new(),
            total_emitted: 0,
            reorder_held: HashMap::new(),
            delay_held: Vec::new(),
            ticks: 0,
            dead: false,
        }
    }

    /// Unwrap the decorator, flushing held messages first. Lets a chaos
    /// scenario keep using a "crashed" rank's underlying endpoint — e.g.
    /// to model straggling traffic from a dead regime arriving after the
    /// survivors relaunched.
    pub fn into_inner(mut self) -> Box<dyn Transport> {
        if !self.dead {
            self.flush_held();
        }
        self.delay_held.clear();
        self.reorder_held.clear();
        self.inner.take().expect("fault transport already unwrapped")
    }

    fn t(&mut self) -> &mut dyn Transport {
        self.inner.as_mut().expect("fault transport inner").as_mut()
    }

    /// Advance the operation clock; release due delays; apply the kill
    /// switch. Returns the error every operation must surface once dead.
    fn tick(&mut self) -> Result<(), TransportError> {
        if !self.dead
            && self.plan.faults.iter().any(
                |f| matches!(f, Fault::KillAfterSends { after } if self.total_emitted >= *after),
            )
        {
            self.dead = true;
            self.delay_held.clear();
            self.reorder_held.clear();
        }
        if self.dead {
            let me = self.t().node();
            return Err(TransportError::Closed(me));
        }
        self.ticks += 1;
        let due: Vec<(NodeId, Payload)> = {
            let ticks = self.ticks;
            let mut released = Vec::new();
            self.delay_held.retain(|(to, payload, due_tick)| {
                if *due_tick <= ticks {
                    released.push((*to, payload.clone()));
                    false
                } else {
                    true
                }
            });
            released
        };
        for (to, payload) in due {
            let _ = self.t().send(to, payload);
        }
        Ok(())
    }

    fn flush_held(&mut self) {
        let delayed: Vec<(NodeId, Payload)> =
            self.delay_held.drain(..).map(|(to, p, _)| (to, p)).collect();
        for (to, p) in delayed {
            let _ = self.t().send(to, p);
        }
        let reordered: Vec<(NodeId, Payload)> = self
            .reorder_held
            .drain()
            .flat_map(|(to, held)| held.into_iter().map(move |p| (to, p)))
            .collect();
        for (to, p) in reordered {
            let _ = self.t().send(to, p);
        }
    }

    /// Emit one message on an edge, applying whatever fault targets this
    /// emission index. A `Duplicate` recurses so the copy gets the next
    /// index and is itself subject to the plan.
    fn emit(&mut self, to: NodeId, payload: Payload) -> Result<(), TransportError> {
        let n = {
            let c = self.emitted.entry(to).or_insert(0);
            let n = *c;
            *c += 1;
            n
        };
        self.total_emitted += 1;
        let fault = self
            .plan
            .faults
            .iter()
            .find(|f| match f {
                Fault::Drop { to: t, nth }
                | Fault::Duplicate { to: t, nth }
                | Fault::Reorder { to: t, nth }
                | Fault::Delay { to: t, nth, .. } => *t == to && *nth == n,
                Fault::KillAfterSends { .. } => false,
            })
            .cloned();
        match fault {
            Some(Fault::Drop { .. }) => Ok(()), // swallowed: the fault under test
            Some(Fault::Reorder { .. }) => {
                self.reorder_held.entry(to).or_default().push(payload);
                Ok(())
            }
            Some(Fault::Delay { ops, .. }) => {
                self.delay_held.push((to, payload, self.ticks + ops));
                Ok(())
            }
            Some(Fault::Duplicate { .. }) => {
                self.wire(to, payload.clone())?;
                self.emit(to, payload)
            }
            _ => self.wire(to, payload),
        }
    }

    /// Put a message on the actual wire, then release anything a
    /// `Reorder` was holding on this edge (it now travels *behind* the
    /// message that overtook it).
    fn wire(&mut self, to: NodeId, payload: Payload) -> Result<(), TransportError> {
        self.t().send(to, payload)?;
        if let Some(held) = self.reorder_held.remove(&to) {
            for p in held {
                self.t().send(to, p)?;
            }
        }
        Ok(())
    }
}

impl Transport for FaultTransport {
    fn node(&self) -> NodeId {
        self.inner.as_ref().expect("fault transport inner").node()
    }

    fn membership(&self) -> Membership {
        self.inner.as_ref().expect("fault transport inner").membership()
    }

    fn send(&mut self, to: NodeId, payload: Payload) -> Result<(), TransportError> {
        self.tick()?;
        self.emit(to, payload)
    }

    fn recv(&mut self, timeout: Duration) -> Result<Message, TransportError> {
        self.tick()?;
        let capped = match self.plan.recv_cap {
            Some(cap) => timeout.min(cap),
            None => timeout,
        };
        self.t().recv(capped)
    }

    fn try_recv(&mut self) -> Option<Message> {
        if self.tick().is_err() {
            return None;
        }
        self.t().try_recv()
    }

    fn frame_bytes(&self) -> usize {
        self.inner.as_ref().expect("fault transport inner").frame_bytes()
    }
}

impl Drop for FaultTransport {
    fn drop(&mut self) {
        // A live endpoint flushes held messages on the way out (a delayed
        // message is late, not lost); a dead one keeps nothing.
        if self.inner.is_some() && !self.dead {
            self.flush_held();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::ChannelGroup;

    fn seg(seg: usize) -> Payload {
        Payload::Segment { iter: 0, seg, data: vec![seg as f32] }
    }

    fn recv_segs(ep: &mut dyn Transport, n: usize) -> Vec<usize> {
        (0..n)
            .map(|_| match ep.recv(Duration::from_secs(1)).unwrap().payload {
                Payload::Segment { seg, .. } => seg,
                p => panic!("unexpected payload {p:?}"),
            })
            .collect()
    }

    fn pair(plan: FaultPlan) -> (FaultTransport, Box<dyn Transport>) {
        let g = ChannelGroup::new();
        let a = g.join(1);
        let b = g.join(2);
        (FaultTransport::new(Box::new(a), plan), Box::new(b))
    }

    #[test]
    fn drop_swallows_exactly_the_nth_emission() {
        let (mut a, mut b) =
            pair(FaultPlan::new(vec![Fault::Drop { to: 2, nth: 1 }]));
        for s in 0..3 {
            a.send(2, seg(s)).unwrap();
        }
        assert_eq!(recv_segs(b.as_mut(), 2), vec![0, 2]);
        assert!(b.try_recv().is_none());
    }

    #[test]
    fn duplicate_emits_twice_and_dup_plus_drop_nets_the_original() {
        let (mut a, mut b) =
            pair(FaultPlan::new(vec![Fault::Duplicate { to: 2, nth: 0 }]));
        a.send(2, seg(7)).unwrap();
        assert_eq!(recv_segs(b.as_mut(), 2), vec![7, 7]);

        // The copy is emission 1; dropping it restores the exact wire.
        let (mut a, mut b) = pair(FaultPlan::new(vec![
            Fault::Duplicate { to: 2, nth: 0 },
            Fault::Drop { to: 2, nth: 1 },
        ]));
        a.send(2, seg(7)).unwrap();
        a.send(2, seg(8)).unwrap();
        assert_eq!(recv_segs(b.as_mut(), 2), vec![7, 8]);
        assert!(b.try_recv().is_none());
    }

    #[test]
    fn reorder_swaps_adjacent_messages_on_one_edge() {
        let (mut a, mut b) =
            pair(FaultPlan::new(vec![Fault::Reorder { to: 2, nth: 1 }]));
        for s in 0..4 {
            a.send(2, seg(s)).unwrap();
        }
        assert_eq!(recv_segs(b.as_mut(), 4), vec![0, 2, 1, 3]);
    }

    #[test]
    fn delay_releases_after_the_configured_operation_ticks() {
        let (mut a, mut b) =
            pair(FaultPlan::new(vec![Fault::Delay { to: 2, nth: 0, ops: 2 }]));
        a.send(2, seg(0)).unwrap(); // held, due at tick 3
        a.send(2, seg(1)).unwrap(); // tick 2
        assert_eq!(recv_segs(b.as_mut(), 1), vec![1]);
        assert!(b.try_recv().is_none(), "delayed message released too early");
        a.send(2, seg(2)).unwrap(); // tick 3: releases seg 0 first
        assert_eq!(recv_segs(b.as_mut(), 2), vec![0, 2]);
    }

    #[test]
    fn held_messages_are_flushed_at_drop_not_lost() {
        let (mut a, mut b) = pair(FaultPlan::new(vec![
            Fault::Delay { to: 2, nth: 0, ops: 1000 },
            Fault::Reorder { to: 2, nth: 1 },
        ]));
        a.send(2, seg(0)).unwrap();
        a.send(2, seg(1)).unwrap();
        assert!(b.try_recv().is_none());
        drop(a);
        assert_eq!(recv_segs(b.as_mut(), 2), vec![0, 1]);
    }

    #[test]
    fn kill_after_sends_makes_every_later_operation_fail_closed() {
        let (mut a, mut b) =
            pair(FaultPlan::new(vec![Fault::KillAfterSends { after: 2 }]));
        a.send(2, seg(0)).unwrap();
        a.send(2, seg(1)).unwrap();
        assert!(matches!(a.send(2, seg(2)), Err(TransportError::Closed(1))));
        assert!(matches!(a.recv(Duration::from_millis(5)), Err(TransportError::Closed(1))));
        assert!(a.try_recv().is_none());
        assert_eq!(recv_segs(b.as_mut(), 2), vec![0, 1]);
        assert!(b.try_recv().is_none());
    }

    #[test]
    fn recv_cap_shortens_a_doomed_wait() {
        let (mut a, _b) = pair(
            FaultPlan::new(vec![]).with_recv_cap(Duration::from_millis(10)),
        );
        let t0 = std::time::Instant::now();
        assert!(matches!(a.recv(Duration::from_secs(10)), Err(TransportError::Timeout)));
        assert!(t0.elapsed() < Duration::from_secs(5), "cap was not applied");
    }

    #[test]
    fn seeded_schedules_are_reproducible_and_cover_all_classes() {
        let order = [1u32, 2, 3, 4];
        for seed in 0..64u64 {
            assert_eq!(
                seeded_schedule(seed, &order)
                    .iter()
                    .map(|p| p.faults.clone())
                    .collect::<Vec<_>>(),
                seeded_schedule(seed, &order)
                    .iter()
                    .map(|p| p.faults.clone())
                    .collect::<Vec<_>>(),
                "seed {seed} not reproducible"
            );
        }
        let mut classes = [false; 5];
        for seed in 0..256u64 {
            for plan in seeded_schedule(seed, &order) {
                for f in &plan.faults {
                    classes[match f {
                        Fault::Drop { .. } => 0,
                        Fault::Duplicate { .. } => 1,
                        Fault::Reorder { .. } => 2,
                        Fault::Delay { .. } => 3,
                        Fault::KillAfterSends { .. } => 4,
                    }] = true;
                }
            }
        }
        assert!(classes.iter().all(|&c| c), "a fault class is unreachable: {classes:?}");
    }
}
