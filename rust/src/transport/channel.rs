//! The in-process channel backend of the [`Transport`] contract.
//!
//! One [`ChannelGroup`] per training session; every uni-task worker
//! [`ChannelGroup::join`]s on spawn and holds a [`ChannelEndpoint`] for
//! its lifetime. Delivery is an `mpsc` send into the receiver's queue —
//! which gives the contract's per-pair FIFO for free (std channels
//! preserve per-sender order) — and membership is a shared map guarded by
//! one mutex, touched only at join/leave/send time, never inside the
//! per-element merge loops.
//!
//! Dropping an endpoint *is* leaving: the epoch bumps and the node's
//! payload [`Residency`] is forgotten, exactly as a departed node's
//! storage would be reclaimed in a real cluster. This makes the revoke
//! path automatic — a revoked worker's thread exits, its endpoint drops,
//! and the group converges without any coordinator involvement.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::NodeId;

use super::{Membership, Message, Payload, Residency, Transport, TransportError};

struct GroupInner {
    epoch: u64,
    members: HashMap<NodeId, Sender<Message>>,
}

/// The shared membership map of the in-process backend.
///
/// Holds one `Sender` per member (so a member's receive queue stays alive
/// exactly as long as its endpoint does) plus the group's payload
/// [`Residency`]. All mutation goes through [`ChannelGroup::join`] and
/// endpoint drop; both bump the epoch.
pub struct ChannelGroup {
    inner: Mutex<GroupInner>,
    residency: Residency,
}

impl ChannelGroup {
    pub fn new() -> Arc<Self> {
        Arc::new(ChannelGroup {
            inner: Mutex::new(GroupInner { epoch: 0, members: HashMap::new() }),
            residency: Residency::default(),
        })
    }

    /// Add `node` to the group and hand back its endpoint. Bumps the
    /// epoch. Panics if the node is already a member — a rejoining worker
    /// must have dropped its previous endpoint first (the worker thread's
    /// exit guarantees this on the revoke path).
    pub fn join(self: &Arc<Self>, node: NodeId) -> ChannelEndpoint {
        let (tx, rx) = channel();
        let mut inner = self.inner.lock().expect("transport group lock");
        assert!(
            inner.members.insert(node, tx).is_none(),
            "node {node} already in the transport group"
        );
        inner.epoch += 1;
        ChannelEndpoint { group: Arc::clone(self), node, rx }
    }

    /// Current membership snapshot (epoch + sorted members).
    pub fn membership(&self) -> Membership {
        let inner = self.inner.lock().expect("transport group lock");
        let mut members: Vec<NodeId> = inner.members.keys().copied().collect();
        members.sort_unstable();
        Membership { epoch: inner.epoch, members }
    }

    /// The group's payload-residency map (shared with the scheduler).
    pub fn residency(&self) -> &Residency {
        &self.residency
    }

    fn leave(&self, node: NodeId) {
        let mut inner = self.inner.lock().expect("transport group lock");
        if inner.members.remove(&node).is_some() {
            inner.epoch += 1;
        }
        drop(inner);
        // A departed node's storage is reclaimed: its payloads are no
        // longer resident anywhere the scheduler may price a warm move to.
        self.residency.forget(node);
    }

    /// `(sender, epoch)` for a live member, under one lock acquisition so
    /// the stamped epoch is the one the member was observed at.
    fn sender_to(&self, to: NodeId) -> Result<(Sender<Message>, u64), TransportError> {
        let inner = self.inner.lock().expect("transport group lock");
        match inner.members.get(&to) {
            Some(tx) => Ok((tx.clone(), inner.epoch)),
            None => Err(TransportError::NoSuchPeer(to)),
        }
    }
}

/// One member's handle on a [`ChannelGroup`]: its receive queue plus the
/// shared membership map. Owned by exactly one worker thread; dropping it
/// leaves the group (epoch bump + residency forgotten).
pub struct ChannelEndpoint {
    group: Arc<ChannelGroup>,
    node: NodeId,
    rx: Receiver<Message>,
}

impl Transport for ChannelEndpoint {
    fn node(&self) -> NodeId {
        self.node
    }

    fn membership(&self) -> Membership {
        self.group.membership()
    }

    fn send(&mut self, to: NodeId, payload: Payload) -> Result<(), TransportError> {
        let (tx, epoch) = self.group.sender_to(to)?;
        tx.send(Message { from: self.node, epoch, payload })
            .map_err(|_| TransportError::Closed(to))
    }

    fn recv(&mut self, timeout: Duration) -> Result<Message, TransportError> {
        use std::sync::mpsc::RecvTimeoutError;
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => TransportError::Timeout,
            // Only possible once this endpoint has left the group (the
            // group itself keeps a sender alive for every member).
            RecvTimeoutError::Disconnected => TransportError::Closed(self.node),
        })
    }

    fn try_recv(&mut self) -> Option<Message> {
        self.rx.try_recv().ok()
    }
}

impl Drop for ChannelEndpoint {
    fn drop(&mut self) {
        self.group.leave(self.node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_leave_bump_epoch_and_sort_members() {
        let g = ChannelGroup::new();
        assert_eq!(g.membership().epoch, 0);
        assert!(g.membership().is_empty());
        let a = g.join(3);
        let b = g.join(1);
        let m = g.membership();
        assert_eq!(m.epoch, 2);
        assert_eq!(m.members, vec![1, 3]);
        assert!(m.contains(3) && !m.contains(2));
        drop(a);
        let m = g.membership();
        assert_eq!(m.epoch, 3);
        assert_eq!(m.members, vec![1]);
        drop(b);
        assert_eq!(g.membership().epoch, 4);
        assert!(g.membership().is_empty());
    }

    #[test]
    fn send_recv_roundtrip_stamps_sender_and_epoch() {
        let g = ChannelGroup::new();
        let mut a = g.join(10);
        let mut b = g.join(20);
        a.send(20, Payload::Segment { iter: 7, seg: 1, data: vec![1.0, 2.0] })
            .unwrap();
        let msg = b.recv(Duration::from_secs(1)).unwrap();
        assert_eq!(msg.from, 10);
        assert_eq!(msg.epoch, 2, "stamped with the epoch at send time");
        match msg.payload {
            Payload::Segment { iter: 7, seg: 1, ref data } => assert_eq!(data, &[1.0, 2.0]),
            ref p => panic!("unexpected payload {p:?}"),
        }
        assert!(b.try_recv().is_none());
        assert!(matches!(
            b.recv(Duration::from_millis(5)),
            Err(TransportError::Timeout)
        ));
    }

    #[test]
    fn per_pair_fifo_is_preserved() {
        let g = ChannelGroup::new();
        let mut a = g.join(1);
        let mut b = g.join(2);
        for seg in 0..10usize {
            a.send(2, Payload::Segment { iter: 0, seg, data: vec![] }).unwrap();
        }
        for seg in 0..10usize {
            match b.recv(Duration::from_secs(1)).unwrap().payload {
                Payload::Segment { seg: s, .. } => assert_eq!(s, seg, "FIFO violated"),
                ref p => panic!("unexpected payload {p:?}"),
            }
        }
    }

    #[test]
    fn send_to_departed_peer_errors() {
        let g = ChannelGroup::new();
        let mut a = g.join(1);
        let b = g.join(2);
        drop(b);
        assert!(matches!(
            a.send(2, Payload::StateRequest),
            Err(TransportError::NoSuchPeer(2))
        ));
    }

    #[test]
    fn leaving_forgets_residency() {
        let g = ChannelGroup::new();
        let a = g.join(1);
        g.residency().record(1, 42);
        assert!(g.residency().resident(1, 42));
        drop(a);
        assert!(!g.residency().resident(1, 42));
    }
}
