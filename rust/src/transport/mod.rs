//! The pluggable transport layer: the system's first wire contract.
//!
//! Everything in this crate runs as threads in one process, but the merge
//! phase no longer has to *pretend* there is a network: this module
//! defines the [`Transport`] trait — point-to-point send/recv of model
//! shards between uni-task peers, plus group membership with epochs — and
//! [`allreduce`] builds ring- and tree-allreduce on top of it, selectable
//! via `SessionConfig::merge_strategy`. The contract is specified in
//! prose in `docs/TRANSPORT.md` (ordering, membership epochs, the rejoin
//! protocol, and what a backend must guarantee for bit-identity); a
//! future TCP/shared-memory backend implements the same trait and
//! inherits the property tests.
//!
//! Three guarantees every backend must provide (see `docs/TRANSPORT.md`
//! § "Backend obligations" for the full list):
//!
//! * **FIFO per ordered pair** — messages from peer A to peer B arrive
//!   in send order. Messages from *different* senders interleave
//!   arbitrarily; the collectives match on `(iter, segment)` tags, never
//!   on arrival order.
//! * **Membership epochs** — every join/leave bumps the group epoch, and
//!   every message is stamped with the sender's epoch at send time. A
//!   collective drops messages stamped *older* than the membership
//!   snapshot it was launched with ([`allreduce`]'s staleness rule), so a
//!   straggling message from a pre-resize regime can never corrupt a
//!   newer collective.
//! * **No reordering with loss** — a backend either delivers a message or
//!   errors the send; silent drops would deadlock a barriered collective.
//!
//! Two backends implement the contract: the in-process
//! [`channel::ChannelGroup`] / [`channel::ChannelEndpoint`] (mpsc
//! channels, a shared membership map) and the real-socket
//! [`tcp::TcpGroup`] / [`tcp::TcpEndpoint`] (length-prefixed frames over
//! loopback `TcpStream`s, one listener/acceptor per endpoint, per-peer
//! writer threads). [`GroupHandle`] abstracts over them so the worker
//! pool can be pointed at either via `SessionConfig::transport`
//! (`channel` | `tcp`, `CHICLE_TRANSPORT` env). Both pass the same
//! backend-generic conformance suite
//! (`rust/tests/transport_conformance/`), and [`fault::FaultTransport`]
//! can wrap either with a deterministic fault schedule for the chaos
//! suite.
//!
//! # Segment geometry
//!
//! Ring-allreduce tiles the model into exactly `k` *fixed-offset*
//! segments — [`segment_range`] — reusing the principle of
//! [`crate::exec::ShardQueue::shard_range`]: geometry is a pure function
//! of `(model_len, k)` and never depends on who sends what when. Combined
//! with the elementwise `merge_shard` invariant
//! ([`crate::algos::Algorithm::merge_shard`]), a ring segment is just
//! another contiguous shard, so the collective's result is bit-identical
//! to the serial fold (see [`allreduce`] for how the fold order is
//! preserved).
//!
//! # Payload residency
//!
//! The group additionally tracks which immutable chunk payloads each
//! member has ever hosted ([`Residency`]). Payloads are write-once
//! (`chunks` module privacy enforces it), so residency is sticky while a
//! node stays a member and forgotten when it leaves — this is what lets
//! the scheduler's `NetworkModel::chunk_cost(warm|cold)` pricing read
//! *real* membership instead of always charging cold
//! (`coordinator::policy::PolicyCtx::move_chunk`).

pub mod allreduce;
pub mod channel;
pub mod fault;
pub mod tcp;

pub use allreduce::{
    fetch_state, ring_allreduce, tree_allreduce, AllreduceKind, AllreduceRun, CollectiveCtx,
    CollectiveStats,
};
pub use channel::{ChannelEndpoint, ChannelGroup};
pub use fault::{seeded_schedule, Fault, FaultPlan, FaultTransport};
pub use tcp::{TcpEndpoint, TcpGroup};

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::chunks::ChunkId;
use crate::cluster::NodeId;

/// A point-in-time snapshot of a transport group's membership.
///
/// `epoch` increments on every join or leave; collectives capture the
/// snapshot once at launch and validate incoming traffic against it
/// (messages stamped with an older epoch are stale by definition — they
/// were sent under a membership regime that no longer exists).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    pub epoch: u64,
    /// Member node ids, sorted ascending (a canonical order so two peers
    /// snapshotting the same epoch agree on ranks).
    pub members: Vec<NodeId>,
}

impl Membership {
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn contains(&self, node: NodeId) -> bool {
        self.members.contains(&node)
    }
}

/// One update's contribution as it travels through a collective: the
/// task's position in the fold order, its merge weight, and its delta
/// (the full vector for tree gather, one segment's slice for ring
/// scatter).
#[derive(Clone, Debug)]
pub struct UpdatePart {
    /// Position in the task-order fold — the serial `merge` folds updates
    /// in this order, and so must every collective (bit-identity).
    pub task_idx: usize,
    /// The update's sample count (lSGD's merge normalizer sums these, so
    /// a slice must carry it even though the delta is partial).
    pub samples: usize,
    pub delta: Vec<f32>,
}

/// What moves over the wire. Every collective payload is tagged with the
/// iteration it belongs to: collectives are barriered per iteration, so
/// the tag (plus the epoch stamp on [`Message`]) is what lets a receiver
/// reject traffic from another regime instead of mis-folding it.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Ring scatter: the sender's own update restricted to segment `seg`,
    /// bound for that segment's owner.
    UpdateSlice {
        iter: u64,
        seg: usize,
        part: UpdatePart,
    },
    /// Ring all-gather: a fully merged fixed-offset segment.
    Segment { iter: u64, seg: usize, data: Vec<f32> },
    /// Tree gather: every update in the sender's subtree (full deltas).
    Updates { iter: u64, parts: Vec<UpdatePart> },
    /// Tree broadcast — and the reply to a [`Payload::StateRequest`]: a
    /// complete model vector.
    Model { iter: u64, data: Vec<f32> },
    /// Rejoin protocol: ask any peer for its latest complete model. The
    /// only payload exempt from epoch staleness checks — a rejoining node
    /// is cross-epoch by design.
    StateRequest,
}

impl Payload {
    /// Bytes this payload would occupy on a real wire (f32 data only;
    /// framing is backend-specific and excluded on purpose so the
    /// recorded byte counts are backend-independent).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::UpdateSlice { part, .. } => part.delta.len() * 4,
            Payload::Segment { data, .. } => data.len() * 4,
            Payload::Updates { parts, .. } => {
                parts.iter().map(|p| p.delta.len() * 4).sum()
            }
            Payload::Model { data, .. } => data.len() * 4,
            Payload::StateRequest => 0,
        }
    }
}

/// A delivered payload plus its provenance: who sent it and under which
/// membership epoch.
#[derive(Clone, Debug)]
pub struct Message {
    pub from: NodeId,
    /// The sender's group epoch at send time (the staleness stamp).
    pub epoch: u64,
    pub payload: Payload,
}

/// Transport-level failures. Deliberately small: a collective either
/// completes bit-identically or surfaces one of these — there is no
/// partial-success state.
#[derive(Debug)]
pub enum TransportError {
    /// The peer's receive side is gone (its endpoint was dropped).
    Closed(NodeId),
    /// Send target is not a current group member.
    NoSuchPeer(NodeId),
    /// `recv` exceeded its timeout with nothing delivered.
    Timeout,
    /// The collective's invariants were violated (wrong part count,
    /// caller not in the rank order, …).
    Protocol(&'static str),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed(n) => write!(f, "peer {n} closed its endpoint"),
            TransportError::NoSuchPeer(n) => write!(f, "no such peer {n} in the group"),
            TransportError::Timeout => write!(f, "transport recv timed out"),
            TransportError::Protocol(msg) => write!(f, "collective protocol violation: {msg}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Point-to-point transport between uni-task peers — the wire contract.
///
/// One endpoint per member; methods take `&mut self` because an endpoint
/// is owned by exactly one worker thread (receive queues are not shared).
/// The contract a backend must satisfy — FIFO per ordered sender/receiver
/// pair, epoch stamping, deliver-or-error — is specified in
/// `docs/TRANSPORT.md`; [`crate::transport::allreduce`]'s property tests
/// are written against the trait, so a new backend inherits them.
pub trait Transport: Send {
    /// This endpoint's node id.
    fn node(&self) -> NodeId;

    /// Current membership snapshot (epoch + sorted members).
    fn membership(&self) -> Membership;

    /// Deliver `payload` to `to`, stamped with the current epoch.
    /// Either delivers or errors — a backend must never drop silently.
    fn send(&mut self, to: NodeId, payload: Payload) -> Result<(), TransportError>;

    /// Block for the next message, up to `timeout`.
    fn recv(&mut self, timeout: Duration) -> Result<Message, TransportError>;

    /// Non-blocking receive; `None` when the queue is empty.
    fn try_recv(&mut self) -> Option<Message>;

    /// Cumulative *framing overhead* bytes this endpoint has written:
    /// every wire byte that is not f32 payload (length prefixes, tags,
    /// handshakes). Zero for backends with no wire format — the
    /// in-process channel moves `Message` values, so the default stands.
    /// Measured per endpoint where the bytes are written, so summing over
    /// a collective's ranks never double-counts; the metrics log reports
    /// the sum as `transport_frame_bytes` next to the backend-independent
    /// `transport_bytes`.
    fn frame_bytes(&self) -> usize {
        0
    }
}

/// A backend-erased transport group: the worker pool holds one of these
/// and `join`s workers into whichever backend the session configured
/// (`SessionConfig::transport`). Backend selection changes *how* bytes
/// move, never what is computed — both variants satisfy the same
/// contract and the conformance suite pins bit-identity across them.
pub enum GroupHandle {
    Channel(Arc<ChannelGroup>),
    Tcp(Arc<TcpGroup>),
}

impl GroupHandle {
    /// A fresh in-process channel group (the default backend).
    pub fn channel() -> Self {
        GroupHandle::Channel(ChannelGroup::new())
    }

    /// A fresh loopback TCP group (real sockets, framed wire format).
    pub fn tcp() -> Self {
        GroupHandle::Tcp(TcpGroup::new())
    }

    /// Add `node` to the group and hand back its (boxed) endpoint.
    pub fn join(&self, node: NodeId) -> Box<dyn Transport> {
        match self {
            GroupHandle::Channel(g) => Box::new(g.join(node)),
            GroupHandle::Tcp(g) => Box::new(g.join(node)),
        }
    }

    /// Current membership snapshot (epoch + sorted members).
    pub fn membership(&self) -> Membership {
        match self {
            GroupHandle::Channel(g) => g.membership(),
            GroupHandle::Tcp(g) => g.membership(),
        }
    }

    /// The group's payload-residency map (shared with the scheduler).
    pub fn residency(&self) -> &Residency {
        match self {
            GroupHandle::Channel(g) => g.residency(),
            GroupHandle::Tcp(g) => g.residency(),
        }
    }
}

/// Which immutable chunk payloads each group member has ever hosted.
///
/// Payloads are write-once, so hosting once means the bytes are still
/// valid forever — residency is *sticky* while the node remains a member
/// and forgotten when its endpoint leaves the group (a departed node's
/// storage is reclaimed in the modeled cluster). The scheduler reads this
/// through [`crate::coordinator::policy::PolicyCtx`] to price chunk moves
/// warm (state-only) vs cold (payload + state) with
/// `NetworkModel::chunk_cost`; because residency is a pure function of
/// the movement history, the priced virtual time stays deterministic.
#[derive(Clone, Default)]
pub struct Residency {
    inner: Arc<Mutex<HashMap<NodeId, HashSet<ChunkId>>>>,
}

impl Residency {
    /// Record that `node` now hosts `chunk`'s payload.
    pub fn record(&self, node: NodeId, chunk: ChunkId) {
        self.inner
            .lock()
            .expect("residency lock")
            .entry(node)
            .or_default()
            .insert(chunk);
    }

    /// Does `node` already hold `chunk`'s payload (a warm destination)?
    pub fn resident(&self, node: NodeId, chunk: ChunkId) -> bool {
        self.inner
            .lock()
            .expect("residency lock")
            .get(&node)
            .is_some_and(|s| s.contains(&chunk))
    }

    /// Forget everything `node` hosted (it left the group).
    pub fn forget(&self, node: NodeId) {
        self.inner.lock().expect("residency lock").remove(&node);
    }

    /// Distinct payloads recorded for `node` (diagnostics/tests).
    pub fn count(&self, node: NodeId) -> usize {
        self.inner
            .lock()
            .expect("residency lock")
            .get(&node)
            .map_or(0, |s| s.len())
    }
}

/// Fixed `(offset, len)` range of ring segment `seg` out of `k`.
///
/// The same fixed-offset principle as [`crate::exec::ShardQueue::shard_range`]
/// — geometry is a pure function of `(model_len, k)` — specialized to
/// *exactly* `k` segments so every rank owns one: segment length is
/// `⌈model_len / k⌉` and, when the model is smaller than the ring, tail
/// segments are empty (their owners send and receive zero-length slices
/// but still participate in every round, keeping the protocol uniform).
/// Non-empty segments coincide exactly with the shards of a
/// `ShardQueue` laid out at one shard per worker.
pub fn segment_range(model_len: usize, k: usize, seg: usize) -> (usize, usize) {
    assert!(k > 0 && seg < k, "segment {seg} of {k}");
    if model_len == 0 {
        return (0, 0);
    }
    let per = model_len.div_ceil(k);
    let offset = (seg * per).min(model_len);
    (offset, per.min(model_len - offset))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{ReduceOptions, ShardQueue};

    #[test]
    fn segments_tile_the_model_in_order() {
        for (len, k) in [(97usize, 4usize), (100, 8), (5, 8), (1, 1), (16, 16), (3, 7)] {
            let mut at = 0usize;
            for s in 0..k {
                let (off, l) = segment_range(len, k, s);
                assert_eq!(off, at.min(len), "len={len} k={k} seg={s}");
                at = off + l;
            }
            assert_eq!(at, len, "len={len} k={k}: segments must cover the model");
        }
    }

    #[test]
    fn model_smaller_than_ring_leaves_empty_tail_segments() {
        // 3 elements over 8 ranks: per = 1, segments 0..3 hold one element
        // each, segments 3..8 are empty but well-formed.
        for s in 0..8 {
            let (off, l) = segment_range(3, 8, s);
            if s < 3 {
                assert_eq!((off, l), (s, 1));
            } else {
                assert_eq!((off, l), (3, 0), "seg {s}");
            }
        }
    }

    #[test]
    fn nonempty_segments_match_one_shard_per_worker_geometry() {
        // The ring reuses exec/reduce.rs's fixed-offset shard geometry:
        // with `shards_per_worker = 1` the ShardQueue's shards are exactly
        // the non-empty ring segments.
        for (len, k) in [(97usize, 4usize), (1000, 8), (64, 2)] {
            let q = ShardQueue::new(len, k, ReduceOptions { shards_per_worker: 1, stealing: true });
            for i in 0..q.n_shards() {
                assert_eq!(q.shard_range(i), segment_range(len, k, i), "len={len} k={k} i={i}");
            }
            for s in q.n_shards()..k {
                assert_eq!(segment_range(len, k, s).1, 0, "tail segment {s} must be empty");
            }
        }
    }

    #[test]
    fn residency_is_sticky_until_forgotten() {
        let r = Residency::default();
        assert!(!r.resident(1, 7));
        r.record(1, 7);
        r.record(1, 8);
        r.record(2, 7);
        assert!(r.resident(1, 7) && r.resident(1, 8) && r.resident(2, 7));
        assert!(!r.resident(2, 8));
        assert_eq!(r.count(1), 2);
        // Re-recording is idempotent.
        r.record(1, 7);
        assert_eq!(r.count(1), 2);
        // Leaving forgets only the departed node.
        r.forget(1);
        assert!(!r.resident(1, 7));
        assert!(r.resident(2, 7));
        assert_eq!(r.count(1), 0);
    }

    #[test]
    fn wire_bytes_count_f32_data_only() {
        let part = UpdatePart { task_idx: 0, samples: 3, delta: vec![0.0; 10] };
        assert_eq!(Payload::UpdateSlice { iter: 0, seg: 0, part: part.clone() }.wire_bytes(), 40);
        assert_eq!(Payload::Segment { iter: 0, seg: 0, data: vec![0.0; 5] }.wire_bytes(), 20);
        assert_eq!(
            Payload::Updates { iter: 0, parts: vec![part.clone(), part] }.wire_bytes(),
            80
        );
        assert_eq!(Payload::Model { iter: 0, data: vec![0.0; 7] }.wire_bytes(), 28);
        assert_eq!(Payload::StateRequest.wire_bytes(), 0);
    }
}
