//! The reduce/dispatch overlap pipeline must be invisible to the science:
//! a pipelined run produces a *bit-identical* iterate trajectory to the
//! barriered schedule — same metrics, same virtual times, same epochs —
//! across elastic resizes, with only the measured wallclock columns
//! (`merge_wall`, `overlap_wall`, `steal_count`) allowed to differ.
//!
//! Also exercises the straggler payoff of the work-stealing reducer
//! end-to-end (ignored by default: timing-sensitive on loaded CI hosts;
//! the CI-gated numbers live in `benches/bench_coordinator.rs`).

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate};
use chicle::chunks::SharedStore;
use chicle::config::{AlgoConfig, ElasticSpec, ModelKind, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::exec::{ReduceOptions, WorkerPool};
use chicle::metrics::MetricsLog;

/// An elastic lSGD/MLP session: 235k-parameter model (well above the
/// parallel-merge threshold), 4 → 2 nodes over the run, evaluation every
/// 5 iterations so most iterations are overlap-eligible.
fn mlp_log(overlap: bool, seed: u64) -> MetricsLog {
    let ds = synth::fmnist_like(1200, 7);
    let mut cfg = SessionConfig::lsgd("overlap-traj", ModelKind::Mlp, 4)
        .with_seed(seed)
        .with_overlap(overlap)
        .with_elastic(ElasticSpec::Gradual { from: 4, to: 2, interval_s: 3.0 });
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = 12;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 5;
        l.target_acc = 2.0; // unreachable: run all iterations
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run().unwrap()
}

#[test]
fn overlapped_trajectory_is_identical_to_barriered() {
    let piped = mlp_log(true, 11);
    let barriered = mlp_log(false, 11);
    assert_eq!(piped.records.len(), barriered.records.len());
    for (p, b) in piped.records.iter().zip(&barriered.records) {
        assert_eq!(p.iter, b.iter);
        assert_eq!(p.epochs, b.epochs, "iter {}", p.iter);
        assert_eq!(p.metric, b.metric, "iter {}", p.iter);
        assert_eq!(p.vtime, b.vtime, "iter {}", p.iter);
        assert_eq!(p.n_tasks, b.n_tasks, "iter {}", p.iter);
        assert_eq!(p.samples, b.samples, "iter {}", p.iter);
        assert_eq!(p.train_loss, b.train_loss, "iter {}", p.iter);
    }
    // The pipeline actually engaged in the overlapped run — and never in
    // the barriered one. Elastic scale-in means n_tasks must still have
    // dropped 4 → 2 with the pipeline live.
    assert!(
        piped.records.iter().any(|r| r.overlap_wall > Duration::ZERO),
        "overlap never engaged"
    );
    assert!(barriered.records.iter().all(|r| r.overlap_wall == Duration::ZERO));
    assert_eq!(piped.records.last().unwrap().n_tasks, 2);
}

#[test]
fn overlapped_run_is_deterministic_across_repeats() {
    let a = mlp_log(true, 3);
    let b = mlp_log(true, 3);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.metric, rb.metric);
        assert_eq!(ra.vtime, rb.vtime);
        assert_eq!(ra.epochs, rb.epochs);
    }
}

/// `run_iters` barriers its last iteration, so a fixed-count loop records
/// exactly the requested iterations even with the pipeline on.
#[test]
fn run_iters_never_outruns_the_request() {
    let ds = synth::fmnist_like(800, 1);
    let mut cfg = SessionConfig::lsgd("overlap-iters", ModelKind::Mlp, 2)
        .with_overlap(true);
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = 50;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 10;
        l.target_acc = 2.0;
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run_iters(7).unwrap();
    assert_eq!(log.records.len(), 7);
    assert_eq!(log.records.last().unwrap().iter, 6);
}

/// One artificially slow worker holds a fixed one-shard-per-worker
/// reduction for its whole (large) shard, but holds the stealing
/// reduction for at most a few small shards. Timing-sensitive, so ignored
/// by default — run explicitly with `cargo test -- --ignored`; the
/// CI-tracked equivalent is the `merge/slow1_*` bench pair.
#[test]
#[ignore = "timing-sensitive; the bench gate tracks the CI numbers"]
fn stealing_beats_fixed_assignment_under_a_straggler() {
    let model_len = 200_000usize;
    let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        chicle::config::CocoaConfig::default(),
        Backend::native_cocoa(),
        10_000,
        model_len,
    ));
    let mut pool = WorkerPool::new(Arc::clone(&algo));
    for i in 0..4u32 {
        pool.spawn_worker(i, SharedStore::new());
    }
    // Node 0 reduces at +100 ns per element — a 10× straggler.
    pool.set_reduce_slowdown(0, 100).unwrap();
    let model = Arc::new(vec![0.1f32; model_len]);
    let updates = Arc::new(vec![
        LocalUpdate { delta: vec![1e-3; model_len], samples: 100, loss_sum: 0.0 };
        3
    ]);

    let mut wall = |opts: ReduceOptions| {
        let mut best = Duration::MAX;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let (merged, _) = pool
                .reduce_model(&model, Arc::clone(&updates), 3, opts)
                .unwrap();
            best = best.min(t0.elapsed());
            assert_eq!(merged.len(), model_len);
        }
        best
    };
    let fixed = wall(ReduceOptions { shards_per_worker: 1, stealing: false });
    let steal = wall(ReduceOptions { shards_per_worker: 16, stealing: true });
    assert!(
        steal * 2 <= fixed,
        "stealing {steal:?} should be ≥2× faster than fixed {fixed:?} under a straggler"
    );
}
