//! The reduce/dispatch overlap pipeline must be invisible to the science:
//! a pipelined run produces a *bit-identical* iterate trajectory to the
//! barriered schedule — same metrics, same virtual times, same epochs —
//! across elastic resizes **and through evaluation points** (the
//! eval-spanning overlap evaluates against a snapshot while the next
//! iteration is already computing), with only the measured wallclock
//! columns (`merge_wall`, `overlap_wall`, `steal_count`, `spw`) allowed
//! to differ.
//!
//! Also exercises the straggler payoff of the work-stealing reducer
//! end-to-end (ignored by default: timing-sensitive on loaded CI hosts;
//! the CI-gated numbers live in `benches/bench_coordinator.rs`).

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, ModelVec};
use chicle::chunks::chunker::make_chunks;
use chicle::chunks::{Chunk, SharedStore};
use chicle::config::{AlgoConfig, ElasticSpec, ModelKind, SessionConfig};
use chicle::coordinator::{Trainer, TrainingSession};
use chicle::data::synth;
use chicle::exec::{ReduceOptions, WorkerPool};
use chicle::metrics::{Metric, MetricsLog};

/// An elastic lSGD/MLP session: 235k-parameter model (well above the
/// parallel-merge threshold), 4 → 2 nodes over the run, evaluation every
/// 5 iterations so most iterations are overlap-eligible.
fn mlp_log(overlap: bool, seed: u64) -> MetricsLog {
    let ds = synth::fmnist_like(1200, 7);
    let mut cfg = SessionConfig::lsgd("overlap-traj", ModelKind::Mlp, 4)
        .with_seed(seed)
        .with_overlap(overlap)
        .with_elastic(ElasticSpec::Gradual { from: 4, to: 2, interval_s: 3.0 });
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = 12;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 5;
        l.target_acc = 2.0; // unreachable: run all iterations
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run().unwrap()
}

#[test]
fn overlapped_trajectory_is_identical_to_barriered() {
    let piped = mlp_log(true, 11);
    let barriered = mlp_log(false, 11);
    assert_eq!(piped.records.len(), barriered.records.len());
    for (p, b) in piped.records.iter().zip(&barriered.records) {
        assert_eq!(p.iter, b.iter);
        assert_eq!(p.epochs, b.epochs, "iter {}", p.iter);
        assert_eq!(p.metric, b.metric, "iter {}", p.iter);
        assert_eq!(p.vtime, b.vtime, "iter {}", p.iter);
        assert_eq!(p.n_tasks, b.n_tasks, "iter {}", p.iter);
        assert_eq!(p.samples, b.samples, "iter {}", p.iter);
        assert_eq!(p.train_loss, b.train_loss, "iter {}", p.iter);
    }
    // The pipeline actually engaged in the overlapped run — and never in
    // the barriered one. Elastic scale-in means n_tasks must still have
    // dropped 4 → 2 with the pipeline live.
    assert!(
        piped.records.iter().any(|r| r.overlap_wall > Duration::ZERO),
        "overlap never engaged"
    );
    // Eval-spanning: evaluation points themselves pipelined (every eval
    // except a final-iteration one has a next iteration to overlap with).
    assert!(
        piped
            .records
            .iter()
            .any(|r| r.metric.is_some() && r.overlap_wall > Duration::ZERO),
        "no eval point overlapped"
    );
    assert!(barriered.records.iter().all(|r| r.overlap_wall == Duration::ZERO));
    assert_eq!(piped.records.last().unwrap().n_tasks, 2);
}

#[test]
fn overlapped_run_is_deterministic_across_repeats() {
    let a = mlp_log(true, 3);
    let b = mlp_log(true, 3);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.metric, rb.metric);
        assert_eq!(ra.vtime, rb.vtime);
        assert_eq!(ra.epochs, rb.epochs);
    }
}

/// `run_iters` barriers its last iteration, so a fixed-count loop records
/// exactly the requested iterations even with the pipeline on.
#[test]
fn run_iters_never_outruns_the_request() {
    let ds = synth::fmnist_like(800, 1);
    let mut cfg = SessionConfig::lsgd("overlap-iters", ModelKind::Mlp, 2)
        .with_overlap(true);
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = 50;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 10;
        l.target_acc = 2.0;
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run_iters(7).unwrap();
    assert_eq!(log.records.len(), 7);
    assert_eq!(log.records.last().unwrap().iter, 6);
}

/// A synthetic algorithm whose `evaluate` *reads the chunks* — and is
/// deliberately order-sensitive (non-commutative f64 accumulation across
/// chunks in store order) — so any eval-spanning overlap that snapshots
/// the wrong chunk state, or the right state in the wrong order, changes
/// the metric bits. `task_iterate` mutates per-sample chunk state every
/// iteration, so evaluating against the *live* stores after the next
/// iteration dispatched would also change the bits. The 40 000-element
/// model clears the trainer's parallel-merge threshold so the pipeline
/// engages.
struct ChunkStateAlgo {
    len: usize,
    target: Option<f64>,
}

impl Algorithm for ChunkStateAlgo {
    fn model_len(&self) -> usize {
        self.len
    }

    fn init_model(&self) -> chicle::Result<ModelVec> {
        Ok(vec![0.0; self.len])
    }

    fn task_iterate(
        &self,
        chunks: &mut [Chunk],
        model: &ModelVec,
        _k_tasks: usize,
        task_seed: u64,
        _budget: Option<usize>,
    ) -> chicle::Result<LocalUpdate> {
        let mut samples = 0usize;
        let mut acc = 0.0f32;
        for c in chunks.iter_mut() {
            if c.state.len() != c.n_samples() {
                c.init_state();
            }
            for s in c.state.iter_mut() {
                *s += 1.0;
                acc += *s;
            }
            samples += c.n_samples();
        }
        let bias = (task_seed % 1009) as f32 * 1e-6 + acc * 1e-8;
        let delta: ModelVec = model
            .iter()
            .enumerate()
            .map(|(i, m)| bias + m * 0.25 + (i % 17) as f32 * 1e-7)
            .collect();
        Ok(LocalUpdate { delta, samples, loss_sum: samples as f64 * 0.5 })
    }

    fn merge_shard(
        &self,
        shard: &mut [f32],
        offset: usize,
        updates: &[LocalUpdate],
        k_tasks: usize,
    ) {
        // Elementwise, folded in task order, scaled only by the
        // shard-independent task count — the required invariant.
        let w = 1.0 / k_tasks.max(1) as f32;
        for u in updates {
            for (i, v) in shard.iter_mut().enumerate() {
                *v += u.delta[offset + i] * w;
            }
        }
    }

    fn evaluate(&self, model: &ModelVec, all_chunks: &[&Chunk]) -> chicle::Result<Metric> {
        // Non-commutative chain over chunks *in the order handed in*:
        // reordering (or mutated state) changes the bits.
        let mut chain = 0.0f64;
        for c in all_chunks {
            for (i, s) in c.state.iter().enumerate() {
                chain = chain * 0.999_999 + (*s as f64) * (1.0 + i as f64 * 1e-4);
            }
        }
        let probe: f64 = model.iter().step_by(977).map(|v| *v as f64).sum();
        let sum: f64 = all_chunks
            .iter()
            .flat_map(|c| c.state.iter())
            .map(|s| *s as f64)
            .sum();
        Ok(Metric::EvalLoss(1000.0 / (1.0 + sum) + chain * 1e-9 + probe * 1e-12))
    }

    fn eval_reads_chunks(&self) -> bool {
        true
    }

    fn samples_per_iteration(&self, local_samples: usize) -> usize {
        local_samples
    }

    fn unit_samples(&self, n_total: usize, ref_nodes: usize) -> f64 {
        n_total as f64 / ref_nodes.max(1) as f64
    }

    fn target(&self) -> Option<f64> {
        self.target
    }
}

/// Build a trainer over the chunk-reading algorithm: CoCoA-style config
/// (eval_every = 1, so *every* iteration is an evaluation point) with an
/// elastic 4 → 2 scale-in mid-run.
fn chunk_state_trainer(overlap: bool, target: Option<f64>) -> Trainer {
    let ds = synth::higgs_like(2000, 13);
    let chunks = make_chunks(&ds, 8 * 1024);
    let algo: Arc<dyn Algorithm> = Arc::new(ChunkStateAlgo { len: 40_000, target });
    let mut cfg = SessionConfig::cocoa("eval-snapshot", 4)
        .with_overlap(overlap)
        .with_elastic(ElasticSpec::Gradual { from: 4, to: 2, interval_s: 20.0 });
    cfg.max_iters = 8;
    cfg.policies.rebalance = true;
    Trainer::new(cfg, algo, chunks).unwrap()
}

/// The eval-*snapshot* path proper: an algorithm whose evaluation reads
/// (order-sensitively) the very chunk state the next overlapped iteration
/// is busy mutating. Every iteration is an eval point; the overlapped
/// trajectory must still be bit-identical to the barriered one, through
/// the elastic scale-in.
#[test]
fn eval_snapshot_trajectory_is_identical_to_barriered() {
    let mut piped = chunk_state_trainer(true, None);
    piped.run().unwrap();
    let mut barriered = chunk_state_trainer(false, None);
    barriered.run().unwrap();

    assert_eq!(piped.metrics.records.len(), barriered.metrics.records.len());
    for (p, b) in piped.metrics.records.iter().zip(&barriered.metrics.records) {
        assert_eq!(p.metric, b.metric, "iter {}", p.iter);
        assert_eq!(p.vtime, b.vtime, "iter {}", p.iter);
        assert_eq!(p.epochs, b.epochs, "iter {}", p.iter);
        assert_eq!(p.n_tasks, b.n_tasks, "iter {}", p.iter);
        assert!(p.metric.is_some(), "every iteration evaluates");
    }
    assert_eq!(piped.model(), barriered.model(), "final model bits diverged");
    assert!(
        piped
            .metrics
            .records
            .iter()
            .any(|r| r.metric.is_some() && r.overlap_wall > Duration::ZERO),
        "the eval-snapshot overlap never engaged"
    );
    // The elastic scale-in really happened under the pipeline.
    assert_eq!(piped.metrics.records.last().unwrap().n_tasks, 2);
}

/// A metric-triggered early stop at an overlapped eval point leaves one
/// speculative iteration in flight; `run()` must drain it and land on the
/// same final model (and the same record count) as the barriered
/// schedule. The loss here decreases monotonically with the per-sample
/// state, crossing the target mid-run.
#[test]
fn metric_early_stop_drains_the_pipeline() {
    // sum(state) after iteration k is 2000·(k+1): loss ≈ 1000/(1+sum)
    // is 0.1250 at iteration 3 and 0.1000 at iteration 4 — it crosses
    // the 0.12 target at iteration 4, well inside max_iters = 8.
    let target = Some(0.12);
    let mut piped = chunk_state_trainer(true, target);
    piped.run().unwrap();
    let mut barriered = chunk_state_trainer(false, target);
    barriered.run().unwrap();

    assert_eq!(
        piped.metrics.records.len(),
        barriered.metrics.records.len(),
        "early stop must fire at the same iteration"
    );
    assert!(
        piped.metrics.records.len() < 8,
        "target was supposed to stop the run early"
    );
    let last = piped.metrics.records.last().unwrap();
    assert!(last.metric.unwrap().reached(0.12));
    assert_eq!(piped.model(), barriered.model(), "drained model diverged");
}

/// The eval-spanning overlap's wallclock payoff, end-to-end: an
/// eval-every-iteration MLP session must run faster pipelined than
/// barriered (the barriered schedule pays compute → reduce round-trip →
/// evaluation sequentially; the pipelined one hides the evaluation under
/// the next iteration's compute). Timing-sensitive, so gated on
/// `CHICLE_TIMING_TESTS=1` — the nightly CI timing job sets it (a quiet,
/// pinned runner); on a loaded dev box or a shared PR runner the test
/// skips itself instead of flaking. The CI-tracked equivalent is the
/// `merge/eval_overlap_mlp_4w_*` bench pair.
#[test]
fn eval_overlap_beats_barriered_flush() {
    if std::env::var("CHICLE_TIMING_TESTS").map_or(true, |v| v != "1") {
        eprintln!("eval_overlap_beats_barriered_flush: skipped (set CHICLE_TIMING_TESTS=1)");
        return;
    }
    let timed = |overlap: bool| {
        let mut best = Duration::MAX;
        for rep in 0..3 {
            let ds = synth::fmnist_like(1200, 9);
            let mut cfg = SessionConfig::lsgd("eval-overlap-wall", ModelKind::Mlp, 4)
                .with_overlap(overlap)
                .with_seed(40 + rep);
            cfg.chunk_bytes = 32 * 1024;
            cfg.max_iters = 50;
            if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
                l.eval_every = 1; // every iteration pays an evaluation
                l.target_acc = 2.0;
            }
            let mut s = TrainingSession::new(cfg, ds).unwrap();
            s.run_iters(2).unwrap(); // warm-up: thread spawn, caches
            let t0 = std::time::Instant::now();
            s.run_iters(10).unwrap();
            best = best.min(t0.elapsed());
        }
        best
    };
    let piped = timed(true);
    let barriered = timed(false);
    assert!(
        piped < barriered,
        "eval-overlapped run {piped:?} should beat the barriered flush {barriered:?}"
    );
}

/// One artificially slow worker holds a fixed one-shard-per-worker
/// reduction for its whole (large) shard, but holds the stealing
/// reduction for at most a few small shards. Timing-sensitive, so ignored
/// by default — run explicitly with `cargo test -- --ignored`; the
/// CI-tracked equivalent is the `merge/slow1_*` bench pair.
#[test]
#[ignore = "timing-sensitive; the bench gate tracks the CI numbers"]
fn stealing_beats_fixed_assignment_under_a_straggler() {
    let model_len = 200_000usize;
    let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        chicle::config::CocoaConfig::default(),
        Backend::native_cocoa(),
        10_000,
        model_len,
    ));
    let mut pool = WorkerPool::new(Arc::clone(&algo));
    for i in 0..4u32 {
        pool.spawn_worker(i, SharedStore::new());
    }
    // Node 0 reduces at +100 ns per element — a 10× straggler.
    pool.set_reduce_slowdown(0, 100).unwrap();
    let model = Arc::new(vec![0.1f32; model_len]);
    let updates = Arc::new(vec![
        LocalUpdate { delta: vec![1e-3; model_len], samples: 100, loss_sum: 0.0 };
        3
    ]);

    let mut wall = |opts: ReduceOptions| {
        let mut best = Duration::MAX;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let (merged, _) = pool
                .reduce_model(&model, Arc::clone(&updates), 3, opts)
                .unwrap();
            best = best.min(t0.elapsed());
            assert_eq!(merged.len(), model_len);
        }
        best
    };
    let fixed = wall(ReduceOptions { shards_per_worker: 1, stealing: false });
    let steal = wall(ReduceOptions { shards_per_worker: 16, stealing: true });
    assert!(
        steal * 2 <= fixed,
        "stealing {steal:?} should be ≥2× faster than fixed {fixed:?} under a straggler"
    );
}
