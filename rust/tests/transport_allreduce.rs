//! The backend-generic transport conformance suite, instantiated for the
//! in-process **channel** backend: ring- and tree-allreduce are
//! *bit-identical* to the serial merge fold — across 1–8 ranks, both
//! algorithm families, a model smaller than the ring, cross-regime
//! (stale) traffic, a rank revoked while the collective is in flight, and
//! a rejoining node fetching state from any peer. These are the
//! guarantees `docs/TRANSPORT.md` promises of every backend; the bodies
//! live in `transport_conformance/` and are shared verbatim with the TCP
//! instantiation (`transport_tcp.rs`).

mod transport_conformance;

use chicle::config::TransportKind;
use chicle::transport::GroupHandle;
use transport_conformance as conf;

fn channel() -> GroupHandle {
    GroupHandle::channel()
}

#[test]
fn prop_ring_and_tree_match_serial_fold_on_every_rank() {
    conf::ring_and_tree_match_serial_fold_on_every_rank(channel);
}

#[test]
fn model_smaller_than_ring_still_allreduces_exactly() {
    conf::model_smaller_than_ring_still_allreduces_exactly(channel);
}

#[test]
fn stale_cross_regime_traffic_is_dropped_not_folded() {
    conf::stale_cross_regime_traffic_is_dropped_not_folded(channel);
}

#[test]
fn prop_mid_collective_revoke_preserves_merge() {
    conf::mid_collective_revoke_preserves_merge(TransportKind::Channel);
}

#[test]
fn pool_allreduce_matches_pool_reduce_bit_for_bit() {
    conf::pool_allreduce_matches_pool_reduce_bit_for_bit(TransportKind::Channel);
}

#[test]
fn single_rank_pool_allreduce_folds_inline() {
    conf::single_rank_pool_allreduce_folds_inline(TransportKind::Channel);
}

#[test]
fn rejoining_node_fetches_state_from_any_peer() {
    conf::rejoining_node_fetches_state_from_any_peer(channel);
}
