//! Backend-generic conformance harness for the transport contract.
//!
//! Every property `docs/TRANSPORT.md` promises of a backend lives here
//! once, parameterized over a group constructor ([`GroupCtor`]) for the
//! transport-level properties and over [`TransportKind`] for the
//! pool-level ones. Each backend gets a thin test binary that
//! instantiates the same functions — `transport_allreduce.rs` pins the
//! in-process channel backend, `transport_tcp.rs` pins loopback TCP —
//! so "passes the suite unchanged" is literal: one body, N backends,
//! byte-for-byte identical expectations.
//!
//! proptest is not available in the offline crate set, so properties are
//! checked over seeded random cases (deterministic, reproducible).
//!
//! Two properties (staleness sieve, rejoin service) observe a message
//! *racing* a collective. The channel backend delivers synchronously, so
//! one attempt always suffices; a real socket delivers through kernel
//! buffers and a reader thread, so those properties settle briefly and
//! retry with a fresh group if the racing message had not yet landed.
//! The bit-identity assertion is unconditional on every attempt — only
//! the *observation* of the race is allowed to need another try.

// Each instantiating binary uses a subset of the harness; the unused
// remainder is not dead weight, it is the other binary's half.
#![allow(dead_code)]

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::nn::NativeModel;
use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, LsgdAlgo, ModelVec};
use chicle::chunks::SharedStore;
use chicle::config::{CocoaConfig, LsgdConfig, ModelKind, TransportKind};
use chicle::exec::{ReduceOptions, WorkerPool};
use chicle::transport::{
    fetch_state, ring_allreduce, tree_allreduce, AllreduceKind, AllreduceRun, CollectiveCtx,
    GroupHandle, Payload, Transport, UpdatePart,
};
use chicle::util::Rng;

/// A fresh, empty group of whichever backend the test binary pins.
pub type GroupCtor = fn() -> GroupHandle;

/// How long a racing message gets to cross a real socket before the
/// collective launches. Generous next to loopback latency (~µs), small
/// next to the test budget.
const SETTLE: Duration = Duration::from_millis(30);

/// Fresh-group retries for the two race-observing properties.
const ATTEMPTS: usize = 5;

/// One representative per algorithm family: CoCoA's merge is a plain
/// accumulate, lSGD's is sample-weighted (`Σ samples` normalizer) — the
/// case that would break if slices lost their weights in transit. The
/// CoCoA dim is a prime so no rank count divides the model evenly.
pub fn families() -> Vec<(&'static str, Arc<dyn Algorithm>)> {
    vec![
        (
            "cocoa",
            Arc::new(CocoaAlgo::new(
                CocoaConfig::default(),
                Backend::native_cocoa(),
                10_000,
                4099,
            )) as Arc<dyn Algorithm>,
        ),
        (
            "lsgd-mlp",
            Arc::new(
                LsgdAlgo::new_classif(
                    LsgdConfig::paper_defaults(ModelKind::Mlp),
                    Backend::native_nn(NativeModel::mlp_default()),
                    784,
                    Vec::new(),
                    Vec::new(),
                    1,
                )
                .unwrap(),
            ),
        ),
    ]
}

pub fn random_updates(rng: &mut Rng, k: usize, len: usize) -> Vec<LocalUpdate> {
    (0..k)
        .map(|_| LocalUpdate {
            delta: (0..len).map(|_| rng.normal_f32()).collect(),
            samples: 1 + rng.below(2000),
            loss_sum: 0.0,
        })
        .collect()
}

/// Run one `kind` collective over `k` fresh endpoints of the given
/// backend (one OS thread per rank, like the worker pool) and return
/// every rank's result in rank order. Node ids are deliberately
/// non-contiguous so rank ≠ id.
pub fn run_collective(
    make: GroupCtor,
    algo: &Arc<dyn Algorithm>,
    model: &ModelVec,
    updates: &[LocalUpdate],
    kind: AllreduceKind,
) -> Vec<AllreduceRun> {
    let k = updates.len();
    let order: Vec<u32> = (0..k as u32).map(|i| 10 * i + 3).collect();
    let group = make();
    let endpoints: Vec<_> = order.iter().map(|&n| group.join(n)).collect();
    let epoch = group.membership().epoch;
    std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let order = &order;
                let algo = Arc::clone(algo);
                s.spawn(move || {
                    let parts = vec![(rank, updates[rank].clone())];
                    let ctx = CollectiveCtx {
                        algo: algo.as_ref(),
                        model,
                        parts: &parts,
                        k_tasks: updates.len(),
                        order,
                        epoch,
                        iter: 42,
                    };
                    match kind {
                        AllreduceKind::Ring => ring_allreduce(ep.as_mut(), &ctx),
                        AllreduceKind::Tree => tree_allreduce(ep.as_mut(), &ctx),
                    }
                    .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Every rank of both collectives ends with the serial fold's exact bits,
/// for 1–8 ranks and both algorithm families, and the measured round
/// count matches the protocol's arithmetic.
pub fn ring_and_tree_match_serial_fold_on_every_rank(make: GroupCtor) {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(11);
        let model = algo.init_model().unwrap();
        for k in 1..=8usize {
            let updates = random_updates(&mut rng, k, len);
            let mut serial = model.clone();
            algo.merge(&mut serial, &updates, k);
            for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
                let runs = run_collective(make, &algo, &model, &updates, kind);
                let expected_rounds = match kind {
                    _ if k == 1 => 0,
                    AllreduceKind::Ring => 2 * (k - 1),
                    AllreduceKind::Tree => 2 * k.ilog2() as usize,
                };
                for (rank, run) in runs.iter().enumerate() {
                    assert_eq!(
                        run.model, serial,
                        "{name}: {kind:?} k={k} rank={rank} diverged from serial fold"
                    );
                    assert_eq!(run.stats.rounds, expected_rounds, "{name} {kind:?} k={k}");
                }
                let wire: usize = runs.iter().map(|r| r.stats.bytes_sent).sum();
                if k == 1 {
                    assert_eq!(wire, 0, "a collective of one must not touch the wire");
                } else {
                    assert!(wire > 0, "{name}: {kind:?} k={k} moved no bytes");
                }
            }
        }
    }
}

/// A model smaller than the ring: tail segments are empty, their owners
/// ship zero-length slices, and the result is still exact on every rank.
pub fn model_smaller_than_ring_still_allreduces_exactly(make: GroupCtor) {
    let algo: Arc<dyn Algorithm> =
        Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, 3));
    let model = vec![1.0f32, -2.0, 0.5];
    let mut rng = Rng::seed_from_u64(23);
    for k in [4usize, 8] {
        let updates = random_updates(&mut rng, k, 3);
        let mut serial = model.clone();
        algo.merge(&mut serial, &updates, k);
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            let runs = run_collective(make, &algo, &model, &updates, kind);
            for (rank, run) in runs.iter().enumerate() {
                assert_eq!(run.model, serial, "{kind:?} k={k} rank={rank}");
            }
        }
    }
}

/// Messages from a membership regime older than the collective's launch
/// snapshot (or from a sender outside the rank order) are dropped, not
/// folded: a stray pre-resize segment must bump `stale_dropped` and leave
/// the merged bits untouched.
pub fn stale_cross_regime_traffic_is_dropped_not_folded(make: GroupCtor) {
    let algo: Arc<dyn Algorithm> =
        Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, 64));
    let model = vec![0.5f32; 64];
    let mut rng = Rng::seed_from_u64(31);
    let updates = random_updates(&mut rng, 2, 64);
    let mut serial = model.clone();
    algo.merge(&mut serial, &updates, 2);

    for _attempt in 0..ATTEMPTS {
        let group = make();
        let order = [1u32, 2u32];
        let eps: Vec<_> = order.iter().map(|&n| group.join(n)).collect();
        {
            // A member from a doomed regime sends collective-shaped
            // traffic with plausible tags, then leaves (bumping the epoch
            // past its stamp). Without the staleness rule this would be
            // folded as rank 1's segment-0 contribution.
            let mut stray = group.join(9);
            stray
                .send(
                    1,
                    Payload::UpdateSlice {
                        iter: 42,
                        seg: 0,
                        part: UpdatePart { task_idx: 1, samples: 7, delta: vec![9.0; 32] },
                    },
                )
                .unwrap();
        }
        // Let the stray frame land before the collective snapshots its
        // epoch (a real socket delivers through a reader thread).
        std::thread::sleep(SETTLE);
        let epoch = group.membership().epoch;
        let runs: Vec<AllreduceRun> = std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    let (algo, model, updates, order) = (&algo, &model, &updates, &order);
                    s.spawn(move || {
                        let parts = vec![(rank, updates[rank].clone())];
                        let ctx = CollectiveCtx {
                            algo: algo.as_ref(),
                            model,
                            parts: &parts,
                            k_tasks: 2,
                            order,
                            epoch,
                            iter: 42,
                        };
                        ring_allreduce(ep.as_mut(), &ctx).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Bit-identity is unconditional: whether the sieve saw the stray
        // slice or it was still in flight, the fold must be exact.
        for run in &runs {
            assert_eq!(run.model, serial, "stale traffic leaked into the fold");
        }
        if runs[0].stats.stale_dropped == 1 {
            return;
        }
    }
    panic!("the stray slice was never observed by the staleness sieve in {ATTEMPTS} attempts");
}

/// Pool-level mid-collective revoke: `DrainChunks` queues behind the
/// `Allreduce` command (FIFO per worker), so the revoked rank completes
/// the collective its peers are blocked on, its reply is stashed, and
/// `collect_allreduce` still assembles the serial fold's exact bits.
pub fn mid_collective_revoke_preserves_merge(transport: TransportKind) {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(5);
        let model = Arc::new(algo.init_model().unwrap());
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            let updates = random_updates(&mut rng, 4, len);
            let mut serial = (*model).clone();
            algo.merge(&mut serial, &updates, 4);

            let mut pool = WorkerPool::new_with_transport(Arc::clone(&algo), transport);
            let order: Vec<u32> = (0..4).collect();
            for &n in &order {
                pool.spawn_worker(n, SharedStore::new());
            }
            let pending = pool
                .begin_allreduce(&order, &model, updates, 4, kind, 0)
                .unwrap();
            // Revoke rank 2 while the collective is in flight.
            let drained = pool.shutdown_worker(2).unwrap();
            assert!(drained.is_empty());
            assert!(!pool.has_worker(2));

            let out = pool.collect_allreduce(pending).unwrap();
            assert_eq!(out.model, serial, "{name}: {kind:?} mid-collective revoke diverged");
            assert!(out.rounds > 0 && out.bytes > 0, "{name}: {kind:?} stats lost in stash");
        }
    }
}

/// The pool's two merge fan-outs agree with each other and with the
/// serial fold: coordinator sharded reduce, ring, and tree all produce
/// the same bits from the same inputs.
pub fn pool_allreduce_matches_pool_reduce_bit_for_bit(transport: TransportKind) {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(77);
        let model = Arc::new(algo.init_model().unwrap());
        let updates = random_updates(&mut rng, 4, len);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 4);

        let mut pool = WorkerPool::new_with_transport(Arc::clone(&algo), transport);
        let order: Vec<u32> = (0..4).collect();
        for &n in &order {
            pool.spawn_worker(n, SharedStore::new());
        }
        let (reduced, _) = pool
            .reduce_model(&model, Arc::new(updates.clone()), 4, ReduceOptions::default())
            .unwrap();
        assert_eq!(reduced, serial, "{name}: coordinator reduce diverged");
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            let out = pool
                .allreduce_model(&order, &model, updates.clone(), 4, kind, 1)
                .unwrap();
            assert_eq!(out.model, serial, "{name}: {kind:?} collective diverged");
        }
    }
}

/// A single-rank order never touches the transport: the pool folds
/// inline, reporting zero rounds and zero bytes (mirroring
/// `reduce_model`'s small-pool path).
pub fn single_rank_pool_allreduce_folds_inline(transport: TransportKind) {
    let algo: Arc<dyn Algorithm> =
        Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, 17));
    let model = Arc::new(vec![0.25f32; 17]);
    let mut rng = Rng::seed_from_u64(3);
    let updates = random_updates(&mut rng, 1, 17);
    let mut serial = (*model).clone();
    algo.merge(&mut serial, &updates, 1);

    let mut pool = WorkerPool::new_with_transport(Arc::clone(&algo), transport);
    pool.spawn_worker(0, SharedStore::new());
    for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
        let out = pool
            .allreduce_model(&[0], &model, updates.clone(), 1, kind, 0)
            .unwrap();
        assert_eq!(out.model, serial, "{kind:?}");
        assert_eq!((out.rounds, out.bytes), (0, 0), "{kind:?} must not touch the wire");
        assert_eq!(out.frame_bytes, 0, "{kind:?} inline fold must add no framing");
    }
}

/// The rejoin protocol: a node outside the collective asks *peers* (not
/// the coordinator) for the latest complete model. Requests queued before
/// the collective are served at entry; the replies carry the pre-merge
/// snapshot every rank holds.
pub fn rejoining_node_fetches_state_from_any_peer(make: GroupCtor) {
    let algo: Arc<dyn Algorithm> =
        Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, 128));
    let model: ModelVec = (0..128).map(|i| i as f32 * 0.01).collect();
    let mut rng = Rng::seed_from_u64(41);
    let updates = random_updates(&mut rng, 3, 128);

    for _attempt in 0..ATTEMPTS {
        let group = make();
        let order = [4u32, 5, 6];
        let eps: Vec<_> = order.iter().map(|&n| group.join(n)).collect();
        let mut rejoiner = group.join(99);
        // Ask two *different* peers before the collective starts: any
        // member must be able to answer — no coordinator bottleneck.
        rejoiner.send(4, Payload::StateRequest).unwrap();
        rejoiner.send(6, Payload::StateRequest).unwrap();
        // Let the requests land in the peers' queues before the
        // collective launches (serve-at-entry is what is under test).
        std::thread::sleep(SETTLE);
        let epoch = group.membership().epoch;
        // Threads hand their endpoints back so the peers stay group
        // members while the rejoiner fetches (a departed peer cannot be
        // sent to).
        let (runs, _live_eps): (Vec<AllreduceRun>, Vec<_>) = std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .enumerate()
                .map(|(rank, mut ep)| {
                    let (algo, model, updates, order) = (&algo, &model, &updates, &order);
                    s.spawn(move || {
                        let parts = vec![(rank, updates[rank].clone())];
                        let ctx = CollectiveCtx {
                            algo: algo.as_ref(),
                            model,
                            parts: &parts,
                            k_tasks: 3,
                            order,
                            epoch,
                            iter: 7,
                        };
                        let run = ring_allreduce(ep.as_mut(), &ctx).unwrap();
                        (run, ep)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).unzip()
        });
        let served: usize = runs.iter().map(|r| r.stats.state_served).sum();
        if served != 2 {
            // A request was still crossing the socket when its peer
            // entered (and left) the collective; try a fresh group.
            continue;
        }
        // `fetch_state` consumes the first queued reply; the second is
        // read raw to prove both peers sent the same pre-merge snapshot.
        let state = fetch_state(rejoiner.as_mut(), 4, Duration::from_secs(1))
            .expect("reply was already queued");
        assert_eq!(state, model, "rejoin state must be the pre-merge model");
        let second = fetch_state(rejoiner.as_mut(), 6, Duration::from_secs(1))
            .expect("second peer's reply was also queued");
        assert_eq!(second, model);
        return;
    }
    panic!("both rejoin requests were never served pre-entry in {ATTEMPTS} attempts");
}
