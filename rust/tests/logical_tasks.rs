//! The decoupled schedule's headline property: with `logical_tasks = K`
//! fixed, the iterate trajectory — metrics, virtual times, epochs, and
//! the final model's exact bits — is identical for any worker-thread
//! count `1 ≤ W ≤ K`, through mid-run W resizes, under both the
//! coordinator-side sharded reduce and the ring-allreduce merge. Only the
//! `n_threads`/occupancy columns (and wallclock) may differ: convergence
//! is governed by the algorithmic parallelism K alone, which is Chicle's
//! central claim.
//!
//! K defaults to 8 and is steered by `CHICLE_LOGICAL_TASKS` (the CI
//! oversubscription leg runs this suite with it set explicitly). The
//! variable is read *once*, so the env test below cannot race the
//! trajectory tests; every trajectory config additionally pins K via the
//! builder, which wins over the env.

use std::sync::OnceLock;

use chicle::config::{AlgoConfig, ElasticSpec, MergeStrategy, ModelKind, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::metrics::MetricsLog;

/// The sweep's logical parallelism degree.
fn k() -> usize {
    static K: OnceLock<usize> = OnceLock::new();
    *K.get_or_init(|| match std::env::var("CHICLE_LOGICAL_TASKS") {
        Ok(s) if !s.is_empty() => s.parse().expect("CHICLE_LOGICAL_TASKS must be an integer"),
        _ => 8,
    })
}

/// Run an elastic lSGD/MLP session (235k-parameter model — large enough
/// for the sharded pool reduce and the overlap pipeline to engage) with K
/// logical tasks on the given thread schedule. Returns the metrics log
/// and the final model's exact bits.
fn run_mlp(
    k_tasks: usize,
    elastic: ElasticSpec,
    strategy: MergeStrategy,
) -> (MetricsLog, Vec<u32>) {
    let ds = synth::fmnist_like(1200, 7);
    let mut cfg = SessionConfig::lsgd("logical-tasks", ModelKind::Mlp, 4)
        .with_seed(23)
        .with_merge_strategy(strategy)
        .with_logical_tasks(k_tasks)
        .with_elastic(elastic);
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = 10;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 4;
        l.target_acc = 2.0; // unreachable: run all iterations
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    let bits = s.trainer().model().iter().map(|x| x.to_bits()).collect();
    (log, bits)
}

/// Same shape for CoCoA (the sample-weighted merge family, serial-fold
/// sized model).
fn run_cocoa(
    k_tasks: usize,
    elastic: ElasticSpec,
    strategy: MergeStrategy,
) -> (MetricsLog, Vec<u32>) {
    let ds = synth::higgs_like(3000, 5);
    let mut cfg = SessionConfig::cocoa("logical-tasks-cocoa", 2)
        .with_seed(31)
        .with_merge_strategy(strategy)
        .with_logical_tasks(k_tasks)
        .with_elastic(elastic);
    cfg.chunk_bytes = 8 * 1024;
    cfg.max_iters = 10;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    let bits = s.trainer().model().iter().map(|x| x.to_bits()).collect();
    (log, bits)
}

/// Everything that defines the science must match; `n_threads` (and the
/// wallclock columns) are exactly what the decoupling is *allowed* to
/// change, so they are deliberately not compared here.
fn assert_same_science(a: &MetricsLog, b: &MetricsLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record counts");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.iter, y.iter, "{label}");
        assert_eq!(x.metric, y.metric, "{label} iter {}", x.iter);
        assert_eq!(x.vtime, y.vtime, "{label} iter {}", x.iter);
        assert_eq!(x.epochs, y.epochs, "{label} iter {}", x.iter);
        assert_eq!(x.n_tasks, y.n_tasks, "{label} iter {}", x.iter);
        assert_eq!(x.samples, y.samples, "{label} iter {}", x.iter);
        assert_eq!(x.train_loss, y.train_loss, "{label} iter {}", x.iter);
    }
}

/// The tentpole property, coordinator-reduce leg: W ∈ {1, 2, K/2, K}
/// rigid schedules plus scale-in and scale-out resizes all produce the
/// reference trajectory and the reference model, bit for bit.
#[test]
fn final_model_bits_identical_across_w_sweep_and_resizes() {
    let k = k();
    let (base_log, base_bits) =
        run_mlp(k, ElasticSpec::Rigid { nodes: k }, MergeStrategy::Coordinator);
    assert!(base_log.records.iter().all(|r| r.n_tasks == k), "K is pinned");
    assert!(base_log.records.iter().all(|r| r.n_threads == k));

    for w in [1, 2, k / 2] {
        let w = w.max(1);
        let (log, bits) =
            run_mlp(k, ElasticSpec::Rigid { nodes: w }, MergeStrategy::Coordinator);
        assert_same_science(&base_log, &log, &format!("W={w}"));
        assert!(log.records.iter().all(|r| r.n_threads == w), "W={w}");
        assert_eq!(bits, base_bits, "final model bits diverged at W={w}");
    }

    // Mid-run resizes in both directions: threads leave (tasks rebind to
    // survivors) and threads join (tasks spread back out).
    for (label, elastic) in [
        ("scale-in", ElasticSpec::Gradual { from: k, to: 2, interval_s: 3.0 }),
        ("scale-out", ElasticSpec::Gradual { from: 2, to: k, interval_s: 3.0 }),
    ] {
        let (log, bits) = run_mlp(k, elastic, MergeStrategy::Coordinator);
        assert_same_science(&base_log, &log, label);
        assert_eq!(bits, base_bits, "final model bits diverged under {label}");
        let threads: Vec<usize> = log.records.iter().map(|r| r.n_threads).collect();
        assert!(
            threads.windows(2).any(|w| w[0] != w[1]),
            "{label}: the resize must actually have fired ({threads:?})"
        );
        assert!(log.records.iter().all(|r| r.n_tasks == k), "{label}: K never budges");
    }
}

/// The ring-allreduce leg: a thread hosting m logical tasks contributes m
/// slices per scatter round, owners fold all K parts in task order — so
/// the same W-sweep invariance holds with updates moving peer-to-peer.
#[test]
fn ring_allreduce_w_sweep_matches_coordinator_reduce() {
    let k = k();
    let (base_log, base_bits) =
        run_cocoa(k, ElasticSpec::Rigid { nodes: k }, MergeStrategy::Coordinator);

    for w in [1, 2, k.max(2)] {
        let (log, bits) = run_cocoa(k, ElasticSpec::Rigid { nodes: w }, MergeStrategy::Ring);
        assert_same_science(&base_log, &log, &format!("ring W={w}"));
        assert_eq!(bits, base_bits, "ring final model bits diverged at W={w}");
        // Rounds follow the *rank* count W (every hosted thread is a
        // rank), not K: 2(W−1) per iteration, 0 for the inline W=1 fold.
        let want = if w > 1 { 2 * (w - 1) } else { 0 };
        assert!(
            log.records.iter().all(|r| r.transport_rounds == want),
            "ring W={w} rounds"
        );
    }

    let (log, bits) = run_cocoa(
        k,
        ElasticSpec::Gradual { from: k.max(2), to: 2, interval_s: 3.0 },
        MergeStrategy::Ring,
    );
    assert_same_science(&base_log, &log, "ring scale-in");
    assert_eq!(bits, base_bits, "ring final model bits diverged through the resize");
}

/// W = K decoupled is the legacy coupling with different bookkeeping: the
/// trajectory and model must match a `logical_tasks = 0` session bit for
/// bit (same seed, same rigid schedule), so enabling the feature at full
/// width is a pure no-op for the science.
#[test]
fn w_equals_k_matches_legacy_coupling_bit_for_bit() {
    let k = k();
    let (legacy_log, legacy_bits) =
        run_mlp(0, ElasticSpec::Rigid { nodes: k }, MergeStrategy::Coordinator);
    let (dec_log, dec_bits) =
        run_mlp(k, ElasticSpec::Rigid { nodes: k }, MergeStrategy::Coordinator);
    assert_same_science(&legacy_log, &dec_log, "legacy-vs-decoupled");
    assert_eq!(dec_bits, legacy_bits, "decoupled W=K must be a bitwise no-op");
    assert!(dec_log.records.iter().all(|r| r.n_threads == r.n_tasks));
}

/// `CHICLE_LOGICAL_TASKS` steers freshly constructed configs (the CI
/// oversubscription leg uses this); configs built with the explicit
/// builder — every trajectory test above — are immune to it. Mirrors
/// `merge_strategies.rs`'s env test for `CHICLE_MERGE_STRATEGY`.
#[test]
fn env_override_steers_new_configs_only() {
    let _ = k(); // pin the sweep's K before mutating the variable
    std::env::set_var("CHICLE_LOGICAL_TASKS", "5");
    let fresh = SessionConfig::cocoa("env-fresh", 2);
    let pinned = SessionConfig::cocoa("env-pinned", 2).with_logical_tasks(3);
    std::env::remove_var("CHICLE_LOGICAL_TASKS");
    assert_eq!(fresh.logical_tasks, 5);
    assert_eq!(fresh.decoupled_tasks(), Some(5));
    assert_eq!(pinned.logical_tasks, 3, "builder pin wins over the env");
    let unset = SessionConfig::cocoa("env-unset", 2);
    assert_eq!(unset.logical_tasks, 0, "no override once the variable is gone");
    assert_eq!(unset.decoupled_tasks(), None, "0 keeps the legacy coupling");
    // Micro-task emulation ignores the knob entirely.
    assert_eq!(
        SessionConfig::cocoa("micro", 2)
            .with_logical_tasks(4)
            .with_microtasks(16)
            .decoupled_tasks(),
        None
    );
}
