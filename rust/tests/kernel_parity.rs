//! Kernel parity suite: the dispatched (possibly AVX2) kernels must be
//! *bit-identical* to the scalar reference — not merely close — because
//! both paths are written with the same IEEE-754 operation order
//! (lane-per-element for the elementwise merge-path kernels, fixed
//! lane-split + fixed horizontal-combine tree for the reductions).
//! Reductions are additionally checked ulp-close against a naive f64
//! fold, and the sharded merge built on these kernels is checked
//! bit-identical and run-to-run deterministic at 1/4/8 workers.
//!
//! Under `--no-default-features` the dispatched path *is* the scalar
//! path and every assertion holds trivially — the suite then pins the
//! scalar reference against the naive models instead.

use std::sync::Arc;

use chicle::algos::nn::linear::Act;
use chicle::algos::svm::{
    scd_pass_dense, scd_pass_dense_scalar, scd_pass_sparse, scd_pass_sparse_scalar,
};
use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, LsgdAlgo};
use chicle::chunks::chunker::make_chunks;
use chicle::chunks::{Samples, SharedStore};
use chicle::config::{CocoaConfig, LsgdConfig, ModelKind};
use chicle::data::{synth, SparseVec};
use chicle::exec::{ReduceOptions, WorkerPool};
use chicle::util::{kernels, Rng, Workspace};

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// Lengths that exercise the empty case, sub-lane sizes, exact lane
/// multiples, and odd tails around the 8- and 16-lane boundaries.
const LENS: [usize; 9] = [0, 1, 7, 8, 15, 16, 17, 255, 1000];

#[test]
fn elementwise_kernels_bit_equal_scalar_reference() {
    let mut rng = Rng::seed_from_u64(11);
    for len in LENS {
        let x = randv(&mut rng, len);
        let y0 = randv(&mut rng, len);

        let (mut a, mut b) = (y0.clone(), y0.clone());
        kernels::acc(&mut a, &x);
        kernels::scalar::acc(&mut b, &x);
        assert_eq!(a, b, "acc len={len}");

        let (mut a, mut b) = (y0.clone(), y0.clone());
        kernels::axpy(&mut a, 0.7315, &x);
        kernels::scalar::axpy(&mut b, 0.7315, &x);
        assert_eq!(a, b, "axpy len={len}");

        let (mut a, mut b) = (y0.clone(), y0.clone());
        kernels::scale_add(&mut a, 0.9, &x);
        kernels::scalar::scale_add(&mut b, 0.9, &x);
        assert_eq!(a, b, "scale_add len={len}");

        let (mut va, mut dva) = (y0.clone(), vec![0.25f32; len]);
        let (mut vb, mut dvb) = (y0.clone(), vec![0.25f32; len]);
        kernels::fused_axpy2(&mut va, &mut dva, 4.0, -0.31, &x);
        kernels::scalar::fused_axpy2(&mut vb, &mut dvb, 4.0, -0.31, &x);
        assert_eq!(va, vb, "fused_axpy2 v len={len}");
        assert_eq!(dva, dvb, "fused_axpy2 dv len={len}");
    }
}

#[test]
fn reduction_kernels_bit_equal_scalar_and_close_to_naive() {
    let mut rng = Rng::seed_from_u64(12);
    for len in LENS {
        let a = randv(&mut rng, len);
        let b = randv(&mut rng, len);

        // Exact-bit agreement between the dispatch and the reference.
        let d = kernels::dot(&a, &b);
        let ds = kernels::scalar::dot(&a, &b);
        assert_eq!(d.to_bits(), ds.to_bits(), "dot len={len}");

        // Bounded closeness to the naive f64 fold (the lane split only
        // re-associates the sum, it cannot drift).
        let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
        assert!(
            (d as f64 - naive).abs() <= 1e-4 * (1.0 + naive.abs()),
            "dot len={len}: {d} vs naive {naive}"
        );

        let m = kernels::vmax(&a);
        let ms = kernels::scalar::vmax(&a);
        assert_eq!(m.to_bits(), ms.to_bits(), "vmax len={len}");
        let fold = a.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(m, fold, "vmax len={len} vs serial fold");
    }
}

#[test]
fn fused_linear_scalar_twin_bit_equal() {
    let mut rng = Rng::seed_from_u64(13);
    // Geometries straddling the cache-block boundaries (BLOCK_K = 128,
    // BLOCK_N = 512) and the lane tails.
    for (m, k, n) in [(1usize, 5usize, 3usize), (4, 130, 515), (8, 784, 256)] {
        let x = randv(&mut rng, m * k);
        let w = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        for act in [Act::None, Act::Relu, Act::Gelu] {
            let (y1, pre1) = kernels::fused_linear_fwd(&x, &w, &bias, m, k, n, act);
            let (y2, pre2) = kernels::fused_linear_fwd_scalar(&x, &w, &bias, m, k, n, act);
            assert_eq!(pre1, pre2, "pre {m}x{k}x{n} {act:?}");
            assert_eq!(y1, y2, "y {m}x{k}x{n} {act:?}");
        }
    }
}

#[test]
fn scd_dense_pass_scalar_twin_bit_equal() {
    let mut rng = Rng::seed_from_u64(14);
    let (s, dim) = (256usize, 37usize); // odd dim: lane tails every row
    let x = randv(&mut rng, s * dim);
    let y: Vec<f32> = (0..s).map(|_| if rng.bool(0.5) { 1.0 } else { -1.0 }).collect();
    let order: Vec<usize> = (0..s).collect();
    let lam_n = 0.01 * s as f32;

    let mut a1 = vec![0.0f32; s];
    let mut v1 = vec![0.01f32; dim];
    let mut dv1 = vec![0.0f32; dim];
    scd_pass_dense(&x, dim, &y, &order, &mut a1, &mut v1, &mut dv1, lam_n, 4.0);

    let mut a2 = vec![0.0f32; s];
    let mut v2 = vec![0.01f32; dim];
    let mut dv2 = vec![0.0f32; dim];
    scd_pass_dense_scalar(&x, dim, &y, &order, &mut a2, &mut v2, &mut dv2, lam_n, 4.0);

    assert_eq!(a1, a2, "alpha diverged");
    assert_eq!(v1, v2, "v diverged");
    assert_eq!(dv1, dv2, "dv diverged");
}

#[test]
fn matmul_zero_skip_bit_equal_dense_on_mixed_input() {
    let mut rng = Rng::seed_from_u64(15);
    let (m, k, n) = (6usize, 133usize, 70usize);
    // Post-ReLU-like A: roughly half exact zeros.
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32().max(0.0)).collect();
    let b = randv(&mut rng, k * n);
    let mut dense = vec![0.0f32; m * n];
    let mut skip = vec![0.0f32; m * n];
    kernels::matmul(&a, &b, &mut dense, m, k, n);
    kernels::matmul_zero_skip(&a, &b, &mut skip, m, k, n);
    assert_eq!(dense, skip);
}

/// Packed-B matmul vs the unpacked blocked matmul, bitwise, across
/// geometries with N below, at, and above the packing block width
/// (BLOCK_N = 512) — and the packed dispatch vs its scalar twin.
#[test]
fn packed_matmul_bit_equal_unpacked_and_scalar_twin() {
    let mut rng = Rng::seed_from_u64(21);
    for (m, k, n) in [(3usize, 130usize, 300usize), (2, 64, 512), (3, 200, 515), (2, 300, 1030)] {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut unpacked = vec![0.0f32; m * n];
        kernels::matmul(&a, &b, &mut unpacked, m, k, n);

        let mut scratch = vec![0.0f32; kernels::packed_b_len(k, n)];
        let mut packed = vec![0.0f32; m * n];
        kernels::matmul_packed(&a, &b, &mut packed, m, k, n, &mut scratch);
        assert_eq!(packed, unpacked, "packed vs unpacked {m}x{k}x{n}");

        let mut packed_scalar = vec![0.0f32; m * n];
        kernels::matmul_packed_scalar(&a, &b, &mut packed_scalar, m, k, n, &mut scratch);
        assert_eq!(packed_scalar, packed, "packed scalar twin {m}x{k}x{n}");
    }
}

/// The sparse gather/scatter kernels against their scalar twins, bitwise,
/// across index patterns (contiguous, strided, clustered) and lengths
/// straddling the lane boundaries.
#[test]
fn sparse_kernels_bit_equal_scalar_reference() {
    let mut rng = Rng::seed_from_u64(22);
    let dim = 4096usize;
    let dense = randv(&mut rng, dim);
    for nnz in [0usize, 1, 7, 8, 15, 16, 17, 255, 1000] {
        // Sorted unique random indices (the SparseVec invariant).
        let mut idx: Vec<u32> = Vec::with_capacity(nnz);
        let mut next = 0u32;
        for _ in 0..nnz {
            next += 1 + rng.below(3) as u32;
            idx.push(next);
        }
        let vals = randv(&mut rng, nnz);

        let d = kernels::sparse_dot(&idx, &vals, &dense);
        let ds = kernels::scalar::sparse_dot(&idx, &vals, &dense);
        assert_eq!(d.to_bits(), ds.to_bits(), "sparse_dot nnz={nnz}");

        let (mut v1, mut dv1) = (dense.clone(), vec![0.5f32; dim]);
        let (mut v2, mut dv2) = (dense.clone(), vec![0.5f32; dim]);
        kernels::sparse_fused_axpy2(&mut v1, &mut dv1, 4.0, -0.37, &idx, &vals);
        kernels::scalar::sparse_fused_axpy2(&mut v2, &mut dv2, 4.0, -0.37, &idx, &vals);
        assert_eq!(v1, v2, "sparse_fused_axpy2 v nnz={nnz}");
        assert_eq!(dv1, dv2, "sparse_fused_axpy2 dv nnz={nnz}");
    }
}

/// Dispatched maxpool4 vs its scalar twin, bitwise, including the
/// tie-heavy case (quantized values force equal candidates — first max
/// must win on both paths).
#[test]
fn maxpool4_bit_equal_scalar_reference() {
    let mut rng = Rng::seed_from_u64(23);
    for c in [1usize, 7, 8, 16, 17, 64] {
        let quantized: Vec<f32> = (0..4 * c).map(|_| rng.below(4) as f32).collect();
        let rows: Vec<&[f32]> =
            (0..4).map(|i| &quantized[i * c..(i + 1) * c]).collect();
        let base = [0u32, 1000, 2000, 3000];

        let (mut y1, mut a1) = (vec![0.0f32; c], vec![0u32; c]);
        let (mut y2, mut a2) = (vec![0.0f32; c], vec![0u32; c]);
        kernels::maxpool4(rows[0], rows[1], rows[2], rows[3], base, &mut y1, &mut a1);
        kernels::scalar::maxpool4(rows[0], rows[1], rows[2], rows[3], base, &mut y2, &mut a2);
        assert_eq!(y1, y2, "maxpool4 y c={c}");
        assert_eq!(a1, a2, "maxpool4 arg c={c}");
    }
}

/// The full sparse SCD pass against its scalar twin on Criteo-like data:
/// the trajectory (α, v, dv) must be bit-identical, not merely close.
#[test]
fn scd_sparse_pass_scalar_twin_bit_equal() {
    let ds = synth::criteo_like_with(512, 2000, 30, 24, 7);
    let chunks = make_chunks(&ds, usize::MAX);
    let (rows, dim, y): (&[SparseVec], usize, &[f32]) = match chunks[0].samples() {
        Samples::SparseBinary { rows, dim, y } => (rows, *dim, y),
        _ => panic!("criteo-like data should chunk sparse"),
    };
    let order: Vec<usize> = (0..y.len()).collect();
    let lam_n = 0.01 * y.len() as f32;

    let mut a1 = vec![0.0f32; y.len()];
    let mut v1 = vec![0.01f32; dim];
    let mut dv1 = vec![0.0f32; dim];
    scd_pass_sparse(rows, y, &order, &mut a1, &mut v1, &mut dv1, lam_n, 4.0);

    let mut a2 = vec![0.0f32; y.len()];
    let mut v2 = vec![0.01f32; dim];
    let mut dv2 = vec![0.0f32; dim];
    scd_pass_sparse_scalar(rows, y, &order, &mut a2, &mut v2, &mut dv2, lam_n, 4.0);

    assert_eq!(a1, a2, "alpha diverged");
    assert_eq!(v1, v2, "v diverged");
    assert_eq!(dv1, dv2, "dv diverged");
}

/// The workspace-reuse contract: running an iteration through a *dirty*
/// workspace (already used by a different-shaped iteration) must produce
/// the exact bits of a fresh workspace — and of the plain allocating
/// `task_iterate`. This is what makes W-sweeps and task rebinding
/// trajectory-invariant.
#[test]
fn dirty_workspace_bit_identical_to_fresh() {
    // CoCoA over dense chunks.
    let ds = synth::higgs_like(1000, 7);
    let chunks = make_chunks(&ds, 16 * 1024);
    let algo =
        CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), ds.n_samples(), ds.dim());
    let model = algo.init_model().unwrap();
    // Chunk state mutates, so each run gets its own clone of the chunks.
    let run = |ws: &mut Workspace| {
        let mut cs = chunks.clone();
        algo.task_iterate_ws(&mut cs, &model, 4, 99, None, ws).unwrap()
    };
    let fresh = run(&mut Workspace::new());
    let mut dirty = Workspace::new();
    // Dirty it: a different seed draws different orders and leaves
    // different garbage in every pooled buffer.
    run(&mut dirty);
    let reused = run(&mut dirty);
    assert_eq!(fresh.delta, reused.delta, "cocoa: dirty workspace changed bits");
    assert_eq!(fresh.samples, reused.samples);
    let plain = {
        let mut cs = chunks.clone();
        algo.task_iterate(&mut cs, &model, 4, 99, None).unwrap()
    };
    assert_eq!(plain.delta, fresh.delta, "cocoa: task_iterate vs task_iterate_ws");

    // lSGD over an MLP (chunks are read-only here).
    let ds = synth::fmnist_like(600, 5);
    let mut cfg = LsgdConfig::paper_defaults(ModelKind::Mlp);
    cfg.h = 2;
    let algo = LsgdAlgo::new_classif(
        cfg,
        Backend::native_nn(chicle::algos::nn::NativeModel::mlp_default()),
        784,
        Vec::new(),
        Vec::new(),
        7,
    )
    .unwrap();
    let mut chunks = make_chunks(&ds, 64 * 1024);
    let model = algo.init_model().unwrap();
    let fresh =
        algo.task_iterate_ws(&mut chunks, &model, 2, 55, None, &mut Workspace::new()).unwrap();
    let mut dirty = Workspace::new();
    algo.task_iterate_ws(&mut chunks, &model, 2, 56, None, &mut dirty).unwrap();
    let reused = algo.task_iterate_ws(&mut chunks, &model, 2, 55, None, &mut dirty).unwrap();
    assert_eq!(fresh.delta, reused.delta, "lsgd: dirty workspace changed bits");
    let plain = algo.task_iterate(&mut chunks, &model, 2, 55, None).unwrap();
    assert_eq!(plain.delta, fresh.delta, "lsgd: task_iterate vs task_iterate_ws");
}

fn pool_of(algo: &Arc<dyn Algorithm>, n_workers: usize) -> WorkerPool {
    let mut pool = WorkerPool::new(Arc::clone(algo));
    for i in 0..n_workers {
        pool.spawn_worker(i as u32, SharedStore::new());
    }
    pool
}

/// Merge determinism on top of the vectorized fold kernels: the sharded
/// reduction equals the serial fold bit-for-bit at 1, 4 and 8 workers,
/// and repeated reductions at each worker count return identical bits
/// (run-to-run determinism — the fixed lane split cannot depend on
/// timing or claim interleaving).
#[test]
fn merge_fold_deterministic_at_1_4_8_workers() {
    let algos: Vec<(&str, Arc<dyn Algorithm>)> = vec![
        (
            "cocoa",
            Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 10_000, 4099))
                as Arc<dyn Algorithm>,
        ),
        (
            "lsgd-mlp",
            Arc::new(
                LsgdAlgo::new_classif(
                    LsgdConfig::paper_defaults(ModelKind::Mlp),
                    Backend::native_nn(chicle::algos::nn::NativeModel::mlp_default()),
                    784,
                    Vec::new(),
                    Vec::new(),
                    1,
                )
                .unwrap(),
            ),
        ),
    ];
    for (name, algo) in algos {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(16);
        let model = Arc::new(algo.init_model().unwrap());
        let updates: Arc<Vec<LocalUpdate>> = Arc::new(
            (0..5)
                .map(|_| LocalUpdate {
                    delta: randv(&mut rng, len),
                    samples: 1 + rng.below(2000),
                    loss_sum: 0.0,
                })
                .collect(),
        );
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 5);
        for n_workers in [1usize, 4, 8] {
            let mut pool = pool_of(&algo, n_workers);
            let (first, _) = pool
                .reduce_model(&model, Arc::clone(&updates), 5, ReduceOptions::default())
                .unwrap();
            assert_eq!(first, serial, "{name}: {n_workers}w diverged from serial fold");
            for round in 0..3 {
                let (again, _) = pool
                    .reduce_model(&model, Arc::clone(&updates), 5, ReduceOptions::default())
                    .unwrap();
                assert_eq!(again, first, "{name}: {n_workers}w round {round} not reproducible");
            }
        }
    }
}
