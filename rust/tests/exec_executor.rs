//! Integration tests for the persistent uni-task executor: determinism of
//! the full trainer on top of it, chunk conservation through the
//! drain-on-revoke protocol, and the worker command protocol itself.

use std::sync::Arc;

use chicle::algos::{Algorithm, Backend, CocoaAlgo};
use chicle::chunks::chunker::make_chunks;
use chicle::chunks::SharedStore;
use chicle::config::{CocoaConfig, ElasticSpec, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::exec::WorkerPool;
use chicle::metrics::MetricsLog;

fn elastic_log(seed: u64) -> MetricsLog {
    let ds = synth::higgs_like(2000, 5);
    let mut cfg = SessionConfig::cocoa("exec-det", 8).with_seed(seed);
    cfg.chunk_bytes = 4 * 1024;
    cfg.elastic = ElasticSpec::Gradual { from: 8, to: 2, interval_s: 5.0 };
    cfg.policies.rebalance = true;
    cfg.max_iters = 15;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run_iters(15).unwrap()
}

/// Two runs with the same seed must produce identical `MetricsLog`
/// records regardless of how the OS schedules the worker threads. `wall`
/// is measured wallclock and is the one deliberately excluded field.
#[test]
fn determinism_identical_metrics_log_across_runs() {
    let a = elastic_log(11);
    let b = elastic_log(11);
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.iter, rb.iter);
        assert_eq!(ra.epochs, rb.epochs);
        assert_eq!(ra.metric, rb.metric);
        assert_eq!(ra.vtime, rb.vtime);
        assert_eq!(ra.n_tasks, rb.n_tasks);
        assert_eq!(ra.samples, rb.samples);
        assert_eq!(ra.train_loss, rb.train_loss);
    }
    // And a different seed must actually change the trajectory.
    let c = elastic_log(12);
    let gaps = |log: &MetricsLog| -> Vec<f64> {
        log.records
            .iter()
            .filter_map(|r| r.metric.map(|m| m.value()))
            .collect()
    };
    assert_ne!(gaps(&a), gaps(&c), "different seeds should differ");
}

/// Scale-in drains every revoked worker through the executor's
/// DrainChunks→Shutdown path; no chunk (or duplicate) may result.
#[test]
fn drain_on_revoke_conserves_chunks_mid_session() {
    let ds = synth::higgs_like(2000, 3);
    let mut cfg = SessionConfig::cocoa("exec-drain", 8);
    cfg.chunk_bytes = 4 * 1024;
    cfg.elastic = ElasticSpec::Gradual { from: 8, to: 2, interval_s: 4.0 };
    cfg.max_iters = 20;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run_iters(20).unwrap();
    assert_eq!(s.trainer().tasks().len(), 2, "scale-in should complete");
    let total: usize = s.trainer().tasks().iter().map(|t| t.n_samples()).sum();
    assert_eq!(total, 2000, "no samples lost through worker shutdown");
    let mut ids: Vec<u32> = s
        .trainer()
        .tasks()
        .iter()
        .flat_map(|t| t.store.chunk_ids())
        .collect();
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "no chunk may land on two tasks");
}

/// Exercise the raw worker command protocol: install chunks, run an
/// iteration against them, then drain-and-shutdown and verify the chunks
/// come back intact with their per-sample optimizer state.
#[test]
fn worker_protocol_install_run_drain_shutdown() {
    let ds = synth::higgs_like(400, 1);
    let chunks = make_chunks(&ds, 4 * 1024);
    let n_chunks = chunks.len();
    let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        CocoaConfig::default(),
        Backend::native_cocoa(),
        ds.n_samples(),
        ds.dim(),
    ));
    let model = Arc::new(algo.init_model().unwrap());
    let mut pool = WorkerPool::new(Arc::clone(&algo));
    pool.spawn_worker(7, SharedStore::new());
    pool.install_chunks(7, chunks).unwrap();

    // The iteration runs against the installed chunks (commands are FIFO).
    let runs = pool
        .run_iteration(&[(7, 99)], Arc::clone(&model), 1, None)
        .unwrap();
    assert_eq!(runs.len(), 1);
    assert_eq!(runs[0].update.samples, 400, "one full local pass");

    // Drain-then-shutdown returns every chunk, state included.
    let drained = pool.shutdown_worker(7).unwrap();
    assert_eq!(drained.len(), n_chunks);
    let total: usize = drained.iter().map(|c| c.n_samples()).sum();
    assert_eq!(total, 400);
    assert!(
        drained.iter().any(|c| c.state.iter().any(|&a| a != 0.0)),
        "per-sample dual state should move with the chunks"
    );
    assert!(!pool.has_worker(7));
}

/// The same seeds through the pool produce bit-identical updates — the
/// worker runtime adds no nondeterminism over direct task_iterate calls.
#[test]
fn pool_updates_match_direct_task_iterate() {
    let ds = synth::higgs_like(600, 2);
    let chunks = make_chunks(&ds, 8 * 1024);
    let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        CocoaConfig::default(),
        Backend::native_cocoa(),
        ds.n_samples(),
        ds.dim(),
    ));
    let model = Arc::new(algo.init_model().unwrap());

    // Direct execution on a private copy of the chunks.
    let mut direct_chunks = chunks.clone();
    let direct = algo
        .task_iterate(&mut direct_chunks, &model, 2, 1234, None)
        .unwrap();

    // Pool execution against the same inputs.
    let store = SharedStore::from_chunks(chunks);
    let mut pool = WorkerPool::new(Arc::clone(&algo));
    pool.spawn_worker(0, store.clone());
    let runs = pool
        .run_iteration(&[(0, 1234)], Arc::clone(&model), 2, None)
        .unwrap();

    assert_eq!(runs[0].update.samples, direct.samples);
    assert_eq!(runs[0].update.delta, direct.delta);
    let pooled_state: Vec<f32> = store
        .lock()
        .iter()
        .flat_map(|c| c.state.clone())
        .collect();
    let direct_state: Vec<f32> = direct_chunks.iter().flat_map(|c| c.state.clone()).collect();
    assert_eq!(pooled_state, direct_state);
}
