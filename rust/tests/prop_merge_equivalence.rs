//! Property tests: the sharded parallel model reduction through the
//! worker pool is *bit-identical* to the serial merge fold — for every
//! algorithm family (CoCoA GLM, lSGD MLP, lSGD CNN), across 1–8 workers,
//! odd shard splits, and an elastic resize mid-run. This is the
//! determinism invariant the trainer's parallel merge phase rests on.
//!
//! proptest is not available in the offline crate set, so properties are
//! checked over seeded random cases (deterministic, reproducible).

use std::sync::Arc;

use chicle::algos::nn::NativeModel;
use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, LsgdAlgo};
use chicle::chunks::SharedStore;
use chicle::config::{CocoaConfig, LsgdConfig, ModelKind};
use chicle::exec::WorkerPool;
use chicle::util::Rng;

/// One representative of each algorithm family. The CoCoA dim is a prime
/// so no worker count divides the model evenly; the NN models exercise
/// real (large) parameter counts.
fn families() -> Vec<(&'static str, Arc<dyn Algorithm>)> {
    vec![
        (
            "cocoa",
            Arc::new(CocoaAlgo::new(
                CocoaConfig::default(),
                Backend::native_cocoa(),
                10_000,
                4099,
            )) as Arc<dyn Algorithm>,
        ),
        (
            "lsgd-mlp",
            Arc::new(
                LsgdAlgo::new_classif(
                    LsgdConfig::paper_defaults(ModelKind::Mlp),
                    Backend::native_nn(NativeModel::mlp_default()),
                    784,
                    Vec::new(),
                    Vec::new(),
                    1,
                )
                .unwrap(),
            ),
        ),
        (
            "lsgd-cnn",
            Arc::new(
                LsgdAlgo::new_classif(
                    LsgdConfig::paper_defaults(ModelKind::Cnn),
                    Backend::native_nn(NativeModel::cnn_default()),
                    3072,
                    Vec::new(),
                    Vec::new(),
                    1,
                )
                .unwrap(),
            ),
        ),
    ]
}

fn random_updates(rng: &mut Rng, k: usize, len: usize) -> Arc<Vec<LocalUpdate>> {
    Arc::new(
        (0..k)
            .map(|_| LocalUpdate {
                delta: (0..len).map(|_| rng.normal_f32()).collect(),
                samples: 1 + rng.below(2000),
                loss_sum: 0.0,
            })
            .collect(),
    )
}

fn pool_of(algo: &Arc<dyn Algorithm>, n_workers: usize) -> WorkerPool {
    let mut pool = WorkerPool::new(Arc::clone(algo));
    for i in 0..n_workers {
        pool.spawn_worker(i as u32, SharedStore::new());
    }
    pool
}

/// Parallel sharded merge == serial merge, bit for bit, for 1–8 workers
/// and several update counts, on every algorithm family.
#[test]
fn prop_sharded_merge_matches_serial() {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(7);
        let model = Arc::new(algo.init_model().unwrap());
        for k_updates in [1usize, 3, 5] {
            let updates = random_updates(&mut rng, k_updates, len);
            let mut serial = (*model).clone();
            algo.merge(&mut serial, &updates, k_updates);
            for n_workers in 1..=8usize {
                let pool = pool_of(&algo, n_workers);
                let merged = pool
                    .reduce_model(&model, Arc::clone(&updates), k_updates)
                    .unwrap();
                assert_eq!(
                    merged, serial,
                    "{name}: k={k_updates} workers={n_workers} diverged from serial fold"
                );
            }
        }
    }
}

/// The invariant holds across an elastic resize: merge at 4 workers,
/// revoke two and assign one (4 → 3, with a fresh node id), merge again —
/// both reductions must equal their serial folds exactly.
#[test]
fn prop_sharded_merge_survives_elastic_resize() {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(99);
        let mut pool = pool_of(&algo, 4);
        let model = Arc::new(algo.init_model().unwrap());

        let u1 = random_updates(&mut rng, 4, len);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &u1, 4);
        let merged = pool.reduce_model(&model, Arc::clone(&u1), 4).unwrap();
        assert_eq!(merged, serial, "{name}: pre-resize merge diverged");
        let model = Arc::new(merged);

        // Elastic event between iterations: shard count and shard→worker
        // assignment both change under the trainer's feet.
        pool.shutdown_worker(1).unwrap();
        pool.shutdown_worker(3).unwrap();
        pool.spawn_worker(7, SharedStore::new());

        let u2 = random_updates(&mut rng, 3, len);
        let mut serial2 = (*model).clone();
        algo.merge(&mut serial2, &u2, 3);
        let merged2 = pool.reduce_model(&model, Arc::clone(&u2), 3).unwrap();
        assert_eq!(merged2, serial2, "{name}: post-resize merge diverged");
    }
}

/// lSGD's weighted merge with zero total samples is the identity — the
/// sharded path must preserve that exactly (no NaNs from 0/0 weights).
#[test]
fn zero_sample_updates_leave_model_unchanged_under_sharding() {
    let (_, algo) = families().remove(1);
    let len = algo.model_len();
    let model = Arc::new(algo.init_model().unwrap());
    let updates = Arc::new(vec![
        LocalUpdate { delta: vec![1.0; len], samples: 0, loss_sum: 0.0 };
        3
    ]);
    let pool = pool_of(&algo, 4);
    let merged = pool.reduce_model(&model, updates, 3).unwrap();
    assert_eq!(merged, *model);
}
