//! Property tests: the sharded parallel model reduction through the
//! worker pool is *bit-identical* to the serial merge fold — for every
//! algorithm family (CoCoA GLM, lSGD MLP, lSGD CNN), across 1–8 workers,
//! shard counts of 1×/4×/16× the worker count, stealing on and off, odd
//! shard splits, an elastic resize between reductions, and a worker
//! revoke *during* an in-flight reduction. This is the determinism
//! invariant the trainer's parallel merge phase (and its reduce/dispatch
//! overlap) rests on.
//!
//! proptest is not available in the offline crate set, so properties are
//! checked over seeded random cases (deterministic, reproducible).

use std::sync::Arc;

use chicle::algos::nn::NativeModel;
use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, LsgdAlgo};
use chicle::chunks::SharedStore;
use chicle::config::{CocoaConfig, LsgdConfig, ModelKind};
use chicle::exec::{ReduceOptions, WorkerPool};
use chicle::util::Rng;

/// One representative of each algorithm family. The CoCoA dim is a prime
/// so no worker count divides the model evenly; the NN models exercise
/// real (large) parameter counts.
fn families() -> Vec<(&'static str, Arc<dyn Algorithm>)> {
    vec![
        (
            "cocoa",
            Arc::new(CocoaAlgo::new(
                CocoaConfig::default(),
                Backend::native_cocoa(),
                10_000,
                4099,
            )) as Arc<dyn Algorithm>,
        ),
        (
            "lsgd-mlp",
            Arc::new(
                LsgdAlgo::new_classif(
                    LsgdConfig::paper_defaults(ModelKind::Mlp),
                    Backend::native_nn(NativeModel::mlp_default()),
                    784,
                    Vec::new(),
                    Vec::new(),
                    1,
                )
                .unwrap(),
            ),
        ),
        (
            "lsgd-cnn",
            Arc::new(
                LsgdAlgo::new_classif(
                    LsgdConfig::paper_defaults(ModelKind::Cnn),
                    Backend::native_nn(NativeModel::cnn_default()),
                    3072,
                    Vec::new(),
                    Vec::new(),
                    1,
                )
                .unwrap(),
            ),
        ),
    ]
}

fn random_updates(rng: &mut Rng, k: usize, len: usize) -> Arc<Vec<LocalUpdate>> {
    Arc::new(
        (0..k)
            .map(|_| LocalUpdate {
                delta: (0..len).map(|_| rng.normal_f32()).collect(),
                samples: 1 + rng.below(2000),
                loss_sum: 0.0,
            })
            .collect(),
    )
}

fn pool_of(algo: &Arc<dyn Algorithm>, n_workers: usize) -> WorkerPool {
    let mut pool = WorkerPool::new(Arc::clone(algo));
    for i in 0..n_workers {
        pool.spawn_worker(i as u32, SharedStore::new());
    }
    pool
}

/// Parallel sharded merge == serial merge, bit for bit, for 1–8 workers
/// and several update counts, on every algorithm family (default
/// work-stealing options).
#[test]
fn prop_sharded_merge_matches_serial() {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(7);
        let model = Arc::new(algo.init_model().unwrap());
        for k_updates in [1usize, 3, 5] {
            let updates = random_updates(&mut rng, k_updates, len);
            let mut serial = (*model).clone();
            algo.merge(&mut serial, &updates, k_updates);
            for n_workers in 1..=8usize {
                let mut pool = pool_of(&algo, n_workers);
                let (merged, _) = pool
                    .reduce_model(
                        &model,
                        Arc::clone(&updates),
                        k_updates,
                        ReduceOptions::default(),
                    )
                    .unwrap();
                assert_eq!(
                    merged, serial,
                    "{name}: k={k_updates} workers={n_workers} diverged from serial fold"
                );
            }
        }
    }
}

/// The stealing reducer is exact across the whole shard-granularity
/// matrix: shard counts of 1×, 4× and 16× the worker count, stealing on
/// and off, 1–8 workers. With stealing on and multiple workers, steals
/// must actually be possible (they depend on scheduling, so only the
/// merged bits — not the steal count — are asserted).
#[test]
fn prop_stealing_matrix_matches_serial() {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(21);
        let model = Arc::new(algo.init_model().unwrap());
        let updates = random_updates(&mut rng, 3, len);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 3);
        for n_workers in 1..=8usize {
            for shards_per_worker in [1usize, 4, 16] {
                for stealing in [false, true] {
                    let mut pool = pool_of(&algo, n_workers);
                    let opts = ReduceOptions { shards_per_worker, stealing };
                    let (merged, stats) = pool
                        .reduce_model(&model, Arc::clone(&updates), 3, opts)
                        .unwrap();
                    assert_eq!(
                        merged, serial,
                        "{name}: workers={n_workers} spw={shards_per_worker} \
                         stealing={stealing} diverged from serial fold"
                    );
                    if n_workers >= 2 && !stealing {
                        assert_eq!(stats.steals, 0, "{name}: fixed assignment cannot steal");
                    }
                }
            }
        }
    }
}

/// The invariant holds across an elastic resize: merge at 4 workers,
/// revoke two and assign one (4 → 3, with a fresh node id), merge again —
/// both reductions must equal their serial folds exactly.
#[test]
fn prop_sharded_merge_survives_elastic_resize() {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(99);
        let mut pool = pool_of(&algo, 4);
        let model = Arc::new(algo.init_model().unwrap());

        let u1 = random_updates(&mut rng, 4, len);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &u1, 4);
        let (merged, _) = pool
            .reduce_model(&model, Arc::clone(&u1), 4, ReduceOptions::default())
            .unwrap();
        assert_eq!(merged, serial, "{name}: pre-resize merge diverged");
        let model = Arc::new(merged);

        // Elastic event between iterations: shard count and shard→worker
        // assignment both change under the trainer's feet.
        pool.shutdown_worker(1).unwrap();
        pool.shutdown_worker(3).unwrap();
        pool.spawn_worker(7, SharedStore::new());

        let u2 = random_updates(&mut rng, 3, len);
        let mut serial2 = (*model).clone();
        algo.merge(&mut serial2, &u2, 3);
        let (merged2, _) = pool
            .reduce_model(&model, Arc::clone(&u2), 3, ReduceOptions::default())
            .unwrap();
        assert_eq!(merged2, serial2, "{name}: post-resize merge diverged");
    }
}

/// A worker revoked *while a stealing reduction is in flight* must not
/// lose shards or desync the reply protocol: commands are FIFO per
/// worker, so the revoked worker finishes its claims before draining, its
/// completion is stashed, and the assembled model still equals the serial
/// fold bit for bit. The drained worker's chunks survive too.
#[test]
fn prop_mid_reduce_revoke_preserves_merge() {
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(5);
        let model = Arc::new(algo.init_model().unwrap());
        let updates = random_updates(&mut rng, 4, len);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 4);

        let mut pool = pool_of(&algo, 4);
        let opts = ReduceOptions { shards_per_worker: 16, stealing: true };
        let pending = pool
            .begin_reduce(&model, Arc::clone(&updates), 4, opts)
            .unwrap();
        let buf = pending.buf();
        // Revoke worker 2 mid-reduce: FIFO guarantees it reduces first,
        // then drains; its ShardsDone reply is stashed for collect.
        let drained = pool.shutdown_worker(2).unwrap();
        assert!(drained.is_empty());
        assert!(!pool.has_worker(2));

        let stats = pool.collect_reduce(pending).unwrap();
        assert_eq!(stats.workers, 4, "{name}: stashed completion must count");
        assert_eq!(buf.into_model(), serial, "{name}: mid-reduce revoke diverged");
    }
}

/// Adaptive shard sizing never changes the merged bits: drive a
/// granularity controller with a synthetic steal/calm schedule (straggler
/// appears, rages, disappears) and reduce at whatever `spw` it recommends
/// each round — every reduction must equal the serial fold exactly, at
/// every granularity the controller visits.
#[test]
fn prop_adaptive_spw_never_changes_merged_bits() {
    use chicle::exec::SpwController;
    use std::collections::BTreeSet;
    for (name, algo) in families() {
        let len = algo.model_len();
        let mut rng = Rng::seed_from_u64(17);
        let model = Arc::new(algo.init_model().unwrap());
        let updates = random_updates(&mut rng, 3, len);
        let mut serial = (*model).clone();
        algo.merge(&mut serial, &updates, 3);
        let mut pool = pool_of(&algo, 4);
        let mut ctl = SpwController::new(8);
        let schedule = [0usize, 4, 8, 16, 4, 0, 0, 0, 0];
        let mut seen_spw = BTreeSet::new();
        for steals in schedule {
            let spw = ctl.current();
            seen_spw.insert(spw);
            let opts = ReduceOptions { shards_per_worker: spw, stealing: true };
            let (merged, _) = pool
                .reduce_model(&model, Arc::clone(&updates), 3, opts)
                .unwrap();
            assert_eq!(merged, serial, "{name}: spw={spw} diverged from serial fold");
            ctl.observe(steals, 4);
        }
        assert!(
            seen_spw.len() > 2,
            "{name}: the synthetic schedule must actually move the granularity \
             (visited {seen_spw:?})"
        );
    }
}

/// lSGD's weighted merge with zero total samples is the identity — the
/// sharded path must preserve that exactly (no NaNs from 0/0 weights).
#[test]
fn zero_sample_updates_leave_model_unchanged_under_sharding() {
    let (_, algo) = families().remove(1);
    let len = algo.model_len();
    let model = Arc::new(algo.init_model().unwrap());
    let updates = Arc::new(vec![
        LocalUpdate { delta: vec![1.0; len], samples: 0, loss_sum: 0.0 };
        3
    ]);
    let mut pool = pool_of(&algo, 4);
    let (merged, _) = pool
        .reduce_model(&model, updates, 3, ReduceOptions::default())
        .unwrap();
    assert_eq!(merged, *model);
}
