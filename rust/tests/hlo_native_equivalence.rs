//! Integration: the HLO (JAX/Pallas via PJRT) and native-rust compute
//! paths must agree numerically on identical inputs.
//!
//! This is the keystone test of the three-layer architecture: the Pallas
//! kernels were validated against pure-jnp oracles by pytest; here the
//! rust mirror is validated against the lowered HLO, closing the loop
//! rust ≡ HLO ≡ pallas ≡ jnp.
//!
//! Requires `make artifacts`; each test skips gracefully when the
//! artifact directory is absent so unit CI stays hermetic.

use std::path::Path;

use chicle::algos::nn::NativeModel;
use chicle::algos::{svm, Backend};
use chicle::chunks::chunker::make_chunks;
use chicle::data::synth;
use chicle::runtime::{HloService, Manifest};
use chicle::util::Rng;

fn hlo() -> Option<(HloService, Manifest)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    let service = HloService::spawn(dir).expect("spawn HLO service");
    let manifest = Manifest::load(dir).expect("load manifest");
    Some((service, manifest))
}

#[test]
fn scd_chunk_hlo_matches_native() {
    let Some((service, manifest)) = hlo() else { return };
    let ds = synth::higgs_like(700, 3);
    // Two chunk sizes: below and above the artifact's S=256 window.
    for chunk_bytes in [16 * 1024usize, 64 * 1024] {
        let chunks_a = make_chunks(&ds, chunk_bytes);
        let mut chunks_b = chunks_a.clone();
        let mut chunks_a = chunks_a;

        let native = Backend::native_cocoa();
        let hlo = Backend::hlo_cocoa(service.clone(), &manifest, 256, 28).unwrap();

        let lam_n = 0.01f32 * 700.0;
        let mut v_a = vec![0.0f32; 28];
        let mut v_b = vec![0.0f32; 28];
        for ci in 0..chunks_a.len() {
            let n = chunks_a[ci].n_samples();
            let order: Vec<usize> = (0..n).collect();
            let dv_a = native
                .scd_chunk(&mut chunks_a[ci], &order, &mut v_a, lam_n, 4.0)
                .unwrap();
            let dv_b = hlo
                .scd_chunk(&mut chunks_b[ci], &order, &mut v_b, lam_n, 4.0)
                .unwrap();
            for (x, y) in dv_a.iter().zip(&dv_b) {
                assert!((x - y).abs() < 1e-4, "dv mismatch: {x} vs {y}");
            }
            for (x, y) in chunks_a[ci].state.iter().zip(&chunks_b[ci].state) {
                assert!((x - y).abs() < 1e-4, "alpha mismatch: {x} vs {y}");
            }
        }
        for (x, y) in v_a.iter().zip(&v_b) {
            assert!((x - y).abs() < 1e-3, "v mismatch: {x} vs {y}");
        }
    }
}

#[test]
fn gap_contributions_hlo_matches_native() {
    let Some((service, manifest)) = hlo() else { return };
    let ds = synth::higgs_like(600, 4);
    let mut chunks = make_chunks(&ds, 24 * 1024);
    let mut rng = Rng::seed_from_u64(0);
    // Random alpha state + weight vector.
    for c in &mut chunks {
        for a in c.state.iter_mut() {
            *a = rng.f32();
        }
    }
    let w: Vec<f32> = (0..28).map(|_| rng.normal_f32() * 0.1).collect();

    let native = Backend::native_cocoa();
    let hlo = Backend::hlo_cocoa(service, &manifest, 256, 28).unwrap();
    for chunk in &chunks {
        let (h1, a1, c1, n1) = native.gap_contributions(chunk, &w).unwrap();
        let (h2, a2, c2, n2) = hlo.gap_contributions(chunk, &w).unwrap();
        assert_eq!(n1, n2);
        assert!((h1 - h2).abs() < 1e-2 * (1.0 + h1.abs()), "hinge {h1} vs {h2}");
        assert!((a1 - a2).abs() < 1e-3 * (1.0 + a1.abs()), "alpha {a1} vs {a2}");
        assert!((c1 - c2).abs() < 0.5, "correct {c1} vs {c2}");
    }
}

#[test]
fn mlp_grad_hlo_matches_native() {
    let Some((service, manifest)) = hlo() else { return };
    let native = Backend::native_nn(NativeModel::mlp_default());
    let hlo = Backend::hlo_nn(service, &manifest, "mlp").unwrap();

    // Same params for both: use the HLO init artifact (jax-side RNG).
    let params = hlo.nn_init(7).unwrap();
    assert_eq!(params.len(), NativeModel::mlp_default().param_count());

    let mut rng = Rng::seed_from_u64(1);
    let l = hlo.nn_grad_batch().unwrap();
    let x: Vec<f32> = (0..l * 784).map(|_| rng.normal_f32()).collect();
    let y: Vec<i32> = (0..l).map(|_| rng.below(10) as i32).collect();

    let (g_n, loss_n, corr_n) = native.nn_grad(&params, &x, &y).unwrap();
    let (g_h, loss_h, corr_h) = hlo.nn_grad(&params, &x, &y).unwrap();
    assert!((loss_n - loss_h).abs() < 1e-3 * (1.0 + loss_n.abs()), "{loss_n} vs {loss_h}");
    assert_eq!(corr_n, corr_h);
    let mut max_err = 0.0f64;
    for (a, b) in g_n.iter().zip(&g_h) {
        max_err = max_err.max((a - b).abs() as f64);
    }
    assert!(max_err < 5e-4, "max grad error {max_err}");
}

#[test]
fn cnn_grad_hlo_matches_native() {
    let Some((service, manifest)) = hlo() else { return };
    let native = Backend::native_nn(NativeModel::cnn_default());
    let hlo = Backend::hlo_nn(service, &manifest, "cnn").unwrap();

    let params = hlo.nn_init(9).unwrap();
    assert_eq!(params.len(), NativeModel::cnn_default().param_count());

    let mut rng = Rng::seed_from_u64(2);
    let l = hlo.nn_grad_batch().unwrap();
    let x: Vec<f32> = (0..l * 3072).map(|_| rng.normal_f32()).collect();
    let y: Vec<i32> = (0..l).map(|_| rng.below(10) as i32).collect();

    let (g_n, loss_n, _) = native.nn_grad(&params, &x, &y).unwrap();
    let (g_h, loss_h, _) = hlo.nn_grad(&params, &x, &y).unwrap();
    assert!(
        (loss_n - loss_h).abs() < 1e-3 * (1.0 + loss_n.abs()),
        "loss {loss_n} vs {loss_h}"
    );
    let mut max_err = 0.0f64;
    for (a, b) in g_n.iter().zip(&g_h) {
        max_err = max_err.max((a - b).abs() as f64);
    }
    assert!(max_err < 1e-3, "max grad error {max_err}");
}

#[test]
fn nn_eval_hlo_matches_native_with_padding() {
    let Some((service, manifest)) = hlo() else { return };
    let native = Backend::native_nn(NativeModel::mlp_default());
    let hlo = Backend::hlo_nn(service, &manifest, "mlp").unwrap();

    let params = hlo.nn_init(3).unwrap();
    let mut rng = Rng::seed_from_u64(4);
    // 300 samples: exercises one full HLO eval batch (256) + padding.
    let n = 300;
    let x: Vec<f32> = (0..n * 784).map(|_| rng.normal_f32()).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();

    let (loss_n, corr_n, nn) = native.nn_eval(&params, &x, &y, 784).unwrap();
    let (loss_h, corr_h, nh) = hlo.nn_eval(&params, &x, &y, 784).unwrap();
    assert_eq!(nn, nh);
    assert_eq!(nn, n as f64);
    assert!((loss_n - loss_h).abs() < 1e-3 * (1.0 + loss_n.abs()));
    assert_eq!(corr_n, corr_h);
}

#[test]
fn lm_grad_runs_and_learns() {
    let Some((service, manifest)) = hlo() else { return };
    if manifest.grad_artifact("tfm_small").is_err() {
        eprintln!("skipping: no transformer artifacts");
        return;
    }
    let hlo = Backend::hlo_nn(service, &manifest, "tfm_small").unwrap();
    let mut params = hlo.nn_init(5).unwrap();
    let ds = synth::token_corpus(8, 64, 1024, 6);
    let tokens = match &ds.features {
        chicle::data::FeatureMatrix::Tokens { data, .. } => data.clone(),
        _ => unreachable!(),
    };
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..8 {
        let (g, loss) = hlo.lm_grad(&params, &tokens, 8).unwrap();
        first.get_or_insert(loss);
        last = loss;
        for (p, gv) in params.iter_mut().zip(&g) {
            *p -= 0.5 * gv;
        }
    }
    assert!(
        last < first.unwrap() * 0.9,
        "LM loss should drop: {first:?} -> {last}"
    );
}
