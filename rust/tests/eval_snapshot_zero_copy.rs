//! The zero-copy chunk data plane, end to end.
//!
//! * A *large-dataset*, chunk-reading evaluator (CoCoA-style: per-sample
//!   state ≪ sample payload) now takes the eval-spanning overlap — the
//!   affordability gate is priced against state bytes, and the snapshot
//!   shares payloads by `Arc` — with the metric/vtime trajectory still
//!   bit-identical to the barriered schedule. Before the payload/state
//!   split this exact configuration was forced onto the barriered path
//!   (the snapshot deep-clone exceeded 4× the model bytes).
//! * The elastic revoke/install protocol moves chunks without ever
//!   copying sample bytes: a coordinator that retains copies across the
//!   round-trip still observes the *same* payload allocations afterwards.

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::{Algorithm, Backend, CocoaAlgo};
use chicle::chunks::chunker::make_chunks;
use chicle::chunks::{Chunk, SharedStore};
use chicle::config::{CocoaConfig, ElasticSpec, SessionConfig};
use chicle::coordinator::Trainer;
use chicle::data::synth;
use chicle::exec::WorkerPool;

/// A sparse, wide CoCoA session: model 40 000 weights (above the
/// parallel-merge threshold so the pipeline engages), dataset payload
/// ≈ 1.2 MiB ≫ 4× the 160 KiB model (the configuration the pre-split
/// affordability gate kicked off the overlapped path), per-sample state
/// only 4 B/sample.
fn cocoa_trainer(overlap: bool) -> Trainer {
    let n = 6000usize;
    let dim = 40_000usize;
    let ds = synth::criteo_like_with(n, dim, 24, 8, 7);
    let chunks = make_chunks(&ds, 16 * 1024);
    // Unreachable target: the gap is non-negative up to rounding, so a
    // negative target can never trigger an early stop mid-comparison.
    let cfg_algo = CocoaConfig { target_gap: -1.0, ..CocoaConfig::default() };
    let algo: Arc<dyn Algorithm> =
        Arc::new(CocoaAlgo::new(cfg_algo, Backend::native_cocoa(), n, dim));
    let mut cfg = SessionConfig::cocoa("zero-copy-eval", 4)
        .with_overlap(overlap)
        .with_elastic(ElasticSpec::Gradual { from: 4, to: 2, interval_s: 20.0 });
    cfg.max_iters = 6;
    cfg.policies.rebalance = true;
    Trainer::new(cfg, algo, chunks).unwrap()
}

#[test]
fn large_dataset_cocoa_takes_the_overlapped_eval_path() {
    let mut piped = cocoa_trainer(true);
    // The premise this test exists for: the payload dwarfs the model by
    // more than the old 4× gate, so the pre-split trainer would have
    // barriered every eval point of this session.
    let payload_bytes: usize = piped.tasks().iter().map(|t| t.store.payload_bytes()).sum();
    let state_bytes: usize = piped.tasks().iter().map(|t| t.store.state_bytes()).sum();
    let model_bytes = 40_000 * 4;
    assert!(
        payload_bytes > 4 * model_bytes,
        "premise lost: payload {payload_bytes} B no longer dwarfs 4×model {model_bytes} B"
    );
    assert!(state_bytes * 16 < payload_bytes, "state must be ≪ payload");

    piped.run().unwrap();
    let mut barriered = cocoa_trainer(false);
    barriered.run().unwrap();

    // Bit-identical trajectory: every CoCoA iteration is an eval point.
    assert_eq!(piped.metrics.records.len(), barriered.metrics.records.len());
    for (p, b) in piped.metrics.records.iter().zip(&barriered.metrics.records) {
        assert_eq!(p.metric, b.metric, "iter {}", p.iter);
        assert_eq!(p.vtime, b.vtime, "iter {}", p.iter);
        assert_eq!(p.epochs, b.epochs, "iter {}", p.iter);
        assert_eq!(p.n_tasks, b.n_tasks, "iter {}", p.iter);
        assert!(p.metric.is_some(), "CoCoA evaluates every iteration");
    }
    assert_eq!(piped.model(), barriered.model(), "final model bits diverged");

    // The point of the PR: eval points themselves overlapped (the gate
    // passed), which the pre-split O(dataset) snapshot gate forbade here.
    assert!(
        piped
            .metrics
            .records
            .iter()
            .any(|r| r.metric.is_some() && r.overlap_wall > Duration::ZERO),
        "large-dataset CoCoA still isn't taking the overlapped eval path"
    );
    assert!(barriered.metrics.records.iter().all(|r| r.overlap_wall == Duration::ZERO));
    // The elastic scale-in really ran under the pipeline.
    assert_eq!(piped.metrics.records.last().unwrap().n_tasks, 2);
}

/// Install → iterate → drain through the worker protocol: the chunks that
/// come back hold the *same* payload allocations a copy-retaining
/// coordinator kept, with only the per-sample state advanced — elastic
/// migration never touches sample bytes.
#[test]
fn revoke_install_round_trip_shares_payloads() {
    let ds = synth::higgs_like(600, 3);
    let chunks = make_chunks(&ds, 8 * 1024);
    let retained: Vec<Chunk> = chunks.clone();
    let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
        CocoaConfig::default(),
        Backend::native_cocoa(),
        ds.n_samples(),
        ds.dim(),
    ));
    let model = Arc::new(algo.init_model().unwrap());
    let mut pool = WorkerPool::new(Arc::clone(&algo));
    pool.spawn_worker(3, SharedStore::new());
    pool.install_chunks(3, chunks).unwrap();
    pool.run_iteration(&[(3, 11)], model, 1, None).unwrap();
    let drained = pool.shutdown_worker(3).unwrap();

    assert_eq!(drained.len(), retained.len());
    for d in &drained {
        let kept = retained.iter().find(|c| c.id == d.id).unwrap();
        assert!(
            d.shares_payload(kept),
            "chunk {}: payload was copied somewhere on the install/drain path",
            d.id
        );
        // The state advanced with the worker; the retained copy's did not
        // (state is private per clone — the snapshot correctness rule).
        assert!(kept.state.iter().all(|&a| a == 0.0));
    }
    assert!(
        drained.iter().any(|c| c.state.iter().any(|&a| a != 0.0)),
        "the iteration should have advanced some α state"
    );
}

/// The eval snapshot allocates O(per-sample state): cloning a store's
/// chunks in snapshot order shares every payload allocation.
#[test]
fn snapshot_clones_share_payloads() {
    let ds = synth::higgs_like(1000, 5);
    let store = SharedStore::from_chunks(make_chunks(&ds, 8 * 1024));
    let snapshot: Vec<Chunk> = store.lock().iter().cloned().collect();
    let guard = store.lock();
    for (snap, live) in snapshot.iter().zip(guard.iter()) {
        assert!(snap.shares_payload(live));
        assert_eq!(snap.id, live.id);
        assert_eq!(snap.state, live.state);
    }
}
