//! `merge_strategy` must be invisible to the science: a session run with
//! ring- or tree-allreduce produces a *bit-identical* trajectory — same
//! metrics, same virtual times, same epochs, same final model — to the
//! default coordinator-side sharded reduce, through elastic resizes. Only
//! the measured transport columns (`transport_rounds`, `transport_bytes`)
//! and wallclock columns may differ.
//!
//! Every config in this file pins `merge_strategy` explicitly via the
//! builder (which wins over the `CHICLE_MERGE_STRATEGY` env override), so
//! the env test below cannot race the trajectory tests.

use std::time::Duration;

use chicle::config::{
    AlgoConfig, ElasticSpec, MergeStrategy, ModelKind, SessionConfig,
};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::metrics::MetricsLog;

/// An elastic lSGD/MLP session (235k-parameter model, 4 → 2 nodes) under
/// the given merge strategy. Mirrors `overlap_pipeline.rs`'s session so
/// a strategy-induced divergence cannot hide behind a trivial workload.
fn mlp_log(strategy: MergeStrategy, overlap: bool) -> MetricsLog {
    let ds = synth::fmnist_like(1200, 7);
    let mut cfg = SessionConfig::lsgd("merge-strategy", ModelKind::Mlp, 4)
        .with_seed(17)
        .with_overlap(overlap)
        .with_merge_strategy(strategy)
        .with_elastic(ElasticSpec::Gradual { from: 4, to: 2, interval_s: 3.0 });
    cfg.chunk_bytes = 32 * 1024;
    cfg.max_iters = 10;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.eval_every = 4;
        l.target_acc = 2.0; // unreachable: run all iterations
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run().unwrap()
}

/// An elastic CoCoA session under the given merge strategy — the
/// sample-weighted-free merge family, scaling 2 → 4 (scale *out*, so
/// ranks join mid-run too).
fn cocoa_log(strategy: MergeStrategy) -> MetricsLog {
    let ds = synth::higgs_like(3000, 5);
    let mut cfg = SessionConfig::cocoa("merge-strategy-cocoa", 2)
        .with_seed(29)
        .with_merge_strategy(strategy)
        .with_elastic(ElasticSpec::Gradual { from: 2, to: 4, interval_s: 3.0 });
    cfg.max_iters = 10;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run().unwrap()
}

fn assert_same_science(a: &MetricsLog, b: &MetricsLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record counts");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.iter, y.iter, "{label}");
        assert_eq!(x.metric, y.metric, "{label} iter {}", x.iter);
        assert_eq!(x.vtime, y.vtime, "{label} iter {}", x.iter);
        assert_eq!(x.epochs, y.epochs, "{label} iter {}", x.iter);
        assert_eq!(x.n_tasks, y.n_tasks, "{label} iter {}", x.iter);
        assert_eq!(x.samples, y.samples, "{label} iter {}", x.iter);
        assert_eq!(x.train_loss, y.train_loss, "{label} iter {}", x.iter);
    }
}

#[test]
fn ring_and_tree_trajectories_match_coordinator_reduce() {
    let coord = mlp_log(MergeStrategy::Coordinator, false);
    let ring = mlp_log(MergeStrategy::Ring, false);
    let tree = mlp_log(MergeStrategy::Tree, false);
    assert_same_science(&coord, &ring, "ring");
    assert_same_science(&coord, &tree, "tree");

    // The coordinator reduce never touches the transport; the collectives
    // record their measured protocol rounds — 2(k−1) for ring,
    // 2·⌊log2 k⌋ for tree — exactly, per iteration, through the resize.
    for r in &coord.records {
        assert_eq!((r.transport_rounds, r.transport_bytes), (0, 0), "iter {}", r.iter);
    }
    for r in &ring.records {
        let k = r.n_tasks;
        let want = if k > 1 { 2 * (k - 1) } else { 0 };
        assert_eq!(r.transport_rounds, want, "ring iter {}", r.iter);
        assert_eq!(r.transport_bytes > 0, k > 1, "ring iter {}", r.iter);
    }
    for r in &tree.records {
        let k = r.n_tasks;
        let want = if k > 1 { 2 * k.ilog2() as usize } else { 0 };
        assert_eq!(r.transport_rounds, want, "tree iter {}", r.iter);
    }
    // The elastic scale-in really ran under the collectives.
    assert_eq!(ring.records.last().unwrap().n_tasks, 2);
}

#[test]
fn cocoa_scale_out_trajectories_match_across_strategies() {
    let coord = cocoa_log(MergeStrategy::Coordinator);
    let ring = cocoa_log(MergeStrategy::Ring);
    let tree = cocoa_log(MergeStrategy::Tree);
    assert_same_science(&coord, &ring, "ring");
    assert_same_science(&coord, &tree, "tree");
    // Ranks joined mid-run and folded in task order all the same.
    assert_eq!(ring.records.last().unwrap().n_tasks, 4);
}

/// Collectives are barriered: under `merge_strategy = ring` the overlap
/// pipeline must stand down (no speculative iteration can run while the
/// merged model only exists inside the collective) — and the trajectory
/// must *still* match an overlapped coordinator run bit for bit.
#[test]
fn collectives_force_the_barriered_schedule() {
    let ring = mlp_log(MergeStrategy::Ring, true);
    assert!(
        ring.records.iter().all(|r| r.overlap_wall == Duration::ZERO),
        "overlap must never engage under a collective merge"
    );
    let coord_piped = mlp_log(MergeStrategy::Coordinator, true);
    assert_same_science(&coord_piped, &ring, "ring-vs-overlapped-coordinator");
}

/// `CHICLE_MERGE_STRATEGY` steers freshly constructed configs (the CI
/// tier-1 ring leg uses this); configs built with the explicit builder —
/// every other test in this file — are immune to it.
#[test]
fn env_override_steers_new_configs_only() {
    std::env::set_var("CHICLE_MERGE_STRATEGY", "tree");
    let fresh = SessionConfig::cocoa("env-fresh", 2);
    let pinned = SessionConfig::cocoa("env-pinned", 2)
        .with_merge_strategy(MergeStrategy::Ring);
    std::env::remove_var("CHICLE_MERGE_STRATEGY");
    assert_eq!(fresh.merge_strategy, MergeStrategy::Tree);
    assert_eq!(pinned.merge_strategy, MergeStrategy::Ring);
    assert_eq!(
        SessionConfig::cocoa("env-unset", 2).merge_strategy,
        MergeStrategy::Coordinator,
        "no override once the variable is gone"
    );
}
