//! Property-based tests on coordinator invariants.
//!
//! proptest is not available in the offline crate set, so properties are
//! checked over many seeded random cases (the seeds are fixed →
//! deterministic, reproducible failures).

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate};
use chicle::chunks::chunker::{make_chunks, make_chunks_shuffled};
use chicle::cluster::NodeSpec;
use chicle::config::{CocoaConfig, ElasticSpec, LsgdConfig, ModelKind, SessionConfig};
use chicle::coordinator::{TrainingSession, Trainer};
use chicle::data::synth;
use chicle::sim::{makespan, microtask_iteration_time, uni_iteration_time};
use chicle::util::Rng;

const CASES: usize = 30;

/// Property: chunking never loses or duplicates samples, for arbitrary
/// dataset sizes, chunk budgets and shuffling.
#[test]
fn prop_chunking_conserves_samples() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(case as u64);
        let n = 50 + rng.below(3000);
        let budget = 512 + rng.below(32 * 1024);
        let ds = if rng.bool(0.5) {
            synth::higgs_like(n, case as u64)
        } else {
            synth::criteo_like_with(n, 5_000, 5 + rng.below(30), 8, case as u64)
        };
        let chunks = if rng.bool(0.5) {
            make_chunks(&ds, budget)
        } else {
            make_chunks_shuffled(&ds, budget, case as u64 + 1)
        };
        let total: usize = chunks.iter().map(|c| c.n_samples()).sum();
        assert_eq!(total, n, "case {case}: lost samples");
        let mut ids: Vec<u32> = chunks.iter().flat_map(|c| c.global_ids().to_vec()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "case {case}: duplicate/missing ids");
        // Per-sample state co-allocated.
        for c in &chunks {
            assert_eq!(c.state.len(), c.n_samples(), "case {case}: state len");
        }
    }
}

/// Property: an arbitrary elastic trace never loses a chunk — the trainer
/// ends with exactly the initial sample count distributed over the final
/// node set, with no chunk on two tasks.
#[test]
fn prop_elastic_traces_conserve_chunks() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + case as u64);
        let n = 2000;
        let ds = synth::higgs_like(n, case as u64);
        // Random trace: 3-6 allocation points, 1-8 nodes each, random speeds.
        let n_points = 3 + rng.below(4);
        let mut points = vec![];
        for p in 0..n_points {
            let k = 1 + rng.below(8);
            let speeds: Vec<f64> = (0..k).map(|_| 0.25 + rng.f64()).collect();
            points.push((p as f64 * (1.0 + rng.f64() * 5.0), speeds));
        }
        points[0].0 = 0.0;
        let mut cfg = SessionConfig::cocoa(&format!("prop{case}"), 1);
        cfg.elastic = ElasticSpec::Trace { points };
        cfg.chunk_bytes = 4 * 1024;
        cfg.max_iters = 12;
        cfg.seed = case as u64;
        cfg.policies.rebalance = rng.bool(0.5);
        cfg.policies.shuffle = rng.bool(0.3);
        let mut s = TrainingSession::new(cfg, ds).unwrap();
        s.run_iters(12).unwrap();
        let total: usize = s.trainer().tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, n, "case {case}: chunk loss under elastic trace");
        let mut ids: Vec<u32> = s
            .trainer()
            .tasks()
            .iter()
            .flat_map(|t| t.store.chunk_ids())
            .collect();
        let before = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), before, "case {case}: duplicated chunk");
    }
}

/// Property: lSGD merge is a convex combination — if every task returns
/// the same delta, the merged model moves by exactly that delta; weights
/// are proportional to samples processed (eq. 2 / Stich'18).
#[test]
fn prop_merge_is_weighted_convex_combination() {
    let ds = synth::fmnist_like(600, 0);
    let (_train, test) = ds.split_test(0.2);
    let (tx, ty) = match (&test.features, &test.labels) {
        (chicle::data::FeatureMatrix::Dense { data, .. }, chicle::data::Labels::Class(y)) => {
            (data.clone(), y.clone())
        }
        _ => unreachable!(),
    };
    let algo = chicle::algos::lsgd::LsgdAlgo::new_classif(
        LsgdConfig::paper_defaults(ModelKind::Mlp),
        Backend::native_nn(chicle::algos::nn::NativeModel::mlp_default()),
        784,
        tx,
        ty,
        0,
    )
    .unwrap();
    let len = algo.model_len();
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + case as u64);
        let k = 1 + rng.below(8);
        let delta_val = rng.normal_f32();
        let updates: Vec<LocalUpdate> = (0..k)
            .map(|_| LocalUpdate {
                delta: vec![delta_val; len],
                samples: 1 + rng.below(500),
                loss_sum: 0.0,
            })
            .collect();
        let mut model = vec![0.0f32; len];
        algo.merge(&mut model, &updates, k);
        assert!(
            (model[0] - delta_val).abs() < 1e-5,
            "case {case}: equal deltas must merge to the same delta"
        );
    }
    // Proportionality: one task with 3× the samples gets 3× the weight.
    let u = vec![
        LocalUpdate { delta: vec![1.0; len], samples: 300, loss_sum: 0.0 },
        LocalUpdate { delta: vec![-1.0; len], samples: 100, loss_sum: 0.0 },
    ];
    let mut m2 = vec![0.0f32; len];
    algo.merge(&mut m2, &u, 2);
    assert!((m2[0] - 0.5).abs() < 1e-6);
}

/// Property: CoCoA keeps v consistent with w(α):
/// model == (1/λn) Σ_i α_i y_i x_i after any number of merges.
#[test]
fn prop_cocoa_v_equals_w_of_alpha() {
    for case in 0..8u64 {
        let n = 1200;
        let ds = synth::higgs_like(n, case);
        let chunks = make_chunks(&ds, 8 * 1024);
        let algo =
            CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), n, ds.dim());
        let mut rng = Rng::seed_from_u64(case);
        let k = 1 + rng.below(6);
        let mut parts: Vec<Vec<chicle::chunks::Chunk>> = (0..k).map(|_| vec![]).collect();
        for (i, c) in chunks.into_iter().enumerate() {
            parts[i % k].push(c);
        }
        let mut model = algo.init_model().unwrap();
        for it in 0..3 {
            let updates: Vec<LocalUpdate> = parts
                .iter_mut()
                .enumerate()
                .map(|(t, ch)| {
                    algo.task_iterate(ch, &model, k, (it * 7 + t) as u64, None).unwrap()
                })
                .collect();
            algo.merge(&mut model, &updates, k);
        }
        // Reconstruct w(α) from chunk state.
        let lam_n = 0.01f32 * n as f32;
        let mut w = vec![0.0f32; ds.dim()];
        for part in &parts {
            for c in part {
                if let chicle::chunks::Samples::DenseBinary { x, dim, y } = c.samples() {
                    for i in 0..y.len() {
                        let scale = c.state[i] * y[i] / lam_n;
                        for j in 0..*dim {
                            w[j] += scale * x[i * dim + j];
                        }
                    }
                }
            }
        }
        for (a, b) in w.iter().zip(&model) {
            assert!(
                (a - b).abs() < 2e-3 * (1.0 + a.abs()),
                "case {case}: v {b} != w(alpha) {a}"
            );
        }
    }
}

/// Property: projection-model identities — uni ≤ best micro schedule,
/// extra nodes never hurt, k=1 makespan = fastest node's task time.
#[test]
fn prop_projection_model_identities() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(3000 + case as u64);
        let n = 1 + rng.below(24);
        let nodes: Vec<NodeSpec> = (0..n)
            .map(|i| NodeSpec::new(i as u32, 0.25 + rng.f64() * 1.5))
            .collect();
        let k = 1 + rng.below(128);
        let micro = microtask_iteration_time(k, 16.0, &nodes);
        let uni = uni_iteration_time(16.0, &nodes);
        assert!(uni <= micro + 1e-9, "case {case}: uni {uni} > micro {micro}");
        let mut more = nodes.clone();
        more.push(NodeSpec::new(99, 1.0));
        let micro_more = microtask_iteration_time(k, 16.0, &more);
        assert!(micro_more <= micro + 1e-9, "case {case}: extra node hurt");
        let fastest = nodes.iter().map(|nd| nd.speed).fold(0.0, f64::max);
        let m1 = makespan(1, 1.0, &nodes);
        assert!((m1 - 1.0 / fastest).abs() < 1e-9, "case {case}");
    }
}

/// Property: rebalancing monotonically reduces (projected) imbalance on a
/// static heterogeneous cluster, and never loses chunks.
#[test]
fn prop_rebalance_reduces_imbalance() {
    for case in 0..10u64 {
        let mut rng = Rng::seed_from_u64(4000 + case);
        let fast = 2 + rng.below(4);
        let slow = 1 + rng.below(4);
        let factor = 1.3 + rng.f64();
        let n = 4000;
        let ds = synth::higgs_like(n, case);
        let chunks = make_chunks(&ds, 4 * 1024);
        let algo: Arc<dyn Algorithm> = Arc::new(CocoaAlgo::new(
            CocoaConfig::default(),
            Backend::native_cocoa(),
            n,
            ds.dim(),
        ));
        let mut cfg = SessionConfig::cocoa(&format!("rb{case}"), fast + slow);
        cfg.elastic = ElasticSpec::Heterogeneous { fast, slow, factor };
        cfg.chunk_bytes = 4 * 1024;
        cfg.policies.rebalance = true;
        cfg.policies.rebalance_step = 4;
        cfg.max_iters = 20;
        let mut tr = Trainer::new(cfg, algo, chunks).unwrap();
        for it in 0..20 {
            tr.step(it).unwrap();
        }
        let first = tr.swimlanes.imbalance(0).unwrap();
        let last = tr.swimlanes.imbalance(19).unwrap();
        assert!(
            last <= first + 1e-9,
            "case {case}: imbalance grew {first} -> {last}"
        );
        assert!(last < factor, "case {case}: no improvement ({last} vs {factor})");
        let total: usize = tr.tasks().iter().map(|t| t.n_samples()).sum();
        assert_eq!(total, n);
    }
}

/// Property: micro-task emulation convergence per epoch is independent of
/// the node schedule — the claim that justifies the paper's methodology
/// (§5.1 "Micro-tasks").
#[test]
fn prop_micro_convergence_node_independent() {
    let n = 2000;
    for case in 0..5u64 {
        let ds = synth::higgs_like(n, case);
        let run = |elastic: ElasticSpec| {
            let mut cfg = SessionConfig::cocoa("micro", 4).with_microtasks(16);
            cfg.elastic = elastic;
            cfg.chunk_bytes = 4 * 1024;
            cfg.max_iters = 6;
            cfg.seed = case;
            let mut s = TrainingSession::new(cfg, ds.clone()).unwrap();
            s.run_iters(6).unwrap()
        };
        let a = run(ElasticSpec::Rigid { nodes: 4 });
        let b = run(ElasticSpec::Gradual { from: 16, to: 2, interval_s: 3.0 });
        for (ra, rb) in a.records.iter().zip(&b.records) {
            let (ga, gb) = (ra.metric.unwrap().value(), rb.metric.unwrap().value());
            assert!(
                (ga - gb).abs() < 1e-9,
                "case {case}: per-epoch convergence depended on nodes: {ga} vs {gb}"
            );
        }
        assert!(a.total_vtime() != b.total_vtime(), "time axes should differ");
    }
}

/// Failure injection: revoking every node must error, not hang or panic.
#[test]
fn revoking_all_nodes_errors_cleanly() {
    let ds = synth::higgs_like(500, 0);
    let mut cfg = SessionConfig::cocoa("fail", 2);
    cfg.chunk_bytes = 2 * 1024;
    cfg.elastic = ElasticSpec::Trace {
        points: vec![(0.0, vec![1.0, 1.0]), (1.0, vec![])],
    };
    cfg.max_iters = 10;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let mut failed = false;
    for it in 0..10 {
        if s.step(it).is_err() {
            failed = true;
            break;
        }
    }
    assert!(failed, "all-nodes revocation should surface an error");
}

/// Determinism: identical configs + seeds give identical metric series.
#[test]
fn prop_runs_are_deterministic() {
    let run = |seed: u64| {
        let ds = synth::higgs_like(1500, 9);
        let mut cfg = SessionConfig::cocoa("det", 4).with_seed(seed);
        cfg.chunk_bytes = 4 * 1024;
        cfg.max_iters = 8;
        let mut s = TrainingSession::new(cfg, ds).unwrap();
        s.run_iters(8).unwrap()
    };
    let a = run(5);
    let b = run(5);
    let c = run(6);
    let gaps = |log: &chicle::metrics::MetricsLog| -> Vec<f64> {
        log.records.iter().filter_map(|r| r.metric.map(|m| m.value())).collect()
    };
    assert_eq!(gaps(&a), gaps(&b), "same seed must reproduce exactly");
    assert_ne!(gaps(&a), gaps(&c), "different seed should differ");
}

/// Virtual time is monotone under elasticity, and scale-out shortens
/// iterations.
#[test]
fn vtime_is_monotone_under_elasticity() {
    let ds = synth::higgs_like(2000, 1);
    let mut cfg = SessionConfig::cocoa("mono", 2);
    cfg.elastic = ElasticSpec::Gradual { from: 2, to: 12, interval_s: 4.0 };
    cfg.chunk_bytes = 4 * 1024;
    cfg.max_iters = 20;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run_iters(20).unwrap();
    let mut prev = Duration::ZERO;
    for r in &log.records {
        assert!(r.vtime >= prev, "vtime went backwards");
        prev = r.vtime;
    }
    let d_first = log.records[0].vtime;
    let d_last = log.records[19].vtime - log.records[18].vtime;
    assert!(d_last < d_first, "{d_last:?} !< {d_first:?}");
}
