//! The backend-generic transport conformance suite, instantiated for the
//! loopback **TCP** backend — the same bodies `transport_allreduce.rs`
//! runs against in-process channels, unchanged, over real framed
//! sockets. Passing both instantiations byte-for-byte is what makes
//! "the TCP backend satisfies the `docs/TRANSPORT.md` contract" a tested
//! statement rather than a claim: bit-identity to the serial fold,
//! staleness sieving, mid-collective revoke, and peer-served rejoin all
//! hold with kernel buffers, reader threads, and reconnects in the path.
//!
//! One TCP-only property rides along: the collective's measured framing
//! overhead (`frame_bytes`) must be nonzero exactly when payload bytes
//! moved — a real wire cannot frame for free.

mod transport_conformance;

use chicle::config::TransportKind;
use chicle::transport::GroupHandle;
use transport_conformance as conf;

fn tcp() -> GroupHandle {
    GroupHandle::tcp()
}

#[test]
fn prop_ring_and_tree_match_serial_fold_on_every_rank() {
    conf::ring_and_tree_match_serial_fold_on_every_rank(tcp);
}

#[test]
fn model_smaller_than_ring_still_allreduces_exactly() {
    conf::model_smaller_than_ring_still_allreduces_exactly(tcp);
}

#[test]
fn stale_cross_regime_traffic_is_dropped_not_folded() {
    conf::stale_cross_regime_traffic_is_dropped_not_folded(tcp);
}

#[test]
fn prop_mid_collective_revoke_preserves_merge() {
    conf::mid_collective_revoke_preserves_merge(TransportKind::Tcp);
}

#[test]
fn pool_allreduce_matches_pool_reduce_bit_for_bit() {
    conf::pool_allreduce_matches_pool_reduce_bit_for_bit(TransportKind::Tcp);
}

#[test]
fn single_rank_pool_allreduce_folds_inline() {
    conf::single_rank_pool_allreduce_folds_inline(TransportKind::Tcp);
}

#[test]
fn rejoining_node_fetches_state_from_any_peer() {
    conf::rejoining_node_fetches_state_from_any_peer(tcp);
}

/// Framing overhead is measured, not modeled: a multi-rank collective
/// over real sockets must report nonzero `frame_bytes` (length prefixes,
/// tags, handshakes), and the payload `bytes` column must stay exactly
/// what the channel backend reports — framing is *extra*, never folded
/// into the backend-independent payload count.
#[test]
fn tcp_collective_reports_nonzero_framing_overhead() {
    use chicle::chunks::SharedStore;
    use chicle::exec::WorkerPool;
    use chicle::transport::AllreduceKind;
    use chicle::util::Rng;
    use std::sync::Arc;

    let (_, algo) = conf::families().remove(0);
    let model = Arc::new(algo.init_model().unwrap());
    let mut rng = Rng::seed_from_u64(91);
    let updates = conf::random_updates(&mut rng, 4, algo.model_len());

    let mut channel_pool = WorkerPool::new_with_transport(Arc::clone(&algo), TransportKind::Channel);
    let mut tcp_pool = WorkerPool::new_with_transport(Arc::clone(&algo), TransportKind::Tcp);
    for &n in &[0u32, 1, 2, 3] {
        channel_pool.spawn_worker(n, SharedStore::new());
        tcp_pool.spawn_worker(n, SharedStore::new());
    }
    let order = [0u32, 1, 2, 3];
    let over_channel = channel_pool
        .allreduce_model(&order, &model, updates.clone(), 4, AllreduceKind::Ring, 0)
        .unwrap();
    let over_tcp = tcp_pool
        .allreduce_model(&order, &model, updates, 4, AllreduceKind::Ring, 0)
        .unwrap();
    assert_eq!(over_tcp.model, over_channel.model, "backends diverged bit-for-bit");
    assert_eq!(over_tcp.bytes, over_channel.bytes, "payload bytes must be backend-independent");
    assert_eq!(over_channel.frame_bytes, 0, "channels have no wire format");
    assert!(over_tcp.frame_bytes > 0, "a real wire cannot frame for free");
}
