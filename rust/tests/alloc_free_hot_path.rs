//! Pins the allocation-free hot-path contract: after warmup iterations
//! prime a task's [`Workspace`], steady-state compute — a full CNN/MLP
//! gradient and an SCD chunk pass — performs **zero** heap allocations,
//! and a whole `task_iterate_ws` performs at most the one documented
//! allocation per iteration (the `LocalUpdate::delta` handoff buffer).
//!
//! The counter is a `#[global_allocator]` wrapper around the system
//! allocator that counts `alloc`, `alloc_zeroed` *and* `realloc` (a
//! grow-in-place still means the pool under-reserved) — per thread, so
//! the harness's other test threads cannot bleed into a window.
//! Integration tests are separate crates, so installing the wrapper
//! here affects only this test binary.
//!
//! Warmup runs several iterations, not one: buffers permute through
//! pool roles across iterations (the LIFO take/put cycle), so a buffer
//! may only reach its largest role — and final capacity — after a few
//! cycles. Steady state is reached once every buffer has cycled.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use chicle::algos::nn::{CnnShape, NativeModel};
use chicle::algos::{Algorithm, Backend, CocoaAlgo, LsgdAlgo};
use chicle::chunks::chunker::make_chunks;
use chicle::config::{CocoaConfig, LsgdConfig, ModelKind};
use chicle::data::synth;
use chicle::util::{Rng, Workspace};

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

fn bump() {
    // try_with: never panic inside the allocator (TLS may be gone
    // during thread teardown — those allocations just go uncounted).
    let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations made by *this thread* while running `f`.
fn count_allocs<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = TL_ALLOCS.with(|c| c.get());
    let r = f();
    (TL_ALLOCS.with(|c| c.get()) - before, r)
}

/// Enough iterations for every pooled buffer to cycle through all the
/// roles its pool position visits (longest cycle ≈ pool size).
const WARMUP: usize = 40;

#[test]
fn cnn_grad_steady_state_allocates_nothing() {
    let shape =
        CnnShape { h: 8, w: 8, c: 1, conv1: 2, conv2: 3, ks: 3, fc1: 6, fc2: 4, classes: 3 };
    let model = NativeModel::Cnn { shape };
    let params = model.init(3);
    let batch = 4usize;
    let mut rng = Rng::seed_from_u64(4);
    let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(3) as i32).collect();

    let mut ws = Workspace::new();
    for _ in 0..WARMUP {
        let (g, ..) = model.grad_ws(&params, &x, &y, &mut ws);
        ws.put(g);
    }

    let (n, _) = count_allocs(|| {
        for _ in 0..5 {
            let (g, ..) = model.grad_ws(&params, &x, &y, &mut ws);
            ws.put(g);
        }
    });
    assert_eq!(n, 0, "steady-state CNN grad allocated {n} times");
}

#[test]
fn mlp_grad_steady_state_allocates_nothing() {
    let model = NativeModel::Mlp { dims: vec![32, 24, 16, 5] };
    let params = model.init(5);
    let batch = 8usize;
    let mut rng = Rng::seed_from_u64(6);
    let x: Vec<f32> = (0..batch * 32).map(|_| rng.normal_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(5) as i32).collect();

    let mut ws = Workspace::new();
    for _ in 0..WARMUP {
        let (g, ..) = model.grad_ws(&params, &x, &y, &mut ws);
        ws.put(g);
    }

    let (n, _) = count_allocs(|| {
        for _ in 0..5 {
            let (g, ..) = model.grad_ws(&params, &x, &y, &mut ws);
            ws.put(g);
        }
    });
    assert_eq!(n, 0, "steady-state MLP grad allocated {n} times");
}

#[test]
fn scd_chunk_steady_state_allocates_nothing() {
    let ds = synth::higgs_like(512, 9);
    let mut chunks = make_chunks(&ds, usize::MAX);
    let backend = Backend::native_cocoa();
    let dim = ds.dim();
    let n = chunks[0].n_samples();
    let order: Vec<usize> = (0..n).collect();
    let lam_n = 0.01 * n as f32;
    let mut v = vec![0.0f32; dim];

    let mut ws = Workspace::new();
    for _ in 0..WARMUP {
        let dv =
            backend.scd_chunk_ws(&mut chunks[0], &order, &mut v, lam_n, 2.0, &mut ws).unwrap();
        ws.put(dv);
    }

    let (count, _) = count_allocs(|| {
        for _ in 0..5 {
            let dv = backend
                .scd_chunk_ws(&mut chunks[0], &order, &mut v, lam_n, 2.0, &mut ws)
                .unwrap();
            ws.put(dv);
        }
    });
    assert_eq!(count, 0, "steady-state SCD chunk pass allocated {count} times");
}

/// A whole task iteration is allowed exactly the documented handoff
/// allocation — the `LocalUpdate::delta` buffer it returns — plus the
/// collection bookkeeping of the test itself.
#[test]
fn task_iterate_steady_state_allocates_only_the_delta() {
    // CoCoA.
    let ds = synth::higgs_like(600, 12);
    let mut chunks = make_chunks(&ds, 16 * 1024);
    let algo =
        CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), ds.n_samples(), ds.dim());
    let model = algo.init_model().unwrap();
    let mut ws = Workspace::new();
    for it in 0..WARMUP as u64 {
        algo.task_iterate_ws(&mut chunks, &model, 2, it, None, &mut ws).unwrap();
    }
    let (count, updates) = count_allocs(|| {
        (0..4u64)
            .map(|it| algo.task_iterate_ws(&mut chunks, &model, 2, it, None, &mut ws).unwrap())
            .collect::<Vec<_>>()
    });
    // Per iteration: one delta Vec; the collect adds a few Vec growths
    // for the results vector itself. Bound generously but meaningfully
    // (an accidentally allocating inner loop would blow far past this).
    assert!(count <= 12, "cocoa task_iterate_ws allocated {count} times over 4 iters");
    drop(updates);

    // lSGD (MLP).
    let ds = synth::fmnist_like(400, 13);
    let mut cfg = LsgdConfig::paper_defaults(ModelKind::Mlp);
    cfg.h = 2;
    let algo = LsgdAlgo::new_classif(
        cfg,
        Backend::native_nn(NativeModel::Mlp { dims: vec![784, 32, 10] }),
        784,
        Vec::new(),
        Vec::new(),
        2,
    )
    .unwrap();
    let mut chunks = make_chunks(&ds, 64 * 1024);
    let model = algo.init_model().unwrap();
    let mut ws = Workspace::new();
    for it in 0..WARMUP as u64 {
        algo.task_iterate_ws(&mut chunks, &model, 2, it, None, &mut ws).unwrap();
    }
    let (count, updates) = count_allocs(|| {
        (0..4u64)
            .map(|it| algo.task_iterate_ws(&mut chunks, &model, 2, it, None, &mut ws).unwrap())
            .collect::<Vec<_>>()
    });
    assert!(count <= 12, "lsgd task_iterate_ws allocated {count} times over 4 iters");
    drop(updates);
}
